"""In-memory fakes for the multihost dispatch tier.

:class:`FakeHostTransport` is an in-memory stand-in for
:class:`~repro.cluster.multihost.ProcessTransport`: a scripted worker thread
that speaks the real wire protocol (every message round-trips through
``pickle``), fabricates ``done`` records, honors the checkpoint-write
contract for preempted segments, answers heartbeats, and supports scripted
faults — mid-segment death (``die_on``, SIGKILL semantics: silent AND
``alive()`` flips false) and mid-segment hangs (``hang_on``: the worker goes
silent while ``alive()`` stays true — only the dispatcher's heartbeat
watchdog can tell the difference). Dispatcher-level fault paths are thereby
testable in milliseconds, without subprocesses or jax.

This lives in ``src`` (not ``tests/``) so benchmarks can drive the real
dispatcher + engine over emulated fleets (e.g. a 2-fast + 1-slow class
layout in ``bench_elastic``) with the same fake the test-suite trusts;
``tests/harness.py`` re-exports it.
"""
from __future__ import annotations

import pickle
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


class FakeHostTransport:
    """In-memory ``ProcessTransport`` stand-in speaking the real protocol.

    A worker thread answers ``init``/``run``/``ping``/``stop``; every message
    is forced through ``pickle`` both ways, so anything that would not
    survive the real process boundary fails here too. Fabricated results
    honor the executor's checkpoint contract: ``done_ids`` produce
    ``adapter`` writes, unfinished resumable adapters produce ``state``
    writes with exact ``steps_done`` accounting, and resumed cids *must*
    have had their state shipped in ``states`` (asserted — recorded on
    ``.resumed``).

    Fault injection:

    * ``die_on(run_idx, payload) -> bool`` — the worker drops the request
      and goes silent with ``alive() == False`` (what SIGKILL looks like
      from the dispatcher); ``kill()`` does the same from the outside.
    * ``hang_on(run_idx, payload) -> bool`` — the worker goes silent but
      ``alive()`` stays True: no reply to the segment, no pong to any later
      heartbeat. Indistinguishable from a wedged process; only the
      dispatcher's watchdog deadline can recover it.
    * ``reply_delay`` — seconds of real latency before every run reply
      (delayed replies for deadline tests).
    * ``pong_delay`` — seconds before each heartbeat pong (RTT shaping).

    Timing: ``iter_scale`` prices one fabricated training step;
    ``real_time=True`` makes the worker actually sleep the fabricated wall
    time before replying, so engine-level makespans measured over this fake
    are real wall-clock — that is what lets ``bench_elastic`` measure
    class-aware vs class-blind placement on an emulated heterogeneous fleet.

    The kernel policy shipped with each run request is recorded on
    ``.policies``; every ``run`` payload's ``trace`` field on
    ``.trace_ctxs`` (with worker-shaped ``spans`` fabricated on the reply
    when tracing is on, exactly like a real traced worker). Heartbeat pings
    received are counted on ``.pings``.
    """

    def __init__(
        self,
        host_id: int,
        n_devices: int,
        *,
        die_on: Optional[Callable] = None,
        hang_on: Optional[Callable] = None,
        iter_scale: float = 1e-3,
        real_time: bool = False,
        reply_delay: float = 0.0,
        pong_delay: float = 0.0,
        on_run: Optional[Callable] = None,
    ):
        self.host_id = host_id
        self.n_devices = n_devices
        self.die_on = die_on
        self.hang_on = hang_on
        self.iter_scale = iter_scale
        self.real_time = real_time
        self.reply_delay = reply_delay
        self.pong_delay = pong_delay
        self.on_run = on_run
        self.runs: List[dict] = []
        self.policies: List = []  # KernelPolicy per run request
        self.trace_ctxs: List = []  # TraceCtx | None per run request
        self.resumed: List[Tuple[int, str]] = []
        self.pings: int = 0
        self.error: Optional[BaseException] = None
        self._in: "queue.Queue" = queue.Queue()
        self._out: "queue.Queue" = queue.Queue()
        self._alive = True
        self._hung = False
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    # -- transport interface -------------------------------------------------

    def send(self, msg) -> None:
        self._in.put(pickle.dumps(msg))

    def recv(self, timeout: Optional[float] = None):
        return pickle.loads(self._out.get(timeout=timeout))

    def alive(self) -> bool:
        return self._alive

    def kill(self) -> None:
        self._alive = False
        self._in.put(None)  # wake the loop so it exits

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)

    # -- scripted worker -----------------------------------------------------

    def _reply(self, msg) -> None:
        self._out.put(pickle.dumps(msg))

    def _loop(self) -> None:
        # any exit — scripted death, stop, or an unexpected exception (e.g.
        # a contract assert below) — must leave alive()==False, or the
        # dispatcher pump would wait forever instead of failing crisply.
        # EXCEPT a scripted hang: alive() stays True, silence is the fault.
        try:
            self._run_loop()
        except BaseException as e:  # noqa: BLE001 — surfaced via .error
            self.error = e
            self._alive = False
            raise
        else:
            if not self._hung:
                self._alive = False

    def _run_loop(self) -> None:
        self._reply(("ready", {"host": self.host_id,
                               "devices": self.n_devices}))
        state: Dict = {}
        while True:
            raw = self._in.get()
            if raw is None or not self._alive:
                return
            if self._hung:
                continue  # wedged: consume and ignore everything
            kind, payload = pickle.loads(raw)
            if kind == "stop":
                self._alive = False
                return
            if kind == "init":
                state = payload
                continue
            if kind == "ping":
                from repro.cluster.multihost import HealthReply

                self.pings += 1
                if self.pong_delay:
                    time.sleep(self.pong_delay)
                self._reply(("pong", HealthReply(
                    host=self.host_id, seq=payload.seq,
                    t_send=payload.t_send, in_flight=0,
                )))
                continue
            assert kind == "run", kind
            from repro.cluster.multihost import (
                CheckpointWrite,
                KernelPolicy,
                RecordMsg,
            )

            run_idx = len(self.runs)
            self.runs.append(payload)
            self.policies.append(payload.get("policy") or KernelPolicy())
            self.trace_ctxs.append(payload.get("trace"))
            if self.die_on is not None and self.die_on(run_idx, payload):
                self._alive = False  # died mid-segment: no reply, ever
                return
            if self.hang_on is not None and self.hang_on(run_idx, payload):
                self._hung = True  # wedged mid-segment: silent but "alive"
                continue
            if self.on_run is not None:
                self.on_run(run_idx, payload)
            seg = payload["seg"]  # SegmentMsg
            cids = tuple(seg.config_ids)
            total = state["total_steps"]
            for cid, st0 in zip(cids, seg.start_steps):
                if st0 > 0:
                    aid = f"{cid:04d}"
                    assert aid in payload["states"], (
                        f"resume of cid {cid} without shipped state"
                    )
                    tree, meta = payload["states"][aid]
                    assert int(meta["steps_done"]) == st0, (meta, st0)
                    self.resumed.append((run_idx, aid))
            writes = []
            if payload["has_pool"]:
                done = set(seg.done_ids)
                for slot, (cid, st0) in enumerate(
                    zip(cids, seg.start_steps)
                ):
                    if cid in done:
                        writes.append(
                            CheckpointWrite(
                                "adapter", f"adapter_{cid:04d}",
                                {"w": np.float32(cid)},
                                {"final_loss": 1.0,
                                 "total_steps": int(total[cid])})
                        )
                    else:
                        writes.append(
                            CheckpointWrite(
                                "state", f"{cid:04d}",
                                {"w": np.float32(cid),
                                 "m": np.float32(0), "v": np.float32(0)},
                                {"steps_done": int(st0 + seg.run_steps),
                                 "total_steps": int(total[cid])})
                        )
            wall = self.iter_scale * seg.run_steps
            if self.reply_delay:
                time.sleep(self.reply_delay)
            if self.real_time:
                time.sleep(wall)
            done = {
                "req": payload["req"],
                "host": self.host_id,
                "record": RecordMsg(
                    config_ids=cids,
                    degree=seg.degree,
                    start=seg.start,
                    end=seg.end,
                    wall_seconds=wall,
                    losses=np.full(len(cids), 1.0, np.float32),
                ),
                "writes": writes,
            }
            if payload.get("trace") is not None:
                # worker-shaped span tree on the worker's own clock (t0=0):
                # a host root + one executor child, as Span.to_dict() dicts
                done["spans"] = [
                    {"name": f"host{self.host_id}.segment", "cat": "host",
                     "track": "", "span_id": 1, "parent_id": None,
                     "root_id": 1, "start": 0.0, "end": wall,
                     "args": {"job_id": seg.job_id, "fake": True}},
                    {"name": "executor.segment", "cat": "executor",
                     "track": "unit0", "span_id": 2, "parent_id": 1,
                     "root_id": 1, "start": 0.0, "end": wall,
                     "args": {"job_id": seg.job_id}},
                ]
                done["span_t0"] = 0.0
            self._reply(("done", done))


class DictPool:
    """Minimal in-memory CheckpointPool double for dispatcher-level tests:
    implements exactly the four methods the segment protocol uses."""

    def __init__(self):
        self.adapters: Dict[str, Tuple[dict, dict]] = {}
        self.states: Dict[str, Tuple[dict, dict]] = {}

    def has_adapter_state(self, aid: str) -> bool:
        return aid in self.states

    def load_adapter_state(self, aid: str):
        return self.states[aid]

    def save_adapter_state(self, aid: str, tree, meta: dict):
        self.states[aid] = (tree, dict(meta))

    def save_adapter(self, aid: str, tree, meta: dict):
        self.adapters[aid] = (tree, dict(meta))

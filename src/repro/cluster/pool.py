"""Device pool: partition the host's devices into disjoint mesh slices.

The scheduler plans jobs over ``g`` abstract *device units*; this module owns
the mapping from those units to real devices (real accelerators, or CPU
devices forced via ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
A :class:`MeshSlice` is a disjoint device subset wide enough for one packed
job's parallelism degree; the pool hands slices out (`acquire` /
`acquire_units`) and takes them back (`release`) with strict accounting, so
concurrently running segments can never share a device by accident.

The pool is thread-safe: the cluster runner's dispatch thread blocks in
``acquire_units`` until a segment's planned units are freed by the real
completions of earlier segments — this is what turns the engine's virtual
device-free events into wall-clock ones.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class MeshSlice:
    """A disjoint subset of the pool's devices backing one packed job."""

    units: Tuple[int, ...]  # pool unit ids (sorted, disjoint across slices)
    devices: Tuple  # the actual devices, one per unit (deduplicated)

    @property
    def width(self) -> int:
        return len(self.devices)

    @property
    def lead(self):
        return self.devices[0]

    def mesh(self, *, data: int = 1, model: Optional[int] = None):
        """Mesh over exactly this slice's devices (see launch.mesh.slice_mesh)."""
        from repro.launch.mesh import slice_mesh

        return slice_mesh(self.devices, data=data, model=model)


class DevicePool:
    """Thread-safe partition of devices into disjoint, accountable slices."""

    def __init__(self, devices: Optional[Sequence] = None):
        if devices is None:
            import jax

            devices = jax.devices()
        if not devices:
            raise ValueError("DevicePool needs at least one device")
        self.devices = list(devices)
        self._lock = threading.Condition()
        self._free = set(range(len(self.devices)))
        self._retired: set = set()

    @property
    def total(self) -> int:
        return len(self.devices)

    @property
    def free(self) -> int:
        with self._lock:
            return len(self._free)

    # ---------------- elastic membership ----------------

    def add_devices(self, devices: Sequence) -> Tuple[int, ...]:
        """Grow the pool mid-run: append ``devices`` as new (free) units and
        wake any waiter blocked in ``acquire``/``acquire_units``. Returns the
        new unit ids (contiguous, after the existing ones — existing unit ids
        never shift, so in-flight slices stay valid)."""
        if not devices:
            raise ValueError("add_devices needs at least one device")
        with self._lock:
            first = len(self.devices)
            self.devices.extend(devices)
            new = tuple(range(first, len(self.devices)))
            self._free |= set(new)
            self._lock.notify_all()
            return new

    def retire_units(
        self, units: Sequence[int], timeout: Optional[float] = None
    ) -> None:
        """Remove ``units`` from circulation (graceful drain): blocks until
        each is free, then marks it retired — it can never be acquired or
        released again. Unit ids stay stable (the device list keeps its
        slot), so other units' addressing is untouched."""
        want = tuple(sorted(set(units)))
        for u in want:
            if not 0 <= u < self.total:
                raise ValueError(f"unit {u} outside pool of {self.total}")
        with self._lock:
            if not self._lock.wait_for(
                lambda: all(u in self._free or u in self._retired
                            for u in want),
                timeout=timeout,
            ):
                busy = [u for u in want
                        if u not in self._free and u not in self._retired]
                raise TimeoutError(f"timed out draining busy units {busy}")
            self._free -= set(want)
            self._retired |= set(want)

    @property
    def retired(self) -> Tuple[int, ...]:
        with self._lock:
            return tuple(sorted(self._retired))

    def _make_slice(self, units: Tuple[int, ...]) -> MeshSlice:
        devs = tuple(self.devices[u] for u in units)
        return MeshSlice(units=units, devices=devs)

    def try_acquire(self, g: int) -> Optional[MeshSlice]:
        """Non-blocking: a slice of ``g`` units, or None if fewer are free."""
        if g <= 0:
            raise ValueError(f"slice width must be positive, got {g}")
        if g > self.total:
            raise ValueError(
                f"slice of width {g} requested but the pool holds only "
                f"{self.total} devices"
            )
        with self._lock:
            if len(self._free) < g:
                return None
            units = tuple(sorted(self._free)[:g])
            self._free -= set(units)
            return self._make_slice(units)

    def acquire(self, g: int, timeout: Optional[float] = None) -> MeshSlice:
        """Block until ``g`` units are free, then take them."""
        if g > self.total:
            raise ValueError(
                f"slice of width {g} requested but the pool holds only "
                f"{self.total} devices"
            )
        with self._lock:
            if not self._lock.wait_for(
                lambda: len(self._free) >= g, timeout=timeout
            ):
                raise TimeoutError(
                    f"timed out waiting for {g} free units "
                    f"({len(self._free)}/{self.total} free)"
                )
            units = tuple(sorted(self._free)[:g])
            self._free -= set(units)
            return self._make_slice(units)

    def acquire_units(
        self, units: Sequence[int], timeout: Optional[float] = None
    ) -> MeshSlice:
        """Block until the *specific* planned units are all free, then take
        them — the cluster runner uses this to honor the scheduler's device
        groups instead of grabbing whatever is idle."""
        want = tuple(sorted(set(units)))
        for u in want:
            if not 0 <= u < self.total:
                raise ValueError(f"unit {u} outside pool of {self.total}")
        with self._lock:
            gone = [u for u in want if u in self._retired]
            if gone:
                raise RuntimeError(f"units {gone} are retired (drained host)")
            if not self._lock.wait_for(
                lambda: all(u in self._free for u in want), timeout=timeout
            ):
                busy = [u for u in want if u not in self._free]
                raise TimeoutError(f"timed out waiting for units {busy}")
            self._free -= set(want)
            return self._make_slice(want)

    # ---------------- leases: acquisition as a context manager ----------------
    #
    # A bare ``acquire`` + ``release`` pair leaks units whenever the code
    # between them dies (an executor crash, a killed worker, an exception in
    # the dispatch loop) — the unit is then gone for the lifetime of the
    # pool and later segments planned on it hang forever. The context
    # managers below make release structurally unskippable, and
    # ``ClusterRunner.run`` asserts the pool drained back to empty at exit.

    @contextmanager
    def lease(self, g: int, timeout: Optional[float] = None):
        """``acquire`` whose release is guaranteed by ``with``-scoping."""
        s = self.acquire(g, timeout=timeout)
        try:
            yield s
        finally:
            self.release(s)

    @contextmanager
    def lease_units(self, units: Sequence[int], timeout: Optional[float] = None):
        """``acquire_units`` whose release is guaranteed by ``with``-scoping."""
        s = self.acquire_units(units, timeout=timeout)
        try:
            yield s
        finally:
            self.release(s)

    @contextmanager
    def held(self, s: MeshSlice):
        """Adopt an *already acquired* slice: release it when the block
        exits, crash or no crash. Used when acquisition must happen in one
        thread (the dispatch loop, to preserve dispatch order) while the
        work — and therefore the crash risk — lives in another."""
        try:
            yield s
        finally:
            self.release(s)

    def release(self, s: MeshSlice) -> None:
        with self._lock:
            dup = set(s.units) & self._free
            if dup:
                raise RuntimeError(f"double release of units {sorted(dup)}")
            bad = [u for u in s.units if not 0 <= u < self.total]
            if bad:
                raise RuntimeError(f"release of foreign units {bad}")
            gone = [u for u in s.units if u in self._retired]
            if gone:
                raise RuntimeError(f"release of retired units {gone}")
            self._free |= set(s.units)
            self._lock.notify_all()

    def map_units(self, units: Sequence[int]) -> Tuple[int, ...]:
        """Fold the scheduler's abstract unit ids onto this pool's units.

        When the virtual pool is wider than the host (the degenerate case —
        e.g. an 8-unit plan executed on a 1-device laptop), planned units
        wrap modulo the pool size; colliding segments then serialize on the
        shared device instead of failing."""
        return tuple(sorted({u % self.total for u in units}))


def pick_host_units(
    free: Sequence[int], degree: int, host_size: Optional[int]
) -> Optional[Tuple[int, ...]]:
    """Pick ``degree`` units from ``free`` (sorted unit ids) such that they
    all live on one host (``unit // host_size``): a packed job's mesh slice
    can never span hosts. ``host_size=None`` is the single-host case —
    lowest-numbered free units, exactly the pre-multihost behavior. With
    hosts, best-fit: the feasible host with the fewest free units (ties to
    the lowest host id), so wide jobs keep finding whole hosts. Returns None
    when no single host currently has ``degree`` free units — callers hold
    the job and retry at the next device-free event."""
    if len(free) < degree:
        return None
    if host_size is None:
        return tuple(free[:degree])
    by_host: Dict[int, List[int]] = {}
    for u in free:
        by_host.setdefault(u // host_size, []).append(u)
    fitting = [(len(us), h) for h, us in by_host.items() if len(us) >= degree]
    if not fitting:
        return None
    _, h = min(fitting)
    return tuple(sorted(by_host[h])[:degree])


def pick_class_units(
    free: Sequence[int],
    degree: int,
    host_size: int,
    *,
    class_of_host: Callable[[int], str],
    ratio_of_class: Callable[[str], float],
    avoid_host: Optional[Callable[[int], bool]] = None,
) -> Optional[Tuple[int, ...]]:
    """Class-aware variant of :func:`pick_host_units` for heterogeneous
    fleets: hosts carry a class tag and ``ratio_of_class`` prices each class
    (measured slowdown vs the prior; 1.0 = unknown/baseline, larger =
    slower). Placement policy:

      * *wide* jobs (``degree == host_size``, occupying a whole host) go to
        the **fastest** feasible class — they dominate the makespan tail;
      * *narrow* jobs go to the **slowest** feasible class — they keep slow
        hosts busy with work whose serial fraction is small, leaving fast
        hosts whole for wide jobs (straggler-aware placement);
      * within a class, best-fit (fewest free units) then lowest host id —
        the same fragmentation-avoidance as the homogeneous picker;
      * hosts flagged by ``avoid_host`` (e.g. heartbeat-SUSPECT) are used
        only when no healthy host fits.

    Returns None when no single host has ``degree`` free units."""
    if len(free) < degree:
        return None
    by_host: Dict[int, List[int]] = {}
    for u in free:
        by_host.setdefault(u // host_size, []).append(u)
    fitting = [h for h, us in by_host.items() if len(us) >= degree]
    if not fitting:
        return None
    wide = degree >= host_size

    def rank(h: int):
        r = float(ratio_of_class(class_of_host(h)))
        suspect = bool(avoid_host(h)) if avoid_host is not None else False
        return (suspect, r if wide else -r, len(by_host[h]), h)

    h = min(fitting, key=rank)
    return tuple(sorted(by_host[h])[:degree])


def assign_units(
    intervals: Sequence[Tuple[float, float, int]],
    g: int,
    host_size: Optional[int] = None,
) -> List[Tuple[int, ...]]:
    """Static unit assignment: replay ``(start, end, degree)`` intervals
    through a ``g``-unit allocator (releases before acquires at equal
    timestamps, lowest-numbered free units first) and return each interval's
    unit tuple. Deterministic; raises if the intervals oversubscribe ``g`` —
    the same feasibility contract as ``OnlineSchedule.validate``. With
    ``host_size`` the allocator additionally keeps every interval's units on
    a single host (see :func:`pick_host_units`) and raises if a planned
    interval cannot be placed host-disjointly."""
    events = []  # (time, kind, idx)  kind 0=release first, 1=acquire
    for i, (start, end, degree) in enumerate(intervals):
        events.append((start, 1, i))
        events.append((end, 0, i))
    free = set(range(g))
    held: Dict[int, Tuple[int, ...]] = {}
    out: List[Optional[Tuple[int, ...]]] = [None] * len(intervals)
    if host_size is None:
        order = sorted(events, key=lambda e: (e[0], e[1]))
    else:
        # at equal (time, kind), place wider intervals first: power-of-2
        # degrees then pack hosts without fragmentation (first-fit-
        # decreasing). Only with hosts — the single-host allocator keeps
        # its historical interval order, byte-for-byte.
        order = sorted(
            events, key=lambda e: (e[0], e[1], -intervals[e[2]][2], e[2])
        )
    for t, kind, i in order:
        if kind == 0:
            free |= set(held.pop(i, ()))
        else:
            degree = intervals[i][2]
            if len(free) < degree:
                raise RuntimeError(
                    f"intervals oversubscribe {g} units at t={t:.2f}"
                )
            units = pick_host_units(sorted(free), degree, host_size)
            if units is None:
                raise RuntimeError(
                    f"no single host of {host_size} units can hold a "
                    f"degree-{degree} interval at t={t:.2f} "
                    f"({len(free)}/{g} units free but fragmented)"
                )
            free -= set(units)
            held[i] = units
            out[i] = units
    return out  # type: ignore[return-value]

"""Cluster runner: real concurrent execution of planned segments.

The engine's event loop plans *virtual* segments — (configs, degree, device
units, start/end). The runner turns that plan into wall-clock reality:

  * a dispatch loop walks segments in virtual-start order;
  * each segment first waits for its resume dependencies (the checkpointed
    state a preempted predecessor writes), then blocks in
    ``DevicePool.acquire_units`` until its *planned* units are freed by the
    real completions of earlier segments — device-free events fire from
    actual training, not the virtual clock;
  * with ``concurrent=True`` the segment then runs on its own thread against
    its own disjoint :class:`MeshSlice`, so segments scheduled on different
    groups genuinely overlap; ``concurrent=False`` runs the identical
    placement serially — the degenerate single-slice pool, and the baseline
    the cluster benchmark compares against.

Because both modes execute the exact same per-segment computation on the
same slice widths, per-adapter losses are bit-identical between them (the
cluster test suite asserts this on a forced 8-device host).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.cluster.executor import SliceExecutor, _slice_track
from repro.cluster.pool import DevicePool, MeshSlice
from repro.obs import NULL_TRACER


@dataclass(frozen=True)
class SegmentTiming:
    """Measured-vs-predicted per-iteration wall time of one executed segment
    — the raw material of the profile feedback loop. ``predicted_iter`` is
    the estimator's answer at dispatch time (NaN when no estimator was
    given); ``drift`` is ``measured / predicted - 1``."""

    job_id: int
    config_ids: Tuple[int, ...]
    degree: int
    run_steps: int
    seq: int
    measured_iter: float
    predicted_iter: float

    @property
    def drift(self) -> float:
        if not (self.predicted_iter > 0.0):  # NaN / zero -> undefined
            return float("nan")
        return self.measured_iter / self.predicted_iter - 1.0


@dataclass
class ClusterResult:
    """Outcome of executing one batch of segments on the pool."""

    records: List  # JobRecord per segment, in virtual-start order
    makespan: float  # wall-clock seconds, first dispatch -> last completion
    concurrent: bool
    # (job_id, real_start, real_end, units) per segment, runner-relative
    timeline: List[Tuple[int, float, float, Tuple[int, ...]]] = field(
        default_factory=list
    )
    # per-segment measured step times (virtual-start order, like records)
    timings: List[SegmentTiming] = field(default_factory=list)

    def max_overlap(self) -> int:
        """Peak number of segments running at the same wall-clock instant."""
        return peak_overlap([(s, e) for _, s, e, _ in self.timeline])


def resume_deps(order: Sequence) -> List[List[int]]:
    """Checkpoint-resume dependencies between virtual-ordered segments.

    ``deps[i]`` lists the indices (into ``order``) whose completion segment
    ``order[i]`` must wait for before it can load resumed adapter state: a
    segment that starts config ``cid`` at step ``s > 0`` depends on the
    LAST earlier segment that checkpoints cid's state at exactly step ``s``.
    Keying on the latest writer (not a bare ``(cid, step)`` event) matters:
    a zero-step re-preemption re-writes the same ``(cid, step)``, and a
    segment must never end up waiting on *itself* or on a later writer —
    that would deadlock the dispatch loop."""
    writer_of: Dict[Tuple[int, int], int] = {}
    deps: List[List[int]] = []
    for idx, seg in enumerate(order):
        deps.append(
            sorted(
                {
                    writer_of[(cid, st0)]
                    for cid, st0 in zip(seg.config_ids, seg.start_steps)
                    if st0 > 0 and (cid, st0) in writer_of
                }
            )
        )
        if seg.preempted:
            done = set(seg.done_ids)
            for cid, st0 in zip(seg.config_ids, seg.start_steps):
                if cid not in done:
                    writer_of[(cid, st0 + seg.run_steps)] = idx
    return deps


def peak_overlap(intervals: Sequence[Tuple[float, float]]) -> int:
    """Sweep-line peak of concurrently open ``(start, end)`` intervals."""
    events = []
    for s, e in intervals:
        events.append((s, 1))
        events.append((e, -1))
    peak = cur = 0
    for _, d in sorted(events):
        cur += d
        peak = max(peak, cur)
    return peak


class ClusterRunner:
    """Drives planned segments onto a :class:`DevicePool`.

    The reference :class:`~repro.cluster.api.Runner` implementation.
    ``concurrent=None`` (default) auto-selects: concurrent when the pool
    holds more than one device, else the degenerate sequential mode — which
    is bit-for-bit the old single-host execution path."""

    def __init__(
        self,
        executor: Optional[SliceExecutor] = None,
        pool: Optional[DevicePool] = None,
        *,
        concurrent: Optional[bool] = None,
        tracer=None,
    ):
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.executor = executor or SliceExecutor(tracer=self.tracer)
        # a caller-supplied executor without its own tracer adopts ours, so
        # one `tracer=` at the runner threads through the whole segment path
        # (executor fakes without a .tracer attribute are left alone)
        ex_tracer = getattr(self.executor, "tracer", None)
        if (self.tracer.enabled and ex_tracer is not None
                and not ex_tracer.enabled):
            self.executor.tracer = self.tracer
        self.device_pool = pool or DevicePool()
        self.concurrent = (
            self.device_pool.total > 1 if concurrent is None else concurrent
        )
        self.last_result: Optional[ClusterResult] = None

    def run(
        self,
        segments: Sequence,  # JobSegment
        configs_by_cid: Dict,
        total_steps: Dict[int, int],
        cfg,
        base_params,
        *,
        seq: int,
        pool=None,  # CheckpointPool
        data_iter_fn: Optional[Callable] = None,
        seed: int = 0,
        estimator=None,  # Optional[repro.sched.cost_model.CostEstimator]
        impl: Optional[str] = None,
        remat: Optional[str] = None,
        base_dtype: Optional[str] = None,
    ) -> ClusterResult:
        """Execute planned segments. With an ``estimator``, each segment's
        predicted per-iteration time is captured at dispatch and its measured
        time is fed back via ``estimator.observe(...)`` on completion (a
        no-op for the pure analytic prior) — the measured/predicted pairs are
        surfaced on ``ClusterResult.timings`` either way.

        ``impl``/``remat``/``base_dtype`` select the kernel policy for every
        segment (``base_dtype`` marks a quantized frozen base); when
        ``impl`` is None the *caller's* context-local default
        (``ops.default_impl()``) is captured here — worker threads never see
        the caller's contextvars, so the policy must cross the thread
        boundary as an explicit argument."""
        if impl is None:
            from repro.kernels.ops import default_impl

            impl = default_impl()
        impl = None if impl == "auto" else impl
        # the pool may be shared with a live serve loop holding its own
        # lease: the drain invariant is "free count returns to what it was
        # at entry", not "fully free"
        free0 = self.device_pool.free
        order = sorted(segments, key=lambda s: (s.start, s.job_id))
        done_events = [threading.Event() for _ in order]
        deps = resume_deps(order)
        results: List = [None] * len(order)
        predicted: List[float] = [float("nan")] * len(order)
        errors: List[BaseException] = []

        tracer = self.tracer
        free_gauge = tracer.metrics.gauge("cluster.free_units")
        run_parent: List[Optional[int]] = [None]

        def worker(idx: int, seg, slice_: MeshSlice):
            # the slice was acquired by the dispatch loop (to preserve
            # dispatch order); `held` guarantees this thread gives it back
            # no matter how the executor dies. The explicit ``parent=``
            # stitches this pool-thread span under the dispatcher-thread
            # "runner.run" span (thread-local stacks don't cross threads).
            try:
                with self.device_pool.held(slice_):
                    with tracer.span(
                        "runner.segment", cat="runner",
                        parent=run_parent[0], track=_slice_track(slice_),
                        job_id=seg.job_id, units=list(slice_.units),
                    ):
                        rec = self.executor.run_segment(
                            seg,
                            configs_by_cid,
                            total_steps,
                            cfg,
                            base_params,
                            seq=seq,
                            pool=pool,
                            data_iter_fn=data_iter_fn,
                            seed=seed,
                            slice_=slice_,
                            impl=impl,
                            remat=remat,
                            base_dtype=base_dtype,
                        )
                    results[idx] = rec
                    if estimator is not None and seg.run_steps > 0:
                        estimator.observe(
                            [configs_by_cid[cid] for cid in seg.config_ids],
                            seg.degree,
                            seq,
                            rec.wall_seconds / seg.run_steps,
                        )
            except BaseException as e:  # noqa: BLE001 — re-raised by run()
                errors.append(e)
            finally:
                free_gauge.set(self.device_pool.free)
                done_events[idx].set()

        # Pre-warm the pack-state template of every distinct pack shape in
        # the dispatcher thread: template init is expensive and GIL-bound,
        # so concurrent workers racing to build the same one would serialize
        # anyway — build each once, up front.
        seen = set()
        for seg in order:
            job_cfgs = tuple(configs_by_cid[cid] for cid in seg.config_ids)
            if job_cfgs not in seen:
                seen.add(job_cfgs)
                self.executor.pack_template(cfg, job_cfgs, seed)

        t0 = time.perf_counter()
        tpe = (
            ThreadPoolExecutor(max_workers=self.device_pool.total)
            if self.concurrent
            else None
        )
        with tracer.span(
            "runner.run", cat="runner", n_segments=len(order),
            concurrent=self.concurrent,
        ) as run_span:
            run_parent[0] = run_span.span_id or None
            try:
                for idx, seg in enumerate(order):
                    if errors:
                        break
                    if estimator is not None:
                        predicted[idx] = estimator.iter_time(
                            [configs_by_cid[cid] for cid in seg.config_ids],
                            seg.degree,
                            seq,
                        )
                    with tracer.span(
                        "runner.wait_units", cat="runner",
                        job_id=seg.job_id,
                        units=list(getattr(seg, "units", ()) or ()),
                    ):
                        for dep in deps[idx]:
                            done_events[dep].wait()
                        units = getattr(seg, "units", ()) or ()
                        if units:
                            slice_ = self.device_pool.acquire_units(
                                self.device_pool.map_units(units)
                            )
                        else:  # unplanned segment: grab whatever fits
                            slice_ = self.device_pool.acquire(
                                min(seg.degree, self.device_pool.total)
                            )
                    free_gauge.set(self.device_pool.free)
                    try:
                        if tpe is not None:
                            tpe.submit(worker, idx, seg, slice_)
                        else:
                            worker(idx, seg, slice_)
                    except RuntimeError:
                        # submit refused (executor already shutting down):
                        # the worker never ran, so give the slice back here
                        self.device_pool.release(slice_)
                        done_events[idx].set()
                        raise
            finally:
                if tpe is not None:
                    tpe.shutdown(wait=True)
        if errors:
            raise errors[0]
        # free dropping below its entry level means a segment path here
        # released without a lease; a *rise* just means some foreign lease
        # (e.g. a serve loop's) was returned while we ran — not ours to flag
        leaked = free0 - self.device_pool.free
        if leaked > 0:
            raise RuntimeError(
                f"device pool leaked {leaked} unit(s) at run exit — a "
                "segment path released without going through a lease"
            )

        timeline = []
        timings = []
        makespan = 0.0
        for idx, (seg, rec) in enumerate(zip(order, results)):
            rec.real_start -= t0
            rec.real_end -= t0
            makespan = max(makespan, rec.real_end)
            timeline.append(
                (seg.job_id, rec.real_start, rec.real_end,
                 tuple(getattr(seg, "units", ()) or ()))
            )
            timings.append(
                SegmentTiming(
                    job_id=seg.job_id,
                    config_ids=tuple(seg.config_ids),
                    degree=seg.degree,
                    run_steps=seg.run_steps,
                    seq=seq,
                    measured_iter=(
                        rec.wall_seconds / seg.run_steps
                        if seg.run_steps > 0
                        else float("nan")
                    ),
                    predicted_iter=predicted[idx],
                )
            )
        result = ClusterResult(
            records=list(results),
            makespan=makespan,
            concurrent=self.concurrent,
            timeline=timeline,
            timings=timings,
        )
        self.last_result = result
        return result

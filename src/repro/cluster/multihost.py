"""Multi-host dispatch tier: process-per-host scale-out of the segment protocol.

The single-host :class:`~repro.cluster.runner.ClusterRunner` is thread-per-
slice inside one process — every plan is capped at one host's devices. This
module scales the *same* segment protocol out across simulated (or, with a
different transport, real) hosts:

  * :class:`HostWorker` — one subprocess per simulated host. Each worker
    self-forces its own CPU device count (``XLA_FLAGS=--xla_force_host_
    platform_device_count=N``, inherited through the environment at spawn
    time) and runs the existing :class:`~repro.cluster.executor.SliceExecutor`
    + :class:`~repro.cluster.pool.DevicePool` over its local devices — the
    per-host execution stack is exactly the single-host one.
  * a **message protocol** replaces the runner's in-memory shared state:
    segments, resumed adapter state, and checkpoint-pool traffic are
    serialized over a pipe/queue transport (:func:`encode_segment` /
    :func:`encode_tree` / :func:`encode_record`). Workers never touch the
    central :class:`~repro.train.checkpoint.CheckpointPool`; a
    :class:`MemoryPool` captures their checkpoint writes and the dispatcher
    applies them *atomically on segment success* — which is what makes a
    killed worker recoverable (no partial state ever lands in the pool, so
    the segment's residual simply re-enters the existing preempt/resume
    path on a fresh worker).
  * :class:`HostDispatcher` — extends :class:`DevicePool` addressing to
    ``(host, unit)`` pairs (:class:`HostUnit`) and implements the
    :class:`~repro.cluster.api.Runner` protocol: ``run`` executes planned
    segments process-per-host, and ``.executor``/``.device_pool`` plug
    straight into ``ExecutionEngine._run_adaptive`` — real device-free and
    checkpoint-ready events surface back into the engine's online/adaptive
    loops unchanged, so ``plan_online``, migration, probes, and the
    ``ProfiledCostModel`` feedback all work across hosts.

Plan host-aware (``ExecutionEngine(cm, g, host_size=...)``) so every
segment's device units stay within one host; the dispatcher rejects
host-spanning slices.

This module is import-light on purpose: the spawn'd child imports it before
any jax backend initializes, and the dispatcher side works without touching
jax until a segment actually runs.
"""
from __future__ import annotations

import itertools
import os
import pickle
import threading
import time
import traceback
from dataclasses import dataclass
from queue import Empty
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.obs import NULL_TRACER, TraceCtx

# ---------------------------------------------------------------------------
# Wire protocol
# ---------------------------------------------------------------------------
#
# Every message is ``(kind, payload)``; payload *contents* are the typed
# dataclasses below (:class:`SegmentMsg`, :class:`RecordMsg`,
# :class:`CheckpointWrite`, :class:`KernelPolicy`,
# :class:`~repro.obs.TraceCtx`) plus plain-python / numpy scalars and
# ``encode_tree``'d arrays, so the protocol survives pickling across process
# boundaries bit-exactly AND a field rename breaks loudly at construction
# instead of silently at a remote KeyError.
#
#   dispatcher -> worker:  ("init", state) ("run", request) ("stop", {})
#   worker -> dispatcher:  ("ready", info) ("done", result) ("err", failure)
#                          ("fatal", failure)   # startup / loop death
#
# A "run" payload optionally carries ``"trace"``, a :class:`TraceCtx`
# naming the dispatcher-side parent span; the matching "done" reply then
# carries ``"spans"`` (the worker's finished span tree, as
# :meth:`repro.obs.Span.to_dict` dicts) and ``"span_t0"`` (the worker root
# span's start on the *worker's* monotonic clock) so the dispatcher can
# rebase and stitch them under its own trace.


class TransportError(RuntimeError):
    """The transport to a host worker failed."""


class WorkerDied(TransportError):
    """The host worker process died (crash / kill) with requests in flight."""


class RemoteSegmentError(RuntimeError):
    """A segment raised inside the worker; carries the remote traceback."""


def encode_tree(tree):
    """Nested-dict tree with every leaf forced to host ``np.ndarray`` —
    the only array type the wire carries (bit-exact, device-free)."""
    if isinstance(tree, dict):
        return {k: encode_tree(v) for k, v in tree.items()}
    return np.asarray(tree)


@dataclass(frozen=True)
class SegmentMsg:
    """One :class:`~repro.sched.engine.JobSegment` on the wire — same
    fields, but a plain frozen dataclass so the wire format is decoupled
    from the scheduler's type (and picklable without importing it)."""

    job_id: int
    config_ids: Tuple[int, ...]
    degree: int
    start: float
    end: float
    start_steps: Tuple[int, ...]
    run_steps: int
    done_ids: Tuple[int, ...]
    preempted: bool
    units: Tuple[int, ...]


@dataclass(frozen=True)
class RecordMsg:
    """A finished segment's :class:`~repro.sched.engine.JobRecord` on the
    wire (losses as host numpy; wall time measured on the worker clock)."""

    config_ids: Tuple[int, ...]
    degree: int
    start: float
    end: float
    wall_seconds: float
    losses: Optional[np.ndarray]


@dataclass(frozen=True)
class CheckpointWrite:
    """One captured checkpoint-pool write: a finished adapter
    (``kind="adapter"``) or preempted per-adapter training state
    (``kind="state"``). ``tree`` is ``encode_tree``'d (host numpy)."""

    kind: str  # "adapter" | "state"
    adapter_id: str
    tree: dict
    meta: dict


@dataclass(frozen=True)
class HeartbeatMsg:
    """Dispatcher -> worker health ping. ``t_send`` is the dispatcher's
    monotonic clock at send time; the worker echoes it untouched so the RTT
    is computed on one clock (worker clocks aren't comparable)."""

    seq: int
    t_send: float


@dataclass(frozen=True)
class HealthReply:
    """Worker -> dispatcher pong: answered *inline* by the worker's message
    loop (segments run on a thread pool), so a missing reply means the loop
    itself is wedged or the process is gone — hung and crashed workers look
    identical to the watchdog, which is the point."""

    host: int
    seq: int
    t_send: float
    in_flight: int


# membership states of one host, as seen by the dispatcher's watchdog
HOST_ALIVE = "ALIVE"        # answering heartbeats (or heartbeats disabled)
HOST_SUSPECT = "SUSPECT"    # missed a heartbeat deadline; backoff running
HOST_DEAD = "DEAD"          # declared dead (backoff exhausted / drained out)
HOST_DRAINING = "DRAINING"  # graceful retirement in progress


@dataclass(frozen=True)
class KernelPolicy:
    """The kernel policy a segment must run under (``--impl`` / ``--remat``).

    Shipped with every run request so host workers execute the same kernel
    tier the caller (and their autotuned cost model) selected — previously
    multi-host dispatch rejected any non-default policy."""

    impl: Optional[str] = None  # None/"auto" = executor default
    remat: Optional[str] = None  # None = executor default ("save")
    base_dtype: Optional[str] = None  # "int8"/"nf4" = quantized frozen base


_SEGMENT_FIELDS = (
    "job_id", "config_ids", "degree", "start", "end",
    "start_steps", "run_steps", "done_ids", "preempted", "units",
)


def encode_segment(seg) -> SegmentMsg:
    return SegmentMsg(**{f: getattr(seg, f) for f in _SEGMENT_FIELDS})


def decode_segment(m: SegmentMsg):
    from repro.sched.engine import JobSegment

    return JobSegment(**{f: getattr(m, f) for f in _SEGMENT_FIELDS})


def encode_record(rec) -> RecordMsg:
    return RecordMsg(
        config_ids=tuple(rec.job.config_ids),
        degree=rec.job.degree,
        start=rec.job.start,
        end=rec.job.end,
        wall_seconds=rec.wall_seconds,
        losses=(
            None if rec.final_losses is None else np.asarray(rec.final_losses)
        ),
    )


def decode_record(m: RecordMsg):
    from repro.sched.engine import JobRecord
    from repro.sched.planner import ScheduledJob

    return JobRecord(
        ScheduledJob(tuple(m.config_ids), m.degree, m.start, m.end),
        m.wall_seconds,
        m.losses,
    )


class MemoryPool:
    """Worker-side stand-in for the central checkpoint pool.

    Reads come from the states the dispatcher shipped with the segment;
    writes are *captured*, not applied — the dispatcher replays them onto the
    real pool only after the segment's ``done`` message arrives. A worker
    killed mid-segment therefore leaves the central pool exactly as it was,
    and the re-dispatched segment resumes from unchanged state."""

    def __init__(self, states: Optional[Dict[str, Tuple[dict, dict]]] = None):
        self.states = dict(states or {})
        self.writes: List[CheckpointWrite] = []

    def has_adapter_state(self, adapter_id: str) -> bool:
        return adapter_id in self.states

    def load_adapter_state(self, adapter_id: str):
        tree, meta = self.states[adapter_id]
        return tree, meta

    def save_adapter_state(self, adapter_id: str, state_tree, meta: dict):
        self.writes.append(
            CheckpointWrite("state", adapter_id, encode_tree(state_tree), meta)
        )

    def save_adapter(self, adapter_id: str, adapter_tree, meta: dict):
        self.writes.append(
            CheckpointWrite(
                "adapter", adapter_id, encode_tree(adapter_tree), meta
            )
        )


# ---------------------------------------------------------------------------
# Worker process (one simulated host)
# ---------------------------------------------------------------------------


def _worker_main(host_id: int, n_devices: int, inbox, outbox) -> None:
    """Entry point of one simulated host. The parent set ``XLA_FLAGS`` /
    ``JAX_PLATFORMS`` in the environment *around* ``Process.start()`` — the
    spawn'd child inherits them before any jax backend initializes, so this
    process sees exactly ``n_devices`` forced CPU devices regardless of how
    the parent's jax was configured."""
    try:
        import jax

        devs = jax.devices()
        if len(devs) < n_devices:
            raise RuntimeError(
                f"host {host_id} expected {n_devices} forced devices but "
                f"jax initialized {len(devs)} — XLA_FLAGS not inherited?"
            )
        from concurrent.futures import ThreadPoolExecutor

        from repro.cluster.executor import SliceExecutor
        from repro.cluster.pool import DevicePool

        executor = SliceExecutor()
        dpool = DevicePool(devs[:n_devices])
        outbox.put(("ready", {"host": host_id, "devices": len(devs)}))
    except BaseException as e:  # noqa: BLE001 — shipped to the dispatcher
        outbox.put(
            ("fatal", {
                "host": host_id,
                "error": repr(e),
                "traceback": traceback.format_exc(),
            })
        )
        return

    state: Dict[str, Any] = {}
    # one worker-side tracer shared by every traced request: span stacks
    # are thread-local and pop_root flushes one request's tree, so
    # concurrent do_run threads don't interleave. Created lazily on the
    # first traced request; untraced runs never pay for it.
    wtracer_box: List[Any] = [None]
    wtracer_lock = threading.Lock()

    def do_run(payload: Dict[str, Any]) -> None:
        rid = payload["req"]
        try:
            seg = decode_segment(payload["seg"])
            policy = payload.get("policy") or KernelPolicy()
            trace_ctx = payload.get("trace")
            mempool = (
                MemoryPool(payload["states"]) if payload["has_pool"] else None
            )
            spans = span_t0 = None
            if trace_ctx is not None:
                from repro.obs import Tracer

                with wtracer_lock:
                    if wtracer_box[0] is None:
                        wtracer_box[0] = Tracer()
                        executor.tracer = wtracer_box[0]
                wtracer = wtracer_box[0]
                root_cm = wtracer.span(
                    f"host{host_id}.segment", cat="host",
                    job_id=seg.job_id, req=rid,
                )
            else:
                root_cm = None
            with dpool.lease_units(payload["units"]) as slice_:
                if root_cm is not None:
                    root = root_cm.__enter__()
                try:
                    rec = executor.run_segment(
                        seg,
                        state["configs_by_cid"],
                        state["total_steps"],
                        state["cfg"],
                        state["base"],
                        seq=state["seq"],
                        pool=mempool,
                        data_iter_fn=state["data_iter_fn"],
                        seed=state["seed"],
                        slice_=slice_,
                        impl=policy.impl,
                        remat=policy.remat,
                        # getattr: a worker may receive a policy pickled by
                        # an older caller without the base_dtype field
                        base_dtype=getattr(policy, "base_dtype", None),
                    )
                finally:
                    if root_cm is not None:
                        root_cm.__exit__(None, None, None)
                        spans = wtracer.pop_root(root.span_id)
                        span_t0 = root.start
            done = {
                "req": rid,
                "host": host_id,
                "record": encode_record(rec),
                "writes": mempool.writes if mempool is not None else [],
            }
            if spans is not None:
                done["spans"] = spans
                done["span_t0"] = span_t0
            outbox.put(("done", done))
        except BaseException as e:  # noqa: BLE001 — shipped to the dispatcher
            outbox.put(
                ("err", {
                    "req": rid,
                    "host": host_id,
                    "error": repr(e),
                    "traceback": traceback.format_exc(),
                })
            )

    tpe = ThreadPoolExecutor(max_workers=max(n_devices, 1))
    n_running = [0]

    def counted_run(payload):
        try:
            do_run(payload)
        finally:
            n_running[0] -= 1

    try:
        while True:
            kind, payload = inbox.get()
            if kind == "stop":
                break
            if kind == "init":
                state = dict(payload)
            elif kind == "run":
                n_running[0] += 1
                tpe.submit(counted_run, payload)
            elif kind == "ping":
                # answered inline, never queued behind segments: a worker
                # that stops ponging has a wedged loop, not a busy one
                outbox.put(("pong", HealthReply(
                    host=host_id, seq=payload.seq, t_send=payload.t_send,
                    in_flight=n_running[0],
                )))
    finally:
        tpe.shutdown(wait=True)


def _forced_xla_flags(n_devices: int) -> str:
    """Parent's XLA_FLAGS with the forced-host-device count replaced."""
    kept = [
        f
        for f in os.environ.get("XLA_FLAGS", "").split()
        if not f.startswith("--xla_force_host_platform_device_count")
    ]
    kept.append(f"--xla_force_host_platform_device_count={n_devices}")
    return " ".join(kept)


# serializes the env-set -> spawn -> env-restore dance when several hosts
# (possibly with different device counts) start concurrently
_SPAWN_LOCK = threading.Lock()


class ProcessTransport:
    """Pipe/queue transport to one :func:`_worker_main` subprocess."""

    def __init__(self, host_id: int, n_devices: int):
        import multiprocessing as mp

        ctx = mp.get_context("spawn")  # fresh interpreter: no inherited jax
        self._inbox = ctx.Queue()
        self._outbox = ctx.Queue()
        self.proc = ctx.Process(
            target=_worker_main,
            args=(host_id, n_devices, self._inbox, self._outbox),
            daemon=True,  # never outlive the dispatcher process
            name=f"plora-host-{host_id}",
        )
        with _SPAWN_LOCK:
            saved_xla = os.environ.get("XLA_FLAGS")
            saved_plat = os.environ.get("JAX_PLATFORMS")
            os.environ["XLA_FLAGS"] = _forced_xla_flags(n_devices)
            os.environ.setdefault("JAX_PLATFORMS", "cpu")
            try:
                self.proc.start()
            finally:
                for key, saved in (
                    ("XLA_FLAGS", saved_xla), ("JAX_PLATFORMS", saved_plat)
                ):
                    if saved is None:
                        os.environ.pop(key, None)
                    else:
                        os.environ[key] = saved

    def send(self, msg) -> None:
        self._inbox.put(msg)

    def recv(self, timeout: Optional[float] = None):
        return self._outbox.get(timeout=timeout)  # raises queue.Empty

    def alive(self) -> bool:
        return self.proc.is_alive()

    def kill(self) -> None:
        if self.proc.is_alive():
            self.proc.kill()
        self.proc.join(timeout=5)

    def join(self, timeout: Optional[float] = None) -> None:
        self.proc.join(timeout)


# ---------------------------------------------------------------------------
# Dispatcher side
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HostUnit:
    """One device unit addressed as a ``(host, local unit)`` pair — the
    virtual 'device' objects backing the dispatcher's :class:`DevicePool`."""

    host: int
    local: int


def _send_with_retry(
    transport, msg, *, deadline: float = 30.0, retries: int = 2
) -> None:
    """Wire send with a per-message deadline and bounded retry: transient
    transport hiccups back off and retry; a send still failing at the
    deadline (or out of attempts) raises :class:`TransportError`."""
    t0 = time.perf_counter()
    last: Optional[BaseException] = None
    for attempt in range(retries + 1):
        try:
            transport.send(msg)
            return
        except Exception as e:  # noqa: BLE001 — retried, then re-raised
            last = e
            if time.perf_counter() - t0 >= deadline or attempt >= retries:
                break
            time.sleep(min(0.05 * (2 ** attempt), 0.5))
    raise TransportError(
        f"send failed after {attempt + 1} attempt(s): {last!r}"
    ) from last


class _Reply:
    """Future for one in-flight segment request."""

    __slots__ = ("_evt", "_kind", "_payload", "_err")

    def __init__(self):
        self._evt = threading.Event()
        self._kind = self._payload = self._err = None

    def resolve(self, kind: str, payload: Dict[str, Any]) -> None:
        self._kind, self._payload = kind, payload
        self._evt.set()

    def fail(self, err: BaseException) -> None:
        self._err = err
        self._evt.set()

    def wait(self, timeout: Optional[float] = None) -> Dict[str, Any]:
        if not self._evt.wait(timeout):
            raise TransportError(
                f"no reply within the {timeout:.0f}s request deadline"
            )
        if self._err is not None:
            raise self._err
        if self._kind == "err":
            raise RemoteSegmentError(
                f"segment failed on host {self._payload['host']}: "
                f"{self._payload['error']}\n--- remote traceback ---\n"
                f"{self._payload['traceback']}"
            )
        return self._payload


class HostWorker:
    """Dispatcher-side handle for one host: transport + pump thread + the
    in-flight request table. A dead worker fails all in-flight requests with
    :class:`WorkerDied`; the dispatcher then spawns a *new* ``HostWorker``
    for the host (the handle itself is never resurrected)."""

    def __init__(
        self, host_id: int, n_devices: int, transport,
        *, on_pong: Optional[Callable] = None,
        send_deadline: float = 30.0, send_retries: int = 2,
    ):
        self.host_id = host_id
        self.n_devices = n_devices
        self.transport = transport
        self.on_pong = on_pong
        self.send_deadline = send_deadline
        self.send_retries = send_retries
        self.ready = threading.Event()
        self.fatal: Optional[Dict[str, Any]] = None
        self.init_version = -1
        self.dead = False
        # did this worker die with requests in flight? Idle deaths (e.g. a
        # spot reclaim between segments) don't burn a restart credit.
        self.died_in_flight = False
        self._lock = threading.Lock()
        self._pending: Dict[int, _Reply] = {}
        self._pump = threading.Thread(
            target=self._pump_loop, name=f"pump-host-{host_id}", daemon=True
        )
        self._pump.start()

    # -- request lifecycle --------------------------------------------------

    def send(self, msg) -> None:
        """Deadline-bounded wire send (shared by requests / init / pings)."""
        _send_with_retry(
            self.transport, msg,
            deadline=self.send_deadline, retries=self.send_retries,
        )

    def request(self, rid: int, msg) -> _Reply:
        reply = _Reply()
        with self._lock:
            if self.dead:
                raise WorkerDied(f"host {self.host_id} worker is dead")
            self._pending[rid] = reply
        try:
            self.send(msg)
        except Exception as e:  # queue to a dead process
            with self._lock:
                self._pending.pop(rid, None)
            raise WorkerDied(f"host {self.host_id} send failed: {e!r}") from e
        return reply

    def in_flight(self) -> int:
        with self._lock:
            return len(self._pending)

    def wait_ready(self, timeout: float) -> None:
        if not self.ready.wait(timeout):
            raise TransportError(
                f"host {self.host_id} worker not ready after {timeout:.0f}s"
            )
        if self.fatal is not None:
            # the worker reported a startup exception: deterministic, so a
            # respawn would just fail the same way — no retry
            raise TransportError(
                f"host {self.host_id} worker failed to start: "
                f"{self.fatal['error']}\n{self.fatal['traceback']}"
            )
        if self.dead:
            # hard-died before 'ready' (SIGKILL / OOM / segfault during
            # startup): possibly transient, so surface it as WorkerDied —
            # the segment retry loop respawns, bounded by max_restarts
            raise WorkerDied(
                f"host {self.host_id} worker died during startup"
            )

    # -- pump ---------------------------------------------------------------

    def _fail_all(self) -> None:
        with self._lock:
            self.dead = True
            pending = list(self._pending.values())
            self._pending.clear()
            if pending:
                self.died_in_flight = True
        err = WorkerDied(f"host {self.host_id} worker died")
        for reply in pending:
            reply.fail(err)
        self.ready.set()  # unblock wait_ready; fatal/dead is checked there

    def _pump_loop(self) -> None:
        while True:
            try:
                msg = self.transport.recv(timeout=0.2)
            except Empty:
                if not self.transport.alive():
                    self._fail_all()
                    return
                continue
            except Exception:  # truncated pickle from a killed writer, EOF
                self._fail_all()
                return
            kind, payload = msg
            if kind == "ready":
                self.ready.set()
            elif kind == "pong":
                if self.on_pong is not None:
                    self.on_pong(self.host_id, payload)
            elif kind == "fatal":
                self.fatal = payload
                self._fail_all()
                return
            else:  # "done" / "err"
                with self._lock:
                    reply = self._pending.pop(payload["req"], None)
                if reply is not None:
                    reply.resolve(kind, payload)


class DispatchExecutor:
    """`SliceExecutor`-shaped facade that executes segments *remotely*.

    ``run_segment`` ships the segment (plus any resumed adapter state read
    from the central pool) to the worker owning the slice's host, blocks on
    the reply, applies the returned checkpoint writes to the central pool,
    and returns a ``JobRecord`` — so ``ClusterRunner``'s dispatch loop and
    the engine's adaptive loop drive multi-host execution without changes.
    A :class:`WorkerDied` mid-segment restarts the host (bounded by the
    dispatcher's ``max_restarts``) and re-dispatches: the segment's inputs
    are still in the pool (writes are success-atomic), so the retry is the
    existing preempt/resume path and no step is lost or double-counted."""

    def __init__(self, dispatcher: "HostDispatcher"):
        self.disp = dispatcher
        # settable so ClusterRunner's tracer-adoption contract applies to
        # the remote executor exactly like the local one
        self.tracer = dispatcher.tracer

    def pack_template(self, cfg, configs, seed: int = 0):
        """Pre-warm hook: templates are built inside each worker (their
        cache lives with the devices), so the dispatcher side is a no-op."""
        return None

    def run_segment(
        self,
        seg,
        configs_by_cid: Dict,
        total_steps: Dict[int, int],
        cfg,
        base_params,
        *,
        seq: int,
        pool,
        data_iter_fn: Optional[Callable] = None,
        seed: int = 0,
        slice_=None,
        impl: Optional[str] = None,
        remat: Optional[str] = None,
        base_dtype: Optional[str] = None,
    ):
        d = self.disp
        if slice_ is None:
            raise ValueError(
                "multi-host dispatch needs an explicit mesh slice "
                "(unplanned segments have no host)"
            )
        hosts = {dev.host for dev in slice_.devices}
        if len(hosts) != 1:
            raise RuntimeError(
                f"segment units {slice_.units} span hosts {sorted(hosts)}; "
                "plan with ExecutionEngine(..., host_size=...) so every "
                "job's units stay on one host"
            )
        host = hosts.pop()
        local_units = tuple(sorted(dev.local for dev in slice_.devices))
        d._prepare(
            cfg, configs_by_cid, total_steps, base_params, seq, seed,
            data_iter_fn,
        )
        states: Dict[str, Tuple[dict, dict]] = {}
        for cid, st0 in zip(seg.config_ids, seg.start_steps):
            if st0 > 0 and pool is not None:
                aid = f"{cid:04d}"
                if pool.has_adapter_state(aid):
                    tree, meta = pool.load_adapter_state(aid)
                    states[aid] = (encode_tree(tree), dict(meta))
        base_payload = {
            "seg": encode_segment(seg),
            "units": local_units,
            "states": states,
            "has_pool": pool is not None,
            # the caller's kernel policy rides with every segment: workers
            # run exactly the tier the dispatcher-side planner selected
            "policy": KernelPolicy(
                impl=None if impl == "auto" else impl, remat=remat,
                base_dtype=base_dtype,
            ),
        }
        tracer = self.tracer
        with tracer.span(
            "dispatch.segment", cat="dispatch", track=f"host{host}",
            job_id=seg.job_id, host=host, units=list(slice_.units),
        ) as dspan:
            if tracer.enabled:
                base_payload["trace"] = tracer.context()
            t_start = time.perf_counter()
            last_died: Optional[WorkerDied] = None
            for _attempt in range(d.max_restarts + 1):
                rid = next(d._rid)
                try:
                    worker = d._ensure_host(host)
                    t_send = time.perf_counter()
                    reply = worker.request(
                        rid, ("run", dict(base_payload, req=rid))
                    )
                    out = reply.wait()
                except WorkerDied as e:
                    last_died = e
                    continue  # respawn + re-dispatch: preempt/resume path
                rec = decode_record(out["record"])
                if pool is not None:
                    for w in out["writes"]:
                        if w.kind == "adapter":
                            pool.save_adapter(w.adapter_id, w.tree, w.meta)
                        else:
                            pool.save_adapter_state(
                                w.adapter_id, w.tree, w.meta
                            )
                if tracer.enabled and out.get("spans"):
                    # worker clocks aren't comparable: rebase so the
                    # worker's root span starts at the moment this side
                    # handed the request to the transport
                    tracer.ingest(
                        out["spans"],
                        offset=t_send - out["span_t0"],
                        parent_id=dspan.span_id,
                        track_prefix=f"host{host}/",
                    )
                # dispatcher-clock interval (worker clocks aren't
                # comparable); ClusterRunner/_run_adaptive re-base these
                # against their t0
                rec.real_start = t_start
                rec.real_end = time.perf_counter()
                return rec
            raise WorkerDied(
                f"host {host} died {d.max_restarts + 1} times executing job "
                f"{seg.job_id} (segment of configs {seg.config_ids})"
            ) from last_died


class HostDispatcher:
    """Process-per-host execution of planned segments.

    Implements the :class:`~repro.cluster.api.Runner` protocol: ``run``
    executes a batch of segments (via an internal ``ClusterRunner`` whose
    executor is remote), and ``.executor`` / ``.device_pool`` /
    ``.concurrent`` plug into ``ExecutionEngine._run_adaptive`` directly.

    ``hosts`` is either a per-host device-count list (``[4, 4]`` = two
    4-device hosts) or an int paired with ``devices_per_host``. Global unit
    ``u`` maps to ``(host, local)`` via the cumulative offsets; plans must
    keep each job on one host (``ExecutionEngine(host_size=...)``).

    ``transport_factory(host_id, n_devices)`` defaults to spawning a real
    subprocess (:class:`ProcessTransport`); tests inject in-memory fakes.
    Workers are started lazily, restarted on death (``max_restarts`` per
    segment), and torn down by ``close()`` / the context manager."""

    def __init__(
        self,
        hosts: Union[int, Sequence[int]],
        devices_per_host: int = 1,
        *,
        transport_factory: Optional[Callable] = None,
        max_restarts: int = 2,
        start_timeout: float = 300.0,
        tracer=None,
        host_classes: Optional[Sequence[str]] = None,
        heartbeat_interval: float = 0.0,
        heartbeat_timeout: Optional[float] = None,
        heartbeat_dead_after: int = 3,
        send_deadline: float = 30.0,
        send_retries: int = 2,
    ):
        if isinstance(hosts, int):
            hosts = [devices_per_host] * hosts
        self.hosts: Tuple[int, ...] = tuple(int(n) for n in hosts)
        if not self.hosts or any(n <= 0 for n in self.hosts):
            raise ValueError(f"bad host layout {self.hosts}")
        if host_classes is None:
            host_classes = [""] * len(self.hosts)
        if len(host_classes) != len(self.hosts):
            raise ValueError(
                f"{len(host_classes)} host classes for {len(self.hosts)} hosts"
            )
        self.host_classes: Tuple[str, ...] = tuple(str(c) for c in host_classes)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.max_restarts = max_restarts
        self.start_timeout = start_timeout
        self._transport_factory = transport_factory or ProcessTransport
        self.n_restarts = 0
        self._rid = itertools.count()
        self._workers: List[Optional[HostWorker]] = [None] * len(self.hosts)
        self._host_locks = [threading.Lock() for _ in self.hosts]
        self._payload: Optional[Dict[str, Any]] = None
        self._payload_token = None
        self._payload_refs: Tuple = ()  # pins id()s used in the memo token
        self._payload_version = 0
        self._prep_lock = threading.Lock()
        self.send_deadline = send_deadline
        self.send_retries = send_retries

        from repro.cluster.pool import DevicePool

        units = [
            HostUnit(h, i)
            for h, n in enumerate(self.hosts)
            for i in range(n)
        ]
        self.device_pool = DevicePool(devices=units)
        # global unit ids per host (stable: add_host only appends)
        self._host_units: List[Tuple[int, ...]] = []
        off = 0
        for n in self.hosts:
            self._host_units.append(tuple(range(off, off + n)))
            off += n
        self.executor = DispatchExecutor(self)
        self.concurrent = True
        self.last_result = None

        # -- membership / health ------------------------------------------
        self._membership_lock = threading.Lock()
        self._membership_subs: List[Callable] = []
        self._host_state: List[str] = [HOST_ALIVE] * len(self.hosts)
        self._last_pong: List[float] = [0.0] * len(self.hosts)
        self._hb_misses: List[int] = [0] * len(self.hosts)
        self.heartbeat_interval = float(heartbeat_interval)
        self.heartbeat_timeout = (
            float(heartbeat_timeout)
            if heartbeat_timeout is not None
            else 3.0 * self.heartbeat_interval
        )
        self.heartbeat_dead_after = int(heartbeat_dead_after)
        self._hb_seq = itertools.count()
        self._closing = threading.Event()
        self._watchdog: Optional[threading.Thread] = None
        self._hosts_alive_gauge()
        if self.heartbeat_interval > 0:
            self._watchdog = threading.Thread(
                target=self._watchdog_loop, name="plora-watchdog", daemon=True
            )
            self._watchdog.start()

    # -- topology -----------------------------------------------------------

    @property
    def total_units(self) -> int:
        return sum(self.hosts)

    @property
    def host_size(self) -> Optional[int]:
        """Uniform per-host width (what ``ExecutionEngine(host_size=...)``
        wants), or None when hosts are heterogeneous."""
        return self.hosts[0] if len(set(self.hosts)) == 1 else None

    def units_of_host(self, host: int) -> Tuple[int, ...]:
        """Global pool unit ids backing one host."""
        return self._host_units[host]

    def host_of_unit(self, unit: int) -> int:
        return self.device_pool.devices[unit].host

    def in_flight(self, host: int) -> int:
        w = self._workers[host]
        return 0 if w is None else w.in_flight()

    # -- membership / health ------------------------------------------------

    def host_state(self, host: int) -> str:
        return self._host_state[host]

    @property
    def hosts_alive(self) -> int:
        return sum(
            1 for s in self._host_state if s in (HOST_ALIVE, HOST_SUSPECT)
        )

    def _hosts_alive_gauge(self) -> None:
        self.tracer.metrics.gauge("cluster.hosts_alive").set(self.hosts_alive)

    def _set_host_state(self, host: int, state: str, **why) -> None:
        prev = self._host_state[host]
        if prev == state:
            return
        self._host_state[host] = state
        self.tracer.instant(
            f"host{host}.{state}", cat="host", track="membership",
            host=host, state=state, prev=prev, **why,
        )
        self._hosts_alive_gauge()

    def membership_subscribe(self, cb: Callable) -> Callable:
        """Register ``cb(event_dict)`` for join/drain notifications (called
        from the announcing thread). Returns an unsubscribe callable. The
        engine's adaptive loop uses this to replan onto joining hosts and
        off draining ones."""
        with self._membership_lock:
            self._membership_subs.append(cb)

        def unsubscribe():
            with self._membership_lock:
                if cb in self._membership_subs:
                    self._membership_subs.remove(cb)

        return unsubscribe

    def _announce(self, event: Dict[str, Any]) -> None:
        with self._membership_lock:
            subs = list(self._membership_subs)
        for cb in subs:
            cb(dict(event))

    def add_host(
        self, n_devices: Optional[int] = None, *, host_class: str = "",
    ) -> int:
        """Admit a new host mid-run: extend the layout, register its units
        with the device pool (free immediately — blocked acquires wake), and
        announce a ``join`` event so the engine replans onto it. The worker
        itself spawns lazily on first dispatch, like every other host.
        Returns the new host id."""
        n = int(n_devices) if n_devices is not None else self.hosts[0]
        if n <= 0:
            raise ValueError(f"bad device count {n}")
        host = len(self.hosts)
        self.hosts = self.hosts + (n,)
        self.host_classes = self.host_classes + (str(host_class),)
        self._workers.append(None)
        self._host_locks.append(threading.Lock())
        self._host_state.append(HOST_ALIVE)
        self._last_pong.append(0.0)
        self._hb_misses.append(0)
        units = self.device_pool.add_devices(
            [HostUnit(host, i) for i in range(n)]
        )
        self._host_units.append(units)
        self.tracer.instant(
            f"host{host}.{HOST_ALIVE}", cat="host", track="membership",
            host=host, state=HOST_ALIVE, reason="join",
            host_class=host_class, units=list(units),
        )
        self._hosts_alive_gauge()
        self._announce({
            "action": "join", "host": host, "units": units,
            "host_class": str(host_class), "n_devices": n,
        })
        return host

    def drain_host(self, host: int, *, timeout: float = 120.0) -> None:
        """Gracefully retire one host: announce ``drain`` (the engine stops
        assigning and force-replans residuals off the host), let in-flight
        segments finish — their checkpoint writes land through the normal
        success-atomic path, so no step is lost — then retire the units from
        the pool and stop the worker. The graceful sibling of
        :meth:`kill_host`."""
        if self._host_state[host] in (HOST_DRAINING, HOST_DEAD):
            return
        self._set_host_state(host, HOST_DRAINING, reason="drain")
        self._announce({
            "action": "drain", "host": host,
            "units": self.units_of_host(host),
            "host_class": self.host_classes[host],
        })
        deadline = time.perf_counter() + timeout
        while True:
            # re-read each pass: a mid-drain death respawns the worker (the
            # retry path re-runs the killed segment from its last checkpoint)
            # and the drain must wait out the *current* worker's in-flight.
            w = self._workers[host]
            if w is None or w.dead or w.in_flight() == 0:
                break
            if time.perf_counter() > deadline:
                raise TimeoutError(
                    f"host {host} still has {w.in_flight()} segment(s) in "
                    f"flight after {timeout:.0f}s drain window"
                )
            time.sleep(0.01)
        # in-flight work done; now the units must come home to the pool
        # (the engine releases each slice as its segment completes)
        self.device_pool.retire_units(
            self.units_of_host(host),
            timeout=max(deadline - time.perf_counter(), 0.01),
        )
        w = self._workers[host]
        if w is not None:
            try:
                if w.transport.alive():
                    w.send(("stop", {}))
                    w.transport.join(timeout=10)
            except Exception:
                pass
            try:
                w.transport.kill()
            except Exception:
                pass
        self._set_host_state(host, HOST_DEAD, reason="drained")

    # -- heartbeat watchdog -------------------------------------------------

    def _on_pong(self, host: int, payload) -> None:
        rtt = time.perf_counter() - payload.t_send
        self.tracer.metrics.histogram("cluster.heartbeat_rtt").record(rtt)
        self._last_pong[host] = time.perf_counter()
        self._hb_misses[host] = 0
        if self._host_state[host] == HOST_SUSPECT:
            self._set_host_state(host, HOST_ALIVE, reason="pong")

    def _watchdog_loop(self) -> None:
        """Ping every live worker each interval; a host missing its deadline
        goes SUSPECT, each further miss doubles the grace (exponential
        backoff — a paused/hung worker can still come back), and after
        ``heartbeat_dead_after`` misses the host is declared DEAD: its
        in-flight replies fail with :class:`WorkerDied` (so ``run()`` never
        hangs on a hung-but-alive process) and the existing restart path
        respawns it on the next dispatch."""
        while not self._closing.wait(self.heartbeat_interval):
            now = time.perf_counter()
            for host in range(len(self.hosts)):
                w = self._workers[host]
                if w is None or w.dead or not w.ready.is_set():
                    continue
                if self._host_state[host] == HOST_DEAD:
                    continue
                if self._last_pong[host] == 0.0:
                    self._last_pong[host] = now  # first ping epoch
                try:
                    w.send(("ping", HeartbeatMsg(
                        seq=next(self._hb_seq), t_send=time.perf_counter(),
                    )))
                except Exception:
                    pass  # counted as a miss below
                misses = self._hb_misses[host]
                due = self._last_pong[host] + (
                    self.heartbeat_timeout * (2 ** misses)
                )
                if now <= due:
                    continue
                self._hb_misses[host] = misses + 1
                if self._host_state[host] == HOST_ALIVE:
                    self._set_host_state(
                        host, HOST_SUSPECT, reason="heartbeat_timeout",
                        misses=misses + 1,
                    )
                if self._hb_misses[host] >= self.heartbeat_dead_after:
                    self._set_host_state(
                        host, HOST_DEAD, reason="heartbeat_expired",
                        misses=self._hb_misses[host],
                    )
                    w._fail_all()
                    try:
                        w.transport.kill()
                    except Exception:
                        pass

    # -- worker lifecycle ---------------------------------------------------

    def _prepare(
        self, cfg, configs_by_cid, total_steps, base_params, seq, seed,
        data_iter_fn,
    ) -> None:
        """Cache the run-level init payload (model config, base params,
        budgets) once per workload; (re)started workers receive it before
        any segment. One dispatcher serves one workload at a time.

        The memo token holds configs/budgets *by value* (LoraConfig is
        hashable) and pins ``base_params``/``data_iter_fn`` alive on
        ``_payload_refs`` so their id()s cannot be recycled by a later
        workload — an id-only token could silently reuse stale state."""
        with self._prep_lock:
            token = (
                cfg, id(base_params), id(data_iter_fn), seq, seed,
                tuple(sorted(configs_by_cid.items())),
                tuple(sorted(total_steps.items())),
            )
            if token == self._payload_token:
                return
            if data_iter_fn is not None:
                try:
                    pickle.dumps(data_iter_fn)
                except Exception as e:
                    raise ValueError(
                        "data_iter_fn must be picklable (a module-level "
                        "callable) to cross the host boundary"
                    ) from e
            self._payload = {
                "cfg": cfg,
                "configs_by_cid": dict(configs_by_cid),
                "total_steps": {int(k): int(v) for k, v in total_steps.items()},
                "base": encode_tree(base_params),
                "seq": int(seq),
                "seed": int(seed),
                "data_iter_fn": data_iter_fn,
            }
            self._payload_token = token
            self._payload_refs = (base_params, data_iter_fn)
            self._payload_version += 1

    def _ensure_host(self, host: int) -> HostWorker:
        """Live, initialized worker for ``host`` — spawning or respawning
        (counted in ``n_restarts``) as needed. Safe to call from concurrent
        segment threads; only one respawn happens per death."""
        with self._host_locks[host]:
            w = self._workers[host]
            if w is not None and not w.dead and w.transport.alive():
                if self._payload is not None and (
                    w.init_version != self._payload_version
                ):
                    w.send(("init", self._payload))
                    w.init_version = self._payload_version
                return w
            if w is not None:
                # restart credits are for failures that cost work: a worker
                # that died *idle* (no request in flight) lost nothing, so
                # its respawn is free — see test_multihost.py regression pair
                if w.died_in_flight:
                    self.n_restarts += 1
                try:
                    w.transport.kill()
                except Exception:
                    pass
            w = HostWorker(
                host, self.hosts[host],
                self._transport_factory(host, self.hosts[host]),
                on_pong=self._on_pong,
                send_deadline=self.send_deadline,
                send_retries=self.send_retries,
            )
            self._workers[host] = w
            w.wait_ready(self.start_timeout)
            self._last_pong[host] = time.perf_counter()
            self._hb_misses[host] = 0
            if self._host_state[host] in (HOST_SUSPECT, HOST_DEAD):
                self._set_host_state(host, HOST_ALIVE, reason="respawn")
            if self._payload is not None:
                w.send(("init", self._payload))
                w.init_version = self._payload_version
            return w

    def kill_host(self, host: int) -> None:
        """Fault injection / hard teardown: SIGKILL the host's worker. Any
        in-flight segment fails with :class:`WorkerDied` and is re-dispatched
        onto a fresh worker by :meth:`DispatchExecutor.run_segment`."""
        w = self._workers[host]
        if w is not None:
            w.transport.kill()

    def close(self) -> None:
        """Graceful stop of every worker (kill as fallback)."""
        self._closing.set()
        if self._watchdog is not None:
            self._watchdog.join(timeout=5)
        for w in self._workers:
            if w is None:
                continue
            try:
                if w.transport.alive():
                    w.transport.send(("stop", {}))
                    w.transport.join(timeout=10)
            except Exception:
                pass
            try:
                w.transport.kill()
            except Exception:
                pass

    def __enter__(self) -> "HostDispatcher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- Runner protocol ----------------------------------------------------

    def run(
        self,
        segments,
        configs_by_cid,
        total_steps,
        cfg,
        base_params,
        *,
        seq: int,
        pool=None,
        data_iter_fn=None,
        seed: int = 0,
        estimator=None,
        impl: Optional[str] = None,
        remat: Optional[str] = None,
        base_dtype: Optional[str] = None,
    ):
        """Execute planned segments across the hosts — same contract as
        :meth:`ClusterRunner.run` (dispatch order, resume dependencies,
        device-free events from real completions, timings feedback), with
        each segment running in its host's worker process. ``impl``/``remat``
        ship to the workers as a :class:`KernelPolicy` with every segment."""
        from repro.cluster.runner import ClusterRunner

        runner = ClusterRunner(
            self.executor, self.device_pool, concurrent=True,
            tracer=self.tracer,
        )
        result = runner.run(
            segments,
            configs_by_cid,
            total_steps,
            cfg,
            base_params,
            seq=seq,
            pool=pool,
            data_iter_fn=data_iter_fn,
            seed=seed,
            estimator=estimator,
            impl=impl,
            remat=remat,
            base_dtype=base_dtype,
        )
        self.last_result = result
        return result

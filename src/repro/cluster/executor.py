"""Slice executor: compile-cached packed train steps placed on mesh slices.

One :class:`SliceExecutor` owns a cache of jitted packed train steps keyed by
(model config, pack width, slice shape). The step itself
(:func:`repro.train.trainer.make_packed_step`) takes the per-adapter
hyperparameter vectors — scales, learning rates, step budgets — as *runtime
arguments*, so two packs with the same (n, r_bucket, batch, seq) shape share
one compiled executable even when their hyperparameters differ. Segment
execution (`run_segment`) is what the engine's ``_execute_segments`` used to
do inline, plus explicit placement onto the segment's :class:`MeshSlice`:

  * width-1 slice — everything ``device_put`` onto the slice's device;
  * width-g slice — params sharded per the production rules
    (``launch.sharding.param_specs``) over a ``slice_mesh`` covering exactly
    the slice's devices, batch per ``batch_specs``, vectors replicated.

Batches are pre-generated and pre-placed in bounded chunks (``PREGEN_CHUNK``)
ahead of the step stream: Python-side data synthesis holds the GIL, and
interleaving it step-by-step serializes concurrently dispatched segments
(measured: it flips a 1.7x concurrency win into a 0.8x loss on a 2-core
host); chunking keeps resident batch memory O(chunk), not O(n_steps).
"""
from __future__ import annotations

import contextlib
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LoraConfig, ModelConfig
from repro.core.adapter import pack_meta
from repro.core.packed_lora import extract_adapter, inject_adapter
from repro.cluster.pool import MeshSlice
from repro.obs import NULL_TRACER

# per-adapter step cap meaning "no budget": always larger than any real
# step count, so the budget mask stays 1.0 and the update is bit-identical
# to an unbudgeted AdamW step.
NO_BUDGET = np.int32(2**31 - 1)

# batches pre-generated and pre-placed per refill (bounds resident batch
# memory for long runs while keeping GIL-bound data synthesis out of the
# concurrent step stream for a whole chunk at a time)
PREGEN_CHUNK = 256


def _slice_track(slice_: Optional[MeshSlice]) -> str:
    """Perfetto track name for a slice: one row per device unit group."""
    if slice_ is None or not slice_.units:
        return "device"
    if len(slice_.units) == 1:
        return f"unit{slice_.units[0]}"
    return f"units{min(slice_.units)}-{max(slice_.units)}"


def _accepts_start_steps(fn) -> bool:
    """Whether a custom data_iter_fn can take per-adapter stream offsets."""
    import inspect

    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False
    return "start_steps" in params or any(
        p.kind == inspect.Parameter.VAR_KEYWORD for p in params.values()
    )


@dataclass
class PackResult:
    """Final state of one packed training run on a slice."""

    lora: Any
    opt: Any
    losses: Optional[np.ndarray]  # final per-adapter losses (None if 0 steps)
    wall_seconds: float  # steady-state loop time (compile excluded)
    real_start: float = 0.0  # absolute perf_counter timestamps of the
    real_end: float = 0.0  # placed+timed region (overlap accounting)


class SliceExecutor:
    """Compile-cached packed-step execution on device slices (thread-safe)."""

    def __init__(self, *, tracer=None):
        self._steps: Dict[Tuple, Callable] = {}
        self._templates: Dict[Tuple, Tuple] = {}
        self._warmed: set = set()
        self._lock = threading.Lock()
        self.n_builds = 0
        self.n_hits = 0
        self.tracer = tracer if tracer is not None else NULL_TRACER

    # ---------------- pack-state templates ----------------

    def pack_template(self, cfg: ModelConfig, configs: Sequence[LoraConfig],
                      seed: int = 0):
        """Fresh (lora, opt) state for this pack shape, from a cached
        template: adapter init depends only on (seed, model config, pack
        meta), and ``init_model`` is expensive enough (~10s on a reduced
        config: it also materializes a base model we throw away) that
        rebuilding it per segment dominated segment runtime. Returned trees
        share leaves with the cache — callers get fresh containers, and
        placement copies the leaves before anything donates them."""
        meta = pack_meta(configs)
        # adapter init depends only on the rank tuple (shapes + rank mask),
        # not on alphas / learning rates / batch sizes
        key = (cfg, meta.ranks, seed)
        with self._lock:
            hit = self._templates.get(key)
        if hit is None:
            from repro.models.model import init_model
            from repro.train.optimizer import init_opt_state

            _, lora = init_model(jax.random.PRNGKey(seed), cfg, meta)
            opt = init_opt_state(lora, n_pack=meta.n)
            hit = (lora, opt)
            with self._lock:
                self._templates.setdefault(key, hit)
        lora, opt = hit
        return (
            jax.tree.map(lambda x: x, lora),
            jax.tree.map(lambda x: x, opt),
        )

    # ---------------- compile cache ----------------

    def step_fn(
        self,
        cfg: ModelConfig,
        n_pack: int,
        slice_: Optional[MeshSlice] = None,
        *,
        nb: int = 0,
        mesh_shape: Optional[Tuple[int, int]] = None,
        fsdp: bool = False,
        seq_parallel: bool = False,
        impl: Optional[str] = None,
        remat: Optional[str] = None,
        ranks: Optional[Tuple[int, ...]] = None,
        blocks: Optional[Tuple[int, int, int]] = None,
        base_dtype: Optional[str] = None,
    ) -> Tuple[Callable, Optional[Any]]:
        """Jitted packed step for this (config, pack width, slice shape).

        Returns ``(step, dist)``; ``dist`` is None for width-1 slices. The
        Python-level cache is the subsystem's compile cache: same-shape packs
        hit the same jitted callable (and, through jax's executable cache,
        the same XLA compilation when placed identically). The kernel policy
        (``impl``/``remat``/the pack's static ``ranks`` tuple, which drives
        ragged same-rank segmentation) is part of the trace, so it is part
        of the key."""
        width = 1 if slice_ is None else slice_.width
        # homogeneous rank tuples normalize to None (trace-identical: ragged
        # segmentation only engages on mixed ranks) so same-width packs keep
        # sharing one compiled step across uniform rank buckets
        ranks = tuple(ranks) if ranks and len(set(ranks)) > 1 else None
        kkey = (impl, remat, ranks, blocks, base_dtype)
        if width == 1:
            key: Tuple = (cfg, n_pack, 1, kkey)
        else:
            key = (
                cfg, n_pack, width, slice_.devices, nb,
                mesh_shape, fsdp, seq_parallel, kkey,
            )
        with self._lock:
            hit = self._steps.get(key)
            if hit is not None:
                self.n_hits += 1
                self.tracer.metrics.counter("executor.compile_cache_hits").inc()
                return hit
            from repro.train.trainer import make_packed_step

            dist = None
            if width > 1:
                from repro.launch.sharding import make_dist

                data, model = mesh_shape or (1, width)
                mesh = slice_.mesh(data=data, model=model)
                dist = make_dist(
                    mesh, nb or None, fsdp=fsdp,
                    seq_sharded_residuals=seq_parallel,
                )
            step = make_packed_step(
                cfg, n_pack, dist=dist, impl=impl, remat=remat, ranks=ranks,
                blocks=blocks, base_dtype=base_dtype,
            )
            self._steps[key] = (step, dist)
            self.n_builds += 1
            self.tracer.metrics.counter("executor.compile_cache_builds").inc()
            return step, dist

    # ---------------- placement ----------------

    @staticmethod
    def _place(slice_: Optional[MeshSlice], cfg, dist, base, lora, opt, vecs):
        """Commit all step inputs to the slice's devices.

        ``lora``/``opt`` leaves may alias a cached pack template, and the
        train step *donates* them — so they are deep-copied on-device
        (``x + 0`` stays on the target placement) while ``base`` (never
        donated, shared by every concurrent segment) is placed as-is."""
        from jax.sharding import NamedSharding, PartitionSpec

        copy = lambda t: jax.tree.map(lambda x: x + 0, t)  # noqa: E731
        if slice_ is None or slice_.width == 1:
            dev = None if slice_ is None else slice_.lead
            put = (lambda t: t) if dev is None else (
                lambda t: jax.device_put(t, dev)
            )
            return (
                put(base), copy(put(lora)), copy(put(opt)),
                tuple(put(v) for v in vecs), put,
            )
        from repro.launch.sharding import param_specs, to_named

        mesh = dist.mesh
        repl = NamedSharding(mesh, PartitionSpec())
        bspec = to_named(param_specs(jax.eval_shape(lambda: base), cfg, mesh), mesh)
        lspec = to_named(param_specs(jax.eval_shape(lambda: lora), cfg, mesh), mesh)
        base_d = jax.device_put(base, bspec)
        lora_d = copy(jax.device_put(lora, lspec))
        opt_d = copy({
            "m": jax.device_put(opt["m"], lspec),
            "v": jax.device_put(opt["v"], lspec),
            "step": jax.device_put(opt["step"], repl),
        })
        vecs_d = tuple(jax.device_put(v, repl) for v in vecs)

        def put_batch(b):
            from repro.launch.sharding import batch_specs

            spec = to_named(batch_specs(jax.eval_shape(lambda: b), mesh), mesh)
            return jax.device_put(b, spec)

        return base_d, lora_d, opt_d, vecs_d, put_batch

    # ---------------- packed training on one slice ----------------

    def train_pack(
        self,
        cfg: ModelConfig,
        configs: Sequence[LoraConfig],
        *,
        n_steps: int,
        seq: int,
        base,
        lora=None,
        opt=None,
        slice_: Optional[MeshSlice] = None,
        seed: int = 0,
        budgets: Optional[np.ndarray] = None,
        data_iter_fn: Optional[Callable] = None,
        data_start_steps: Optional[Sequence[int]] = None,
        mesh_shape: Optional[Tuple[int, int]] = None,
        fsdp: bool = False,
        seq_parallel: bool = False,
        step_callback: Optional[Callable] = None,
        impl: Optional[str] = None,
        remat: Optional[str] = None,
        blocks: Optional[Tuple[int, int, int]] = None,
        base_dtype: Optional[str] = None,
    ) -> PackResult:
        """Train one pack for ``n_steps`` on ``slice_`` (default device when
        None). ``lora``/``opt`` may carry resumed state; ``budgets`` is the
        per-adapter step-cap vector (None = uncapped); ``data_start_steps``
        fast-forwards each adapter's data stream past batches consumed in
        earlier segments (resumed packs see the same samples they would have
        seen uninterrupted). ``step_callback(i, metrics)`` is invoked after
        every step (it synchronizes — use for logging, not benchmarking).
        Compilation happens on throwaway copies outside the timed region, so
        ``wall_seconds`` is steady-state."""
        from repro.train.data import packed_batch_iterator
        from repro.train.optimizer import init_opt_state

        meta = pack_meta(configs)
        if lora is None:
            lora, tmpl_opt = self.pack_template(cfg, configs, seed)
            if opt is None:
                opt = tmpl_opt
        if opt is None:
            opt = init_opt_state(lora, n_pack=meta.n)
        if budgets is None:
            budgets = np.full((meta.n,), NO_BUDGET, np.int32)
        nb = meta.n * meta.max_batch
        step, dist = self.step_fn(
            cfg, meta.n, slice_, nb=nb, mesh_shape=mesh_shape,
            fsdp=fsdp, seq_parallel=seq_parallel,
            impl=impl, remat=remat, ranks=meta.ranks, blocks=blocks,
            base_dtype=base_dtype,
        )
        vecs = (
            meta.scales(),
            meta.lr_vector(),
            jnp.asarray(budgets, jnp.int32),
        )
        real_start = time.perf_counter()
        base_d, lora_d, opt_d, (scales, lr_vec, budg), put_batch = self._place(
            slice_, cfg, dist, base, lora, opt, vecs
        )
        wall = 0.0
        losses = None
        m = None
        if n_steps > 0:
            skip = (
                tuple(int(s) for s in data_start_steps)
                if data_start_steps is not None and any(data_start_steps)
                else None
            )
            if data_iter_fn:
                # custom iterators own their stream; the offsets are passed
                # through only when a resumed segment actually needs them
                # AND the callable opts in by accepting ``start_steps`` —
                # legacy 3-arg iterators keep their pre-offset behavior
                # (resumed adapters replay the stream) instead of crashing
                if skip and _accepts_start_steps(data_iter_fn):
                    it = data_iter_fn(
                        cfg, list(configs), seq, start_steps=skip
                    )
                else:
                    it = data_iter_fn(cfg, list(configs), seq)
            else:
                it = packed_batch_iterator(
                    cfg, list(configs), seq=seq, start_steps=skip
                )
            # Pre-generate + pre-place batches in bounded chunks: the
            # GIL-bound data synthesis stays out of the (possibly
            # concurrent) step stream for a whole chunk at a time, while
            # resident batch memory stays O(PREGEN_CHUNK) instead of
            # O(n_steps) for long launcher runs.
            first = [
                put_batch(next(it))
                for _ in range(min(n_steps, PREGEN_CHUNK))
            ]
            # compile outside the timed region on throwaway copies (the
            # paper times steady state); `x + 0` keeps each copy on the
            # slice's own devices, so donation cannot invalidate the
            # originals. Skipped when this exact executable (step key +
            # batch shapes + placement) was already warmed — segmented runs
            # (probe / preempt / resume) would otherwise pay one throwaway
            # iteration per segment for a compile that is already cached.
            wkey = (
                cfg, meta.n, meta.r_bucket, meta.ranks, impl, remat, blocks,
                base_dtype,
                None if slice_ is None else slice_.devices,
                nb, mesh_shape, fsdp, seq_parallel,
                tuple(sorted(
                    (k, tuple(v.shape), str(v.dtype))
                    for k, v in first[0].items()
                )),
            )
            with self._lock:
                need_warm = wkey not in self._warmed
            track = _slice_track(slice_)
            if need_warm:
                with self.tracer.span(
                    "executor.compile", cat="executor", track=track,
                    n_pack=meta.n, width=1 if slice_ is None else slice_.width,
                ):
                    lora_w = jax.tree.map(lambda x: x + 0, lora_d)
                    opt_w = jax.tree.map(lambda x: x + 0, opt_d)
                    _, _, warm = step(
                        base_d, lora_w, opt_w, first[0], scales, lr_vec, budg
                    )
                    jax.block_until_ready(warm["loss"])
                with self._lock:
                    self._warmed.add(wkey)
            with self.tracer.span(
                "executor.train", cat="executor", track=track,
                n_pack=meta.n, n_steps=n_steps,
            ):
                t0 = time.perf_counter()
                i = 0
                batches = first
                while batches:
                    for batch in batches:
                        lora_d, opt_d, m = step(
                            base_d, lora_d, opt_d, batch, scales, lr_vec, budg
                        )
                        if step_callback is not None:
                            step_callback(i, m)
                        i += 1
                    batches = [
                        put_batch(next(it))
                        for _ in range(min(n_steps - i, PREGEN_CHUNK))
                    ]
                jax.block_until_ready(m["loss"])
                wall = time.perf_counter() - t0
            losses = np.asarray(m["per_adapter_loss"])
        return PackResult(
            lora=lora_d,
            opt=opt_d,
            losses=losses,
            wall_seconds=wall,
            real_start=real_start,
            real_end=time.perf_counter(),
        )

    # ---------------- one planned segment (engine integration) ----------------

    def run_segment(
        self,
        seg,  # JobSegment
        configs_by_cid: Dict[int, LoraConfig],
        total_steps: Dict[int, int],
        cfg: ModelConfig,
        base_params,
        *,
        seq: int,
        pool,  # Optional[CheckpointPool]
        data_iter_fn: Optional[Callable] = None,
        seed: int = 0,
        slice_: Optional[MeshSlice] = None,
        impl: Optional[str] = None,
        remat: Optional[str] = None,
        base_dtype: Optional[str] = None,
    ):
        """Execute one planned segment on ``slice_``: resume preempted
        adapters from the checkpoint pool, train ``seg.run_steps`` packed
        iterations, then save finished adapters / re-checkpoint the
        still-unfinished ones. Returns a ``JobRecord``."""
        from repro.sched.engine import JobRecord
        from repro.sched.planner import ScheduledJob

        track = _slice_track(slice_)
        with self.tracer.span(
            "executor.segment", cat="executor", track=track,
            job_id=seg.job_id, cids=list(seg.config_ids),
            degree=seg.degree, units=list(seg.units),
        ):
            return self._run_segment_inner(
                seg, configs_by_cid, total_steps, cfg, base_params,
                seq=seq, pool=pool, data_iter_fn=data_iter_fn, seed=seed,
                slice_=slice_, impl=impl, remat=remat,
                base_dtype=base_dtype, track=track,
                JobRecord=JobRecord, ScheduledJob=ScheduledJob,
            )

    def _run_segment_inner(
        self, seg, configs_by_cid, total_steps, cfg, base_params, *,
        seq, pool, data_iter_fn, seed, slice_, impl, remat, base_dtype,
        track, JobRecord, ScheduledJob,
    ):
        job_cfgs = [configs_by_cid[cid] for cid in seg.config_ids]
        meta = pack_meta(job_cfgs)
        lora, opt = self.pack_template(cfg, job_cfgs, seed)
        resumed_ids = [
            cid for cid, st0 in zip(seg.config_ids, seg.start_steps) if st0
        ]
        resume_cm = (
            self.tracer.span(
                "executor.resume_load", cat="executor", track=track,
                cids=resumed_ids,
            )
            if resumed_ids
            else contextlib.nullcontext()
        )
        with resume_cm:
            for slot, (cid, st0) in enumerate(
                zip(seg.config_ids, seg.start_steps)
            ):
                if st0 == 0:
                    continue
                if pool is None or not pool.has_adapter_state(f"{cid:04d}"):
                    raise RuntimeError(
                        f"segment resumes config {cid} at step {st0} but the "
                        "pool holds no checkpointed state for it"
                    )
                state, smeta = pool.load_adapter_state(f"{cid:04d}")
                assert int(smeta["steps_done"]) == st0, (cid, smeta, st0)
                lora = inject_adapter(lora, state["w"], slot)
                opt["m"] = inject_adapter(opt["m"], state["m"], slot)
                opt["v"] = inject_adapter(opt["v"], state["v"], slot)
                opt["step"] = opt["step"].at[slot].set(st0)
        budgets = np.asarray(
            [total_steps[cid] for cid in seg.config_ids], np.int32
        )
        res = self.train_pack(
            cfg,
            job_cfgs,
            n_steps=seg.run_steps,
            seq=seq,
            base=base_params,
            lora=lora,
            opt=opt,
            slice_=slice_,
            seed=seed,
            budgets=budgets,
            data_iter_fn=data_iter_fn,
            data_start_steps=seg.start_steps,
            impl=impl,
            remat=remat,
            base_dtype=base_dtype,
        )
        lora, opt, losses = res.lora, res.opt, res.losses
        done = set(seg.done_ids)
        save_cm = (
            self.tracer.span(
                "executor.checkpoint_save", cat="executor", track=track,
                cids=list(seg.config_ids),
            )
            if pool is not None
            else contextlib.nullcontext()
        )
        with save_cm:
            self._save_segment_state(
                seg, configs_by_cid, total_steps, meta, pool,
                lora, opt, losses, done,
            )
        return JobRecord(
            ScheduledJob(seg.config_ids, seg.degree, seg.start, seg.end),
            res.wall_seconds,
            losses,
            real_start=res.real_start,
            real_end=res.real_end,
        )

    def _save_segment_state(self, seg, configs_by_cid, total_steps, meta,
                            pool, lora, opt, losses, done):
        for slot, cid in enumerate(seg.config_ids):
            c = configs_by_cid[cid]
            if cid in done:
                if pool is None:
                    continue
                adapter = extract_adapter(lora, slot, meta.ranks)
                pool.save_adapter(
                    f"adapter_{cid:04d}",
                    adapter,
                    {
                        "rank": c.rank,
                        "alpha": c.alpha,
                        "learning_rate": c.learning_rate,
                        "batch_size": c.batch_size,
                        "final_loss": (
                            float(losses[slot]) if losses is not None
                            else float("nan")
                        ),
                        "total_steps": int(total_steps[cid]),
                    },
                )
            else:  # preempted mid-training: checkpoint resumable state
                assert pool is not None
                state = {
                    "w": extract_adapter(lora, slot, meta.ranks),
                    "m": extract_adapter(opt["m"], slot, meta.ranks),
                    "v": extract_adapter(opt["v"], slot, meta.ranks),
                }
                pool.save_adapter_state(
                    f"{cid:04d}",
                    state,
                    {
                        "steps_done": int(seg.start_steps[slot] + seg.run_steps),
                        "rank": c.rank,
                        "total_steps": int(total_steps[cid]),
                    },
                )

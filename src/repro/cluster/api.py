"""The runner contract: what it means to execute planned segments.

Before this module the contract was folklore — ``ClusterRunner`` defined it
implicitly, ``HostDispatcher`` duck-typed it ("duck-types as a
ClusterRunner"), and the engine's ``_execute_segments``/``_run_adaptive``
assumed it. Everything that *drives* runners (``ExecutionEngine``,
benchmarks, launch scripts) now types against :class:`Runner`, and every
implementation — :class:`~repro.cluster.runner.ClusterRunner` (thread-per-
slice, one host), :class:`~repro.cluster.multihost.HostDispatcher`
(process-per-host), :class:`~repro.serve.engine.ServeEngine` (training
segments sharing a device pool with a live decode loop) and the test fakes
in ``tests/harness.py`` — conforms to it (asserted by the conformance test
parametrized over all of them).

The protocol is ``runtime_checkable`` so ``isinstance(x, Runner)`` verifies
the *surface* (methods + attributes exist); the conformance test exercises
the semantics (dispatch order, pool accounting, records).
"""
from __future__ import annotations

from typing import (
    Any,
    Callable,
    Dict,
    Optional,
    Protocol,
    Sequence,
    runtime_checkable,
)


@runtime_checkable
class Runner(Protocol):
    """Executes planned :class:`~repro.sched.engine.JobSegment`s for real.

    Required surface:

    ``executor``
        The segment executor (``SliceExecutor``-shaped: ``run_segment`` +
        ``pack_template``). The engine's adaptive loop calls through it for
        probe segments.
    ``device_pool``
        The :class:`~repro.cluster.pool.DevicePool` backing execution.
        Device-free events the scheduler plans against come from this pool's
        real acquire/release traffic.
    ``concurrent``
        Whether segments on disjoint slices genuinely overlap in wall time
        (thread-per-slice / process-per-host) or run serially (the
        degenerate 1-device mode).
    ``run(...)``
        Execute a batch of segments and return a
        :class:`~repro.cluster.runner.ClusterResult`. Contract: segments
        dispatch in virtual ``(start, job_id)`` order; a segment blocks on
        its resume dependencies and then on its *planned* units; the pool
        must drain back to its entry free count at exit (leases held by
        others — e.g. a live serve loop — are not the runner's to release); ``estimator.observe`` is fed
        measured step times; ``impl``/``remat`` select the kernel policy for
        every segment (``None`` = capture the caller's context default).
    """

    executor: Any
    device_pool: Any
    concurrent: bool

    def run(
        self,
        segments: Sequence,  # JobSegment
        configs_by_cid: Dict,
        total_steps: Dict[int, int],
        cfg,
        base_params,
        *,
        seq: int,
        pool=None,  # CheckpointPool
        data_iter_fn: Optional[Callable] = None,
        seed: int = 0,
        estimator=None,
        impl: Optional[str] = None,
        remat: Optional[str] = None,
    ):
        ...

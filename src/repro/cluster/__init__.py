"""Concurrent mesh-slice cluster executor (paper §4 made real).

``DevicePool`` partitions the host's devices into disjoint mesh slices,
``SliceExecutor`` compile-caches one packed train step per (slice shape,
model config, pack width), and ``ClusterRunner`` drives planned segments
onto slices with thread-per-slice dispatch — so concurrent LoRA jobs
scheduled on different device groups actually overlap in wall-clock time.
"""
from repro.cluster.api import Runner
from repro.cluster.executor import NO_BUDGET, PackResult, SliceExecutor
from repro.cluster.multihost import (
    HOST_ALIVE,
    HOST_DEAD,
    HOST_DRAINING,
    HOST_SUSPECT,
    CheckpointWrite,
    DispatchExecutor,
    HealthReply,
    HeartbeatMsg,
    HostDispatcher,
    HostUnit,
    HostWorker,
    KernelPolicy,
    MemoryPool,
    RecordMsg,
    RemoteSegmentError,
    SegmentMsg,
    TraceCtx,
    TransportError,
    WorkerDied,
)
from repro.cluster.pool import (
    DevicePool,
    MeshSlice,
    assign_units,
    pick_class_units,
    pick_host_units,
)
from repro.cluster.runner import (
    ClusterResult,
    ClusterRunner,
    SegmentTiming,
    peak_overlap,
    resume_deps,
)

__all__ = [
    "Runner",
    "NO_BUDGET",
    "PackResult",
    "SliceExecutor",
    "DevicePool",
    "MeshSlice",
    "assign_units",
    "pick_class_units",
    "pick_host_units",
    "HOST_ALIVE",
    "HOST_DEAD",
    "HOST_DRAINING",
    "HOST_SUSPECT",
    "HeartbeatMsg",
    "HealthReply",
    "ClusterResult",
    "ClusterRunner",
    "SegmentTiming",
    "peak_overlap",
    "resume_deps",
    "CheckpointWrite",
    "KernelPolicy",
    "RecordMsg",
    "SegmentMsg",
    "TraceCtx",
    "DispatchExecutor",
    "HostDispatcher",
    "HostUnit",
    "HostWorker",
    "MemoryPool",
    "RemoteSegmentError",
    "TransportError",
    "WorkerDied",
]

"""repro — PLoRA: efficient LoRA hyperparameter tuning, in JAX for TPU pods."""
__version__ = "0.1.0"

"""Top-level model: embeddings, (optional) encoder, decoder stack, LM head.

Public API (all functional, params are plain pytrees):

  init_model(key, cfg, meta, dtype)          -> (base_params, lora_params)
  forward(base, lora, scales, batch, cfg, .) -> (hidden (NB,S,d), aux)
  logits(base, hidden, cfg)                  -> (NB,S,V)   [small seqs only]
  init_caches(cfg, nb, smax)                 -> cache pytree
  prefill(...)                               -> (hidden, caches, aux)
  decode_step(...)                           -> (logits (NB,1,V), caches)

The pack dim N is folded into the leading batch: every tensor is (N*B, ...).
Modality frontends are stubs per the assignment: audio/vlm batches carry
precomputed frame/patch embeddings ("frames"/"patches").
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.adapter import PackMeta
from repro.models.layers.common import apply_norm, init_linear, init_norm
from repro.models.transformer import (
    DistContext,
    LayerSpec,
    apply_stack,
    init_stack,
    init_stack_cache,
    layer_specs,
    make_rope_cache,
)


def encoder_specs(cfg: ModelConfig):
    return [
        LayerSpec(mixer="attn", ffn="dense", theta=cfg.attention.rope_theta)
        for _ in range(cfg.encoder_layers)
    ]


def init_model(key, cfg: ModelConfig, meta: Optional[PackMeta], dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    base: Dict[str, Any] = {
        "embed": {"w": jax.random.normal(ks[0], (cfg.padded_vocab, cfg.d_model), dtype) * 0.02},
        "final_norm": init_norm(cfg.d_model, cfg.norm_kind, dtype),
    }
    lora: Dict[str, Any] = {}
    dec_p, dec_l, _ = init_stack(ks[1], cfg, layer_specs(cfg), meta, dtype)
    base["decoder"] = dec_p
    lora["decoder"] = dec_l
    if not cfg.tie_embeddings:
        base["lm_head"] = init_linear(ks[2], cfg.d_model, cfg.padded_vocab, False, dtype)
    if cfg.is_encdec:
        enc_p, enc_l, _ = init_stack(ks[3], cfg, encoder_specs(cfg), meta, dtype)
        base["encoder"] = enc_p
        base["enc_norm"] = init_norm(cfg.d_model, cfg.norm_kind, dtype)
        lora["encoder"] = enc_l
    if cfg.n_patch_tokens:
        base["patch_proj"] = init_linear(ks[4], cfg.d_model, cfg.d_model, True, dtype)
    return base, lora


def _embed(base, tokens, cfg, batch):
    x = jnp.take(base["embed"]["w"], tokens, axis=0)
    if cfg.n_patch_tokens and "patches" in batch:
        pp = base["patch_proj"]
        pe = batch["patches"].astype(x.dtype) @ pp["w"].astype(x.dtype) + pp["b"].astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
    return x


def _encode(base, lora, scales, frames, cfg, *, n_pack, dist, chunk_q, kcfg=None):
    """Whisper encoder over precomputed frame embeddings (B, S_enc, d)."""
    espec = encoder_specs(cfg)
    pos = jnp.arange(frames.shape[1])
    rc = make_rope_cache(cfg, pos)
    h, _, _ = apply_stack(
        base["encoder"], lora.get("encoder", {"blocks": {}, "rest": {}}),
        scales, frames, cfg, espec,
        n_pack=n_pack, rope_cache=rc, dist=dist, chunk_q=chunk_q, causal=False,
        kcfg=kcfg,
    )
    return apply_norm(base["enc_norm"], h, cfg.norm_kind)


def forward(
    base,
    lora,
    scales,
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    n_pack: int = 1,
    dist: Optional[DistContext] = None,
    chunk_q: int = 512,
    make_cache: bool = False,
    kcfg=None,
):
    """batch: {"tokens": (NB, S)[, "frames": (NB,Se,d)][, "patches": (NB,P,d)]}.
    Returns (hidden (NB, S_total, d), caches|None, aux). ``kcfg`` is the
    static kernel policy (impl / remat / pack rank vector) every
    ``lora_linear`` below runs under."""
    tokens = batch["tokens"]
    x = _embed(base, tokens, cfg, batch)
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(
            base, lora, scales, batch["frames"].astype(x.dtype), cfg,
            n_pack=n_pack, dist=dist, chunk_q=chunk_q, kcfg=kcfg,
        )
    s_total = x.shape[1]
    positions = jnp.arange(s_total)
    rc = make_rope_cache(cfg, positions)
    specs = layer_specs(cfg)
    x, caches, aux = apply_stack(
        base["decoder"], lora.get("decoder", {"blocks": {}, "rest": {}}),
        scales, x, cfg, specs,
        n_pack=n_pack, rope_cache=rc, dist=dist, enc_out=enc_out,
        make_cache=make_cache, chunk_q=chunk_q, kcfg=kcfg,
    )
    x = apply_norm(base["final_norm"], x, cfg.norm_kind)
    return x, (caches if make_cache else None), aux


def unembed_w(base, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return base["embed"]["w"].T  # (d, V)
    return base["lm_head"]["w"]


def logits(base, hidden, cfg: ModelConfig):
    """(NB, S, padded_vocab); padded columns masked to -inf."""
    lg = hidden @ unembed_w(base, cfg).astype(hidden.dtype)
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        lg = jnp.where(mask, lg, -1e30)
    return lg


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, nb: int, smax: int, dtype=jnp.bfloat16):
    return init_stack_cache(cfg, layer_specs(cfg), nb, smax, dtype)


def decode_step(
    base,
    lora,
    scales,
    token: jnp.ndarray,  # (NB, 1) int32
    caches,
    pos,  # () int32 shared, or (NB,) int32 per-row write/attend positions
    cfg: ModelConfig,
    *,
    n_pack: int = 1,
    dist: Optional[DistContext] = None,
    enc_out=None,
    kcfg=None,
):
    """One serve step: embed token at `pos`, run stack against caches,
    return (logits (NB, 1, V), new_caches). For enc-dec models the cached
    cross-KV is used unless `enc_out` is passed explicitly."""
    x = jnp.take(base["embed"]["w"], token, axis=0)
    # scalar pos -> shared (1, D/2) tables; vector pos (NB,) -> per-row
    # (NB, 1, D/2) tables (apply_rope's per-example decode layout). A flat
    # (NB,) argument would build (NB, D/2) tables that broadcast wrongly.
    rc = make_rope_cache(cfg, pos[None] if jnp.ndim(pos) == 0 else pos[:, None])
    specs = layer_specs(cfg)
    x, new_caches, _ = apply_stack(
        base["decoder"], lora.get("decoder", {"blocks": {}, "rest": {}}),
        scales, x, cfg, specs,
        n_pack=n_pack, rope_cache=rc, dist=dist, enc_out=enc_out,
        caches=caches, pos=pos, remat=False, kcfg=kcfg,
    )
    x = apply_norm(base["final_norm"], x, cfg.norm_kind)
    return logits(base, x, cfg), new_caches


def prefill(
    base, lora, scales, batch, cfg: ModelConfig, *,
    n_pack: int = 1, dist=None, chunk_q: int = 512, kcfg=None,
):
    """Full-sequence forward that also returns the KV caches (inference
    prefill). Returns (last-position logits (NB,1,V), caches)."""
    hidden, caches, _ = forward(
        base, lora, scales, batch, cfg,
        n_pack=n_pack, dist=dist, chunk_q=chunk_q, make_cache=True,
        kcfg=kcfg,
    )
    lg = logits(base, hidden[:, -1:, :], cfg)
    return lg, caches


def prefill_chunk(
    base,
    lora,
    scales,
    tokens: jnp.ndarray,  # (NB, C) int32 — one chunk of the prompt
    caches,
    pos,  # () int32: absolute position of the chunk's first token
    cfg: ModelConfig,
    *,
    n_pack: int = 1,
    dist: Optional[DistContext] = None,
    kcfg=None,
):
    """One chunk of a chunk-resumable prefill: embed ``tokens`` at absolute
    positions ``pos + [0, C)``, run the stack against partially-filled
    ``caches`` (attention writes the chunk's K/V at ``pos`` and attends the
    whole cache under the causal/window masks; SSM resumes conv window +
    SSD state), and return (last-position logits (NB,1,V), new_caches).

    With cache capacity exactly equal to the prompt length, iterating this
    over consecutive chunks reproduces ``prefill``'s caches and final-token
    logits *bitwise* — the serve engine's interleaved-admission invariant
    (chunk boundaries commute with causal attention; for SSM stacks ``pos``
    must advance in multiples of ``cfg.ssm.chunk_size``). Encoder-decoder
    and patch-prefix (VLM) configs still require one-shot ``prefill``."""
    assert not cfg.is_encdec, "chunked prefill: enc-dec needs one-shot prefill"
    s = tokens.shape[1]
    x = jnp.take(base["embed"]["w"], tokens, axis=0)
    rc = make_rope_cache(cfg, pos + jnp.arange(s))
    specs = layer_specs(cfg)
    x, new_caches, _ = apply_stack(
        base["decoder"], lora.get("decoder", {"blocks": {}, "rest": {}}),
        scales, x, cfg, specs,
        n_pack=n_pack, rope_cache=rc, dist=dist,
        caches=caches, pos=pos, remat=False, kcfg=kcfg,
    )
    x = apply_norm(base["final_norm"], x, cfg.norm_kind)
    return logits(base, x[:, -1:, :], cfg), new_caches

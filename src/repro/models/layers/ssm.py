"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD scan: within a chunk the contribution is computed as a decay-
masked quadratic form (the "attention-like" dual); across chunks a lax.scan
carries the (H, P, N) state — O(S) time, O(chunk^2) working set, exact w.r.t.
the step recurrence (``ssd_reference`` below; tests assert allclose).

LoRA targets: "ssm_in" (z/x input projection) and "ssm_out" (output proj) —
the paper's packing applies unchanged to SSM projections (DESIGN.md §5).
Single-group (G=1) B/C, as used by both assigned SSM configs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.core.adapter import PackMeta, init_lora_pair
from repro.core.packed_lora import lora_linear
from repro.models.layers.common import apply_norm, init_linear


def init_ssm(key, d_model: int, scfg: SSMConfig, meta, targets, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    di = scfg.d_inner(d_model)
    h = scfg.n_heads(d_model)
    n, g = scfg.d_state, scfg.n_groups
    assert g == 1, "single-group SSD"
    conv_ch = di + 2 * g * n
    params = {
        "zx": init_linear(ks[0], d_model, 2 * di, False, dtype),
        "bc": init_linear(ks[1], d_model, 2 * g * n, False, dtype),
        "dt": init_linear(ks[2], d_model, h, False, dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "conv_w": jax.random.normal(ks[3], (scfg.d_conv, conv_ch), dtype) * 0.2,
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
        ).astype(dtype),
        "d_skip": jnp.ones((h,), dtype),
        "norm": {"scale": jnp.ones((di,), dtype)},
        "out": init_linear(ks[4], di, d_model, False, dtype),
    }
    lora = {}
    if meta is not None:
        if "ssm_in" in targets:
            lora["zx"] = init_lora_pair(ks[5], meta, d_model, 2 * di, dtype)
        if "ssm_out" in targets:
            lora["out"] = init_lora_pair(ks[6], meta, di, d_model, dtype)
    return params, lora


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (NB, S, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(k)
    )
    return out + b.astype(x.dtype)


def _ssd_scan(xs, b, c, dt, a_log, chunk: int, state0=None):
    """Chunked SSD. xs: (NB,S,H,P); b/c: (NB,S,N); dt: (NB,S,H) (post-softplus).
    ``state0`` resumes the recurrence mid-sequence (chunk-resumable prefill);
    None starts from zeros. Returns (y (NB,S,H,P), final_state (NB,H,P,N))."""
    nb, s, h, p = xs.shape
    n = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))  # (H,), negative
    if s % chunk:
        pad = chunk - s % chunk
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    sp = xs.shape[1]
    nc = sp // chunk
    xs_c = xs.reshape(nb, nc, chunk, h, p)
    b_c = b.reshape(nb, nc, chunk, n)
    c_c = c.reshape(nb, nc, chunk, n)
    dt_c = dt.reshape(nb, nc, chunk, h).astype(jnp.float32)
    da_c = dt_c * a  # (NB,nc,Q,H) log-decay per step

    iq = jnp.arange(chunk)
    tri = iq[:, None] >= iq[None, :]  # j <= i

    @jax.checkpoint
    def body(state, inp):
        xq, bq, cq, dtq, daq = inp  # per-chunk slices (NB, Q, ...)
        cum = jnp.cumsum(daq, axis=1)  # (NB,Q,H) inclusive
        # inter-chunk: y_i += exp(cum_i) * C_i . state_prev
        y_inter = jnp.einsum(
            "bqn,bhpn->bqhp", cq.astype(jnp.float32), state
        ) * jnp.exp(cum)[..., None]
        # intra-chunk quadratic dual
        cb = jnp.einsum(
            "bin,bjn->bij", cq.astype(jnp.float32), bq.astype(jnp.float32)
        )
        ldiff = cum[:, :, None, :] - cum[:, None, :, :]  # (NB,i,j,H)
        l_mat = jnp.exp(jnp.where(tri[None, :, :, None], ldiff, -jnp.inf))
        m = cb[:, :, :, None] * l_mat * dtq[:, None, :, :]  # (NB,i,j,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", m, xs_f(xq))
        # state update
        last = cum[:, -1:, :]  # (NB,1,H)
        decay_rem = jnp.exp(last - cum)  # (NB,Q,H)
        new_state = state * jnp.exp(last)[:, 0, :, None, None] + jnp.einsum(
            "bqh,bqn,bqhp->bhpn", dtq * decay_rem, bq.astype(jnp.float32), xs_f(xq)
        )
        return new_state, y_inter + y_intra

    def xs_f(xq):
        return xq.astype(jnp.float32)

    if state0 is None:
        state0 = jnp.zeros((nb, h, p, n), jnp.float32)
    else:
        state0 = state0.astype(jnp.float32)
    # scan over chunks: move chunk axis to front
    inps = (
        jnp.moveaxis(xs_c, 1, 0),
        jnp.moveaxis(b_c, 1, 0),
        jnp.moveaxis(c_c, 1, 0),
        jnp.moveaxis(dt_c, 1, 0),
        jnp.moveaxis(da_c, 1, 0),
    )
    final_state, ys = jax.lax.scan(body, state0, inps)
    y = jnp.moveaxis(ys, 0, 1).reshape(nb, sp, h, p)[:, :s]
    return y.astype(xs.dtype), final_state


def apply_ssm(
    params,
    lora,
    scales,
    x,
    *,
    scfg: SSMConfig,
    n_pack: int = 1,
    return_state: bool = False,
    kcfg=None,
):
    """Full-sequence SSD block. x: (NB, S, d). Returns (out, cache|None)."""
    lo = lora or {}
    nb, s, d = x.shape
    di = scfg.d_inner(d)
    h = scfg.n_heads(d)
    n = scfg.d_state
    zx = lora_linear(x, params["zx"], lo.get("zx"), scales, n_pack, kcfg=kcfg)
    z, xs = zx[..., :di], zx[..., di:]
    bc = x @ params["bc"]["w"].astype(x.dtype)
    dt_raw = x @ params["dt"]["w"].astype(x.dtype) + params["dt_bias"].astype(x.dtype)

    conv_in = jnp.concatenate([xs, bc], -1)
    conv = jax.nn.silu(_causal_conv(conv_in, params["conv_w"], params["conv_b"]))
    xs, b, c = conv[..., :di], conv[..., di : di + n], conv[..., di + n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32))

    y, state = _ssd_scan(
        xs.reshape(nb, s, h, -1), b, c, dt, params["a_log"], scfg.chunk_size
    )
    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] * xs.reshape(
        nb, s, h, -1
    )
    y = y.reshape(nb, s, di)
    y = apply_norm(params["norm"], y * jax.nn.silu(z), "rmsnorm")
    out = lora_linear(y, params["out"], lo.get("out"), scales, n_pack, kcfg=kcfg)
    cache = None
    if return_state:
        cache = {
            "conv": conv_in[:, -(scfg.d_conv - 1) :, :],
            "state": state,
        }
    return out, cache


def apply_ssm_chunk(params, lora, scales, x, cache, *, scfg: SSMConfig, n_pack=1, kcfg=None):
    """Chunk-resumable prefill step. x: (NB, S, d) with S > 1; cache:
    {conv (NB,K-1,C), state (NB,H,P,N)} as produced by ``apply_ssm``/this.

    Matches the one-shot ``apply_ssm`` bitwise as long as every resume
    boundary falls on a multiple of ``scfg.chunk_size`` (the SSD sub-chunk
    grid must line up — the engine rounds its ``prefill_chunk`` up to that);
    the conv window is replayed from the cached K-1 trailing inputs, and the
    SSD scan resumes from the cached state via ``_ssd_scan(state0=...)``."""
    lo = lora or {}
    nb, s, d = x.shape
    di = scfg.d_inner(d)
    h = scfg.n_heads(d)
    n = scfg.d_state
    k = scfg.d_conv
    zx = lora_linear(x, params["zx"], lo.get("zx"), scales, n_pack, kcfg=kcfg)
    z, xs = zx[..., :di], zx[..., di:]
    bc = x @ params["bc"]["w"].astype(x.dtype)
    dt_raw = x @ params["dt"]["w"].astype(x.dtype) + params["dt_bias"].astype(x.dtype)

    conv_in = jnp.concatenate([xs, bc], -1)  # (NB,S,C)
    win = jnp.concatenate([cache["conv"].astype(conv_in.dtype), conv_in], 1)
    # _causal_conv zero-pads K-1 on the left; dropping those first K-1
    # outputs leaves exactly the chunk's positions, each computed over the
    # true trailing window (cached rows stand in for the left pad)
    conv = jax.nn.silu(
        _causal_conv(win, params["conv_w"], params["conv_b"])[:, k - 1 :]
    )
    xs, b, c = conv[..., :di], conv[..., di : di + n], conv[..., di + n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32))

    y, state = _ssd_scan(
        xs.reshape(nb, s, h, -1), b, c, dt, params["a_log"],
        scfg.chunk_size, state0=cache["state"],
    )
    y = y + params["d_skip"].astype(y.dtype)[None, None, :, None] * xs.reshape(
        nb, s, h, -1
    )
    y = y.reshape(nb, s, di)
    y = apply_norm(params["norm"], y * jax.nn.silu(z), "rmsnorm")
    out = lora_linear(y, params["out"], lo.get("out"), scales, n_pack, kcfg=kcfg)
    return out, {"conv": win[:, -(k - 1) :], "state": state}


def apply_ssm_decode(params, lora, scales, x, cache, *, scfg: SSMConfig, n_pack=1, kcfg=None):
    """One-token step. x: (NB, 1, d); cache: {conv (NB,K-1,C), state (NB,H,P,N)}."""
    lo = lora or {}
    nb, _, d = x.shape
    di = scfg.d_inner(d)
    h = scfg.n_heads(d)
    n = scfg.d_state
    zx = lora_linear(x, params["zx"], lo.get("zx"), scales, n_pack, kcfg=kcfg)
    z, xs = zx[..., :di], zx[..., di:]
    bc = x @ params["bc"]["w"].astype(x.dtype)
    dt_raw = x @ params["dt"]["w"].astype(x.dtype) + params["dt_bias"].astype(x.dtype)

    conv_in = jnp.concatenate([xs, bc], -1)  # (NB,1,C)
    win = jnp.concatenate([cache["conv"], conv_in], 1)  # (NB,K,C)
    conv = jnp.einsum("bkc,kc->bc", win, params["conv_w"].astype(win.dtype))
    conv = jax.nn.silu(conv + params["conv_b"].astype(conv.dtype))
    xs1, b1, c1 = conv[..., :di], conv[..., di : di + n], conv[..., di + n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32))[:, 0]  # (NB,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)  # (NB,H)
    xh = xs1.reshape(nb, h, -1).astype(jnp.float32)
    state = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, b1.astype(jnp.float32), xh
    )
    y = jnp.einsum("bn,bhpn->bhp", c1.astype(jnp.float32), state)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(nb, 1, di).astype(x.dtype)
    y = apply_norm(params["norm"], y * jax.nn.silu(z), "rmsnorm")
    out = lora_linear(y, params["out"], lo.get("out"), scales, n_pack, kcfg=kcfg)
    return out, {"conv": win[:, 1:], "state": state}


def init_ssm_cache(nb, d_model: int, scfg: SSMConfig, dtype=jnp.float32):
    di = scfg.d_inner(d_model)
    h = scfg.n_heads(d_model)
    conv_ch = di + 2 * scfg.n_groups * scfg.d_state
    return {
        "conv": jnp.zeros((nb, scfg.d_conv - 1, conv_ch), dtype),
        "state": jnp.zeros((nb, h, scfg.head_dim, scfg.d_state), jnp.float32),
    }


def ssd_reference(xs, b, c, dt, a_log):
    """Naive step recurrence oracle (tests only). Same inputs as _ssd_scan."""
    nb, s, h, p = xs.shape
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(state, inp):
        xt, bt, ct, dtt = inp
        decay = jnp.exp(dtt * a)  # (NB,H)
        state = state * decay[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dtt, bt, xt
        )
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    state0 = jnp.zeros((nb, h, p, b.shape[-1]), jnp.float32)
    xs_t = jnp.moveaxis(xs.astype(jnp.float32), 1, 0)
    _, ys = jax.lax.scan(
        step,
        state0,
        (
            xs_t,
            jnp.moveaxis(b.astype(jnp.float32), 1, 0),
            jnp.moveaxis(c.astype(jnp.float32), 1, 0),
            jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        ),
    )
    return jnp.moveaxis(ys, 0, 1)

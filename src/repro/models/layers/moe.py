"""Mixture-of-Experts FFN: dense (exact oracle) and expert-parallel paths.

``impl="dense"`` computes every expert for every token and combines with the
top-k gate weights — exact, used for smoke tests / small E, and for Grok-1
whose E=8 does not divide the mandated 16-way model axis (weights are then
FSDP-sharded over data x model; see DESIGN.md §4).

``impl="ep"`` is the production path: activations are replicated across the
model axis (they already are, under megatron-style TP), experts are sharded
over it, and each shard routes the full local token set to its own experts
with a capacity-bounded sort-based dispatch (no giant one-hot). Partial
outputs are combined with a single psum over the model axis — the same
collective cost as a TP MLP. Requires E % model_axis == 0.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers.common import init_linear


def init_moe(key, d_model: int, mcfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    e, f = mcfg.n_experts, mcfg.d_expert
    s = d_model**-0.5
    return {
        "router": init_linear(ks[0], d_model, e, False, jnp.float32),
        "w_gate": jax.random.normal(ks[1], (e, d_model, f), dtype) * s,
        "w_up": jax.random.normal(ks[2], (e, d_model, f), dtype) * s,
        "w_down": jax.random.normal(ks[3], (e, f, d_model), dtype) * (f**-0.5),
    }


def _router(x, params, mcfg: MoEConfig):
    """x: (T, d) -> (gates (T, k) normalized, idx (T, k), aux load-balance loss)."""
    logits = (x.astype(jnp.float32)) @ params["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, mcfg.top_k)
    gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
    # Switch-style load-balance aux loss: E * sum_e f_e * p_e
    e = mcfg.n_experts
    me = probs.mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (
        idx.size + 1e-9
    )
    aux = e * jnp.sum(me * ce)
    return gates, idx, aux


def _expert_ffn(w_gate, w_up, w_down, h):
    """h: (E_loc, C, d) -> (E_loc, C, d), SwiGLU experts via batched GEMM."""
    g = jnp.einsum("ecd,edf->ecf", h, w_gate.astype(h.dtype))
    u = jnp.einsum("ecd,edf->ecf", h, w_up.astype(h.dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down.astype(h.dtype))


def _moe_dense(params, x, mcfg: MoEConfig, chunk: int = 1024):
    """All-experts compute, gate-weighted combine; token-chunked so the
    (chunk, E, f) intermediate stays small. FLOPs are E/top_k x the routed
    cost — acceptable for small E (Grok-1 E=8) and exact for testing."""
    t, d = x.shape
    gates, idx, aux = _router(x, params, mcfg)
    comb = jnp.zeros((t, mcfg.n_experts), x.dtype)
    comb = comb.at[jnp.arange(t)[:, None], idx].set(gates.astype(x.dtype))

    def one_chunk(args):
        xc, cc = args  # (c, d), (c, E)
        g = jnp.einsum("td,edf->etf", xc, params["w_gate"].astype(xc.dtype))
        u = jnp.einsum("td,edf->etf", xc, params["w_up"].astype(xc.dtype))
        h = jax.nn.silu(g) * u
        # fold gate weight in before the down projection
        return jnp.einsum(
            "etf,efd,te->td", h, params["w_down"].astype(xc.dtype), cc
        )

    if t <= chunk:
        y = one_chunk((x, comb))
    else:
        n = t // chunk
        pad = n * chunk < t
        if pad:
            n += 1
            xpad = jnp.pad(x, ((0, n * chunk - t), (0, 0)))
            cpad = jnp.pad(comb, ((0, n * chunk - t), (0, 0)))
        else:
            xpad, cpad = x, comb
        y = jax.lax.map(
            jax.checkpoint(one_chunk),
            (xpad.reshape(n, chunk, d), cpad.reshape(n, chunk, -1)),
        ).reshape(n * chunk, d)[:t]
    return y, aux


def _moe_ep_local(params_local, x, mcfg: MoEConfig, e_lo, e_local: int, capacity: int):
    """Process the local expert slice [e_lo, e_lo + e_local) for all local
    tokens; returns this shard's partial output (psum'd by the caller)."""
    t, d = x.shape
    gates, idx, aux = _router(x, params_local, mcfg)
    k = mcfg.top_k
    flat_e = idx.reshape(-1)  # (T*k,)
    flat_g = gates.reshape(-1)
    tok_of = jnp.repeat(jnp.arange(t), k)

    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    counts = jnp.zeros((mcfg.n_experts,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[se]

    local = (se >= e_lo) & (se < e_lo + e_local) & (pos_in_e < capacity)
    slot = jnp.where(local, (se - e_lo) * capacity + pos_in_e, e_local * capacity)
    buf = jnp.zeros((e_local * capacity + 1, d), x.dtype).at[slot].set(x[tok_of[order]])
    h = buf[:-1].reshape(e_local, capacity, d)
    y_e = _expert_ffn(
        params_local["w_gate"], params_local["w_up"], params_local["w_down"], h
    ).reshape(e_local * capacity, d)
    y_e = jnp.concatenate([y_e, jnp.zeros((1, d), y_e.dtype)], 0)
    contrib = y_e[slot] * (flat_g[order] * local.astype(jnp.float32)).astype(
        y_e.dtype
    )[:, None]
    y = jnp.zeros((t, d), x.dtype).at[tok_of[order]].add(contrib)
    return y, aux


def moe_capacity(t: int, mcfg: MoEConfig) -> int:
    c = int(t * mcfg.top_k / mcfg.n_experts * mcfg.capacity_factor) + 1
    return max(8, min(c, t))


def apply_moe(
    params,
    x: jnp.ndarray,
    mcfg: MoEConfig,
    *,
    model_axis: Optional[str] = None,
    model_axis_size: int = 1,
):
    """x: (NB, S, d) -> (y, aux_loss).

    When called inside shard_map with ``model_axis`` set, params hold only the
    local expert slice and the partial outputs are psum'd over the axis.
    Outside shard_map (CPU tests), all experts are local.
    """
    nb, s, d = x.shape
    xt = x.reshape(nb * s, d)
    if mcfg.impl == "dense":
        y, aux = _moe_dense(params, xt, mcfg)
        if model_axis is not None:
            aux = jax.lax.pmean(aux, model_axis)
    else:
        e_local = mcfg.n_experts // max(model_axis_size, 1)
        cap = moe_capacity(nb * s, mcfg)
        if model_axis is not None:
            e_lo = jax.lax.axis_index(model_axis) * e_local
            y, aux = _moe_ep_local(params, xt, mcfg, e_lo, e_local, cap)
            y = jax.lax.psum(y, model_axis)
            aux = jax.lax.pmean(aux, model_axis)
        else:
            y, aux = _moe_ep_local(params, xt, mcfg, 0, mcfg.n_experts, cap)
    return y.reshape(nb, s, d), aux

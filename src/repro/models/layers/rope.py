"""Rotary position embeddings, including dual-theta (Gemma-3 local/global)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_tables(positions: jnp.ndarray, dim: int, theta: float):
    """cos/sin tables for given positions. positions: (...,) int; dim even."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs (split-half convention).

    x: (..., S, H, D). cos/sin: (S, D/2) shared across batch, or
    (B, S, D/2) per-example (decode). A head axis is inserted at -2 and
    leading axes broadcast.
    """
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = jnp.expand_dims(cos, -2)  # (..., S, 1, D/2)
    s = jnp.expand_dims(sin, -2)
    while c.ndim < x1.ndim:
        c, s = c[None], s[None]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)

"""Attention: GQA (with sliding window) and MLA, prefill/train + cached decode.

Memory-safe attention for long sequences: the scores matrix is never
materialized for the full sequence — we scan over query chunks (flash-style)
with the chunk body rematerialized in the backward pass. When a *static*
sliding window is set, key/value are sliced to the reachable band per query
chunk, so local-attention layers (Gemma-3) get sub-quadratic FLOPs, not just
masking.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.core.adapter import PackMeta, init_lora_pair
from repro.core.packed_lora import lora_linear
from repro.models.layers.common import init_linear
from repro.models.layers.rope import apply_rope, rope_tables

# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------


def _attend_chunk(q, k, v, qpos, kpos, causal, window, scale):
    """q: (B, cq, H, D); k/v: (B, Sk, KV, Dk/Dv); returns (B, cq, H, Dv)."""
    b, cq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, cq, kv, g, d)
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    ) * scale
    mask = jnp.ones((cq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(b, cq, h, v.shape[-1])


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    chunk_q: int = 512,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Chunked attention. q: (B, Sq, H, D); k/v: (B, Sk, KV, D*).

    Scans over query chunks; with a static ``window`` the K/V band is sliced
    per chunk (sub-quadratic local attention). Bodies are ``jax.checkpoint``ed
    so the backward pass re-materializes per-chunk scores.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else d**-0.5
    kpos = jnp.arange(sk)

    if sq <= chunk_q:
        qpos = q_offset + jnp.arange(sq)
        return _attend_chunk(q, k, v, qpos, kpos, causal, window, scale)

    # pad queries to a chunk multiple (padding rows are sliced off at the
    # end; they never influence real outputs)
    sq_pad = (-sq) % chunk_q
    if sq_pad:
        q = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0), (0, 0)))
    sq_p = sq + sq_pad
    n_chunks = sq_p // chunk_q
    qc = q.reshape(b, n_chunks, chunk_q, h, d)

    if window and causal:
        # band slice: queries in chunk c reach keys in
        # [c*chunk_q - window + 1, c*chunk_q + chunk_q). Pad K/V on the left
        # so every chunk reads a fixed-size band of length window+chunk_q.
        band = window + chunk_q
        pad = window
        kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        kpos_p = jnp.concatenate([jnp.full((pad,), -(10**9)), kpos])

        @jax.checkpoint
        def body(_, c):
            start = c * chunk_q  # band start in padded coords
            kb = jax.lax.dynamic_slice_in_dim(kp, start, band, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vp, start, band, axis=1)
            kpb = jax.lax.dynamic_slice_in_dim(kpos_p, start, band, axis=0)
            qpos = q_offset + c * chunk_q + jnp.arange(chunk_q)
            o = _attend_chunk(qc[:, c], kb, vb, qpos, kpb, causal, window, scale)
            return None, o

        _, outs = jax.lax.scan(body, None, jnp.arange(n_chunks))
    else:

        @jax.checkpoint
        def body(_, c):
            qpos = q_offset + c * chunk_q + jnp.arange(chunk_q)
            o = _attend_chunk(qc[:, c], k, v, qpos, kpos, causal, window, scale)
            return None, o

        _, outs = jax.lax.scan(body, None, jnp.arange(n_chunks))
    # outs: (n_chunks, B, cq, H, D) -> (B, Sq, H, D)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq_p, h, v.shape[-1])
    return out[:, :sq] if sq_pad else out


def decode_attention(q, k, v, pos, *, window: int = 0, scale=None):
    """Single-step attention against a cache. q: (B, 1, H, D);
    k/v: (B, Smax, KV, D*); pos: () shared position, or (B,) per-row
    positions (continuous batching — each row is its own request). Cache
    entries beyond a row's position are masked."""
    b, _, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = scale if scale is not None else d**-0.5
    kpos = jnp.arange(k.shape[1])
    qg = q.reshape(b, kv, g, d)
    scores = jnp.einsum(
        "bhgd,bkhd->bhgk", qg, k, preferred_element_type=jnp.float32
    ) * scale
    if jnp.ndim(pos) > 0:
        mask = kpos[None, :] <= pos[:, None]  # (B, Smax)
        if window:
            mask &= (pos[:, None] - kpos[None, :]) < window
        scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    else:
        mask = kpos <= pos
        if window:
            mask &= (pos - kpos) < window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v.dtype), v)
    return out.reshape(b, 1, h, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------


def init_gqa(key, acfg: AttentionConfig, d_model, meta, targets, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    h, kv, hd = acfg.n_heads, acfg.n_kv_heads, acfg.head_dim
    params = {
        "q": init_linear(ks[0], d_model, h * hd, acfg.use_bias, dtype),
        "k": init_linear(ks[1], d_model, kv * hd, acfg.use_bias, dtype),
        "v": init_linear(ks[2], d_model, kv * hd, acfg.use_bias, dtype),
        "o": init_linear(ks[3], h * hd, d_model, False, dtype),
    }
    lora = {}
    if meta is not None:
        for i, nm in enumerate(("q", "k", "v", "o")):
            if nm in targets:
                d_in, d_out = params[nm]["w"].shape
                lora[nm] = init_lora_pair(ks[4 + i], meta, d_in, d_out, dtype)
    return params, lora


def apply_gqa(
    params,
    lora,
    scales,
    x,
    *,
    acfg: AttentionConfig,
    n_pack: int,
    rope: Optional[Tuple[jnp.ndarray, jnp.ndarray]],
    window: int = 0,
    causal: bool = True,
    cache: Optional[dict] = None,
    pos=None,
    cross_kv: Optional[dict] = None,
    make_cache: bool = False,
    chunk_q: int = 512,
    kcfg=None,
):
    """x: (NB, S, d). Returns (out, new_cache_or_None)."""
    lo = lora or {}
    nb, s, _ = x.shape
    h, kvh, hd = acfg.n_heads, acfg.n_kv_heads, acfg.head_dim
    q = lora_linear(x, params["q"], lo.get("q"), scales, n_pack, kcfg=kcfg).reshape(nb, s, h, hd)

    if cross_kv is not None:
        k, v = cross_kv["k"], cross_kv["v"]
        out = flash_attention(q, k, v, causal=False, chunk_q=chunk_q)
        new_cache = None
    else:
        k = lora_linear(x, params["k"], lo.get("k"), scales, n_pack, kcfg=kcfg)
        v = lora_linear(x, params["v"], lo.get("v"), scales, n_pack, kcfg=kcfg)
        k = k.reshape(nb, s, kvh, hd)
        v = v.reshape(nb, s, kvh, hd)
        if rope is not None:
            cos, sin = rope
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        if cache is not None:
            # decode / chunked prefill: write this step's (or chunk's) k/v at
            # `pos`, attend to <= pos. Vector pos = per-row positions: each
            # row writes at its own slot (single-token decode only).
            if jnp.ndim(pos) > 0:
                assert s == 1, "vector pos requires single-token decode"
                row_upd = jax.vmap(
                    lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0, 0))
                )
                ck = row_upd(cache["k"], k.astype(cache["k"].dtype), pos)
                cv = row_upd(cache["v"], v.astype(cache["v"].dtype), pos)
            else:
                ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
                cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
            if s == 1:
                out = decode_attention(q, ck, cv, pos, window=window)
            else:
                # chunk-resumable prefill: the chunk's queries attend the
                # whole cache under the same causal/window masks as the
                # one-shot path — with cache capacity == s_total the shapes
                # match flash_attention's single-chunk body exactly, so the
                # outputs are bitwise identical (tests/test_serve_engine.py)
                out = _attend_chunk(
                    q, ck, cv, pos + jnp.arange(s),
                    jnp.arange(ck.shape[1]), causal, window, hd**-0.5,
                )
            new_cache = {"k": ck, "v": cv}
        else:
            out = flash_attention(
                q, k, v, causal=causal, window=window, chunk_q=chunk_q
            )
            new_cache = {"k": k, "v": v} if make_cache else None

    out = out.reshape(nb, s, h * hd)
    out = lora_linear(out, params["o"], lo.get("o"), scales, n_pack, kcfg=kcfg)
    return out, new_cache


def init_gqa_cache(nb, smax, acfg: AttentionConfig, dtype=jnp.bfloat16):
    kv, hd = acfg.n_kv_heads, acfg.head_dim
    return {
        "k": jnp.zeros((nb, smax, kv, hd), dtype),
        "v": jnp.zeros((nb, smax, kv, hd), dtype),
    }


# ---------------------------------------------------------------------------
# MLA block (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------


def init_mla(key, acfg: AttentionConfig, d_model, meta, targets, dtype=jnp.float32):
    ks = jax.random.split(key, 10)
    h = acfg.n_heads
    qlr, kvlr = acfg.q_lora_rank, acfg.kv_lora_rank
    dn, dr, dv = acfg.qk_nope_head_dim, acfg.qk_rope_head_dim, acfg.v_head_dim
    params = {
        "q_a": init_linear(ks[0], d_model, qlr, False, dtype),
        "q_norm": {"scale": jnp.ones((qlr,), dtype)},
        "q_b": init_linear(ks[1], qlr, h * (dn + dr), False, dtype),
        "kv_a": init_linear(ks[2], d_model, kvlr + dr, False, dtype),
        "kv_norm": {"scale": jnp.ones((kvlr,), dtype)},
        "kv_b_k": init_linear(ks[3], kvlr, h * dn, False, dtype),
        "kv_b_v": init_linear(ks[4], kvlr, h * dv, False, dtype),
        "o": init_linear(ks[5], h * dv, d_model, False, dtype),
    }
    lora = {}
    if meta is not None:
        tmap = {"q": "q_a", "kv": "kv_a", "o": "o"}
        for i, (t, pname) in enumerate(tmap.items()):
            if t in targets:
                d_in, d_out = params[pname]["w"].shape
                lora[pname] = init_lora_pair(ks[6 + i], meta, d_in, d_out, dtype)
    return params, lora


def _mla_qkv(params, lo, scales, x, n_pack, acfg, rope, kcfg=None):
    """Shared projections for the MLA train/prefill path."""
    from repro.models.layers.common import apply_norm

    nb, s, _ = x.shape
    h = acfg.n_heads
    dn, dr, dv = acfg.qk_nope_head_dim, acfg.qk_rope_head_dim, acfg.v_head_dim
    cos, sin = rope
    cq = lora_linear(x, params["q_a"], lo.get("q_a"), scales, n_pack, kcfg=kcfg)
    cq = apply_norm(params["q_norm"], cq, "rmsnorm")
    q = lora_linear(cq, params["q_b"], None, scales, n_pack, kcfg=kcfg).reshape(nb, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, cos, sin)

    ckv_full = lora_linear(x, params["kv_a"], lo.get("kv_a"), scales, n_pack, kcfg=kcfg)
    ckv, k_rope = ckv_full[..., : acfg.kv_lora_rank], ckv_full[..., acfg.kv_lora_rank :]
    ckv = apply_norm(params["kv_norm"], ckv, "rmsnorm")
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # (NB,S,1,dr)
    return q_nope, q_rope, ckv, k_rope


def apply_mla(
    params,
    lora,
    scales,
    x,
    *,
    acfg: AttentionConfig,
    n_pack: int,
    rope,
    cache: Optional[dict] = None,
    pos=None,
    make_cache: bool = False,
    chunk_q: int = 512,
    kcfg=None,
):
    lo = lora or {}
    nb, s, _ = x.shape
    h = acfg.n_heads
    dn, dr, dv = acfg.qk_nope_head_dim, acfg.qk_rope_head_dim, acfg.v_head_dim
    scale = (dn + dr) ** -0.5
    q_nope, q_rope, ckv, k_rope = _mla_qkv(params, lo, scales, x, n_pack, acfg, rope, kcfg)

    if cache is None:
        # train/prefill: expand compressed KV to per-head K/V
        k_nope = (ckv @ params["kv_b_k"]["w"].astype(ckv.dtype)).reshape(nb, s, h, dn)
        v = (ckv @ params["kv_b_v"]["w"].astype(ckv.dtype)).reshape(nb, s, h, dv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (nb, s, h, dr))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        out = flash_attention(q, k, v, causal=True, chunk_q=chunk_q, scale=scale)
        new_cache = {"ckv": ckv, "k_rope": k_rope[:, :, 0, :]} if make_cache else None
    else:
        # absorbed decode: score against the compressed cache directly.
        # Vector pos = per-row positions (continuous batching).
        if jnp.ndim(pos) > 0:
            assert s == 1, "vector pos requires single-token decode"
            row_upd = jax.vmap(
                lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0))
            )
            ckv_c = row_upd(cache["ckv"], ckv.astype(cache["ckv"].dtype), pos)
            kr_c = row_upd(
                cache["k_rope"],
                k_rope[:, :, 0, :].astype(cache["k_rope"].dtype), pos,
            )
        else:
            ckv_c = jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, pos, 0)
            )
            kr_c = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope[:, :, 0, :].astype(cache["k_rope"].dtype), (0, pos, 0)
            )
        if s > 1:
            # chunk-resumable prefill: the absorbed decode formulation is
            # algebraically but NOT bitwise equal to the prefill path, so
            # chunks expand the full compressed cache through kv_b_k/kv_b_v
            # exactly like the train/prefill branch — with cache capacity ==
            # s_total every shape (and therefore every bit) matches one-shot
            sc = ckv_c.shape[1]
            k_nope = (
                ckv_c @ params["kv_b_k"]["w"].astype(ckv_c.dtype)
            ).reshape(nb, sc, h, dn)
            vc = (
                ckv_c @ params["kv_b_v"]["w"].astype(ckv_c.dtype)
            ).reshape(nb, sc, h, dv)
            kc = jnp.concatenate(
                [k_nope, jnp.broadcast_to(kr_c[:, :, None, :], (nb, sc, h, dr))],
                -1,
            )
            q = jnp.concatenate([q_nope, q_rope], -1)
            out = _attend_chunk(
                q, kc, vc, pos + jnp.arange(s), jnp.arange(sc),
                True, 0, scale,
            )
            out = lora_linear(
                out.reshape(nb, s, h * dv), params["o"], lo.get("o"),
                scales, n_pack, kcfg=kcfg,
            )
            return out, {"ckv": ckv_c, "k_rope": kr_c}
        wk = params["kv_b_k"]["w"].reshape(acfg.kv_lora_rank, h, dn)
        # absorb W_uk into q: (NB,1,H,dn) x (kvlr,H,dn) -> (NB,H,kvlr)
        q_abs = jnp.einsum("bshd,rhd->bhr", q_nope, wk.astype(q_nope.dtype))
        s1 = jnp.einsum(
            "bhr,bkr->bhk", q_abs, ckv_c.astype(q_abs.dtype),
            preferred_element_type=jnp.float32,
        )
        s2 = jnp.einsum(
            "bshd,bkd->bhk", q_rope, kr_c.astype(q_rope.dtype),
            preferred_element_type=jnp.float32,
        )
        scores = (s1 + s2) * scale
        kpos = jnp.arange(ckv_c.shape[1])
        if jnp.ndim(pos) > 0:
            scores = jnp.where(
                (kpos[None, :] <= pos[:, None])[:, None, :], scores, -1e30
            )
        else:
            scores = jnp.where((kpos <= pos)[None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        # attend in compressed space then expand through W_uv
        ctx = jnp.einsum("bhk,bkr->bhr", p.astype(ckv_c.dtype), ckv_c)
        wv = params["kv_b_v"]["w"].reshape(acfg.kv_lora_rank, h, dv)
        out = jnp.einsum("bhr,rhd->bhd", ctx, wv.astype(ctx.dtype))[:, None]
        new_cache = {"ckv": ckv_c, "k_rope": kr_c}

    out = out.reshape(nb, s, h * dv)
    out = lora_linear(out, params["o"], lo.get("o"), scales, n_pack, kcfg=kcfg)
    return out, new_cache


def init_mla_cache(nb, smax, acfg: AttentionConfig, dtype=jnp.bfloat16):
    return {
        "ckv": jnp.zeros((nb, smax, acfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((nb, smax, acfg.qk_rope_head_dim), dtype),
    }

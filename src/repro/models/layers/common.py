"""Shared layer primitives: norms, MLPs, linear init."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.adapter import PackMeta, init_lora_pair
from repro.core.packed_lora import lora_linear


def init_linear(key, d_in: int, d_out: int, bias: bool, dtype=jnp.float32) -> dict:
    w = jax.random.normal(key, (d_in, d_out), dtype) * (d_in**-0.5)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def init_norm(d: int, kind: str, dtype=jnp.float32) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: dict, x: jnp.ndarray, kind: str, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = (xf**2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants. "swiglu": gate/up/down; "gelu": gated-gelu (geglu);
# "gelu2": classic two-matrix up -> gelu -> down (starcoder2/whisper).
# ---------------------------------------------------------------------------


def init_mlp(
    key,
    d_model: int,
    d_ff: int,
    kind: str,
    bias: bool,
    meta: Optional[PackMeta],
    targets,
    dtype=jnp.float32,
):
    ks = jax.random.split(key, 6)
    params, lora = {}, {}
    if kind == "gelu2":
        params["up"] = init_linear(ks[0], d_model, d_ff, bias, dtype)
        params["down"] = init_linear(ks[1], d_ff, d_model, bias, dtype)
    else:
        params["gate"] = init_linear(ks[0], d_model, d_ff, bias, dtype)
        params["up"] = init_linear(ks[1], d_model, d_ff, bias, dtype)
        params["down"] = init_linear(ks[2], d_ff, d_model, bias, dtype)
    if meta is not None:
        names = ["up", "down"] if kind == "gelu2" else ["gate", "up", "down"]
        for i, nm in enumerate(names):
            if nm in targets:
                d_in, d_out = params[nm]["w"].shape
                lora[nm] = init_lora_pair(ks[3 + i], meta, d_in, d_out, dtype)
    return params, lora


def apply_mlp(params, lora, scales, x, kind: str, n_pack: int = 1, kcfg=None):
    lo = lora or {}
    if kind == "gelu2":
        h = lora_linear(x, params["up"], lo.get("up"), scales, n_pack, kcfg=kcfg)
        h = jax.nn.gelu(h)
        return lora_linear(h, params["down"], lo.get("down"), scales, n_pack, kcfg=kcfg)
    g = lora_linear(x, params["gate"], lo.get("gate"), scales, n_pack, kcfg=kcfg)
    u = lora_linear(x, params["up"], lo.get("up"), scales, n_pack, kcfg=kcfg)
    act = jax.nn.gelu(g) if kind == "gelu" else jax.nn.silu(g)
    return lora_linear(act * u, params["down"], lo.get("down"), scales, n_pack, kcfg=kcfg)

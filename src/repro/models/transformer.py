"""Composable decoder (+ optional encoder) stack over all assigned families.

Layers are grouped into *pattern blocks*: the per-layer spec sequence (mixer
kind, ffn kind, window, rope theta, cross-attn) has a minimal period p; the
stack is a ``lax.scan`` over L//p stacked blocks (compile-time O(p) at 512
devices) plus an unstacked remainder prefix (L % p layers, e.g. Gemma-3's
26 = 4*6 + 2). Every per-position spec inside a block body is static, so
sliding-window layers get the sub-quadratic sliced-band attention path and
hybrid (Jamba) blocks mix SSD and attention sublayers without traced
branching. Block bodies are rematerialized (jax.checkpoint).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.adapter import PackMeta
from repro.models.layers.attention import (
    apply_gqa,
    apply_mla,
    init_gqa,
    init_gqa_cache,
    init_mla,
    init_mla_cache,
)
from repro.models.layers.common import apply_mlp, apply_norm, init_linear, init_mlp, init_norm
from repro.models.layers.moe import apply_moe, init_moe
from repro.models.layers.rope import rope_tables
from repro.models.layers.ssm import (
    apply_ssm,
    apply_ssm_chunk,
    apply_ssm_decode,
    init_ssm,
    init_ssm_cache,
)


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` appeared (with ``check_vma``) after 0.4.x; older
    releases ship ``jax.experimental.shard_map`` whose equivalent knob is
    ``check_rep``. One entry point so the EP path runs on both."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


@dataclass(frozen=True)
class LayerSpec:
    mixer: str  # "attn" | "ssm"
    ffn: str  # "dense" | "moe" | "none"
    window: int = 0
    theta: float = 10_000.0
    cross: bool = False  # whisper decoder cross-attention sublayer


@dataclass(frozen=True)
class DistContext:
    """Distribution info threaded through layers (None on single device)."""

    mesh: Any = None
    data_axes: Tuple[str, ...] = ()
    model_axis: Optional[str] = None
    model_axis_size: int = 1
    # Sequence-parallel residuals (beyond-paper §Perf optimization): constrain
    # the inter-block hidden state to be sharded over the model axis on the
    # sequence dim. XLA then converts the megatron all-reduce pairs into
    # all-gather + reduce-scatter (same wire bytes) and — the point — the
    # lax.scan residual carry stack saved for backward shrinks by the TP
    # degree. Applies to train/prefill (S >= model_axis_size); decode
    # (S == 1) ignores it.
    seq_sharded_residuals: bool = False
    # FSDP execution mode: pin the residual stream to fully-batch-sharded
    # (over data AND model axes) at block boundaries, so SPMD propagation
    # can't invent tensor-parallel intermediate layouts that all-reduce
    # activations (EXPERIMENTS.md §Perf, starcoder2 train hillclimb).
    fsdp: bool = False

    def residual_constraint(self, x):
        if self.mesh is None or x.ndim != 3:
            return x
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        if self.fsdp and self.data_axes:
            spec = P(self.data_axes, None, None)
            return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))
        if (
            not self.seq_sharded_residuals
            or self.model_axis is None
            or x.shape[1] % self.model_axis_size != 0
            or x.shape[1] <= 1
        ):
            return x
        spec = P(self.data_axes if self.data_axes else None, self.model_axis, None)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


def layer_specs(cfg: ModelConfig) -> List[LayerSpec]:
    a = cfg.attention
    specs = []
    mixers = cfg.layer_kinds()
    ffns = cfg.ffn_kinds()
    for i in range(cfg.n_layers):
        window, theta = 0, a.rope_theta
        if mixers[i] == "attn" and a.global_every:
            if (i % a.global_every) == (a.global_every - 1):
                theta = a.global_rope_theta or a.rope_theta
            else:
                window = a.sliding_window
        elif mixers[i] == "attn":
            window = a.sliding_window
        specs.append(
            LayerSpec(
                mixer=mixers[i],
                ffn=ffns[i],
                window=window,
                theta=theta,
                cross=cfg.is_encdec,
            )
        )
    return specs


def find_period(specs: List[LayerSpec]) -> int:
    L = len(specs)
    for p in range(1, L + 1):
        if all(specs[i] == specs[i % p] for i in range(L)):
            return p
    return L


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ModelConfig, spec: LayerSpec, meta, dtype):
    ks = jax.random.split(key, 6)
    a = cfg.attention
    params: Dict[str, Any] = {"norm1": init_norm(cfg.d_model, cfg.norm_kind, dtype)}
    lora: Dict[str, Any] = {}
    if spec.mixer == "ssm":
        p, lo = init_ssm(ks[0], cfg.d_model, cfg.ssm, meta, cfg.lora_targets, dtype)
        params["ssm"] = p
        if lo:
            lora["ssm"] = lo
    else:
        init_fn = init_mla if a.is_mla else init_gqa
        p, lo = init_fn(ks[0], a, cfg.d_model, meta, cfg.lora_targets, dtype)
        params["attn"] = p
        if lo:
            lora["attn"] = lo
    if spec.cross:
        p, lo = init_gqa(ks[1], a, cfg.d_model, meta, cfg.lora_targets, dtype)
        params["cross"] = p
        params["norm_cross"] = init_norm(cfg.d_model, cfg.norm_kind, dtype)
        if lo:
            lora["cross"] = lo
    if spec.ffn == "dense":
        p, lo = init_mlp(
            ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_kind,
            a.use_bias, meta, cfg.lora_targets, dtype,
        )
        params["mlp"] = p
        params["norm2"] = init_norm(cfg.d_model, cfg.norm_kind, dtype)
        if lo:
            lora["mlp"] = lo
    elif spec.ffn == "moe":
        params["moe"] = init_moe(ks[2], cfg.d_model, cfg.moe, dtype)
        params["norm2"] = init_norm(cfg.d_model, cfg.norm_kind, dtype)
    return params, lora


def _ropes_for(spec: LayerSpec, rope_cache):
    return rope_cache[spec.theta]


def apply_layer(
    params,
    lora,
    scales,
    x,
    spec: LayerSpec,
    cfg: ModelConfig,
    *,
    n_pack: int,
    rope_cache,
    dist: Optional[DistContext],
    enc_out=None,
    cache=None,
    pos=None,
    make_cache: bool = False,
    chunk_q: int = 512,
    causal: bool = True,
    kcfg=None,
):
    """Pre-norm residual layer. Returns (x, new_cache, aux_loss)."""
    lo = lora or {}
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    h = apply_norm(params["norm1"], x, cfg.norm_kind)
    if spec.mixer == "ssm":
        if cache is not None:
            # single-token decode vs multi-token chunked prefill: the chunk
            # path replays the conv window and resumes the SSD state
            ssm_fn = apply_ssm_decode if h.shape[1] == 1 else apply_ssm_chunk
            y, c = ssm_fn(
                params["ssm"], lo.get("ssm"), scales, h,
                cache["ssm"], scfg=cfg.ssm, n_pack=n_pack, kcfg=kcfg,
            )
        else:
            y, c = apply_ssm(
                params["ssm"], lo.get("ssm"), scales, h,
                scfg=cfg.ssm, n_pack=n_pack, return_state=make_cache,
                kcfg=kcfg,
            )
        if c is not None:
            new_cache["ssm"] = c
    else:
        a = cfg.attention
        rope = _ropes_for(spec, rope_cache)
        if a.is_mla:
            y, c = apply_mla(
                params["attn"], lo.get("attn"), scales, h,
                acfg=a, n_pack=n_pack, rope=rope,
                cache=cache.get("attn") if cache else None,
                pos=pos, make_cache=make_cache, chunk_q=chunk_q, kcfg=kcfg,
            )
        else:
            y, c = apply_gqa(
                params["attn"], lo.get("attn"), scales, h,
                acfg=a, n_pack=n_pack, rope=rope, window=spec.window,
                causal=causal,
                cache=cache.get("attn") if cache else None,
                pos=pos, make_cache=make_cache, chunk_q=chunk_q, kcfg=kcfg,
            )
        if c is not None:
            new_cache["attn"] = c
    x = x + y

    if spec.cross and (enc_out is not None or (cache is not None and "cross_kv" in cache)):
        h = apply_norm(params["norm_cross"], x, cfg.norm_kind)
        if enc_out is None:
            ckv = cache["cross_kv"]
        else:
            a = cfg.attention
            kv, hd = a.n_kv_heads, a.head_dim
            nb = enc_out.shape[0]
            k = (enc_out @ params["cross"]["k"]["w"].astype(enc_out.dtype))
            v = (enc_out @ params["cross"]["v"]["w"].astype(enc_out.dtype))
            if "b" in params["cross"]["k"]:
                k = k + params["cross"]["k"]["b"].astype(k.dtype)
                v = v + params["cross"]["v"]["b"].astype(v.dtype)
            ckv = {
                "k": k.reshape(nb, -1, kv, hd),
                "v": v.reshape(nb, -1, kv, hd),
            }
        y, _ = apply_gqa(
            params["cross"], lo.get("cross"), scales, h,
            acfg=cfg.attention, n_pack=n_pack, rope=None,
            causal=False, cross_kv=ckv, chunk_q=chunk_q, kcfg=kcfg,
        )
        if make_cache or cache is not None:
            new_cache["cross_kv"] = ckv
        x = x + y

    if spec.ffn == "dense":
        h = apply_norm(params["norm2"], x, cfg.norm_kind)
        x = x + apply_mlp(params["mlp"], lo.get("mlp"), scales, h, cfg.mlp_kind, n_pack, kcfg=kcfg)
    elif spec.ffn == "moe":
        h = apply_norm(params["norm2"], x, cfg.norm_kind)
        if dist is not None and dist.model_axis is not None and cfg.moe.impl == "ep":
            from jax.sharding import PartitionSpec as P

            da = dist.data_axes
            x_spec = P(da if da else None, None, None)
            moe_specs = {
                "router": {"w": P()},
                "w_gate": P(dist.model_axis, None, None),
                "w_up": P(dist.model_axis, None, None),
                "w_down": P(dist.model_axis, None, None),
            }

            def _moe_body(mp, hh):
                y, aux_local = apply_moe(
                    mp, hh, cfg.moe,
                    model_axis=dist.model_axis,
                    model_axis_size=dist.model_axis_size,
                )
                if da:
                    aux_local = jax.lax.pmean(aux_local, da)
                return y, aux_local

            y, aux_l = shard_map_compat(
                _moe_body,
                mesh=dist.mesh,
                in_specs=(moe_specs, x_spec),
                out_specs=(x_spec, P()),
                check_vma=False,
            )(params["moe"], h)
        else:
            y, aux_l = apply_moe(params["moe"], h, cfg.moe)
        aux = aux + aux_l
        x = x + y
    return x, (new_cache or None), aux


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------


def _stack(trees: List[Any]):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_stack(key, cfg: ModelConfig, specs: List[LayerSpec], meta, dtype):
    """Returns ({"blocks": stacked, "rest": dict}, same-for-lora, period)."""
    p = find_period(specs)
    L = len(specs)
    n_blocks, n_rest = L // p, L % p
    keys = jax.random.split(key, L)
    blocks_p, blocks_l = [], []
    for b in range(n_blocks):
        bp, bl = {}, {}
        for i in range(p):
            lp, ll = init_layer(keys[b * p + i], cfg, specs[i], meta, dtype)
            bp[f"l{i}"] = lp
            if ll:
                bl[f"l{i}"] = ll
        blocks_p.append(bp)
        blocks_l.append(bl)
    rest_p, rest_l = {}, {}
    for i in range(n_rest):
        lp, ll = init_layer(keys[n_blocks * p + i], cfg, specs[i], meta, dtype)
        rest_p[f"l{i}"] = lp
        if ll:
            rest_l[f"l{i}"] = ll
    params = {"blocks": _stack(blocks_p) if n_blocks else {}, "rest": rest_p}
    lora = {"blocks": _stack(blocks_l) if (n_blocks and blocks_l[0]) else {}, "rest": rest_l}
    return params, lora, p


def apply_stack(
    params,
    lora,
    scales,
    x,
    cfg: ModelConfig,
    specs: List[LayerSpec],
    *,
    n_pack: int,
    rope_cache,
    dist,
    enc_out=None,
    caches=None,
    pos=None,
    make_cache: bool = False,
    chunk_q: int = 512,
    causal: bool = True,
    remat: bool = True,
    kcfg=None,
):
    """Run the whole stack. Returns (x, new_caches, total_aux)."""
    p = find_period(specs)
    L = len(specs)
    n_blocks, n_rest = L // p, L % p
    kw = dict(
        cfg=cfg, n_pack=n_pack, rope_cache=rope_cache, dist=dist,
        chunk_q=chunk_q, causal=causal, kcfg=kcfg,
    )

    def block_body(x, inp):
        bp, bl, bc = inp
        new_c = {}
        aux = jnp.zeros((), jnp.float32)
        if dist is not None:
            x = dist.residual_constraint(x)
        for i in range(p):
            x, c, a = apply_layer(
                bp[f"l{i}"], (bl or {}).get(f"l{i}"), scales, x, specs[i],
                enc_out=enc_out,
                cache=(bc or {}).get(f"l{i}") if bc is not None else None,
                pos=pos, make_cache=make_cache, **kw,
            )
            if c is not None:
                new_c[f"l{i}"] = c
            aux = aux + a
        return x, (new_c if (make_cache or caches is not None) else None, aux)

    body = jax.checkpoint(block_body) if remat else block_body
    total_aux = jnp.zeros((), jnp.float32)
    new_caches = {"blocks": None, "rest": {}}
    if n_blocks:
        bc = caches["blocks"] if caches is not None else None
        xs_in = (params["blocks"], lora.get("blocks") or None, bc)
        if caches is None:
            xs_in = (params["blocks"], lora.get("blocks") or None, None)
            x, (cs, auxs) = jax.lax.scan(
                lambda xx, inp: body(xx, (inp[0], inp[1], None)),
                x,
                (params["blocks"], _none_like(lora.get("blocks"))),
            )
        else:
            x, (cs, auxs) = jax.lax.scan(
                lambda xx, inp: body(xx, inp),
                x,
                (params["blocks"], _none_like(lora.get("blocks")), bc),
            )
        new_caches["blocks"] = cs
        total_aux = total_aux + auxs.sum()
    for i in range(n_rest):
        x, c, a = apply_layer(
            params["rest"][f"l{i}"], (lora.get("rest") or {}).get(f"l{i}"),
            scales, x, specs[i], enc_out=enc_out,
            cache=(caches["rest"].get(f"l{i}") if caches is not None else None),
            pos=pos, make_cache=make_cache, **kw,
        )
        if c is not None:
            new_caches["rest"][f"l{i}"] = c
        total_aux = total_aux + a
    return x, new_caches, total_aux


def _none_like(tree):
    """scan can't take None as an xs leaf container mismatch; use {} for
    'no lora' so tree structure is consistent."""
    return tree if tree else {}


def make_rope_cache(cfg: ModelConfig, positions: jnp.ndarray):
    """Precompute cos/sin per distinct theta; rope dim depends on attn kind."""
    a = cfg.attention
    dim = a.qk_rope_head_dim if a.is_mla else a.head_dim
    thetas = {s.theta for s in layer_specs(cfg) if s.mixer == "attn"}
    if not thetas:
        thetas = {a.rope_theta}
    return {t: rope_tables(positions, dim, t) for t in thetas}


def init_stack_cache(cfg, specs, nb: int, smax: int, dtype=jnp.bfloat16):
    """Cache pytree matching apply_stack(caches=...) structure."""
    a = cfg.attention
    p = find_period(specs)
    L = len(specs)
    n_blocks, n_rest = L // p, L % p

    def one(spec: LayerSpec):
        c = {}
        if spec.mixer == "ssm":
            c["ssm"] = init_ssm_cache(nb, cfg.d_model, cfg.ssm, jnp.float32)
        else:
            c["attn"] = (
                init_mla_cache(nb, smax, a, dtype)
                if a.is_mla
                else init_gqa_cache(nb, smax, a, dtype)
            )
        if spec.cross:
            kv, hd = a.n_kv_heads, a.head_dim
            c["cross_kv"] = {
                "k": jnp.zeros((nb, cfg.encoder_seq_len, kv, hd), dtype),
                "v": jnp.zeros((nb, cfg.encoder_seq_len, kv, hd), dtype),
            }
        return c

    blocks = [
        {f"l{i}": one(specs[i]) for i in range(p)} for _ in range(n_blocks)
    ]
    return {
        "blocks": _stack(blocks) if n_blocks else None,
        "rest": {f"l{i}": one(specs[i]) for i in range(n_rest)},
    }

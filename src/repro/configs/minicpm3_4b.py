"""minicpm3-4b — dense with Multi-head Latent Attention [hf:openbmb/MiniCPM3-4B].

62L d_model=2560, 40 heads, d_ff=6400, vocab=73448. MLA: q_lora_rank=768,
kv_lora_rank=256, qk_nope=64, qk_rope=32, v_head=64.
"""
from repro.configs.base import AttentionConfig, ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        n_layers=62,
        d_model=2560,
        d_ff=6400,
        vocab_size=73_448,
        attention=AttentionConfig(
            n_heads=40,
            n_kv_heads=40,
            head_dim=96,  # qk head dim = nope(64) + rope(32)
            q_lora_rank=768,
            kv_lora_rank=256,
            qk_nope_head_dim=64,
            qk_rope_head_dim=32,
            v_head_dim=64,
        ),
        lora_targets=("q", "kv", "o", "gate", "up", "down"),
        citation="hf:openbmb/MiniCPM3-4B (MLA)",
    )

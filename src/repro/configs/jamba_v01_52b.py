"""jamba-v0.1-52b — Mamba+attention 1:7 hybrid with MoE [arXiv:2403.19887].

32L d_model=4096; attention layers (GQA 32H kv=8, head_dim=128) every 8th
layer; MoE (16 experts top-2, d_ff=14336) every other layer; vocab=65536.
Jamba uses Mamba-1 blocks (d_state=16); we use the SSD (Mamba-2) formulation
for the scan with d_state=16 — same recurrence family (DESIGN.md §7).
"""
from repro.configs.base import (
    AttentionConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    register,
)


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        d_ff=14_336,
        vocab_size=65_536,
        attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128),
        moe=MoEConfig(n_experts=16, top_k=2, d_expert=14_336, moe_every=2, impl="ep"),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk_size=256),
        attn_every=8,
        attn_offset=3,
        lora_targets=("q", "k", "v", "o", "ssm_in", "ssm_out"),
        supports_long_context=True,
        citation="arXiv:2403.19887 (Jamba)",
    )

"""mamba2-370m — SSD (state-space duality), attention-free [arXiv:2405.21060].

48L d_model=1024, d_ff=0 (mamba2 blocks have no separate FFN), vocab=50280,
ssm_state=128, expand=2, head_dim=64 -> 32 SSD heads per block.
"""
from repro.configs.base import ModelConfig, SSMConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        d_ff=0,
        vocab_size=50_280,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        tie_embeddings=True,
        lora_targets=("ssm_in", "ssm_out"),
        supports_long_context=True,
        citation="arXiv:2405.21060 (Mamba-2 / SSD)",
    )

"""grok-1-314b — 8-expert top-2 MoE [hf:xai-org/grok-1].

64L d_model=6144, 48 heads (GQA kv=8, head_dim=128), expert d_ff=32768,
vocab=131072, MoE 8e top-2 on every layer. E=8 < model-axis 16, so expert
parallelism on the mandated flat mesh is uneven; we use the dense-MoE path
with expert weights sharded over (data x model) — see DESIGN.md §4.
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        d_ff=32_768,
        vocab_size=131_072,
        attention=AttentionConfig(n_heads=48, n_kv_heads=8, head_dim=128),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32_768, moe_every=1, impl="dense"),
        lora_targets=("q", "k", "v", "o"),
        citation="hf:xai-org/grok-1",
    )

"""qwen3-moe-30b-a3b — 128 experts top-8 MoE [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048, 32 heads (GQA kv=4, head_dim=128), expert d_ff=768,
vocab=151936, MoE 128e top-8 on every layer.
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        d_ff=768,
        vocab_size=151_936,
        attention=AttentionConfig(
            n_heads=32, n_kv_heads=4, head_dim=128, rope_theta=1e6
        ),
        moe=MoEConfig(n_experts=128, top_k=8, d_expert=768, moe_every=1, impl="ep"),
        lora_targets=("q", "k", "v", "o"),
        citation="hf:Qwen/Qwen3-30B-A3B",
    )

"""starcoder2-7b — dense GQA with RoPE [arXiv:2402.19173].

32L d_model=4608, 36 heads (GQA kv=4, head_dim=128), d_ff=18432, vocab=49152.
Classic (non-gated) GELU MLP with biases.
"""
from repro.configs.base import AttentionConfig, ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        n_layers=32,
        d_model=4608,
        d_ff=18_432,
        vocab_size=49_152,
        attention=AttentionConfig(
            n_heads=36, n_kv_heads=4, head_dim=128, use_bias=True, rope_theta=1e5
        ),
        mlp_kind="gelu2",  # classic up->gelu->down MLP
        norm_kind="layernorm",
        citation="arXiv:2402.19173 (StarCoder2)",
    )

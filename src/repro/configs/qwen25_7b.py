"""qwen25-7b — the paper's own primary base model [arXiv:2412.15115].

28L d_model=3584, 28 heads (GQA kv=4, head_dim=128), d_ff=18944, vocab=152064.
Used by the paper-faithful benchmarks (makespan / throughput / kernels).
"""
from repro.configs.base import AttentionConfig, ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen25-7b",
        family="dense",
        n_layers=28,
        d_model=3584,
        d_ff=18_944,
        vocab_size=152_064,
        attention=AttentionConfig(
            n_heads=28, n_kv_heads=4, head_dim=128, use_bias=True, rope_theta=1e6
        ),
        citation="arXiv:2412.15115 (Qwen2.5); paper §7 base model",
    )

"""Configuration system for repro: model architectures, input shapes, LoRA spaces.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` that builds a
:class:`ModelConfig` with the exact published dimensions (source cited in the
module docstring), plus a ``reduced()`` variant used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Attention variants
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionConfig:
    """Grouped-query attention; MLA and sliding-window are expressed on top."""

    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 64
    rope_theta: float = 10_000.0
    use_bias: bool = False
    causal: bool = True

    # Sliding-window attention (Gemma-3 style local layers). 0 = full.
    sliding_window: int = 0
    # Pattern of local:global layers, e.g. gemma3 = 6 (5 local + 1 global,
    # every 6th layer is global). 0 = all layers use `sliding_window` as-is.
    global_every: int = 0
    # rope theta used by "global" layers when global_every > 0
    global_rope_theta: float = 0.0

    # Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style). When
    # kv_lora_rank > 0 the layer uses MLA projections instead of plain GQA.
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    @property
    def is_mla(self) -> bool:
        return self.kv_lora_rank > 0


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0  # expert hidden (ffn) size
    # every `moe_every` layers the FFN is MoE (1 = all layers, 2 = alternating)
    moe_every: int = 1
    # "dense" = all-experts einsum (exact, small-scale / oracle)
    # "ep"    = expert-parallel shard_map + all_to_all (requires E % tp == 0)
    impl: str = "dense"
    capacity_factor: float = 1.25
    router_jitter: float = 0.0

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration [arXiv:2405.21060]."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256

    @property
    def enabled(self) -> bool:
        return self.d_state > 0

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """One architecture. `family` in {dense, moe, ssm, hybrid, audio, vlm}."""

    name: str
    family: str
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)

    # Layer mixing for hybrids: an attention layer every `attn_every` layers
    # (jamba = 8: layers 3, 11, 19, 27 given offset). 0 = attn everywhere
    # (or nowhere if family == "ssm").
    attn_every: int = 0
    attn_offset: int = 3

    # MLP kind: "swiglu" | "gelu" (gelu implies the classic 2-matrix MLP)
    mlp_kind: str = "swiglu"
    norm_kind: str = "rmsnorm"  # "rmsnorm" | "layernorm"
    tie_embeddings: bool = False
    max_seq_len: int = 131_072

    # Encoder-decoder (whisper): encoder consumes precomputed frame embeddings
    encoder_layers: int = 0
    encoder_seq_len: int = 0  # e.g. whisper 1500 frames

    # VLM: number of prefix patch-embedding positions supplied by the stub
    n_patch_tokens: int = 0

    # Which projections get LoRA adapters (see DESIGN.md §5)
    lora_targets: Tuple[str, ...] = ("q", "k", "v", "o", "gate", "up", "down")

    # long_500k applicability (sub-quadratic path exists)
    supports_long_context: bool = False
    citation: str = ""

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def padded_vocab(self) -> int:
        """Embedding/LM-head rows padded to a 256 multiple so vocab-parallel
        sharding divides the 16-way model axis (several published vocab
        sizes — 151655, 50280, 51865, 73448 — are odd). Padded logits are
        masked to -inf in the loss and at decode."""
        return (self.vocab_size + 255) // 256 * 256

    def layer_kinds(self) -> Tuple[str, ...]:
        """Mixer kind per decoder layer: 'attn' or 'ssm'."""
        kinds = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                kinds.append("ssm")
            elif self.family == "hybrid":
                kinds.append(
                    "attn" if (i % self.attn_every) == self.attn_offset else "ssm"
                )
            else:
                kinds.append("attn")
        return tuple(kinds)

    def ffn_kinds(self) -> Tuple[str, ...]:
        """FFN kind per decoder layer: 'dense' | 'moe' | 'none'."""
        kinds = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                kinds.append("none")  # mamba2 blocks have no separate FFN
            elif self.moe.enabled and (i % self.moe.moe_every) == (
                self.moe.moe_every - 1
            ):
                kinds.append("moe")
            else:
                kinds.append("dense")
        return tuple(kinds)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# LoRA hyperparameter space (paper Table 1)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LoraConfig:
    """One point of the hyperparameter search space (a 'LoRA configuration')."""

    rank: int = 8
    alpha: float = 8.0
    learning_rate: float = 1e-4
    batch_size: int = 1
    seq_len: int = 1024
    targets: Tuple[str, ...] = ("q", "k", "v", "o", "gate", "up", "down")

    def key(self) -> Tuple:
        return (self.rank, self.alpha, self.learning_rate, self.batch_size)


def default_search_space(n: int = 120, seq_len: int = 1024) -> list:
    """Grid over paper Table 1 ranges: LR 2e-5..4e-4, BS 1..32, r 8..128,
    alpha r/4..4r. Returns the first `n` points of a deterministic grid."""
    lrs = [2e-5, 6e-5, 1e-4, 2e-4, 4e-4]
    bss = [1, 2, 4, 8]
    ranks = [8, 16, 32, 64, 128]
    alpha_mult = [0.25, 1.0, 4.0]
    space = []
    for r in ranks:
        for lr in lrs:
            for bs in bss:
                for am in alpha_mult:
                    space.append(
                        LoraConfig(
                            rank=r,
                            alpha=am * r,
                            learning_rate=lr,
                            batch_size=bs,
                            seq_len=seq_len,
                        )
                    )
    return space[:n]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def reduced(cfg: ModelConfig, n_layers: int = 2, d_model: int = 256) -> ModelConfig:
    """Smoke-test variant of the same family: ≤2 layers (scaled so the layer
    pattern still contains every mixer/ffn kind), d_model ≤ 512, ≤4 experts."""
    attn = cfg.attention
    head_dim = 32
    n_heads = max(2, min(4, attn.n_heads))
    n_kv = max(1, min(n_heads, attn.n_kv_heads))
    while n_heads % n_kv:
        n_kv -= 1
    new_attn = dataclasses.replace(
        attn,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        sliding_window=min(attn.sliding_window, 64) if attn.sliding_window else 0,
        q_lora_rank=48 if attn.q_lora_rank else 0,
        kv_lora_rank=32 if attn.kv_lora_rank else 0,
        qk_nope_head_dim=16 if attn.is_mla else 0,
        qk_rope_head_dim=16 if attn.is_mla else 0,
        v_head_dim=32 if attn.is_mla else 0,
    )
    moe = cfg.moe
    if moe.enabled:
        # capacity_factor = E/top_k => capacity >= T: no token dropping, so
        # step-wise and full-sequence routing agree exactly in smoke tests
        moe = dataclasses.replace(
            moe, n_experts=4, top_k=min(2, moe.top_k), d_expert=64,
            capacity_factor=4 / min(2, moe.top_k),
        )
    ssm = cfg.ssm
    if ssm.enabled:
        ssm = dataclasses.replace(ssm, d_state=16, head_dim=32, chunk_size=32)
    nl = n_layers
    if cfg.family == "hybrid":
        # keep one attn + ssm layers; shrink the attn period instead
        nl = 4
        cfg = cfg.replace(attn_every=4, attn_offset=1)
    if attn.global_every:
        nl = max(nl, attn.global_every)  # keep one global layer in the pattern
        nl = min(nl, 6)
    enc_layers = 2 if cfg.encoder_layers else 0
    return cfg.replace(
        name=cfg.name + "-reduced",
        n_layers=nl,
        d_model=min(d_model, cfg.d_model),
        d_ff=min(384, cfg.d_ff) if cfg.d_ff else 0,
        vocab_size=512,
        attention=new_attn,
        moe=moe,
        ssm=ssm,
        encoder_layers=enc_layers,
        encoder_seq_len=32 if cfg.encoder_seq_len else 0,
        n_patch_tokens=8 if cfg.n_patch_tokens else 0,
        max_seq_len=512,
    )


_REGISTRY = {}


def register(cfg_fn):
    """Decorator: register `<module>.config()` under the arch id."""
    cfg = cfg_fn()
    _REGISTRY[cfg.name] = cfg_fn
    return cfg_fn


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    # import all arch modules for registration side effects
    from repro.configs import (  # noqa: F401
        mamba2_370m,
        qwen3_moe_30b_a3b,
        whisper_tiny,
        minicpm3_4b,
        gemma3_1b,
        command_r_35b,
        jamba_v01_52b,
        starcoder2_7b,
        grok_1_314b,
        internvl2_1b,
        qwen25_7b,
    )

    _LOADED = True

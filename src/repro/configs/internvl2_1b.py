"""internvl2-1b — VLM: InternViT + Qwen2-0.5B LM backbone [arXiv:2404.16821].

LM backbone: 24L d_model=896, 14 heads (GQA kv=2, head_dim=64), d_ff=4864,
vocab=151655. The vision encoder + projector is a STUB: ``input_specs``
provides 256 precomputed patch embeddings (B, 256, 896) spliced as a prefix.
"""
from repro.configs.base import AttentionConfig, ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        d_ff=4864,
        vocab_size=151_655,
        attention=AttentionConfig(
            n_heads=14, n_kv_heads=2, head_dim=64, use_bias=True, rope_theta=1e6
        ),
        n_patch_tokens=256,
        citation="arXiv:2404.16821 (InternVL2); LM = Qwen2-0.5B",
    )

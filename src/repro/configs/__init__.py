from repro.configs.base import (
    INPUT_SHAPES,
    AttentionConfig,
    LoraConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    default_search_space,
    get_config,
    list_archs,
    reduced,
)

__all__ = [
    "INPUT_SHAPES",
    "AttentionConfig",
    "LoraConfig",
    "ModelConfig",
    "MoEConfig",
    "ShapeConfig",
    "SSMConfig",
    "default_search_space",
    "get_config",
    "list_archs",
    "reduced",
]

"""gemma3-1b — dense, 5:1 local:global sliding-window [hf:google/gemma-3-1b-pt].

26L d_model=1152, 4 heads (GQA kv=1, head_dim=256), d_ff=6912, vocab=262144.
Local layers use a 512-token sliding window with rope theta 10k; every 6th
layer is global with rope theta 1M. Sub-quadratic local path => long_500k runs.
"""
from repro.configs.base import AttentionConfig, ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        family="dense",
        n_layers=26,
        d_model=1152,
        d_ff=6912,
        vocab_size=262_144,
        attention=AttentionConfig(
            n_heads=4,
            n_kv_heads=1,
            head_dim=256,
            rope_theta=10_000.0,
            sliding_window=512,
            global_every=6,
            global_rope_theta=1e6,
        ),
        mlp_kind="gelu",  # gemma uses geglu; we use the gated-gelu variant
        tie_embeddings=True,
        supports_long_context=True,
        citation="hf:google/gemma-3-1b-pt",
    )

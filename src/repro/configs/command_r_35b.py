"""command-r-35b — dense GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].

40L d_model=8192, 64 heads (GQA kv=8, head_dim=128), d_ff=22528, vocab=256000.
"""
from repro.configs.base import AttentionConfig, ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b",
        family="dense",
        n_layers=40,
        d_model=8192,
        d_ff=22_528,
        vocab_size=256_000,
        attention=AttentionConfig(
            n_heads=64, n_kv_heads=8, head_dim=128, use_bias=False, rope_theta=8e6
        ),
        tie_embeddings=True,
        citation="hf:CohereForAI/c4ai-command-r-v01",
    )

"""whisper-tiny — encoder-decoder ASR backbone [arXiv:2212.04356].

4L enc + 4L dec, d_model=384, 6 heads (kv=6, head_dim=64), d_ff=1536,
vocab=51865. The mel-spectrogram + conv frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, 1500, 384). Positional encoding is
RoPE in this reproduction (whisper uses sinusoidal/learned absolute; the
backbone compute/sharding is unchanged — noted in DESIGN.md §7).
"""
from repro.configs.base import AttentionConfig, ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,
        d_model=384,
        d_ff=1536,
        vocab_size=51_865,
        attention=AttentionConfig(n_heads=6, n_kv_heads=6, head_dim=64, use_bias=True),
        mlp_kind="gelu",
        norm_kind="layernorm",
        encoder_layers=4,
        encoder_seq_len=1500,
        lora_targets=("q", "v", "gate", "up", "down"),
        max_seq_len=448,
        citation="arXiv:2212.04356 (Whisper)",
    )

"""Quantized frozen-base primitives (ISSUE 8 tentpole).

PLoRA packs N adapters against ONE shared frozen base, so the base weights
are by far the largest resident tensor — paid once per pack, never
gradient-updated (only A/B train). That makes them safe to quantize once at
pack build and dequantize on the fly inside the kernels:

  * ``int8``: symmetric per-output-channel. One f32 scale per output column
    (absmax over the K axis / 127); dequant is ``codes * scales``.
  * ``nf4``: 4-bit block-scaled. Values are snapped to the 16-level
    NormalFloat codebook, two codes packed per uint8 along K (low nibble =
    even K-row, high nibble = odd), with one f32 absmax scale per
    ``block``-sized K slab per output column.

A quantized weight is a plain dict ``{"codes": ..., "scales": ...}`` — a
pytree, so it survives ``device_put``, ``encode_tree`` (the multihost wire),
scan-stacked block slicing, and ``param_specs`` (codes/scales fall to the
replicate rule) without any special casing. The scheme is inferred from the
codes dtype: int8 -> per-channel, uint8 -> packed nf4.

The quantizer is pure numpy (runs once, host-side, at pack build); only
``dequantize`` must be jittable — it is expressed entirely in jnp ops so the
same formula runs under XLA, inside the Pallas megakernel's K-loop, and in
Pallas interpret mode (the CPU oracle).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

MODES = ("int8", "nf4")

# QLoRA NormalFloat-4 codebook: 16 quantiles of N(0,1) normalised to
# [-1, 1], asymmetric around the exact-zero level.
NF4_CODEBOOK = np.array(
    [
        -1.0,
        -0.6961928009986877,
        -0.5250730514526367,
        -0.39491748809814453,
        -0.28444138169288635,
        -0.18477343022823334,
        -0.09105003625154495,
        0.0,
        0.07958029955625534,
        0.16093020141124725,
        0.24611230194568634,
        0.33791524171829224,
        0.44070982933044434,
        0.5626170039176941,
        0.7229568362236023,
        1.0,
    ],
    dtype=np.float32,
)


def is_quantized(w) -> bool:
    """True when ``w`` is a quantized-weight dict (vs a dense array)."""
    return isinstance(w, dict) and "codes" in w and "scales" in w


def quant_mode(w) -> str:
    """Scheme of a quantized weight, inferred from the codes dtype."""
    dt = np.dtype(w["codes"].dtype)
    if dt == np.int8:
        return "int8"
    if dt == np.uint8:
        return "nf4"
    raise ValueError(f"unrecognised quantized codes dtype {dt}")


def logical_shape(w) -> tuple:
    """Dense ``(..., d_in, d_out)`` shape a quantized weight dequantizes to."""
    shape = tuple(w["codes"].shape)
    if quant_mode(w) == "nf4":  # two K-rows packed per uint8
        shape = shape[:-2] + (2 * shape[-2],) + shape[-1:]
    return shape


def quantized_nbytes(w) -> int:
    """Resident bytes of a quantized weight (codes + scales)."""
    return int(np.asarray(w["codes"]).nbytes + np.asarray(w["scales"]).nbytes)


def nf4_block(d_in: int) -> int:
    """Block length along K: the largest power of two <= 64 dividing d_in."""
    b = 64
    while b > 1 and d_in % b:
        b //= 2
    return b


def quantize_weight(w, mode: str):
    """Quantize a dense ``(..., d_in, d_out)`` weight (pure numpy, host-side).

    Returns ``{"codes", "scales"}``. int8: codes int8 ``(..., d_in, d_out)``,
    scales f32 ``(..., 1, d_out)``. nf4: codes uint8 ``(..., d_in//2,
    d_out)`` (low nibble = even K-row), scales f32 ``(..., d_in//block,
    d_out)``.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    w = np.asarray(w, dtype=np.float32)
    if w.ndim < 2:
        raise ValueError(f"need (..., d_in, d_out), got shape {w.shape}")
    if mode == "int8":
        absmax = np.max(np.abs(w), axis=-2, keepdims=True)
        scales = np.where(absmax > 0, absmax / 127.0, 1.0).astype(np.float32)
        codes = np.clip(np.rint(w / scales), -127, 127).astype(np.int8)
        return {"codes": codes, "scales": scales}
    d_in, d_out = w.shape[-2], w.shape[-1]
    if d_in % 2:
        raise ValueError(f"nf4 needs even d_in, got {d_in}")
    blk = nf4_block(d_in)
    lead = w.shape[:-2]
    wb = w.reshape(lead + (d_in // blk, blk, d_out))
    absmax = np.max(np.abs(wb), axis=-2, keepdims=True)
    scales = np.where(absmax > 0, absmax, 1.0).astype(np.float32)
    normed = wb / scales  # in [-1, 1]
    idx = np.argmin(
        np.abs(normed[..., None] - NF4_CODEBOOK), axis=-1
    ).astype(np.uint8)
    idx = idx.reshape(lead + (d_in, d_out))
    pair = idx.reshape(lead + (d_in // 2, 2, d_out))
    codes = (pair[..., 0, :] | (pair[..., 1, :] << 4)).astype(np.uint8)
    return {"codes": codes, "scales": scales[..., 0, :]}


def dequantize(w, dtype=jnp.float32):
    """Jittable dequant of a ``{"codes", "scales"}`` dict to a dense array.

    Pure jnp — the identical formula runs under XLA, in-kernel under Pallas
    (per-tile: elementwise dequant is tiling-invariant, so per-tile equals
    global dequant bit-for-bit), and in interpret mode.
    """
    codes = jnp.asarray(w["codes"])
    scales = jnp.asarray(w["scales"])
    if codes.dtype == jnp.int8:
        out = codes.astype(jnp.float32) * scales
        return out.astype(dtype)
    lo = (codes & 0xF).astype(jnp.int32)
    hi = (codes >> 4).astype(jnp.int32)
    idx = jnp.stack([lo, hi], axis=-2)  # (..., P, 2, d_out)
    lead = codes.shape[:-2]
    d_in = 2 * codes.shape[-2]
    d_out = codes.shape[-1]
    idx = idx.reshape(lead + (d_in, d_out))
    vals = jnp.take(jnp.asarray(NF4_CODEBOOK), idx)
    nb = scales.shape[-2]
    vb = vals.reshape(lead + (nb, d_in // nb, d_out))
    out = (vb * scales[..., :, None, :]).reshape(lead + (d_in, d_out))
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Tree-level quantization of a model's frozen base.
#
# Only weights consumed through ``lora_linear`` are eligible: those are the
# projections the fused/two-pass kernels already route, so a quantized dict
# in the "w" slot flows through the dispatch this PR extends. Weights the
# model layers matmul *directly* (MLA kv_b splits, SSM bc/dt, the MoE
# router), embeddings, and heads stay dense.
ELIGIBLE_NAMES = frozenset(
    {"q", "k", "v", "o", "q_a", "q_b", "kv_a", "gate", "up", "down",
     "zx", "out"}
)
EXCLUDE_SUBTREES = frozenset(
    {"cross", "moe", "embed", "lm_head", "patch_proj"}
)


def quantize_base_params(params, mode: Optional[str]):
    """Quantize the eligible frozen-base projections of a param tree.

    Returns a new tree in which each eligible ``{"w": dense}`` leaf becomes
    ``{"w": {"codes", "scales"}}`` (bias and norms untouched). Works on both
    flat layer dicts and scan-stacked "blocks" subtrees (leading L dim rides
    along; per-output-channel/blockwise math only touches the last two
    axes). ``mode=None``/"none" is the identity.
    """
    if mode is None or mode == "none":
        return params

    def walk(node, name=None):
        if not isinstance(node, dict):
            return node
        if is_quantized(node):
            return node
        out = {}
        for k, v in node.items():
            if k in EXCLUDE_SUBTREES:
                out[k] = v
            elif (
                k == "w"
                and name in ELIGIBLE_NAMES
                and hasattr(v, "ndim")
                and v.ndim >= 2
                and (mode == "int8" or v.shape[-2] % 2 == 0)
            ):
                out[k] = quantize_weight(np.asarray(v), mode)
            else:
                out[k] = walk(v, name=k)
        return out

    return walk(params)


def dequantize_base_params(params):
    """Inverse walk: replace every quantized dict with its dense f32 form."""

    def walk(node):
        if not isinstance(node, dict):
            return node
        if is_quantized(node):
            return np.asarray(dequantize(node))
        return {k: walk(v) for k, v in node.items()}

    return walk(params)

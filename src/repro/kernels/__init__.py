"""Pallas TPU kernels for packed-LoRA grouped GEMMs (paper §5)."""

"""Packed-LoRA kernel tier (paper §5): grouped GEMMs (packed_matmul), the
fused base+delta megakernel (fused), backend dispatch / ragged-rank
segmentation / remat policy (ops), and the block-size autotuner whose
measured rates feed the cost model (autotune)."""

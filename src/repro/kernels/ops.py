"""Jit'd public ops for packed-LoRA computation.

``packed_lora_delta(x, a, b, alpha)`` computes the adapter-side contribution
``alpha_n * (x_n @ A_n) @ B_n`` for all N packed adapters with a custom VJP
whose four gradient dataflows mirror the paper's backward cases (§5.2):

  case 1  dB    = (xA)^T @ g        (tile over output dim, contract over seq)
  case 2  d(xA) = g @ B^T           (tile over seq + rank, contract over k)
  case 3  dA    = x^T @ d(xA)       (tile over d + rank, contract over seq)
  case 4  dx    = d(xA) @ A^T       (tile over seq + d, contract over rank)

All four are the grouped-GEMM primitive with transposed operands; on TPU the
rank-dim reduction of case 4 is a single K-step inside the tile (rank <= 128),
avoiding the scratch-buffer bookkeeping the paper describes on GPU.

Backend selection (``KernelConfig.impl`` / the ``impl=`` kwarg):
  impl="pallas"       : two-pass Pallas grouped kernel (interpret off-TPU)
  impl="xla"          : two-pass batched einsum (XLA-fused GEMMs)
  impl="fused"        : base+delta megakernel (kernels/fused.py) — resolves
                        to fused_pallas on TPU, fused_xla elsewhere
  impl="fused_pallas" : the Pallas megakernel explicitly
  impl="fused_xla"    : the one-custom_vjp XLA formulation explicitly
  impl="auto"         : pallas on TPU, xla elsewhere (default — CPU tests/
                        benches measure real XLA wall-clock, TPU gets the
                        custom kernel)

The process default is a ``contextvars.ContextVar`` (NOT a mutable global):
``set_default_impl`` only affects the calling context, so the thread-per-
slice ``ClusterRunner`` can never race it. New threads do NOT inherit the
calling thread's value — cross-thread executors must capture
``default_impl()`` at dispatch time and plumb it explicitly (the trainer /
cluster executor take ``impl=`` for exactly this reason).

Heterogeneous-rank packs: pass ``ranks=`` (the pack's static per-adapter
rank tuple, carried by ``core.adapter.PackMeta``) and same-rank adapters are
grouped into grid *segments* — each segment computes at its own rank, so a
rank-8 adapter packed with a rank-128 one stops paying the bucket-padding
FLOPs (``(r_bucket - r) / r_bucket`` of the delta work). The padded weight
columns are sliced off before the kernel ever sees them, so their gradient
is *structurally* zero (stronger than the numerically-zero padding
invariant the bucket path relies on).

``alpha`` is a hyperparameter, not a trainable weight: its cotangent is zero.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.packed_matmul import packed_matmul as _pallas_matmul

IMPLS = ("auto", "pallas", "xla", "fused", "fused_pallas", "fused_xla")

# Backward xA policy: "recompute" re-derives the (N, ..., r<=128) xA
# intermediate in the backward (one extra GEMM over the full d_in), "save"
# stores it as a residual. Both are bit-identical (same op on the same
# inputs). Measured crossover (bench_kernels remat rows, d=2048..18944,
# N=8..32, seq=16): "save" wins the backward by 1.2-1.5x on typical runs and
# stays within CPU timing noise on the rest — the recomputed GEMM contracts
# over the LARGE d_in, while the saved residual is only (N, T, r<=128).
# Under the jax.checkpoint'd block stacks every model here trains with, the
# residual is block-local (saved during the block's backward re-forward,
# freed at the block boundary), so the memory cost is one projection's xA,
# not the whole stack's. Hence "save" is the default.
DEFAULT_REMAT = "save"

_IMPL_VAR: contextvars.ContextVar = contextvars.ContextVar(
    "plora_impl", default="auto"
)


def set_default_impl(impl: str) -> None:
    """Set the *context-local* default impl (see module docstring)."""
    assert impl in IMPLS, impl
    _IMPL_VAR.set(impl)


def default_impl() -> str:
    return _IMPL_VAR.get()


@contextlib.contextmanager
def use_impl(impl: str):
    """Scoped impl override: ``with use_impl("fused"): ...``."""
    assert impl in IMPLS, impl
    token = _IMPL_VAR.set(impl)
    try:
        yield
    finally:
        _IMPL_VAR.reset(token)


def _resolve(impl: Optional[str]) -> str:
    impl = impl or _IMPL_VAR.get()
    on_tpu = jax.default_backend() == "tpu"
    if impl == "auto":
        return "pallas" if on_tpu else "xla"
    if impl == "fused":
        return "fused_pallas" if on_tpu else "fused_xla"
    return impl


def _unfused(impl: str) -> str:
    """The two-pass backend implied by a resolved impl (the grouped delta
    primitive underlying a fused variant's auxiliary contractions)."""
    return {"fused_pallas": "pallas", "fused_xla": "xla"}.get(impl, impl)


@dataclass(frozen=True)
class KernelConfig:
    """Static kernel policy threaded from the trainer down to every
    ``lora_linear`` call site (hashable: safe as a jit-static argument).

    impl   : backend name from ``IMPLS`` (None -> context default)
    remat  : backward xA policy "recompute" | "save" (None -> DEFAULT_REMAT)
    ranks  : the pack's per-adapter rank tuple; heterogeneous tuples switch
             the delta to ragged same-rank grid segments (None -> treat the
             pack as rank-homogeneous at the bucket rank)
    blocks : Pallas (block_m, block_l, block_k) override (autotuner hook)
    base_dtype : frozen-base storage scheme — None (dense, whatever dtype
             the checkpoint carries) or "int8"/"nf4" (kernels/quant.py);
             part of the policy so executor caches and the multihost wire
             distinguish quantized from dense compilations
    """

    impl: Optional[str] = None
    remat: Optional[str] = None
    ranks: Optional[Tuple[int, ...]] = None
    blocks: Optional[Tuple[int, int, int]] = None
    base_dtype: Optional[str] = None

    def resolved_impl(self) -> str:
        return _resolve(self.impl)

    def resolved_remat(self) -> str:
        return self.remat or DEFAULT_REMAT

    @property
    def ragged(self) -> bool:
        return self.ranks is not None and len(set(self.ranks)) > 1


def rank_segments(
    ranks: Sequence[int],
) -> Tuple[Tuple[int, ...], Tuple[int, ...], List[Tuple[int, int, int]]]:
    """Group a pack's adapters into same-rank segments.

    Returns ``(order, inv, segments)``: ``order`` is a static permutation
    sorting adapters by rank (stable, so same-rank adapters keep their
    relative slot order), ``inv`` undoes it, and each segment ``(lo, hi, r)``
    is a contiguous run of rank-``r`` adapters in the sorted view.
    """
    n = len(ranks)
    order = tuple(sorted(range(n), key=lambda i: (ranks[i], i)))
    inv = tuple(
        int(i) for i in sorted(range(n), key=lambda i: order[i])
    )
    segments: List[Tuple[int, int, int]] = []
    lo = 0
    for hi in range(1, n + 1):
        if hi == n or ranks[order[hi]] != ranks[order[lo]]:
            segments.append((lo, hi, int(ranks[order[lo]])))
            lo = hi
    return order, inv, segments


def delta_flops(
    ranks: Sequence[int], d_in: int, d_out: int, tokens: int, *,
    ragged: bool,
) -> float:
    """Forward delta FLOPs of one projection for a pack — the structural
    metric ``bench_kernels`` reports: bucket-padded packs compute every
    adapter at ``r_bucket`` (max rank rounded up to 8); ragged segments
    compute each adapter at its own rank."""
    if not ranks:
        return 0.0
    bucket = max(8, (max(ranks) + 7) // 8 * 8)
    total = 0.0
    for r in ranks:
        r_eff = r if ragged else bucket
        total += 2.0 * tokens * r_eff * (d_in + d_out)
    return total


def grouped_matmul(x, w, scale=None, *, impl: Optional[str] = None):
    """out[n] = scale[n] * x[n] @ w[n]; dispatches pallas/xla.

    x may carry extra token dims (N, ..., K). The Pallas kernel is a 3D
    grouped GEMM, so those dims are flattened around the call; the xla path
    keeps them (sharding-friendly under pjit — see packed_matmul_ref)."""
    if _unfused(_resolve(impl)) == "pallas":
        lead = x.shape[1:-1]
        x3 = x.reshape(x.shape[0], -1, x.shape[-1])
        out = _pallas_matmul(
            x3, w, scale, interpret=jax.default_backend() != "tpu"
        )
        return out.reshape(x.shape[0], *lead, w.shape[-1])
    return _ref.packed_matmul_ref(x, w, scale)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _packed_lora_delta(x, a, b, alpha, impl, remat):
    xa = grouped_matmul(x, a, impl=impl)
    return grouped_matmul(xa, b, alpha, impl=impl)


def _fwd(x, a, b, alpha, impl, remat):
    xa = grouped_matmul(x, a, impl=impl)
    out = grouped_matmul(xa, b, alpha, impl=impl)
    return out, (x, a, b, alpha, xa if remat == "save" else None)


def _bwd(impl, remat, res, g):
    x, a, b, alpha, saved_xa = res
    g = g.astype(x.dtype)
    # xA policy: recompute (cheap: (N, ..., r<=128)) or reuse the residual —
    # bit-identical either way (same op on the same inputs)
    xa = saved_xa if saved_xa is not None else grouped_matmul(x, a, impl=impl)
    g_s = g * alpha.reshape(alpha.shape[0], *([1] * (g.ndim - 1))).astype(g.dtype)
    if x.ndim == 3:
        # 3D: all four cases go through the grouped kernel (paper §5.2)
        # case 1: dB = (xA)^T @ g_s               (N, r, k)
        db = grouped_matmul(jnp.swapaxes(xa, 1, 2), g_s, impl=impl)
        # case 2: d(xA) = g_s @ B^T               (N, T, r)
        dxa = grouped_matmul(g_s, jnp.swapaxes(b, 1, 2), impl=impl)
        # case 3: dA = x^T @ d(xA)                (N, d, r)
        da = grouped_matmul(jnp.swapaxes(x, 1, 2), dxa, impl=impl)
        # case 4: dx = d(xA) @ A^T                (N, T, d)
        dx = grouped_matmul(dxa, jnp.swapaxes(a, 1, 2), impl=impl)
        return dx, da, db, jnp.zeros_like(alpha)
    # N-D (FSDP pack layout): weight grads contract over ALL token dims
    db = jnp.einsum("n...r,n...k->nrk", xa, g_s)
    dxa = grouped_matmul(g_s, jnp.swapaxes(b, 1, 2), impl=impl)
    da = jnp.einsum("n...d,n...r->ndr", x, dxa)
    dx = grouped_matmul(dxa, jnp.swapaxes(a, 1, 2), impl=impl)
    return dx, da.astype(a.dtype), db.astype(b.dtype), jnp.zeros_like(alpha)


_packed_lora_delta.defvjp(_fwd, _bwd)


def _ragged_call(fn, x, a, b, alpha, ranks):
    """Run a per-segment delta/fused op over same-rank grid segments.

    ``fn(x_seg, a_seg, b_seg, alpha_seg)`` sees each segment's weights
    sliced to the segment's true rank; outputs are reassembled in original
    slot order. The permutation is static (``jnp.take`` with constant
    indices), so gradients route exactly and the sliced-off padding columns
    receive no gradient at all.
    """
    assert len(ranks) == x.shape[0], (ranks, x.shape)
    order, inv, segments = rank_segments(ranks)
    xs = jnp.take(x, jnp.asarray(order), axis=0)
    a_s = jnp.take(a, jnp.asarray(order), axis=0)
    b_s = jnp.take(b, jnp.asarray(order), axis=0)
    al_s = jnp.take(alpha, jnp.asarray(order), axis=0)
    outs = []
    for lo, hi, r in segments:
        outs.append(
            fn(
                xs[lo:hi],
                a_s[lo:hi, :, :r],
                b_s[lo:hi, :r, :],
                al_s[lo:hi],
            )
        )
    out = jnp.concatenate(outs, axis=0)
    return jnp.take(out, jnp.asarray(inv), axis=0)


def packed_lora_delta(
    x,
    a,
    b,
    alpha,
    *,
    impl: Optional[str] = None,
    remat: Optional[str] = None,
    ranks: Optional[Tuple[int, ...]] = None,
):
    """alpha_n * (x_n @ A_n) @ B_n for N packed adapters.

    x: (N, T, d); a: (N, d, r); b: (N, r, k); alpha: (N,) -> (N, T, k).
    Heterogeneous ranks are zero-padded to the pack's bucket rank by
    ``repro.core.pack``; with ``ranks=None`` padded columns/rows contribute
    exactly zero to both the output and every gradient, and with the pack's
    static rank tuple passed the padding is sliced away entirely (ragged
    same-rank segments — no wasted FLOPs, structurally zero pad grads).
    ``remat`` picks the backward xA policy (module docstring).
    """
    impl_r = _unfused(_resolve(impl))
    remat_r = remat or DEFAULT_REMAT
    assert remat_r in ("recompute", "save"), remat_r
    alpha = alpha.astype(jnp.float32)
    if ranks is not None and len(set(ranks)) > 1:
        return _ragged_call(
            lambda xs, as_, bs, als: _packed_lora_delta(
                xs, as_, bs, als, impl_r, remat_r
            ),
            x, a, b, alpha, ranks,
        )
    return _packed_lora_delta(x, a, b, alpha, impl_r, remat_r)


def fused_lora_linear(
    x,
    w,
    a,
    b,
    alpha,
    *,
    impl: Optional[str] = None,
    remat: Optional[str] = None,
    ranks: Optional[Tuple[int, ...]] = None,
    blocks: Optional[Tuple[int, int, int]] = None,
):
    """Fused ``x @ W + alpha_n * (x_n @ A_n) @ B_n`` (kernels/fused.py),
    with the same ragged-rank segmentation as :func:`packed_lora_delta` —
    each same-rank segment runs its own fused grid pass (the base GEMM rides
    along per segment, so a segment never re-reads another segment's rows).

    x: (N, ..., d_in); w: (d_in, d_out); a/b/alpha as usual.
    """
    from repro.kernels.fused import fused_lora

    impl_r = _resolve(impl)
    if impl_r in ("pallas", "xla", "auto"):
        impl_r = {"pallas": "fused_pallas", "xla": "fused_xla"}.get(
            impl_r, "fused_xla"
        )
    remat_r = remat or DEFAULT_REMAT
    alpha = alpha.astype(jnp.float32)
    if ranks is not None and len(set(ranks)) > 1:
        return _ragged_call(
            lambda xs, as_, bs, als: fused_lora(
                xs, w, as_, bs, als, impl=impl_r, remat=remat_r, blocks=blocks
            ),
            x, a, b, alpha, ranks,
        )
    return fused_lora(
        x, w, a, b, alpha, impl=impl_r, remat=remat_r, blocks=blocks
    )

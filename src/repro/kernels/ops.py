"""Jit'd public ops for packed-LoRA computation.

``packed_lora_delta(x, a, b, alpha)`` computes the adapter-side contribution
``alpha_n * (x_n @ A_n) @ B_n`` for all N packed adapters with a custom VJP
whose four gradient dataflows mirror the paper's backward cases (§5.2):

  case 1  dB    = (xA)^T @ g        (tile over output dim, contract over seq)
  case 2  d(xA) = g @ B^T           (tile over seq + rank, contract over k)
  case 3  dA    = x^T @ d(xA)       (tile over d + rank, contract over seq)
  case 4  dx    = d(xA) @ A^T       (tile over seq + d, contract over rank)

All four are the grouped-GEMM primitive with transposed operands; on TPU the
rank-dim reduction of case 4 is a single K-step inside the tile (rank <= 128),
avoiding the scratch-buffer bookkeeping the paper describes on GPU.

Backend selection:
  impl="pallas"  : the Pallas kernel (interpret=True automatically off-TPU)
  impl="xla"     : batched einsum (same packed semantics, XLA-fused GEMMs)
  impl="auto"    : pallas on TPU, xla elsewhere (default — CPU tests/benches
                   measure real XLA wall-clock, TPU gets the custom kernel)

``alpha`` is a hyperparameter, not a trainable weight: its cotangent is zero.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.packed_matmul import packed_matmul as _pallas_matmul

_IMPL_DEFAULT = "auto"


def set_default_impl(impl: str) -> None:
    global _IMPL_DEFAULT
    assert impl in ("auto", "pallas", "xla")
    _IMPL_DEFAULT = impl


def _resolve(impl: Optional[str]) -> str:
    impl = impl or _IMPL_DEFAULT
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


def grouped_matmul(x, w, scale=None, *, impl: Optional[str] = None):
    """out[n] = scale[n] * x[n] @ w[n]; dispatches pallas/xla.

    x may carry extra token dims (N, ..., K). The Pallas kernel is a 3D
    grouped GEMM, so those dims are flattened around the call; the xla path
    keeps them (sharding-friendly under pjit — see packed_matmul_ref)."""
    if _resolve(impl) == "pallas":
        lead = x.shape[1:-1]
        x3 = x.reshape(x.shape[0], -1, x.shape[-1])
        out = _pallas_matmul(
            x3, w, scale, interpret=jax.default_backend() != "tpu"
        )
        return out.reshape(x.shape[0], *lead, w.shape[-1])
    return _ref.packed_matmul_ref(x, w, scale)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _packed_lora_delta(x, a, b, alpha, impl):
    xa = grouped_matmul(x, a, impl=impl)
    return grouped_matmul(xa, b, alpha, impl=impl)


def _fwd(x, a, b, alpha, impl):
    out = _packed_lora_delta(x, a, b, alpha, impl)
    return out, (x, a, b, alpha)


def _bwd(impl, res, g):
    x, a, b, alpha = res
    g = g.astype(x.dtype)
    # recompute xA (cheap: (N, ..., r<=128)) instead of saving — rematerialize
    xa = grouped_matmul(x, a, impl=impl)  # (N, ..., r)
    g_s = g * alpha.reshape(alpha.shape[0], *([1] * (g.ndim - 1))).astype(g.dtype)
    if x.ndim == 3:
        # 3D: all four cases go through the grouped kernel (paper §5.2)
        # case 1: dB = (xA)^T @ g_s               (N, r, k)
        db = grouped_matmul(jnp.swapaxes(xa, 1, 2), g_s, impl=impl)
        # case 2: d(xA) = g_s @ B^T               (N, T, r)
        dxa = grouped_matmul(g_s, jnp.swapaxes(b, 1, 2), impl=impl)
        # case 3: dA = x^T @ d(xA)                (N, d, r)
        da = grouped_matmul(jnp.swapaxes(x, 1, 2), dxa, impl=impl)
        # case 4: dx = d(xA) @ A^T                (N, T, d)
        dx = grouped_matmul(dxa, jnp.swapaxes(a, 1, 2), impl=impl)
        return dx, da, db, jnp.zeros_like(alpha)
    # N-D (FSDP pack layout): weight grads contract over ALL token dims
    db = jnp.einsum("n...r,n...k->nrk", xa, g_s)
    dxa = grouped_matmul(g_s, jnp.swapaxes(b, 1, 2), impl=impl)
    da = jnp.einsum("n...d,n...r->ndr", x, dxa)
    dx = grouped_matmul(dxa, jnp.swapaxes(a, 1, 2), impl=impl)
    return dx, da.astype(a.dtype), db.astype(b.dtype), jnp.zeros_like(alpha)


_packed_lora_delta.defvjp(_fwd, _bwd)


def packed_lora_delta(x, a, b, alpha, *, impl: Optional[str] = None):
    """alpha_n * (x_n @ A_n) @ B_n for N packed adapters.

    x: (N, T, d); a: (N, d, r); b: (N, r, k); alpha: (N,) -> (N, T, k).
    Heterogeneous ranks are zero-padded to the pack's bucket rank by
    ``repro.core.pack``; padded columns/rows contribute exactly zero to both
    the output and every gradient.
    """
    return _packed_lora_delta(x, a, b, alpha.astype(jnp.float32), impl)

"""Fused base+delta LoRA megakernel: ``y = x @ W + alpha * (x @ A) @ B``.

The two-pass formulation (``packed_lora.lora_linear``: base GEMM, then the
grouped delta of ``ops.packed_lora_delta``) reads the activations twice and
dispatches two kernel sequences per projection. LoRAFusion's observation
(PAPERS.md) is that the LoRA computation is small enough to ride the base
GEMM's tiles: the A-contraction consumes exactly the x tiles the base matmul
is already streaming through VMEM (rank <= 128 = one lane width, so the whole
rank dimension lives inside a single K-tile), and the delta is applied when
the output tile is written. One grid pass, one read of x, one write of y.

Two implementations with identical semantics:

  * ``fused_matmul`` — the Pallas TPU kernel. Grid (N, M/bm, L/bl, K/bk),
    K innermost; two VMEM f32 scratch accumulators (base tile ``acc`` and
    running ``xa``); on the last K step the output tile is written once as
    ``acc + alpha * xa @ B_tile``. ``interpret=True`` runs the same kernel
    body on CPU as a correctness oracle.
  * ``_fused_xla`` — the same computation as one jit-fusable XLA expression,
    used off-TPU so CPU CI measures real wall-clock (interpret mode is a
    semantics check, not a timing path).

Both are wrapped in ONE ``custom_vjp`` (``fused_lora``): the backward's
``dx = g @ W^T + d(xA) @ A^T`` is *again* the fused primitive with transposed
operands — ``fused(g, W^T, B^T, A^T, alpha)`` — so dx shares g tiles exactly
like the forward shares x tiles. The xA intermediate needed for dB follows a
configurable remat policy: ``remat="save"`` (the measured-crossover default,
``ops.DEFAULT_REMAT``: the (N, ..., r<=128) residual buys one full-d_in GEMM
off the backward) or ``remat="recompute"`` — see ``benchmarks/bench_kernels
.py`` remat rows. Both policies produce bit-identical gradients; the Pallas
path always recomputes (xA never leaves VMEM scratch).

``w``'s cotangent is computed honestly (the primitive is differentiable in
every array argument) — training takes grads w.r.t. adapters only, so XLA
dead-code-eliminates the base-weight gradient GEMM.

Quantized frozen base (ISSUE 8): ``w`` may also be a ``{"codes", "scales"}``
dict (see ``kernels/quant.py``). The Pallas kernel then dequantizes each W
tile in-register inside the K-loop (scales ride as a second operand; the
VMEM scratch accumulators are unchanged), and the XLA path dequantizes once
before the same expression. Elementwise dequant is tiling-invariant, so the
in-kernel per-tile form is bit-exact against dequantize-then-same-kernel on
identical quantized weights. The backward dequantizes once and reuses the
dense tiles for ``dx = g @ W^T + d(xA) @ A^T``; the codes' cotangent is the
mandatory ``float0`` zero (integers have no tangent space) — the base stays
frozen by construction.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quant import NF4_CODEBOOK, dequantize, is_quantized

# default Pallas tile sizes; the autotuner (kernels/autotune.py) overrides
# them per (backend, shape bucket)
DEFAULT_BLOCKS = (256, 256, 512)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# Pallas megakernel
# ---------------------------------------------------------------------------


def _fused_kernel(
    x_ref, w_ref, a_ref, b_ref, scale_ref, out_ref, acc_ref, xa_ref, *, n_k: int
):
    """One (adapter, m-tile, l-tile, k-step) grid cell.

    ``acc`` accumulates the base tile ``x @ W``; ``xa`` accumulates the
    A-contraction off the SAME x tile (rank is never tiled: it fits one lane
    width). On the last K step the delta is applied in-register and the
    output tile is written exactly once.
    """
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[0]
    acc_ref[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    xa_ref[...] += jnp.dot(x, a_ref[0], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        scale = scale_ref[0, 0]
        delta = jnp.dot(
            xa_ref[...],
            b_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        out_ref[0, ...] = (acc_ref[...] + scale * delta).astype(out_ref.dtype)


def _dequant_tile(wq, ws, mode, blk, dtype):
    """Dequantize one (bk, bl) W tile in-register from its codes/scales tiles.

    Elementwise per tile, so per-tile dequant == global dequant exactly; the
    final cast to ``dtype`` mirrors the dense path's ``w.astype(x.dtype)``
    (bit-exactness vs dequantize-then-dense-kernel requires identical casts
    before the dot).
    """
    if mode == "int8":
        w = wq.astype(jnp.float32) * ws  # (bk, bl) * (1, bl)
    else:  # nf4: unpack 2 codes per uint8 (low nibble = even K-row)
        lo = (wq & 0xF).astype(jnp.int32)
        hi = (wq >> 4).astype(jnp.int32)
        p, bl = wq.shape
        idx = jnp.stack([lo, hi], axis=1).reshape(2 * p, bl)
        # codebook lookup as a select chain: Pallas kernels cannot capture
        # array constants, and 16 scalar selects vectorize on the VPU; the
        # result is value-identical to the gather ``dequantize`` uses.
        vals = jnp.zeros(idx.shape, jnp.float32)
        for i, c in enumerate(NF4_CODEBOOK.tolist()):
            vals = jnp.where(idx == i, jnp.float32(c), vals)
        nb = ws.shape[0]  # = bk // blk scale rows in this tile
        w = (vals.reshape(nb, blk, bl) * ws[:, None, :]).reshape(2 * p, bl)
    return w.astype(dtype)


def _fused_kernel_q(
    x_ref, wq_ref, ws_ref, a_ref, b_ref, scale_ref, out_ref, acc_ref, xa_ref,
    *, n_k: int, mode: str, blk: int
):
    """Quantized-base variant of ``_fused_kernel``: identical grid, identical
    VMEM scratch; the only change is that the W tile is dequantized
    in-register before the base dot (codes + scales stream in as two
    operands instead of one dense tile)."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[0]
    w = _dequant_tile(wq_ref[...], ws_ref[...], mode, blk, x.dtype)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)
    xa_ref[...] += jnp.dot(x, a_ref[0], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        scale = scale_ref[0, 0]
        delta = jnp.dot(
            xa_ref[...],
            b_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        out_ref[0, ...] = (acc_ref[...] + scale * delta).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_l", "block_k", "interpret"),
)
def fused_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    scale: Optional[jnp.ndarray] = None,
    w_scales: Optional[jnp.ndarray] = None,
    *,
    block_m: int = DEFAULT_BLOCKS[0],
    block_l: int = DEFAULT_BLOCKS[1],
    block_k: int = DEFAULT_BLOCKS[2],
    interpret: bool = True,
) -> jnp.ndarray:
    """out[n] = x[n] @ w + scale[n] * (x[n] @ a[n]) @ b[n].

    x: (N, M, K); w: (K, L) shared; a: (N, K, r); b: (N, r, L); scale: (N,).
    Inputs are zero-padded to tile multiples (exact for contractions; the
    output is sliced back); the rank dim is padded to one lane width and
    never tiled. ``interpret=True`` validates on CPU; on TPU pass False.

    With ``w_scales``, ``w`` is quantized codes instead of a dense weight —
    int8 codes (K, L) with per-channel scales (1, L), or packed nf4 uint8
    codes (K//2, L) with block scales (K//blk, L) — and the kernel
    dequantizes each W tile in-register inside the K-loop.
    """
    n, m, k = x.shape
    if w_scales is None:
        mode, blk = None, 0
        k2, l = w.shape
    else:
        mode = "int8" if w.dtype == jnp.int8 else "nf4"
        k2 = w.shape[0] * (2 if mode == "nf4" else 1)
        l = w.shape[1]
        blk = 0 if mode == "int8" else k2 // w_scales.shape[0]
    n2, k3, r = a.shape
    n3, r2, l2 = b.shape
    assert k == k2 == k3 and n == n2 == n3 and r == r2 and l == l2, (
        x.shape, w.shape, a.shape, b.shape,
    )
    if scale is None:
        scale = jnp.ones((n,), dtype=jnp.float32)
    scale = scale.astype(jnp.float32).reshape(n, 1)

    # TPU-aligned tiles: last dim multiple of 128 (lanes), 2nd-to-last of 8;
    # the rank lives inside one 128-lane register tile (never grid-tiled).
    bm = min(block_m, _round_up(m, 8))
    bl = min(block_l, _round_up(l, 128))
    bk = min(block_k, _round_up(k, 128))
    rp = _round_up(r, 128)
    mp, lp, kp = _round_up(m, bm), _round_up(l, bl), _round_up(k, bk)
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, 0), (0, mp - m), (0, kp - k)))
    if mode is None:
        if (kp, lp) != (k, l):
            w = jnp.pad(w, ((0, kp - k), (0, lp - l)))
    else:
        # K-padding of codes/scales with zeros is exact: the padded K rows of
        # x are zeros, and 0 * finite == 0 in f32 whatever the padded codes
        # dequantize to.
        if mode == "int8":
            w = jnp.pad(w, ((0, kp - k), (0, lp - l)))
            w_scales = jnp.pad(w_scales, ((0, 0), (0, lp - l)))
        else:
            assert bk % 2 == 0 and blk > 0 and bk % blk == 0, (bk, blk)
            w = jnp.pad(w, ((0, (kp - k) // 2), (0, lp - l)))
            w_scales = jnp.pad(
                w_scales, ((0, (kp - k) // blk), (0, lp - l))
            )

    if (kp, rp) != (k, r):
        a = jnp.pad(a, ((0, 0), (0, kp - k), (0, rp - r)))
    if (rp, lp) != (r, l):
        b = jnp.pad(b, ((0, 0), (0, rp - r), (0, lp - l)))

    n_k = kp // bk
    grid = (n, mp // bm, lp // bl, n_k)

    x_spec = pl.BlockSpec((1, bm, bk), lambda ad, i, j, s: (ad, i, s))
    a_spec = pl.BlockSpec((1, bk, rp), lambda ad, i, j, s: (ad, s, 0))
    b_spec = pl.BlockSpec((1, rp, bl), lambda ad, i, j, s: (ad, 0, j))
    s_spec = pl.BlockSpec((1, 1), lambda ad, i, j, s: (ad, 0))
    if mode is None:
        kernel = functools.partial(_fused_kernel, n_k=n_k)
        in_specs = [
            x_spec,
            pl.BlockSpec((bk, bl), lambda ad, i, j, s: (s, j)),
            a_spec, b_spec, s_spec,
        ]
        operands = (x, w, a, b, scale)
    else:
        kernel = functools.partial(
            _fused_kernel_q, n_k=n_k, mode=mode, blk=blk
        )
        wq_rows = bk // 2 if mode == "nf4" else bk
        ws_rows = 1 if mode == "int8" else bk // blk
        in_specs = [
            x_spec,
            pl.BlockSpec((wq_rows, bl), lambda ad, i, j, s: (s, j)),
            pl.BlockSpec(
                (ws_rows, bl),
                (lambda ad, i, j, s: (0, j)) if mode == "int8"
                else (lambda ad, i, j, s: (s, j)),
            ),
            a_spec, b_spec, s_spec,
        ]
        operands = (x, w, w_scales, a, b, scale)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bl), lambda ad, i, j, s: (ad, i, j)),
        out_shape=jax.ShapeDtypeStruct((n, mp, lp), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bl), jnp.float32),
            pltpu.VMEM((bm, rp), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    return out[:, :m, :l]


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def _bcast(alpha: jnp.ndarray, ndim: int) -> jnp.ndarray:
    return alpha.reshape(alpha.shape[0], *([1] * (ndim - 1)))


def _xa(x, a):
    return jnp.einsum("n...k,nkr->n...r", x, a, preferred_element_type=jnp.float32).astype(x.dtype)


def _fused_xla(x, w, a, b, alpha):
    """One fusable XLA expression: base + scaled delta in a single add.

    The base contraction matches the two-pass path's ``x @ w`` bit-for-bit
    (same dot_general dims); the single final add is the only reassociation
    versus two-pass (which adds bias between base and delta when present).
    A quantized ``w`` is dequantized up front — the identical jnp formula the
    two-pass reference uses, so parity stays bit-exact.
    """
    if is_quantized(w):
        w = dequantize(w)
    base = x @ w.astype(x.dtype)
    xa = _xa(x, a)
    delta = jnp.einsum(
        "n...r,nrl->n...l", xa, b, preferred_element_type=jnp.float32
    )
    delta = delta * _bcast(alpha, delta.ndim)
    return base + delta.astype(x.dtype)


def _run_fwd(x, w, a, b, alpha, impl, blocks):
    if impl == "fused_pallas":
        lead = x.shape[1:-1]
        x3 = x.reshape(x.shape[0], -1, x.shape[-1])
        bm, bl, bk = blocks or DEFAULT_BLOCKS
        if is_quantized(w):
            wq, ws = w["codes"], w["scales"]
            d_out = wq.shape[-1]
            out = fused_matmul(
                x3, wq, a.astype(x.dtype), b.astype(x.dtype),
                alpha, ws,
                block_m=bm, block_l=bl, block_k=bk,
                interpret=jax.default_backend() != "tpu",
            )
        else:
            d_out = w.shape[-1]
            out = fused_matmul(
                x3, w.astype(x.dtype), a.astype(x.dtype), b.astype(x.dtype),
                alpha,
                block_m=bm, block_l=bl, block_k=bk,
                interpret=jax.default_backend() != "tpu",
            )
        return out.reshape(x.shape[0], *lead, d_out)
    return _fused_xla(x, w, a.astype(x.dtype), b.astype(x.dtype), alpha)


# ---------------------------------------------------------------------------
# One custom_vjp covering both implementations
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _fused_lora(x, w, a, b, alpha, impl, remat, blocks):
    return _run_fwd(x, w, a, b, alpha, impl, blocks)


def _fwd(x, w, a, b, alpha, impl, remat, blocks):
    out = _run_fwd(x, w, a, b, alpha, impl, blocks)
    # cast exactly as the backward's recompute would: both policies must be
    # bit-identical even for callers passing a in a different dtype than x
    saved_xa = (
        _xa(x, a.astype(x.dtype))
        if remat == "save" and impl != "fused_pallas"
        else None
    )
    return out, (x, w, a, b, alpha, saved_xa)


def _bwd(impl, remat, blocks, res, g):
    x, w, a, b, alpha, saved_xa = res
    # Quantized base: dequantize ONCE, then reuse the dense tiles for the
    # whole dx GEMM — identical ops to the reference backward run on
    # pre-dequantized weights, hence bit-exact against it.
    wd = dequantize(w) if is_quantized(w) else w
    g = g.astype(x.dtype)
    al = _bcast(alpha, g.ndim).astype(g.dtype)
    g_s = g * al
    a_c = a.astype(x.dtype)
    b_c = b.astype(x.dtype)
    # d(xA) = g_s @ B^T  (needed for dA either way)
    dxa = jnp.einsum(
        "n...l,nrl->n...r", g_s, b_c, preferred_element_type=jnp.float32
    ).astype(x.dtype)
    # dx = g @ W^T + d(xA) @ A^T — the fused primitive again, on transposed
    # operands: fused(g, W^T, B^T, A^T, alpha) shares g tiles the way the
    # forward shares x tiles.
    if impl == "fused_pallas":
        lead = g.shape[1:-1]
        g3 = g.reshape(g.shape[0], -1, g.shape[-1])
        bm, bl, bk = blocks or DEFAULT_BLOCKS
        dx = fused_matmul(
            g3,
            jnp.swapaxes(wd.astype(x.dtype), 0, 1),
            jnp.swapaxes(b_c, 1, 2),
            jnp.swapaxes(a_c, 1, 2),
            alpha,
            block_m=bm, block_l=bl, block_k=bk,
            interpret=jax.default_backend() != "tpu",
        ).reshape(g.shape[0], *lead, wd.shape[0])
    else:
        dx = (
            jnp.einsum(
                "n...l,kl->n...k", g, wd.astype(g.dtype),
                preferred_element_type=jnp.float32,
            ).astype(x.dtype)
            + jnp.einsum(
                "n...r,nkr->n...k", dxa, a_c,
                preferred_element_type=jnp.float32,
            ).astype(x.dtype)
        )
    xa = saved_xa if saved_xa is not None else _xa(x, a_c)
    da = jnp.einsum("n...k,n...r->nkr", x, dxa).astype(a.dtype)
    db = jnp.einsum("n...r,n...l->nrl", xa, g_s).astype(b.dtype)
    if is_quantized(w):
        # frozen by construction: integer codes have no tangent space (the
        # mandatory float0 zero), and the scales' cotangent is zero.
        dw = {
            "codes": np.zeros(w["codes"].shape, dtype=jax.dtypes.float0),
            "scales": jnp.zeros_like(w["scales"]),
        }
    else:
        # base weights are frozen in training (grads only w.r.t. adapters),
        # so XLA dead-code-eliminates this GEMM there; it exists so the
        # primitive is honestly differentiable in w for any dense caller.
        dw = jnp.einsum("n...k,n...l->kl", x, g).astype(w.dtype)
    return dx, dw, da, db, jnp.zeros_like(alpha)


_fused_lora.defvjp(_fwd, _bwd)


def fused_lora(
    x: jnp.ndarray,
    w: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    impl: str = "fused_xla",
    remat: Optional[str] = None,
    blocks: Optional[Tuple[int, int, int]] = None,
) -> jnp.ndarray:
    """``x @ w + alpha_n * (x_n @ A_n) @ B_n`` for N packed adapters.

    x: (N, ..., d_in); w: (d_in, d_out) shared frozen base — dense array or
    quantized ``{"codes", "scales"}`` dict; a: (N, d_in, r);
    b: (N, r, d_out); alpha: (N,). ``impl`` is the *resolved* backend
    ("fused_pallas" | "fused_xla" — dispatch lives in ``ops.py``); ``remat``
    picks the backward xA policy (None -> ``ops.DEFAULT_REMAT``, the
    measured-crossover default every production path uses); ``blocks``
    overrides the Pallas tile sizes (autotuner hook).
    """
    if remat is None:
        from repro.kernels.ops import DEFAULT_REMAT

        remat = DEFAULT_REMAT
    assert impl in ("fused_pallas", "fused_xla"), impl
    assert remat in ("recompute", "save"), remat
    return _fused_lora(
        x, w, a, b, alpha.astype(jnp.float32), impl, remat,
        tuple(blocks) if blocks is not None else None,
    )

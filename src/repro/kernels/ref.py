"""Pure-jnp oracles for the packed-LoRA kernels.

These define the semantics that the Pallas kernels must match bit-for-bit
(up to float accumulation order). Shapes:

  x     : (N, M, K)   N = number of packed adapters, M = batch*seq tokens
  w     : (N, K, L)
  scale : (N,) or None
  out   : (N, M, L)   out[n] = scale[n] * x[n] @ w[n]

``packed_lora_delta_ref`` is the full adapter delta  alpha_n * (x_n A_n) B_n
with zero-padded heterogeneous ranks (padding contributes exactly 0).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def packed_matmul_ref(
    x: jnp.ndarray, w: jnp.ndarray, scale: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """x: (N, ..., K); w: (N, K, L) -> (N, ..., L). The token dims stay
    un-merged ("n...k") so that under pjit a pack batch (N, B, S, d) with B
    sharded over the model axis never needs an unrepresentable (B,S)-merge
    resharding (FSDP execution mode, DESIGN.md §9)."""
    out = jnp.einsum(
        "n...k,nkl->n...l", x, w, preferred_element_type=jnp.float32
    )
    if scale is not None:
        out = out * scale.reshape(scale.shape[0], *([1] * (out.ndim - 1)))
    return out.astype(x.dtype)


def packed_lora_delta_ref(
    x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, alpha: jnp.ndarray
) -> jnp.ndarray:
    """alpha_n * (x_n @ A_n) @ B_n  for each adapter n."""
    xa = packed_matmul_ref(x, a)
    return packed_matmul_ref(xa, b, scale=alpha)


def sequential_lora_delta_ref(x, a, b, alpha):
    """The paper's naive baseline: loop adapters one by one (python loop,
    one small GEMM pair per adapter) — used by benchmarks, not by the system."""
    outs = []
    for n in range(x.shape[0]):
        xa = x[n] @ a[n]
        outs.append(alpha[n] * (xa @ b[n]))
    return jnp.stack(outs).astype(x.dtype)

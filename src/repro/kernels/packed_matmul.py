"""Pallas TPU kernel: grouped batched GEMM over packed LoRA adapters.

This is the TPU adaptation of PLoRA's CUTLASS grouped kernels (paper §5.2).
One ``pallas_call`` covers all N adapters: the adapter index is the leading
grid dimension, so small per-adapter GEMMs (rank as low as 8) are batched into
a single kernel with MXU-aligned (seq/hidden) tiles — never tiling the rank
dimension, which lives inside a single K-tile (rank <= 128 = one lane width).

Grid: (N, M/bm, L/bl, K/bk); K is innermost so a VMEM f32 scratch accumulates
partial products across K-steps and the output tile is written once on the
last step (optionally scaled by the per-adapter alpha).

All four backward dataflows of the paper (§5.2 cases 1-4) are expressed as
this same primitive with transposed operands — see ``ops.py``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, scale_ref, out_ref, acc_ref, *, n_k: int):
    """One (adapter, m-tile, l-tile, k-step) grid cell."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        scale = scale_ref[0, 0]
        out_ref[0, ...] = (acc_ref[...] * scale).astype(out_ref.dtype)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_l", "block_k", "interpret"),
)
def packed_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    scale: Optional[jnp.ndarray] = None,
    *,
    block_m: int = 256,
    block_l: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """out[n] = scale[n] * (x[n] @ w[n]).

    x: (N, M, K); w: (N, K, L); scale: (N,) or None. Inputs are zero-padded to
    tile multiples (exact for the contraction; output is sliced back), so any
    shape is accepted. ``interpret=True`` validates on CPU; on TPU pass False.
    """
    n, m, k = x.shape
    n2, k2, l = w.shape
    assert n == n2 and k == k2, (x.shape, w.shape)
    if scale is None:
        scale = jnp.ones((n,), dtype=jnp.float32)
    scale = scale.astype(jnp.float32).reshape(n, 1)

    # TPU-aligned tiles: last dim multiple of 128 (lanes), 2nd-to-last of 8.
    bm = min(block_m, _round_up(m, 8))
    bl = min(block_l, _round_up(l, 128))
    bk = min(block_k, _round_up(k, 128))
    mp, lp, kp = _round_up(m, bm), _round_up(l, bl), _round_up(k, bk)
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, 0), (0, mp - m), (0, kp - k)))
    if (kp, lp) != (k, l):
        w = jnp.pad(w, ((0, 0), (0, kp - k), (0, lp - l)))

    n_k = kp // bk
    grid = (n, mp // bm, lp // bl, n_k)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda a, i, j, s: (a, i, s)),
            pl.BlockSpec((1, bk, bl), lambda a, i, j, s: (a, s, j)),
            pl.BlockSpec((1, 1), lambda a, i, j, s: (a, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bl), lambda a, i, j, s: (a, i, j)),
        out_shape=jax.ShapeDtypeStruct((n, mp, lp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bl), jnp.float32)],
        interpret=interpret,
    )(x, w, scale)
    return out[:, :m, :l]

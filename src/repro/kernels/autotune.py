"""Micro-benchmark autotuner for the fused LoRA kernel tier.

The Pallas megakernel's ``(block_m, block_l, block_k)`` tile sizes were
hard-coded constants the planner never saw. This module closes that gap in
both directions:

  * **downward** — sweep a small candidate grid of tile shapes per
    ``(backend, shape bucket)``, time each with the kernel's own entry
    point, and persist the winner + its achieved FLOP/s in a JSON cache so
    repeated runs (and other processes) skip the sweep;
  * **upward** — feed the *measured* throughputs into the scheduling stack:
    ``KernelProfile.calibrate`` returns a :class:`~repro.sched.cost_model
    .CostModel` prior whose LoRA compute term runs at the measured
    fused-vs-two-pass speedup and whose FLOP accounting is ragged (each
    adapter billed at its own rank, since the kernels now run ragged
    same-rank segments), and ``seed_observations`` writes fused-rate
    predictions into a :class:`~repro.sched.profile.ObservationStore` so a
    :class:`~repro.sched.profile.ProfiledCostModel` planner sees
    fused-kernel rates before the first real segment executes.

Backend semantics: on TPU the sweep drives the real Pallas kernel
(``interpret=False``) across all candidates; off-TPU Pallas interpret mode
is a semantics oracle with meaningless timings, so the tuner measures the
fused **XLA** formulation instead (one candidate, ``blocks=None``) — that is
the backend CPU CI actually runs, and its fused/two-pass ratio is exactly
what the cost model needs. ``measure_fn`` is injectable for tests.

Cache format (one JSON file can hold several backends)::

    {"schema": 1, "entries": {"cpu|4,256,2048,2048,64": {
        "blocks": null, "seconds": ..., "flops_per_s": ...,
        "speedup_vs_twopass": ..., "n": 4, "m": 256, "k": 2048,
        "l": 2048, "r": 64}}}
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

_SCHEMA = 1

# Pallas (block_m, block_l, block_k) sweep grid: MXU-aligned, biased toward
# the K-heavy tiles that win on long-d_in LoRA projections.
CANDIDATES: Tuple[Tuple[int, int, int], ...] = (
    (128, 128, 256),
    (128, 256, 512),
    (256, 128, 512),
    (256, 256, 256),
    (256, 256, 512),
    (512, 256, 512),
)


def _pow2(v: int) -> int:
    return 1 << max(0, int(v - 1).bit_length())


def shape_bucket(n: int, m: int, k: int, l: int, r: int) -> Tuple[int, ...]:
    """Power-of-two bucketing: nearby shapes share a tuned entry."""
    return (_pow2(n), _pow2(m), _pow2(k), _pow2(l), max(8, _pow2(r)))


def fused_flops(n: int, m: int, k: int, l: int, r: int) -> float:
    """FLOPs of one fused forward: base GEMM + delta at rank r."""
    return 2.0 * n * m * (k * l + r * (k + l))


def _bucket_key(backend: str, bucket: Tuple[int, ...]) -> str:
    return f"{backend}|" + ",".join(str(v) for v in bucket)


def measure(fn: Callable, *args, iters: int = 3) -> float:
    """Best-of-iters steady-state seconds (compile excluded)."""
    jax.block_until_ready(fn(*args))  # compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


@dataclass
class KernelProfile:
    """Autotune results + the hooks that feed them into planning."""

    backend: str
    entries: Dict[str, Dict] = field(default_factory=dict)

    # ---------------- lookups ----------------

    def entry(self, n: int, m: int, k: int, l: int, r: int) -> Optional[Dict]:
        return self.entries.get(
            _bucket_key(self.backend, shape_bucket(n, m, k, l, r))
        )

    def best_blocks(
        self, n: int, m: int, k: int, l: int, r: int
    ) -> Optional[Tuple[int, int, int]]:
        e = self.entry(n, m, k, l, r)
        if e is None or e.get("blocks") is None:
            return None
        return tuple(e["blocks"])

    def rate(self) -> Optional[float]:
        """Median measured fused FLOP/s across this backend's entries."""
        rates = sorted(
            e["flops_per_s"]
            for k, e in self.entries.items()
            if k.startswith(self.backend + "|") and e.get("flops_per_s")
        )
        if not rates:
            return None
        return rates[len(rates) // 2]

    def lora_speedup(self) -> float:
        """Median measured fused-vs-two-pass speedup (>= 1 when fusing wins);
        1.0 before any measurement. This is the hardware-relative number the
        cost-model calibration uses — absolute CPU rates would not transfer
        to an accelerator prior, the ratio does."""
        sp = sorted(
            e["speedup_vs_twopass"]
            for k, e in self.entries.items()
            if k.startswith(self.backend + "|")
            and e.get("speedup_vs_twopass")
        )
        if not sp:
            return 1.0
        return sp[len(sp) // 2]

    # ---------------- planner feedback ----------------

    def calibrate(self, prior):
        """Return a copy of the analytic prior that prices LoRA work at the
        measured fused-kernel rate and bills ragged (per-adapter-rank)
        FLOPs — what the kernels now actually compute."""
        import dataclasses

        return dataclasses.replace(
            prior, ragged=True, lora_rate_scale=max(self.lora_speedup(), 1e-9)
        )

    def seed_observations(self, store, prior, packs: Sequence[Tuple]) -> None:
        """Write fused-rate iter-time predictions into an ObservationStore.

        ``packs`` is an iterable of ``(configs, degree, seq)``. Each entry is
        recorded as one observation (measured = the autotune-calibrated
        prediction, predicted = the raw prior), so a ProfiledCostModel
        planner prices those pack shapes at fused-kernel rates before the
        first real segment runs — and real measurements EWMA over the seed
        as they arrive."""
        from repro.sched.profile import obs_key

        cal = self.calibrate(prior)
        for configs, d, seq in packs:
            store.update(
                obs_key(prior.cfg.name, configs, d, seq),
                cal.iter_time(configs, d, seq),
                prior.iter_time(configs, d, seq),
            )

    # ---------------- persistence ----------------

    def to_json(self) -> Dict:
        return {"schema": _SCHEMA, "entries": self.entries}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, blob: Dict, backend: Optional[str] = None) -> "KernelProfile":
        if blob.get("schema") != _SCHEMA:
            raise ValueError(f"unknown autotune schema {blob.get('schema')!r}")
        return cls(
            backend=backend or jax.default_backend(),
            entries=dict(blob.get("entries", {})),
        )

    @classmethod
    def load(cls, path: str, backend: Optional[str] = None) -> "KernelProfile":
        with open(path) as f:
            return cls.from_json(json.load(f), backend=backend)


def _default_measure(
    n, m, k, l, r, blocks, backend, twopass: bool = True
) -> Tuple[float, Optional[float]]:
    """(fused_seconds, twopass_seconds|None) for one shape / candidate.

    The two-pass baseline is the backend's OWN unfused tier (pallas grouped
    kernel on TPU, xla einsum elsewhere) — the ratio that calibrates the
    cost model must compare against what the backend would actually run.
    ``twopass=False`` skips the baseline (its timing is blocks-independent,
    so the sweep measures it once per shape, not once per candidate)."""
    from repro.kernels.fused import fused_lora
    from repro.kernels.ops import packed_lora_delta

    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (n, m, k), jnp.float32)
    w = jax.random.normal(ks[1], (k, l), jnp.float32) * 0.02
    a = jax.random.normal(ks[2], (n, k, r), jnp.float32) * 0.02
    b = jax.random.normal(ks[3], (n, r, l), jnp.float32) * 0.02
    alpha = jnp.ones((n,), jnp.float32)

    on_tpu = backend == "tpu"
    if on_tpu:
        fused = jax.jit(
            lambda x, w, a, b, al: fused_lora(
                x, w, a, b, al, impl="fused_pallas", blocks=blocks
            )
        )
    else:
        fused = jax.jit(
            lambda x, w, a, b, al: fused_lora(x, w, a, b, al, impl="fused_xla")
        )
    fused_t = measure(fused, x, w, a, b, alpha)
    if not twopass:
        return fused_t, None
    two_pass = jax.jit(
        lambda x, w, a, b, al: x @ w + packed_lora_delta(
            x, a, b, al, impl="pallas" if on_tpu else "xla"
        )
    )
    return fused_t, measure(two_pass, x, w, a, b, alpha)


def autotune_shape(
    n: int,
    m: int,
    k: int,
    l: int,
    r: int,
    *,
    backend: Optional[str] = None,
    candidates: Sequence[Tuple[int, int, int]] = CANDIDATES,
    measure_fn: Optional[Callable] = None,
    tracer=None,
) -> Dict:
    """Tune one shape: sweep candidates (TPU) or time the XLA fused path
    (anything else), returning the cache entry dict."""
    from repro.obs import NULL_TRACER

    tracer = tracer if tracer is not None else NULL_TRACER
    backend = backend or jax.default_backend()
    measure_fn = measure_fn or _default_measure
    sweep: List[Optional[Tuple[int, int, int]]] = (
        list(candidates) if backend == "tpu" else [None]
    )
    best_blocks, best_t, tp_t = None, float("inf"), float("inf")
    for i, blocks in enumerate(sweep):
        # the two-pass baseline is blocks-independent: time it once per
        # shape (first candidate), not once per candidate
        with tracer.span(
            "autotune.measure", cat="autotune", track="autotune",
            shape=[n, m, k, l, r],
            blocks=list(blocks) if blocks else None,
        ) as msp:
            fused_t, twopass_t = measure_fn(
                n, m, k, l, r, blocks, backend, twopass=(i == 0)
            )
            if tracer.enabled:
                msp.args["seconds"] = fused_t
        if twopass_t is not None:
            tp_t = min(tp_t, twopass_t)
        if fused_t < best_t:
            best_t, best_blocks = fused_t, blocks
    return {
        "n": n, "m": m, "k": k, "l": l, "r": r,
        "blocks": list(best_blocks) if best_blocks else None,
        "seconds": best_t,
        "flops_per_s": fused_flops(n, m, k, l, r) / max(best_t, 1e-12),
        "speedup_vs_twopass": tp_t / max(best_t, 1e-12),
    }


def tune(
    shapes: Sequence[Tuple[int, int, int, int, int]],
    *,
    cache_path: Optional[str] = None,
    backend: Optional[str] = None,
    force: bool = False,
    candidates: Sequence[Tuple[int, int, int]] = CANDIDATES,
    measure_fn: Optional[Callable] = None,
    tracer=None,
) -> KernelProfile:
    """Tune every ``(n, m, k, l, r)`` shape not already in the cache; merge
    into (and re-save) ``cache_path`` when given."""
    backend = backend or jax.default_backend()
    profile = KernelProfile(backend=backend)
    if cache_path:
        import os

        if os.path.exists(cache_path):
            profile = KernelProfile.load(cache_path, backend=backend)
    dirty = False
    for n, m, k, l, r in shapes:
        key = _bucket_key(backend, shape_bucket(n, m, k, l, r))
        if not force and key in profile.entries:
            continue
        profile.entries[key] = autotune_shape(
            n, m, k, l, r,
            backend=backend, candidates=candidates, measure_fn=measure_fn,
            tracer=tracer,
        )
        dirty = True
    if cache_path and dirty:
        profile.save(cache_path)
    return profile


def model_shapes(cfg, configs, seq: int, *, fast: bool = True):
    """Representative fused-kernel shapes of one pack on one model: the
    attention d_model x d_model projection and (full mode) the d_model x
    d_ff MLP projection, at the pack's width / bucket rank / per-adapter
    token count."""
    n = max(1, len(configs))
    m = max((c.batch_size for c in configs), default=1) * seq
    r = max(8, (max((c.rank for c in configs), default=8) + 7) // 8 * 8)
    shapes = [(n, m, cfg.d_model, cfg.d_model, r)]
    if not fast:
        shapes.append((n, m, cfg.d_model, cfg.d_ff, r))
    return shapes


def tune_for_model(
    cfg,
    configs,
    *,
    seq: int,
    cache_path: Optional[str] = None,
    fast: bool = True,
    measure_fn: Optional[Callable] = None,
    tracer=None,
) -> KernelProfile:
    """Launcher hook: tune this pack's representative projection shapes."""
    return tune(
        model_shapes(cfg, configs, seq, fast=fast),
        cache_path=cache_path,
        measure_fn=measure_fn,
        tracer=tracer,
    )

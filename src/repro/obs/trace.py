"""Span-based structured tracer with Chrome trace-event export.

One :class:`Tracer` per run collects :class:`Span` records — named,
categorized intervals on a monotonic clock (`time.perf_counter`) — from
every tier of the stack and exports them as a single Chrome trace-event
JSON that loads in Perfetto or ``chrome://tracing``.

Span naming convention (see ROADMAP.md, Observability):

* names are dotted ``tier.operation`` — ``engine.replan``,
  ``runner.wait_units``, ``executor.train``, ``dispatch.segment``,
  ``host0.segment``, ``serve.step``, ``autotune.measure``;
* ``cat`` is the tier — one of :data:`TIER_CATS` — and is what the CI
  trace check counts (``scripts/check_trace.py --min-tiers``);
* ``track`` picks the Perfetto row: device units (``unit3`` or
  ``units0-3``), hosts (``host1``), serve rows (``row2``), or the
  emitting thread name when unset.

Concurrency: span stacks are thread-local, so concurrently open spans on
different threads nest independently; the finished-span list and id
counter are lock-protected. Cross-process stitching (multihost workers)
ships finished spans back as plain dicts and re-ingests them with
:meth:`Tracer.ingest`, which remaps ids, rebases clocks, and reparents
the worker's root onto the dispatcher-side span.

Disabled tracing is a true no-op: :data:`NULL_TRACER` returns one shared
context-manager singleton from ``span()`` and touches no state, so
always-on call sites cost an attribute lookup and a method call.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional

from .metrics import MetricsRegistry, NULL_METRICS

# tiers a span's `cat` may belong to; the CI trace check counts distinct
# members of this set present in a capture
TIER_CATS = (
    "engine",
    "runner",
    "executor",
    "dispatch",
    "host",
    "serve",
    "autotune",
)


@dataclass(frozen=True)
class TraceCtx:
    """Trace context crossing the multihost pickle boundary.

    Carried as the ``trace`` field of the host wire-protocol run payload
    (`repro.cluster.multihost` re-exports it beside the other wire
    dataclasses): ``trace_id`` names the dispatcher's trace, ``parent``
    is the dispatcher-side span id the worker's root span stitches
    under. Plain picklable data, like :class:`~repro.cluster.multihost.KernelPolicy`."""

    trace_id: str
    parent: Optional[int] = None


@dataclass
class Span:
    """One finished (or still-open) interval on the trace timeline.

    ``start``/``end`` are absolute `time.perf_counter` seconds in the
    owning tracer's clock domain; export rebases them onto the tracer's
    ``t0``. ``args`` must stay JSON-serializable — it lands verbatim in
    the Chrome event's ``args``."""

    name: str
    cat: str = ""
    track: str = ""
    span_id: int = 0
    parent_id: Optional[int] = None
    root_id: int = 0
    start: float = 0.0
    end: float = 0.0
    args: Dict[str, Any] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "cat": self.cat,
            "track": self.track,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "root_id": self.root_id,
            "start": self.start,
            "end": self.end,
            "args": self.args,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Span":
        return cls(
            name=d["name"],
            cat=d.get("cat", ""),
            track=d.get("track", ""),
            span_id=d.get("span_id", 0),
            parent_id=d.get("parent_id"),
            root_id=d.get("root_id", 0),
            start=d.get("start", 0.0),
            end=d.get("end", 0.0),
            args=dict(d.get("args") or {}),
        )


class _SpanCM:
    """Context manager handed out by :meth:`Tracer.span`.

    Not ``@contextmanager``: a plain object with ``__enter__``/``__exit__``
    is cheaper, and lets the disabled path reuse one shared instance."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        self._tracer._push(self._span)
        return self._span

    def __exit__(self, exc_type, exc, tb) -> None:
        self._tracer._pop(self._span)
        return None


class _NullSpanCM:
    """Shared no-op context manager; yields a throwaway blank Span so
    call sites may still write ``sp.args[...]`` without branching."""

    __slots__ = ()
    _BLANK = Span(name="")

    def __enter__(self) -> Span:
        return self._BLANK

    def __exit__(self, exc_type, exc, tb) -> None:
        return None


_NULL_CM = _NullSpanCM()


class Tracer:
    """Collects spans from any thread; exports one Chrome trace.

    Parameters
    ----------
    enabled:
        ``False`` makes every operation a no-op (``span()`` returns the
        shared null context manager). :data:`NULL_TRACER` is the shared
        disabled instance — prefer it over constructing your own.
    metrics:
        A :class:`MetricsRegistry` to pair with this tracer; created on
        demand if omitted. Instrumented tiers reach it via ``.metrics``
        so one object threads both signals through the stack.
    """

    def __init__(self, enabled: bool = True,
                 metrics: Optional[MetricsRegistry] = None):
        self.enabled = enabled
        if metrics is not None:
            self.metrics = metrics
        else:
            self.metrics = MetricsRegistry() if enabled else NULL_METRICS
        self.trace_id = f"trace-{id(self):x}"
        self.t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._finished: List[Span] = []
        self._next_id = 1
        self._tls = threading.local()

    # -- internal span lifecycle -------------------------------------------

    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _alloc_id(self) -> int:
        with self._lock:
            sid = self._next_id
            self._next_id += 1
            return sid

    def _push(self, span: Span) -> None:
        span.start = time.perf_counter()
        self._stack().append(span)

    def _pop(self, span: Span) -> None:
        span.end = time.perf_counter()
        st = self._stack()
        if st and st[-1] is span:
            st.pop()
        else:  # mis-nested exit; drop from wherever it sits
            try:
                st.remove(span)
            except ValueError:
                pass
        with self._lock:
            self._finished.append(span)

    # -- public recording API ----------------------------------------------

    def span(self, name: str, *, cat: str = "", track: str = "",
             parent: Optional[int] = None, **args: Any):
        """Open a span: ``with tracer.span("executor.train", cat="executor",
        track="unit0", job_id=3) as sp: ...``.

        ``parent`` overrides the implicit parent (top of this thread's
        stack) — used when the logical parent lives on another thread,
        e.g. engine-submitted work running on a pool thread."""
        if not self.enabled:
            return _NULL_CM
        if parent is None:
            st = self._stack()
            top = st[-1] if st else None
            parent_id = top.span_id if top else None
            root_id = top.root_id if top else None
        else:
            parent_id = parent
            root_id = None
            with self._lock:
                for s in reversed(self._finished):
                    if s.span_id == parent:
                        root_id = s.root_id
                        break
            if root_id is None:
                st = self._stack()
                for s in reversed(st):
                    if s.span_id == parent:
                        root_id = s.root_id
                        break
        sid = self._alloc_id()
        sp = Span(name=name, cat=cat, track=track, span_id=sid,
                  parent_id=parent_id,
                  root_id=root_id if root_id is not None else sid,
                  args=dict(args))
        return _SpanCM(self, sp)

    def instant(self, name: str, *, cat: str = "", track: str = "",
                **args: Any) -> None:
        """Record a zero-duration marker (rendered as a thin slice)."""
        if not self.enabled:
            return
        st = self._stack()
        top = st[-1] if st else None
        now = time.perf_counter()
        sid = self._alloc_id()
        sp = Span(name=name, cat=cat, track=track, span_id=sid,
                  parent_id=top.span_id if top else None,
                  root_id=top.root_id if top else sid,
                  start=now, end=now, args=dict(args))
        with self._lock:
            self._finished.append(sp)

    def add_span(self, name: str, start: float, end: float, *,
                 cat: str = "", track: str = "",
                 parent: Optional[int] = None, **args: Any) -> None:
        """Record a span from externally measured `perf_counter` times
        (e.g. a serve request's whole lifetime, assembled at retire)."""
        if not self.enabled:
            return
        sid = self._alloc_id()
        sp = Span(name=name, cat=cat, track=track, span_id=sid,
                  parent_id=parent, root_id=sid,
                  start=start, end=end, args=dict(args))
        with self._lock:
            self._finished.append(sp)

    def current_span_id(self) -> Optional[int]:
        if not self.enabled:
            return None
        st = self._stack()
        return st[-1].span_id if st else None

    # -- cross-process stitching -------------------------------------------

    def context(self) -> TraceCtx:
        """Snapshot (trace_id, current span id) for the wire."""
        return TraceCtx(trace_id=self.trace_id,
                        parent=self.current_span_id())

    def pop_root(self, root_id: int) -> List[Dict[str, Any]]:
        """Remove and return (as dicts) every finished span belonging to
        the tree rooted at ``root_id`` — the worker-side flush."""
        if not self.enabled:
            return []
        with self._lock:
            mine = [s for s in self._finished if s.root_id == root_id]
            self._finished = [
                s for s in self._finished if s.root_id != root_id
            ]
        return [s.to_dict() for s in mine]

    def ingest(self, span_dicts: Iterable[Dict[str, Any]], *,
               offset: float = 0.0, parent_id: Optional[int] = None,
               track_prefix: str = "") -> None:
        """Adopt spans recorded by another tracer (another process).

        Ids are remapped into this tracer's id space; times are shifted
        by ``offset`` (seconds) to rebase the foreign clock; parentless
        spans are attached under ``parent_id``; tracks get
        ``track_prefix`` so each host lands on its own Perfetto rows."""
        if not self.enabled:
            return
        spans = [Span.from_dict(d) for d in span_dicts]
        idmap: Dict[int, int] = {}
        for s in spans:
            idmap[s.span_id] = self._alloc_id()
        for s in spans:
            s.span_id = idmap[s.span_id]
            if s.parent_id is not None and s.parent_id in idmap:
                s.parent_id = idmap[s.parent_id]
            else:
                s.parent_id = parent_id
            s.root_id = idmap.get(s.root_id, s.span_id)
            s.start += offset
            s.end += offset
            s.track = track_prefix + (s.track or "worker")
        with self._lock:
            self._finished.extend(spans)

    # -- export ------------------------------------------------------------

    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._finished)

    def to_chrome(self) -> Dict[str, Any]:
        """Build the Chrome trace-event dict: ``X`` events for spans (ts in
        µs relative to tracer start), ``M`` thread-name metadata per track,
        ``C`` counter events from sampled gauges."""
        with self._lock:
            finished = list(self._finished)
        events: List[Dict[str, Any]] = []
        tids: Dict[str, int] = {}

        def tid_for(track: str) -> int:
            t = tids.get(track)
            if t is None:
                t = tids[track] = len(tids) + 1
            return t

        for s in sorted(finished, key=lambda s: s.start):
            track = s.track or "main"
            args = dict(s.args)
            if s.parent_id is not None:
                args["parent_span"] = s.parent_id
            args["span_id"] = s.span_id
            events.append({
                "name": s.name,
                "cat": s.cat or "default",
                "ph": "X",
                "ts": max(0.0, (s.start - self.t0) * 1e6),
                "dur": max(0.0, (s.end - s.start) * 1e6),
                "pid": 1,
                "tid": tid_for(track),
                "args": args,
            })
        for g in self.metrics.gauges():
            samples = g.samples()
            if not samples:
                continue
            tid = tid_for(f"counter:{g.name}")
            for t, v in samples:
                events.append({
                    "name": g.name,
                    "cat": "metric",
                    "ph": "C",
                    "ts": max(0.0, (t - self.t0) * 1e6),
                    "pid": 1,
                    "tid": tid,
                    "args": {"value": v},
                })
        meta: List[Dict[str, Any]] = [{
            "name": "process_name",
            "ph": "M",
            "pid": 1,
            "args": {"name": f"repro:{self.trace_id}"},
        }]
        for track, tid in sorted(tids.items(), key=lambda kv: kv[1]):
            meta.append({
                "name": "thread_name",
                "ph": "M",
                "pid": 1,
                "tid": tid,
                "args": {"name": track},
            })
            meta.append({
                "name": "thread_sort_index",
                "ph": "M",
                "pid": 1,
                "tid": tid,
                "args": {"sort_index": tid},
            })
        return {
            "traceEvents": meta + events,
            "displayTimeUnit": "ms",
            "otherData": {"trace_id": self.trace_id},
        }

    def export(self, path: str) -> None:
        """Write the Chrome trace JSON to ``path``."""
        with open(path, "w") as fh:
            json.dump(self.to_chrome(), fh)

    def export_metrics(self, path: str) -> None:
        """Write the metrics-registry snapshot JSON to ``path``."""
        with open(path, "w") as fh:
            json.dump(self.metrics.to_json(), fh, indent=2)


NULL_TRACER = Tracer(enabled=False)


def validate_chrome_trace(obj: Any) -> List[str]:
    """Validate a parsed trace dict against the Chrome trace-event schema
    subset this module emits. Returns a list of problems (empty = valid)."""
    problems: List[str] = []
    if not isinstance(obj, dict):
        return ["top level is not an object"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M", "C", "B", "E", "i", "I"):
            problems.append(f"{where}: bad ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            problems.append(f"{where}: name missing")
        if "pid" not in ev:
            problems.append(f"{where}: pid missing")
        if ph == "X":
            for key in ("ts", "dur", "tid"):
                if not isinstance(ev.get(key), (int, float)):
                    problems.append(f"{where}: {key} missing or non-numeric")
            if isinstance(ev.get("ts"), (int, float)) and ev["ts"] < 0:
                problems.append(f"{where}: negative ts")
            if isinstance(ev.get("dur"), (int, float)) and ev["dur"] < 0:
                problems.append(f"{where}: negative dur")
        elif ph == "C":
            if not isinstance(ev.get("ts"), (int, float)):
                problems.append(f"{where}: ts missing or non-numeric")
            if not isinstance(ev.get("args"), dict):
                problems.append(f"{where}: counter args missing")
        elif ph == "M":
            if not isinstance(ev.get("args"), dict):
                problems.append(f"{where}: metadata args missing")
    return problems


def trace_tiers(obj: Dict[str, Any]) -> List[str]:
    """Distinct tier categories (members of :data:`TIER_CATS`) present in
    a parsed Chrome trace dict."""
    seen = set()
    for ev in obj.get("traceEvents", []):
        if isinstance(ev, dict) and ev.get("ph") == "X":
            cat = ev.get("cat", "")
            if cat in TIER_CATS:
                seen.add(cat)
    return sorted(seen)

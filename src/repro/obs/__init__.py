"""Observability layer: structured tracing + metrics for every tier.

Two halves, one handle:

* :class:`~repro.obs.trace.Tracer` — span-based tracing with Chrome
  trace-event export (Perfetto-loadable), cross-process stitching for
  multihost workers, and a true no-op disabled mode
  (:data:`~repro.obs.trace.NULL_TRACER`);
* :class:`~repro.obs.metrics.MetricsRegistry` — counters, gauges, and
  p50/p95/p99 latency histograms, reachable as ``tracer.metrics``.

Constructors across the stack (`ExecutionEngine`, `ClusterRunner`,
`SliceExecutor`, `HostDispatcher`, `ServeEngine`, the autotuner entry
points) accept ``tracer=``; passing one object threads both signals
through a run. ``launch/train.py --trace-out/--metrics-out`` and
``benchmarks/bench_serve.py --trace-out`` are the CLI surfaces.
"""
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_METRICS,
    NullMetrics,
    percentile,
)
from .trace import (
    NULL_TRACER,
    Span,
    TIER_CATS,
    TraceCtx,
    Tracer,
    trace_tiers,
    validate_chrome_trace,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_METRICS",
    "NullMetrics",
    "percentile",
    "NULL_TRACER",
    "Span",
    "TIER_CATS",
    "TraceCtx",
    "Tracer",
    "trace_tiers",
    "validate_chrome_trace",
]

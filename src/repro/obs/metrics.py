"""Metrics registry: counters, gauges, and latency histograms.

The quantitative half of the observability layer (`repro.obs`): while the
tracer answers *where time went* on a timeline, the registry answers *how
much / how often / how slow* as scalars — compile-cache hit counters,
device-idle gauges, TTFT / inter-token-latency histograms with
p50/p95/p99 summaries.

Everything here is import-light (no jax, no numpy) and thread-safe; a
metric costs one lock + one list append, so always-on instrumentation of
per-step hot loops is fine. Gauges optionally keep a bounded ``(t, value)``
sample trail so :meth:`repro.obs.trace.Tracer.to_chrome` can export them as
Perfetto counter tracks.

Null variants (:data:`NULL_METRICS`) back the disabled tracer: every
operation is a method call on a shared singleton that touches no state.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

# gauges keep at most this many (t, value) samples for trace export; beyond
# it the trail stops growing (the final value is still exact)
GAUGE_SAMPLE_CAP = 65536


class Counter:
    """Monotonic event counter."""

    def __init__(self, name: str = ""):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Last-value gauge with an optional bounded sample trail.

    ``sample=True`` records ``(perf_counter, value)`` pairs on every ``set``
    (capped at :data:`GAUGE_SAMPLE_CAP`) — the raw material for Perfetto
    counter tracks."""

    def __init__(self, name: str = "", *, sample: bool = False):
        self.name = name
        self._value: float = 0.0
        self._lock = threading.Lock()
        self._samples: Optional[List[Tuple[float, float]]] = (
            [] if sample else None
        )

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)
            if (
                self._samples is not None
                and len(self._samples) < GAUGE_SAMPLE_CAP
            ):
                self._samples.append((time.perf_counter(), float(value)))

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def samples(self) -> List[Tuple[float, float]]:
        with self._lock:
            return list(self._samples or ())


def percentile(sorted_values: List[float], q: float) -> float:
    """Linear-interpolation percentile of an ascending list (q in [0, 1])."""
    if not sorted_values:
        return float("nan")
    if len(sorted_values) == 1:
        return sorted_values[0]
    pos = q * (len(sorted_values) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_values) - 1)
    frac = pos - lo
    return sorted_values[lo] * (1.0 - frac) + sorted_values[hi] * frac


class Histogram:
    """Latency histogram: records raw values, summarizes as percentiles."""

    def __init__(self, name: str = ""):
        self.name = name
        self._values: List[float] = []
        self._lock = threading.Lock()

    def record(self, value: float) -> None:
        with self._lock:
            self._values.append(float(value))

    @property
    def count(self) -> int:
        with self._lock:
            return len(self._values)

    def values(self) -> List[float]:
        with self._lock:
            return list(self._values)

    def summary(self) -> Dict[str, float]:
        """``{count, mean, min, p50, p95, p99, max}`` (NaNs when empty)."""
        with self._lock:
            vs = sorted(self._values)
        if not vs:
            nan = float("nan")
            return {"count": 0, "mean": nan, "min": nan, "p50": nan,
                    "p95": nan, "p99": nan, "max": nan}
        return {
            "count": len(vs),
            "mean": sum(vs) / len(vs),
            "min": vs[0],
            "p50": percentile(vs, 0.50),
            "p95": percentile(vs, 0.95),
            "p99": percentile(vs, 0.99),
            "max": vs[-1],
        }


class MetricsRegistry:
    """Get-or-create registry of named metrics (thread-safe).

    One registry per run; tiers reach it through their tracer
    (``tracer.metrics``) so a single ``--metrics-out`` JSON captures every
    layer. Names are dotted ``tier.metric`` (``serve.queue_depth``,
    ``executor.compile_cache_hits``) — the naming convention is documented
    in ROADMAP.md's Observability section."""

    def __init__(self, *, sample_gauges: bool = True):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._sample_gauges = sample_gauges

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(
                    name, sample=self._sample_gauges
                )
            return g

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name)
            return h

    def gauges(self) -> List[Gauge]:
        with self._lock:
            return list(self._gauges.values())

    def to_json(self) -> Dict:
        """Machine-readable snapshot: ``{counters, gauges, histograms}``
        with histogram percentile summaries inlined."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
        return {
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: g.value for k, g in sorted(gauges.items())},
            "histograms": {
                k: h.summary() for k, h in sorted(hists.items())
            },
        }


# ---------------------------------------------------------------------------
# Null variants (the disabled tracer's registry: shared stateless singletons)
# ---------------------------------------------------------------------------


class _NullCounter:
    name = ""
    value = 0

    def inc(self, n: int = 1) -> None:
        pass


class _NullGauge:
    name = ""
    value = 0.0

    def set(self, value: float) -> None:
        pass

    def samples(self) -> List[Tuple[float, float]]:
        return []


class _NullHistogram:
    name = ""
    count = 0

    def record(self, value: float) -> None:
        pass

    def values(self) -> List[float]:
        return []

    def summary(self) -> Dict[str, float]:
        return Histogram().summary()


class NullMetrics:
    """No-op registry: every lookup returns the same stateless singleton."""

    _counter = _NullCounter()
    _gauge = _NullGauge()
    _histogram = _NullHistogram()

    def counter(self, name: str) -> _NullCounter:
        return self._counter

    def gauge(self, name: str) -> _NullGauge:
        return self._gauge

    def histogram(self, name: str) -> _NullHistogram:
        return self._histogram

    def gauges(self) -> List[Gauge]:
        return []

    def to_json(self) -> Dict:
        return {"counters": {}, "gauges": {}, "histograms": {}}


NULL_METRICS = NullMetrics()

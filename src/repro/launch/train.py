"""Training launcher: run a packed-LoRA fine-tuning job for a selected
architecture on this host (real execution) through the cluster subsystem —
the job trains on a :class:`~repro.cluster.DevicePool` mesh slice wide
enough for the requested mesh (the whole-host slice by default), via the
same compile-cached :class:`~repro.cluster.SliceExecutor` the concurrent
engine uses.

  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-7b \
      --reduced --steps 20 --ranks 8,16 --lrs 1e-3,5e-4 --seq 32

  # sharded on 8 forced host devices (4 data x 2 model):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --arch qwen25-7b --reduced \
      --mesh 4x2 --steps 10

Full (non-reduced) configs are for the dry-run (repro.launch.dryrun); this
driver trains for real, so use --reduced on CPU.
"""
import argparse

import jax
import numpy as np

from repro.cluster import DevicePool, SliceExecutor
from repro.configs.base import LoraConfig, get_config, list_archs, reduced
from repro.core.adapter import pack_meta
from repro.core.packed_lora import extract_adapter
from repro.kernels.quant import quantize_base_params
from repro.models.model import init_model
from repro.train.checkpoint import CheckpointPool


def _estimator(args, cfg):
    """Profiled estimator shared by the single- and multi-host paths:
    analytic prior for the selected hardware + (optionally pre-seeded)
    observation store."""
    from repro.sched.cost_model import A10_24G, A100_40G, TPU_V5E, CostModel
    from repro.sched.profile import ObservationStore, ProfiledCostModel

    hw = {"a100-40g": A100_40G, "a10-24g": A10_24G, "tpu-v5e": TPU_V5E}[args.hw]
    store = (
        ObservationStore.load(args.profile_in) if args.profile_in
        else ObservationStore()
    )
    # a quantized frozen base shrinks the per-job memory floor, so the plan
    # itself gets denser (more configs co-packed per device) — the estimator
    # must price the same base bytes the kernels will actually stream
    quant = None if args.quant == "none" else args.quant
    return ProfiledCostModel(CostModel(cfg, hw, base_dtype=quant), store), store


def _make_tracer(args):
    """One Tracer for the whole launch when --trace-out/--metrics-out asked
    for it, else the shared no-op — every tier below receives this object."""
    from repro.obs import NULL_TRACER, Tracer

    if args.trace_out or args.metrics_out:
        return Tracer()
    return NULL_TRACER


def _export_obs(args, tracer):
    if args.trace_out:
        tracer.export(args.trace_out)
        print(f"saved Chrome trace to {args.trace_out} "
              f"({len(tracer.spans())} span(s)) — open in ui.perfetto.dev")
    if args.metrics_out:
        tracer.export_metrics(args.metrics_out)
        print(f"saved metrics to {args.metrics_out}")


def _drift_table(records, timings, seq):
    """Join executed records to their measured timings by (config_ids, seq).

    The two lists are usually parallel, but the runner orders timings by
    virtual start while records come back in the engine's order — a plain
    zip mispairs them whenever those differ, so key the join instead."""
    from collections import deque

    by_key = {}
    for t in timings:
        by_key.setdefault((t.config_ids, t.seq), deque()).append(t)
    for rec in records:
        key = (tuple(rec.job.config_ids), seq)
        q = by_key.get(key)
        seg_timing = q.popleft() if q else None
        per_adapter = (
            np.round(np.asarray(rec.final_losses), 3)
            if rec.final_losses is not None else None
        )
        if seg_timing is None:
            print(f"  job cids={rec.job.config_ids} deg={rec.job.degree} "
                  f"     (no timing)  losses={per_adapter}")
            continue
        drift = seg_timing.drift
        drift_s = f"{100 * drift:+.1f}%" if drift == drift else "n/a"
        print(f"  job cids={rec.job.config_ids} deg={rec.job.degree} "
              f"{1e3 * seg_timing.measured_iter:8.1f} ms/step "
              f"(plan drift {drift_s})  losses={per_adapter}")


def _run_multihost(args, cfg, configs, tracer):
    """--hosts N: plan host-aware, execute process-per-host.

    Each simulated host is a subprocess that forces its own
    ``--devices-per-host`` CPU devices, so this runs on any machine without
    touching the parent's XLA_FLAGS. The plan caps per-job parallelism at
    the host width and keeps every job's device units on one host; the
    dispatch tier then overlaps jobs across hosts for real.

    Elastic knobs: ``--host-classes`` tags each host (the adaptive engine
    then places wide jobs on fast classes and narrow ones on slow),
    ``--heartbeat`` arms the liveness watchdog, and ``--drain-after`` /
    ``--join-after`` exercise membership mid-run (drain the last host /
    admit a new one after N seconds). Drain/join need replanning, so they
    switch execution to the adaptive online path (``run_online_local``)."""
    import threading
    import time

    from repro.cluster import HostDispatcher
    from repro.sched.engine import Arrival, ExecutionEngine
    from repro.sched.planner import plan

    per = args.devices_per_host
    g = args.hosts * per
    classes = None
    if args.host_classes:
        classes = [c.strip() for c in args.host_classes.split(",")]
        if len(classes) != args.hosts:
            raise SystemExit(
                f"--host-classes names {len(classes)} classes for "
                f"{args.hosts} hosts"
            )
    est, store = _estimator(args, cfg)
    elastic = args.drain_after is not None or args.join_after is not None
    sched = plan(est, configs, g, args.seq, args.steps, max_degree=per)
    print(f"multi-host plan: {len(sched.jobs)} job(s) on {args.hosts} hosts "
          f"x {per} device(s), virtual makespan {sched.makespan:.1f}s")
    meta = pack_meta(configs)
    base, _ = init_model(jax.random.PRNGKey(0), cfg, meta)
    quant = None if args.quant == "none" else args.quant
    if quant:
        base = quantize_base_params(base, quant)
        print(f"quantized frozen base to {quant} "
              f"(projection weights -> codes+scales dicts)")
    pool = CheckpointPool(args.pool) if args.pool else None
    eng = ExecutionEngine(est, g, host_size=per, tracer=tracer)
    timers = []
    with HostDispatcher(
        args.hosts, per, tracer=tracer, host_classes=classes,
        heartbeat_interval=args.heartbeat,
    ) as disp:
        if args.drain_after is not None:
            target = len(disp.hosts) - 1
            timers.append(threading.Timer(
                args.drain_after, lambda: disp.drain_host(target)
            ))
        if args.join_after is not None:
            join_class = classes[-1] if classes else ""
            timers.append(threading.Timer(
                args.join_after,
                lambda: disp.add_host(per, host_class=join_class),
            ))
        for t in timers:
            t.daemon = True
            t.start()
        t0 = time.perf_counter()
        if elastic:
            # membership changes need replanning: run the same workload as
            # an online trace through the adaptive loop, which subscribes
            # to the dispatcher's join/drain feed
            arrivals = [Arrival(0.0, c, args.steps) for c in configs]
            records, osched = eng.run_online_local(
                arrivals, cfg, base, n_steps=args.steps, seq=args.seq,
                pool=pool, runner=disp,
                probe_steps=min(4, args.steps),
            )
            makespan = osched.makespan
        else:
            # --impl/--remat ride the wire as a KernelPolicy with every
            # segment, so each host worker runs the tier selected here
            records, makespan = eng.run_local(
                sched, configs, cfg, base, n_steps=args.steps, seq=args.seq,
                pool=pool, runner=disp, impl=args.impl, remat=args.remat,
                base_dtype=quant,
            )
        elapsed = time.perf_counter() - t0
        for t in timers:
            t.cancel()
    result = disp.last_result
    overlap = result.max_overlap() if result is not None else "n/a"
    print(f"{len(records)} job(s) in {elapsed:.1f}s wall "
          f"(makespan {makespan:.1f}s, peak overlap "
          f"{overlap}, {disp.n_restarts} worker restart(s))")
    if elastic or args.heartbeat:
        states = ", ".join(
            f"host{h}={disp.host_state(h)}"
            for h in range(len(disp.hosts))
        )
        print(f"membership: {states}")
    if result is not None:
        _drift_table(records, result.timings, args.seq)
    if args.profile_out:
        store.save(args.profile_out)
        print(f"saved profile to {args.profile_out}")
    if pool is not None:
        print(f"saved {len(pool.list())} adapters to {args.pool}")
    _export_obs(args, tracer)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen25-7b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized variant of the same family")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--ranks", default="8,16")
    ap.add_argument("--lrs", default="1e-3,5e-4")
    ap.add_argument("--alphas", default=None, help="default: 2*rank")
    ap.add_argument("--batch-sizes", default=None, help="default: 1 each")
    ap.add_argument("--mesh", default=None, help="e.g. 4x2 (data x model)")
    ap.add_argument("--impl", default=None,
                    choices=["auto", "pallas", "xla", "fused", "fused_pallas",
                             "fused_xla"],
                    help="packed-LoRA kernel backend (kernels/ops.py): "
                         "'fused' runs base+delta as one megakernel "
                         "(fused_pallas on TPU, fused_xla elsewhere); "
                         "default: context default ('auto')")
    ap.add_argument("--quant", default="none", choices=["none", "int8", "nf4"],
                    help="quantize the frozen base (kernels/quant.py): "
                         "projection weights are stored as int8 per-channel "
                         "or nf4 block-scaled codes and dequantized inside "
                         "the fused kernel's K-loop; adapters/optimizer "
                         "stay full precision, so losses match the "
                         "dequantized-base run bit-for-bit")
    ap.add_argument("--remat", default=None, choices=["recompute", "save"],
                    help="backward xA policy of the LoRA kernels (default: "
                         "measured crossover, see bench_kernels)")
    ap.add_argument("--autotune-cache", default=None,
                    help="JSON autotune cache (kernels/autotune.py): "
                         "micro-benchmark fused-kernel block sizes / rates "
                         "for this arch's projection shapes, persist them "
                         "here, and calibrate the cost-model prior with the "
                         "measured rates")
    ap.add_argument("--hosts", type=int, default=1,
                    help="run through the multi-host dispatch tier: N "
                         "simulated hosts (one subprocess each, self-forcing "
                         "--devices-per-host CPU devices via XLA_FLAGS); the "
                         "configs are planned host-aware and executed "
                         "process-per-host")
    ap.add_argument("--devices-per-host", type=int, default=1,
                    help="device units per simulated host; values > 1 route "
                         "through the dispatch tier even with --hosts 1 "
                         "(one subprocess host of that width)")
    ap.add_argument("--host-classes", default=None,
                    help="comma list tagging each host's hardware class "
                         "(e.g. 'fast,fast,slow'); the adaptive engine "
                         "learns per-class step-time ratios and places "
                         "wide jobs on fast classes, narrow jobs on slow")
    ap.add_argument("--heartbeat", type=float, default=0.0,
                    help="heartbeat interval in seconds (0 = off): the "
                         "dispatcher pings every worker, marks silent hosts "
                         "SUSPECT then DEAD, and re-runs their segments")
    ap.add_argument("--drain-after", type=float, default=None,
                    help="gracefully drain the last host N seconds into the "
                         "run (elastic demo; switches to the adaptive "
                         "online execution path)")
    ap.add_argument("--join-after", type=float, default=None,
                    help="admit one extra host N seconds into the run "
                         "(elastic demo; switches to the adaptive online "
                         "execution path)")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--pool", default=None, help="checkpoint pool dir")
    ap.add_argument("--profile-in", default=None,
                    help="load a profile (observation store JSON) from a "
                         "previous run; predictions below use it")
    ap.add_argument("--profile-out", default=None,
                    help="dump the observation store (with this run's "
                         "measured step time folded in) for reuse via "
                         "--profile-in / the adaptive engine")
    ap.add_argument("--hw", default="a100-40g",
                    choices=["a100-40g", "a10-24g", "tpu-v5e"],
                    help="hardware prior for the plan-vs-measured summary")
    ap.add_argument("--save-state", action="store_true",
                    help="checkpoint the full packed state (adapters + "
                         "optimizer + step counts) into --pool at the end")
    ap.add_argument("--resume-state", action="store_true",
                    help="resume a packed run saved with --save-state "
                         "(same arch/ranks) instead of initializing fresh")
    ap.add_argument("--state-id", default=None,
                    help="packed-state id in the pool (default: the arch)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON of the run "
                         "(spans from every tier, one Perfetto track per "
                         "device unit / host / serve row); load it at "
                         "ui.perfetto.dev or chrome://tracing")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics registry (counters / gauges / "
                         "histogram summaries) as JSON")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()
    if (args.save_state or args.resume_state) and not args.pool:
        ap.error("--save-state/--resume-state require --pool")
    if args.resume_state and args.mesh:
        ap.error("--resume-state is not supported together with --mesh")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    ranks = [int(r) for r in args.ranks.split(",")]
    lrs = [float(x) for x in args.lrs.split(",")]
    alphas = (
        [float(a) for a in args.alphas.split(",")]
        if args.alphas
        else [2.0 * r for r in ranks]
    )
    bss = (
        [int(b) for b in args.batch_sizes.split(",")]
        if args.batch_sizes
        else [1] * len(ranks)
    )
    assert len(lrs) == len(ranks) == len(alphas) == len(bss)
    configs = [
        LoraConfig(rank=r, alpha=a, learning_rate=lr, batch_size=b, seq_len=args.seq)
        for r, a, lr, b in zip(ranks, alphas, lrs, bss)
    ]
    meta = pack_meta(configs)
    print(f"arch={cfg.name} pack N={meta.n} r_bucket={meta.r_bucket} "
          f"steps={args.steps} seq={args.seq}")

    tracer = _make_tracer(args)
    if args.hosts > 1 or args.devices_per_host > 1:
        if (args.mesh or args.fsdp or args.seq_parallel or args.save_state
                or args.resume_state):
            ap.error("--hosts is incompatible with --mesh/--fsdp/"
                     "--seq-parallel/--save-state/--resume-state (per-job "
                     "parallelism comes from the planner; use "
                     "--devices-per-host for host width)")
        _run_multihost(args, cfg, configs, tracer)
        return
    if (args.host_classes or args.heartbeat
            or args.drain_after is not None or args.join_after is not None):
        ap.error("--host-classes/--heartbeat/--drain-after/--join-after "
                 "need the dispatch tier: pass --hosts N (or "
                 "--devices-per-host > 1)")

    mesh_shape = None
    width = 1
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh_shape = (d, m)
        width = d * m

    device_pool = DevicePool()
    if width > device_pool.total:
        raise SystemExit(
            f"--mesh {args.mesh} needs {width} devices but this host has "
            f"{device_pool.total}; set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={width} or request a smaller mesh"
        )
    slice_ = device_pool.acquire(width)
    print(f"device pool: {device_pool.total} device(s), job slice "
          f"units={slice_.units}")

    key = jax.random.PRNGKey(0)
    base, lora = init_model(key, cfg, meta)
    quant = None if args.quant == "none" else args.quant
    if quant:
        base = quantize_base_params(base, quant)
        print(f"quantized frozen base to {quant} "
              f"(projection weights -> codes+scales dicts)")
    opt = None

    state_id = args.state_id or cfg.name
    if args.resume_state:
        pool = CheckpointPool(args.pool)
        lora, opt, smeta = pool.load_packed_state(state_id)
        if tuple(smeta["ranks"]) != meta.ranks:
            raise SystemExit(
                f"saved state {state_id!r} has ranks {smeta['ranks']}, "
                f"requested {list(meta.ranks)}"
            )
        done = np.asarray(opt["step"]).tolist()
        print(f"resumed packed state {state_id!r} (per-adapter steps {done})")

    def log(i, m):
        if args.log_every and i % args.log_every == 0:
            per = np.asarray(m["per_adapter_loss"])
            print(f"step {i:4d}  loss={float(m['loss']):.4f}  "
                  f"per-adapter={np.round(per, 3)}")

    # profile feedback loop: prior + (optionally pre-seeded) observations
    est, store = _estimator(args, cfg)
    blocks = None
    if args.autotune_cache:
        from repro.kernels.autotune import model_shapes, tune_for_model

        # the calibration prices FUSED-kernel rates, so the run must
        # execute the fused tier — otherwise the planner would predict work
        # the kernels never do
        if args.impl in (None, "auto"):
            args.impl = "fused"
            print("autotune: --impl not set; running the fused tier the "
                  "calibration measures")
        elif args.impl in ("xla", "pallas"):
            ap.error("--autotune-cache calibrates measured FUSED rates; "
                     "combine it with --impl fused/fused_xla/fused_pallas")
        prof = tune_for_model(
            cfg, configs, seq=args.seq, cache_path=args.autotune_cache,
            fast=True, tracer=tracer,
        )
        est = type(est)(prof.calibrate(est.prior), est.store)
        # tuned Pallas tile sizes for this pack's representative projection
        # (None off-TPU: the XLA path has no block parameter)
        blocks = prof.best_blocks(*model_shapes(cfg, configs, args.seq)[0])
        print(f"autotune: {len(prof.entries)} shape bucket(s) in "
              f"{args.autotune_cache} (backend={prof.backend}); prior "
              f"calibrated with measured fused rates"
              + (f", blocks={blocks}" if blocks else ""))
    degree = max(width, 1)
    pred_prior = est.prior.iter_time(configs, degree, args.seq)
    pred_profiled = est.iter_time(configs, degree, args.seq)  # before observing

    ex = SliceExecutor(tracer=tracer)
    res = ex.train_pack(
        cfg,
        configs,
        n_steps=args.steps,
        seq=args.seq,
        base=base,
        lora=lora,
        opt=opt,
        slice_=slice_,
        mesh_shape=mesh_shape,
        fsdp=args.fsdp,
        seq_parallel=args.seq_parallel,
        step_callback=log if args.log_every else None,
        impl=args.impl,
        remat=args.remat,
        blocks=blocks,
        base_dtype=quant,
    )
    device_pool.release(slice_)
    lora, opt = res.lora, res.opt
    print(f"{args.steps} steps in {res.wall_seconds:.1f}s "
          f"({1e3 * res.wall_seconds / max(args.steps, 1):.0f} ms/step)")

    # plan-vs-measured summary: how far the analytic prior (and, when a
    # profile was loaded, the calibrated estimator) was from reality
    if args.steps > 0:
        measured = res.wall_seconds / args.steps
        est.observe(configs, degree, args.seq, measured)

        def _row(label, pred):
            drift = measured / pred - 1.0 if pred > 0 else float("nan")
            print(f"  {label:<22} {1e3 * pred:9.2f} ms/step   "
                  f"drift {100.0 * drift:+8.1f}%")

        print(f"\nplan-vs-measured  key={est.key(configs, degree, args.seq)}")
        print(f"  {'measured':<22} {1e3 * measured:9.2f} ms/step")
        _row(f"prior ({est.hw.name})", pred_prior)
        if args.profile_in:
            _row("profiled (loaded)", pred_profiled)
        print(f"  store: {len(store)} key(s), "
              f"{store.n_observations} observation(s)")
    if args.profile_out:
        store.save(args.profile_out)
        print(f"saved profile to {args.profile_out}")

    if args.save_state:
        pool = CheckpointPool(args.pool)
        pool.save_packed_state(
            state_id, lora, opt,
            {"arch": cfg.name, "ranks": list(meta.ranks),
             "alphas": list(meta.alphas), "seq": args.seq,
             "steps_done": np.asarray(opt["step"]).tolist()},
        )
        print(f"saved packed state {state_id!r} to {args.pool}")

    if args.pool:
        pool = CheckpointPool(args.pool)
        per = res.losses if res.losses is not None else np.full(meta.n, np.nan)
        for i, c in enumerate(configs):
            pool.save_adapter(
                f"{cfg.name}_adapter_{i:03d}",
                extract_adapter(lora, i, meta.ranks),
                {"rank": c.rank, "alpha": c.alpha, "learning_rate": c.learning_rate,
                 "batch_size": c.batch_size, "final_loss": float(per[i])},
            )
        print(f"saved {len(configs)} adapters to {args.pool}")

    _export_obs(args, tracer)


if __name__ == "__main__":
    main()

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination
on the production mesh with 512 placeholder host devices.

  PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --out results/dryrun

Per combination this prints compiled.memory_analysis() (proves the program
fits 16 GB/chip) and cost_analysis() (FLOPs/bytes for the roofline), parses
collective bytes from the optimized HLO, and appends a JSON row consumed by
EXPERIMENTS.md §Dry-run/§Roofline.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import INPUT_SHAPES, get_config, list_archs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.sharding import (  # noqa: E402
    batch_axes,
    batch_specs,
    cache_specs,
    make_dist,
    param_specs,
    to_named,
)
from repro.launch.specs import (  # noqa: E402
    decode_inputs,
    default_pack,
    model_shapes,
    train_inputs,
)
from repro.roofline.analysis import analyze  # noqa: E402
from repro.sched.cost_model import active_param_count  # noqa: E402

SKIP_LONG = {
    # pure full-attention archs: no sub-quadratic path => long_500k skipped
    # (DESIGN.md §6). whisper's decoder is 448-token by construction.
    "qwen3-moe-30b-a3b", "whisper-tiny", "minicpm3-4b", "command-r-35b",
    "starcoder2-7b", "grok-1-314b", "internvl2-1b", "qwen25-7b",
}


def applicable(arch: str, shape_name: str) -> bool:
    return shape_name != "long_500k" or arch not in SKIP_LONG


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                compile_: bool = True, chunk_q: int = 512,
                vocab_chunk: int = 512, seq_parallel: bool = False,
                decode_seq_shard: bool = False, fsdp: bool = False):
    """Returns (RooflineReport | None, info dict)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(map(str, mesh.devices.shape))
    meta = default_pack(shape)
    nb = shape.global_batch
    dist = make_dist(mesh, nb, seq_sharded_residuals=seq_parallel, fsdp=fsdp)
    base_s, lora_s = model_shapes(cfg, meta)
    base_sp = to_named(param_specs(base_s, cfg, mesh), mesh)
    lora_sp = to_named(param_specs(lora_s, cfg, mesh), mesh)
    n_active = active_param_count(cfg)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            from repro.train.optimizer import init_opt_state
            from repro.train.trainer import make_train_step

            batch_s = train_inputs(cfg, shape)
            batch_sp = to_named(batch_specs(batch_s, mesh, include_model=fsdp), mesh)
            opt_s = jax.eval_shape(init_opt_state, lora_s)
            opt_sp = to_named(param_specs(opt_s, cfg, mesh), mesh)
            step = make_train_step(
                cfg, meta, dist=dist, chunk_q=chunk_q,
                vocab_chunk=vocab_chunk, jit=False,
            )
            jitted = jax.jit(
                step, in_shardings=(base_sp, lora_sp, opt_sp, batch_sp)
            )
            lowered = jitted.lower(base_s, lora_s, opt_s, batch_s)
            tokens = nb * shape.seq_len
            model_flops = 6.0 * n_active * tokens
        elif shape.kind == "prefill":
            from repro.serve.decode import make_prefill

            batch_s = train_inputs(cfg, shape)
            batch_s.pop("labels")
            batch_sp = to_named(batch_specs(batch_s, mesh, include_model=fsdp), mesh)
            fn = make_prefill(cfg, meta, dist=dist, chunk_q=chunk_q, jit=False)
            jitted = jax.jit(fn, in_shardings=(base_sp, lora_sp, batch_sp))
            lowered = jitted.lower(base_s, lora_s, batch_s)
            model_flops = 2.0 * n_active * nb * shape.seq_len
        else:  # decode
            from repro.serve.decode import make_serve_step

            caches_s, token_s, pos_s = decode_inputs(cfg, shape)
            caches_sp = to_named(
                cache_specs(caches_s, mesh, nb, seq_over_model=decode_seq_shard),
                mesh,
            )
            ba = batch_axes(mesh, nb)
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P

            token_sp = NamedSharding(mesh, P(ba if ba else None, None))
            pos_sp = NamedSharding(mesh, P())
            fn = make_serve_step(cfg, meta, dist=dist, jit=False)
            jitted = jax.jit(
                fn, in_shardings=(base_sp, lora_sp, caches_sp, token_sp, pos_sp)
            )
            lowered = jitted.lower(base_s, lora_s, caches_s, token_s, pos_s)
            model_flops = 2.0 * n_active * nb
        t_lower = time.time() - t0
        if not compile_:
            return None, {"lower_s": t_lower}
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    rep = analyze(compiled, arch, shape_name, mesh_name, model_flops=model_flops)
    info = {"lower_s": t_lower, "compile_s": t_compile,
            "n_devices": mesh.devices.size}
    return rep, info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL rows here")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="sequence-parallel residuals (beyond-paper, §Perf)")
    args = ap.parse_args()

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    archs = [a for a in archs if a != "qwen25-7b"] if args.all else archs
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    rows = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
                if not applicable(arch, shape_name):
                    print(f"[skip] {tag}: full-attention arch, long_500k n/a")
                    rows.append({"arch": arch, "shape": shape_name,
                                 "mesh": "2x16x16" if mp else "16x16",
                                 "status": "skipped"})
                    continue
                try:
                    rep, info = lower_combo(
                        arch, shape_name, multi_pod=mp,
                        compile_=not args.no_compile,
                        seq_parallel=args.seq_parallel,
                    )
                    if rep is None:
                        print(f"[lowered] {tag} in {info['lower_s']:.1f}s")
                        rows.append({"arch": arch, "shape": shape_name,
                                     "status": "lowered", **info})
                        continue
                    row = rep.row(info["n_devices"])
                    row.update(status="ok", **info)
                    rows.append(row)
                    print(
                        f"[ok] {tag}: lower {info['lower_s']:.0f}s compile "
                        f"{info['compile_s']:.0f}s | compute {rep.t_compute*1e3:.2f}ms "
                        f"memory {rep.t_memory*1e3:.2f}ms collective "
                        f"{rep.t_collective*1e3:.2f}ms -> {rep.bottleneck} | "
                        f"peak {row['peak_memory_gb']:.2f} GB/dev | useful-FLOP "
                        f"{row['useful_flop_ratio'] and round(row['useful_flop_ratio'],3)}"
                    )
                except Exception as e:
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
                    traceback.print_exc()
                    rows.append({"arch": arch, "shape": shape_name,
                                 "mesh": "2x16x16" if mp else "16x16",
                                 "status": "fail", "error": str(e)[:500]})
                if args.out:
                    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                    with open(args.out + ".jsonl", "a") as f:
                        f.write(json.dumps(rows[-1]) + "\n")
    n_ok = sum(r.get("status") == "ok" for r in rows)
    print(f"\n{n_ok} ok / {len(rows)} combos")


if __name__ == "__main__":
    main()

"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``train_inputs``  -> {"tokens", "labels"[, "frames"/"patches"]}
``decode_inputs`` -> (caches, token, pos) against a seq_len-capacity cache.

Pack layout for the assigned shapes: 8 packed adapters (rank 32, the paper's
job-level setting) splitting the global batch, except long_500k (b=1, single
adapter). VLM/audio shapes keep the assigned token budget: for internvl2 the
patch prefix replaces the first n_patch positions; whisper decodes against
its (stubbed) 1500-frame encoder output.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LoraConfig, ModelConfig, ShapeConfig
from repro.core.adapter import PackMeta, pack_meta
from repro.models.model import init_caches, init_model


def default_pack(shape: ShapeConfig) -> PackMeta:
    # pack size == data-axis size (16): data shard k owns adapter k's samples
    n = 1 if shape.global_batch < 16 else 16
    return pack_meta(
        [
            LoraConfig(rank=32, alpha=32.0, learning_rate=1e-4,
                       batch_size=shape.global_batch // n, seq_len=shape.seq_len)
            for _ in range(n)
        ]
    )


def model_shapes(cfg: ModelConfig, meta: PackMeta, dtype=jnp.bfloat16):
    """(base, lora) as ShapeDtypeStructs via eval_shape — no allocation."""
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_model(k, cfg, meta, dtype), key)


def train_inputs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    nb = shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32
    out = {
        "tokens": jax.ShapeDtypeStruct((nb, s), i32),
        "labels": jax.ShapeDtypeStruct((nb, s), i32),
    }
    if cfg.is_encdec:
        out["frames"] = jax.ShapeDtypeStruct(
            (nb, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16
        )
        # labels for the (patch-free) decoder tokens only
    if cfg.n_patch_tokens:
        # patch prefix + text fills the assigned seq budget
        s_text = s - cfg.n_patch_tokens
        out["tokens"] = jax.ShapeDtypeStruct((nb, s_text), i32)
        out["labels"] = jax.ShapeDtypeStruct((nb, s), i32)
        out["patches"] = jax.ShapeDtypeStruct(
            (nb, cfg.n_patch_tokens, cfg.d_model), jnp.bfloat16
        )
    return out


def decode_inputs(
    cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16
) -> Tuple:
    """(caches, token, pos) for serve_step: ONE new token against a cache of
    capacity seq_len."""
    nb = shape.global_batch
    caches = jax.eval_shape(
        lambda: init_caches(cfg, nb, shape.seq_len, dtype)
    )
    token = jax.ShapeDtypeStruct((nb, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return caches, token, pos


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Unified entry: kind-dependent input structs (assignment API)."""
    if shape.kind == "train" or shape.kind == "prefill":
        return train_inputs(cfg, shape)
    return decode_inputs(cfg, shape)

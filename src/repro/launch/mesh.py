"""Production mesh construction (assignment-mandated shapes)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly forced) host devices exist —
    used by tests that exercise sharding logic without 512 fake devices."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))

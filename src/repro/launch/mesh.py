"""Mesh construction: production shapes, host meshes, and device-subset
(mesh-slice) meshes for the concurrent cluster executor.

All constructors validate the requested shape against the devices actually
present and fail with an actionable message (available vs requested, plus the
``XLA_FLAGS`` incantation to force host devices) instead of surfacing a raw
XLA assertion.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np


def _require_devices(n_req: int, shape, axes) -> None:
    avail = jax.device_count()
    if avail < n_req:
        raise RuntimeError(
            f"mesh {dict(zip(axes, shape))} needs {n_req} devices but this "
            f"host has only {avail}. On CPU, force host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_req} "
            f"(set before the first jax import), or request a smaller mesh."
        )


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    _require_devices(int(np.prod(shape)), shape, axes)
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly forced) host devices exist —
    used by tests that exercise sharding logic without 512 fake devices."""
    _require_devices(data * model, (data, model), ("data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def slice_mesh(src, g: Optional[int] = None, *, data: int = 1,
               model: Optional[int] = None):
    """Mesh over an explicit *subset* of devices — a cluster mesh slice.

    ``src`` may be a ``repro.cluster.DevicePool`` / ``MeshSlice`` (anything
    with a ``.devices`` attribute) or a plain device sequence; ``g`` takes
    the first ``g`` of them (default: all). The slice mesh has shape
    ``(data, model)`` with ``data * model == g`` (default ``(1, g)``:
    tensor-parallel within the slice, matching the cost model's TP
    assumption). Unlike ``jax.make_mesh`` this never touches devices outside
    the subset, so disjoint slices can host concurrently running jobs.
    """
    devices = list(getattr(src, "devices", src))
    if g is None:
        g = len(devices)
    if g > len(devices):
        raise RuntimeError(
            f"slice of width {g} requested but the source holds only "
            f"{len(devices)} devices"
        )
    devices = devices[:g]
    if model is None:
        if g % data:
            raise ValueError(f"slice width {g} not divisible by data={data}")
        model = g // data
    if data * model != g:
        raise ValueError(
            f"slice mesh ({data}, {model}) does not cover width {g}"
        )
    return jax.sharding.Mesh(
        np.asarray(devices, dtype=object).reshape(data, model),
        ("data", "model"),
    )


def mesh_axes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))

"""Sharding rules: params, LoRA packs, optimizer state, batches, caches.

Megatron-style tensor parallelism over the "model" axis; batch over
("pod","data"); large LoRA operands FSDP-sharded over "data" (gathered by XLA
where used — adapters are small relative to the base, Appendix A.1.1).

All rules are name+shape based and divisibility-guarded, so the same code
shards a 314B Grok and a 2-layer smoke model (where most dims simply fall
back to replication).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.transformer import DistContext


def _axis_size(mesh, name) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _div(n: int, mesh, axes) -> bool:
    if isinstance(axes, str):
        axes = (axes,)
    total = 1
    for a in axes:
        total *= _axis_size(mesh, a)
    return n % total == 0 and total > 1


def batch_axes(mesh, nb: int, *, include_model: bool = False) -> Tuple[str, ...]:
    """Axes for the pack-major batch dim. Order is ("data", "pod"): the pack
    dim is the OUTER factor of the batch and pack size == data-axis size, so
    data shard k owns exactly adapter k's samples; the per-pack batch splits
    over "pod". This keeps the (N, B*S, d) packed-kernel reshape exactly
    representable — no resharding inside the layer stack (DESIGN.md §4).

    ``include_model`` (FSDP execution mode, §Perf): also shard the batch over
    the model axis. Weights stay sharded as before but are now all-gathered
    per use (ZeRO-3 style) instead of activations being tensor-parallel —
    the right trade when weight bytes << activation bytes (small models,
    huge token batches)."""
    order = [a for a in ("data", "pod") if a in mesh.axis_names]
    if include_model and "model" in mesh.axis_names:
        order.append("model")
    out = []
    for a in order:
        if _div(nb, mesh, tuple(out + [a])):
            out.append(a)
    return tuple(out)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

_COL_PARALLEL = {"q", "k", "v", "gate", "up", "q_b", "kv_b_k", "kv_b_v", "zx", "dt"}
_ROW_PARALLEL = {"o", "down", "out"}


def _param_rule(path_keys, leaf, cfg: ModelConfig, mesh) -> P:
    """PartitionSpec for one base-param leaf; extra leading dims (layer
    stacks) are padded with None."""
    names = [getattr(k, "key", str(k)) for k in path_keys]
    leafname = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    gparent = names[-3] if len(names) >= 3 else ""
    shape = leaf.shape

    def pad(spec_tail):
        return P(*([None] * (len(shape) - len(spec_tail)) + list(spec_tail)))

    # --- embeddings / head ---
    if parent == "embed" and leafname == "w":
        return pad([("model" if _div(shape[0], mesh, "model") else None), None])
    if parent == "lm_head" and leafname == "w":
        return pad([None, "model" if _div(shape[-1], mesh, "model") else None])

    # --- MoE experts (E, d, f) / (E, f, d) ---
    if parent == "moe" or gparent == "moe":
        if leafname in ("w_gate", "w_up", "w_down") or parent in (
            "w_gate", "w_up", "w_down",
        ):
            e, a, b = shape[-3], shape[-2], shape[-1]
            if cfg.moe.impl == "ep" and _div(e, mesh, "model"):
                return pad(["model", None, None])
            # dense/FSDP path (grok): f over model, other big dim over data
            is_down = leafname == "w_down" or parent == "w_down"
            if is_down:  # (E, f, d)
                return pad([
                    None,
                    "model" if _div(a, mesh, "model") else None,
                    "data" if _div(b, mesh, "data") else None,
                ])
            return pad([
                None,
                "data" if _div(a, mesh, "data") else None,
                "model" if _div(b, mesh, "model") else None,
            ])
        return pad([None] * len(shape))  # router etc.

    # --- LoRA packs {a, b}: the pack dim N shards over "data" (adapter k's
    # gradient comes only from data shard k — zero-communication adapter
    # grads); the big matrix dim follows the base weight's TP sharding.
    if leafname == "a" and len(shape) >= 3:
        n, d_in = shape[-3], shape[-2]
        npack = "data" if _div(n, mesh, "data") else None
        return pad([npack, None, None])
    if leafname == "b" and len(shape) >= 3:
        n, d_out = shape[-3], shape[-1]
        npack = "data" if _div(n, mesh, "data") else None
        if parent in _COL_PARALLEL and _div(d_out, mesh, "model"):
            return pad([npack, None, "model"])
        return pad([npack, None, None])

    # --- plain linears ---
    if leafname == "w" and len(shape) >= 2:
        if parent in _COL_PARALLEL:
            return pad([None, "model" if _div(shape[-1], mesh, "model") else None])
        if parent in _ROW_PARALLEL:
            return pad(["model" if _div(shape[-2], mesh, "model") else None, None])
        return pad([None, None])
    if leafname == "b" and len(shape) >= 1:  # bias vectors
        if parent in _COL_PARALLEL and _div(shape[-1], mesh, "model"):
            return pad(["model"])
        return pad([None])

    # norms, conv, scalars, a_log, dt_bias, ...
    return pad([None] * len(shape))


def param_specs(params_shape, cfg: ModelConfig, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_rule(path, leaf, cfg, mesh), params_shape
    )


def to_named(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def batch_specs(batch_shape, mesh, *, include_model: bool = False):
    """tokens/labels (NB, S); frames/patches (NB, S, d)."""

    def rule(path, leaf):
        ba = batch_axes(mesh, leaf.shape[0], include_model=include_model)
        tail = [None] * (len(leaf.shape) - 1)
        return P(ba if ba else None, *tail)

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


def cache_specs(cache_shape, mesh, nb: int, *, seq_over_model: bool = False):
    """KV caches: batch over data axes when divisible, else seq over data;
    head_dim / feature dims over model when divisible.

    ``seq_over_model`` (beyond-paper §Perf optimization — flash-decode
    layout): shard the cache SEQUENCE dim over the model axis instead of
    head_dim. Attention against the cache then keeps every byte of cache
    local (each shard scores its own positions; softmax statistics and the
    weighted sum reduce with tiny all-reduces) instead of XLA replicating the
    cache to satisfy the head_dim contraction."""
    ba = batch_axes(mesh, nb)
    da = data_axes(mesh)

    def rule(path, leaf):
        names = [getattr(k, "key", str(k)) for k in path.__iter__()]
        leafname = names[-1]
        shape = leaf.shape

        def spec(tail):
            return P(*([None] * (len(shape) - len(tail)) + list(tail)))

        if leafname in ("k", "v"):  # (NB, S, KV, hd)
            b = ba if ba else None
            if seq_over_model and _div(shape[-3], mesh, "model"):
                return spec([b, "model", None, None])
            s = da if (not ba and _div(shape[-3], mesh, da)) else None
            hd = "model" if _div(shape[-1], mesh, "model") else None
            return spec([b, s, None, hd])
        if leafname == "ckv":  # (NB, S, kvlr)
            b = ba if ba else None
            if seq_over_model and _div(shape[-2], mesh, "model"):
                return spec([b, "model", None])
            s = da if (not ba and _div(shape[-2], mesh, da)) else None
            return spec([b, s, "model" if _div(shape[-1], mesh, "model") else None])
        if leafname == "k_rope":  # (NB, S, dr)
            b = ba if ba else None
            if seq_over_model and _div(shape[-2], mesh, "model"):
                return spec([b, "model", None])
            s = da if (not ba and _div(shape[-2], mesh, da)) else None
            return spec([b, s, None])
        if leafname == "conv":  # (NB, K-1, C)
            return spec([ba if ba else None, None,
                         "model" if _div(shape[-1], mesh, "model") else None])
        if leafname == "state":  # (NB, H, P, N)
            return spec([ba if ba else None,
                         "model" if _div(shape[-3], mesh, "model") else None,
                         None, None])
        return spec([None] * len(shape))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def make_dist(
    mesh,
    nb: Optional[int] = None,
    *,
    seq_sharded_residuals: bool = False,
    fsdp: bool = False,
) -> DistContext:
    """nb: the step's leading batch size — the shard_map data axes must match
    how the batch is actually sharded (e.g. long_500k b=1 is unsharded).
    ``fsdp``: batch also sharded over the model axis (see batch_axes); the
    model axis then carries no tensor parallelism at runtime, so MoE "ep"
    shard_map is not used in this mode."""
    da = (
        data_axes(mesh)
        if nb is None
        else batch_axes(mesh, nb, include_model=fsdp)
    )
    return DistContext(
        mesh=mesh,
        data_axes=da,
        model_axis=None if fsdp else "model",
        model_axis_size=1 if fsdp else _axis_size(mesh, "model"),
        seq_sharded_residuals=seq_sharded_residuals,
        fsdp=fsdp,
    )

"""Continuous-batching multi-LoRA serving engine (train-to-serve tier).

The decode batch has a fixed width of ``rows`` independent slots. Each row
carries its *own* adapter — the packed-LoRA delta dispatch that accelerates
tuning (``lora_linear`` over ``n_pack`` adapters) runs here at row
granularity: ``n_pack == rows`` with a per-row batch of 1, per-row scales,
and per-row decode positions (the vector-``pos`` path of
``models.model.decode_step``). Admission and retirement are per *token
step*: when a row finishes its request, the next queued request is admitted
into that row on the following step — the batch never drains. With
``prefill_chunk`` set, admission streams the prompt into a row-private
exact-capacity cache in bounded chunks interleaved with decode steps
(``models.model.prefill_chunk``), so other rows keep emitting while a long
prompt fills; the default (None) is the legacy synchronous one-shot prefill.
Either way the resulting row state — and every emitted token — is bitwise
identical to the sequential baseline's.

Three pieces:

``AdapterSlotCache``
    Fixed-capacity host-side staging for adapter weights, LRU-evicted.
    Misses load from a :class:`~repro.train.checkpoint.CheckpointPool`;
    ``publish()`` injects an adapter straight from a finished training job
    (the tune-then-serve handoff — no disk round trip). Adapters referenced
    by active rows are pinned and never evicted.

``ServeExecutor``
    The compile cache for serving, mirroring ``SliceExecutor``'s keyed-
    closure idiom: one jitted prefill and one jitted decode step per
    ``(cfg, n_rows, dist, ...)`` key, with ``scales`` as a *runtime*
    argument so admission never recompiles. ``serve.decode.generate`` routes
    through the process-default instance (``default_executor()``) instead of
    rebuilding its closures per call.

``ServeEngine``
    The event loop. It also implements the
    :class:`~repro.cluster.api.Runner` protocol: ``run()`` executes planned
    *training* segments through an inner
    :class:`~repro.cluster.runner.ClusterRunner` on the engine's own
    ``DevicePool``, so a live decode loop (holding ``serve_lease()``) and a
    training schedule share one pool — training blocks at planned-unit
    acquisition when serving holds capacity (serve priority), and rebalances
    at the budget-capped preemption boundaries the planner already emits.

Bit-exactness: decode rows are computed independently (batched einsums), so
a row served in a width-``rows`` continuous batch emits exactly the tokens
the same request emits under width-1 sequential decode — for dense models.
MoE capacity couples rows; serve bit-exactness claims use non-MoE configs.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LoraConfig, ModelConfig
from repro.core.adapter import PackMeta, pack_meta
from repro.core.packed_lora import extract_adapter, inject_adapter
from repro.models.model import decode_step, init_model, prefill, prefill_chunk
from repro.obs import NULL_TRACER, Histogram
from repro.serve.decode import align_prefill_chunk, pad_caches


# ---------------------------------------------------------------------------
# Request / result / stats surface
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ServeRequest:
    """One decode request against one adapter.

    ``arrival`` is in virtual time (decode steps since trace start) so
    admission order is deterministic and replayable; wall-clock SLO numbers
    are measured separately on the result. ``rank``/``alpha`` override the
    adapter checkpoint's own metadata when that lacks them.

    ``temperature``/``top_k`` select per-request sampling: 0.0 temperature
    (the default) is greedy argmax — the engine's bit-exactness baseline —
    and any positive temperature switches that row to top-k/temperature
    sampling. Both are *runtime* values of the jitted sample step, so mixing
    greedy and sampled rows in one batch never recompiles.

    ``deadline_ms`` is a wall-clock SLO measured from the moment the request
    entered the engine's queue: a queued request already past it is rejected
    before any prefill work (``error="deadline"``, zero tokens), and an
    in-flight row that goes overdue retires as a *partial* result — tokens
    emitted so far, pins released — with the same ``error`` marker."""

    request_id: int
    adapter_id: str
    prompt: np.ndarray  # (S,) int32 token ids
    max_new_tokens: int = 16
    arrival: float = 0.0
    rank: Optional[int] = None
    alpha: Optional[float] = None
    extra: Optional[dict] = None  # extra prefill batch fields (VLM frames..)
    temperature: float = 0.0  # 0.0 = greedy (bit-exactness baseline)
    top_k: int = 0  # 0 = full vocabulary (no top-k truncation)
    deadline_ms: Optional[float] = None  # wall SLO from enqueue; None = none


@dataclass
class ServeResult:
    """Emitted tokens + admission/latency accounting for one request.

    ``error`` is None for a served request. A request the engine *rejects at
    admission* (oversized prompt, unresolvable rank/alpha) comes back with
    ``error`` set, zero tokens, and admitted == finished at the rejection
    point — the drain keeps serving every other request instead of raising
    mid-flight with active rows abandoned. ``tokens`` may also be shorter
    than ``max_new_tokens`` (with ``error`` None) when a ``max_steps`` bound
    retired the row early — a partial result, not a failure. A blown
    ``deadline_ms`` marks the result ``error="deadline"``: zero tokens if it
    expired in the queue, the partial tokens if it expired in flight."""

    request_id: int
    adapter_id: str
    tokens: np.ndarray  # (<= max_new_tokens,) int32
    n_prompt: int
    arrival: float  # virtual steps (copied from the request)
    admitted_step: int  # virtual step at admission
    finished_step: int  # virtual step when the last token was emitted
    admitted_wall: float  # seconds since serve() start
    finished_wall: float
    error: Optional[str] = None  # admission-rejection reason

    @property
    def queue_steps(self) -> float:
        """Admission delay in decode steps (the virtual-time SLO)."""
        return self.admitted_step - self.arrival

    @property
    def latency_wall(self) -> float:
        return self.finished_wall - self.admitted_wall


@dataclass
class ServeStats:
    """Aggregate outcome of one ``ServeEngine.serve`` drain.

    The latency histograms are always on (a histogram record is one lock +
    one float append, tracer or not): ``ttft`` is seconds from a request
    entering the engine's queue (``submit()`` or trace arrival) to its
    first emitted token, ``itl`` is the gap between a row's consecutive
    emitted tokens — recorded once per decoding row per step, with any
    admission/prefill work that ran between the two tokens included, so
    prefill stalls show up where the request actually felt them — and
    ``queue_wait`` is seconds from enqueue to the start of admission
    (rejected requests record neither). Percentiles via e.g.
    ``stats.ttft.summary()["p95"]``."""

    results: List[ServeResult] = field(default_factory=list)
    steps: int = 0  # decode steps executed
    tokens_emitted: int = 0
    occupancy_sum: int = 0  # sum over steps of active rows
    wall_seconds: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_evictions: int = 0
    ttft: Histogram = field(default_factory=lambda: Histogram("serve.ttft"))
    itl: Histogram = field(default_factory=lambda: Histogram("serve.itl"))
    queue_wait: Histogram = field(
        default_factory=lambda: Histogram("serve.queue_wait")
    )

    @property
    def adapters_served(self) -> int:
        return len({r.adapter_id for r in self.results})

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_emitted / self.wall_seconds if self.wall_seconds else 0.0

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / self.steps if self.steps else 0.0

    def latency_summaries(self) -> Dict[str, Dict[str, float]]:
        """``{ttft, itl, queue_wait}`` percentile summaries, in seconds."""
        return {
            "ttft": self.ttft.summary(),
            "itl": self.itl.summary(),
            "queue_wait": self.queue_wait.summary(),
        }


def poisson_requests(
    adapter_ids: Sequence[str],
    prompts: Sequence[np.ndarray],
    mean_interarrival: float,
    *,
    max_new_tokens: int = 16,
    seed: int = 0,
) -> List[ServeRequest]:
    """A Poisson request trace (arrival gaps ~ Exp(mean_interarrival), in
    decode steps) — the serving analogue of ``sched.engine.poisson_trace``,
    shifted so the first request arrives at t=0."""
    assert len(adapter_ids) == len(prompts)
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(mean_interarrival, size=len(adapter_ids))
    times = np.cumsum(gaps) - gaps[0]
    return [
        ServeRequest(
            request_id=i,
            adapter_id=aid,
            prompt=np.asarray(p, np.int32),
            max_new_tokens=max_new_tokens,
            arrival=float(t),
        )
        for i, (aid, p, t) in enumerate(zip(adapter_ids, prompts, times))
    ]


# ---------------------------------------------------------------------------
# Adapter slot cache
# ---------------------------------------------------------------------------


class AdapterSlotCache:
    """Fixed-capacity LRU cache of host-side adapter weights.

    ``get`` loads from the checkpoint pool on miss; ``publish`` inserts an
    in-memory adapter directly (tune-then-serve: the training job's final
    weights go straight into a serve slot, no disk round trip). ``pin``ned
    adapters (referenced by active decode rows) are never evicted; if every
    slot is pinned the cache refuses a new insert rather than silently
    growing past capacity."""

    def __init__(self, capacity: int, pool=None, *, metrics=None):
        assert capacity >= 1
        self.capacity = capacity
        self.pool = pool
        self._slots: "OrderedDict[str, Tuple[dict, dict]]" = OrderedDict()
        self._pins: Dict[str, int] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        # optional MetricsRegistry: mirrors the local counters into the
        # run-wide registry (serve.adapter_cache_*) when tracing is on
        self.metrics = metrics if metrics is not None else NULL_TRACER.metrics

    def __contains__(self, adapter_id: str) -> bool:
        return adapter_id in self._slots

    def __len__(self) -> int:
        return len(self._slots)

    def ids(self) -> List[str]:
        """Slot ids in LRU order (least-recently-used first)."""
        return list(self._slots)

    def pin(self, adapter_id: str) -> None:
        self._pins[adapter_id] = self._pins.get(adapter_id, 0) + 1

    def unpin(self, adapter_id: str) -> None:
        n = self._pins.get(adapter_id, 0) - 1
        if n <= 0:
            self._pins.pop(adapter_id, None)
        else:
            self._pins[adapter_id] = n

    def _evict_to_fit(self) -> None:
        while len(self._slots) >= self.capacity:
            victim = next(
                (aid for aid in self._slots if aid not in self._pins), None
            )
            if victim is None:
                raise RuntimeError(
                    f"all {self.capacity} adapter slots are pinned by active "
                    "rows; cannot admit a new adapter (raise slot_capacity "
                    "or lower rows)"
                )
            self._slots.pop(victim)
            self.evictions += 1
            self.metrics.counter("serve.adapter_cache_evictions").inc()

    def publish(self, adapter_id: str, adapter_tree: dict, meta: dict) -> None:
        """Insert (or refresh) an adapter from memory — no pool involved."""
        if adapter_id in self._slots:
            self._slots.pop(adapter_id)
        else:
            self._evict_to_fit()
        self._slots[adapter_id] = (adapter_tree, dict(meta))

    def get(self, adapter_id: str) -> Tuple[dict, dict]:
        if adapter_id in self._slots:
            self.hits += 1
            self.metrics.counter("serve.adapter_cache_hits").inc()
            self._slots.move_to_end(adapter_id)
            return self._slots[adapter_id]
        self.misses += 1
        self.metrics.counter("serve.adapter_cache_misses").inc()
        if self.pool is None or not self.pool.has(adapter_id):
            raise KeyError(
                f"adapter {adapter_id!r} is neither staged nor in the "
                "checkpoint pool"
            )
        tree = self.pool.load_adapter(adapter_id)
        meta = self.pool.load_meta(adapter_id)
        self._evict_to_fit()
        self._slots[adapter_id] = (tree, dict(meta))
        return self._slots[adapter_id]


# ---------------------------------------------------------------------------
# Compile-cached serve executor
# ---------------------------------------------------------------------------


def sample_tokens(lg, temp, topk, rng):
    """Per-row temperature/top-k sampling over last-position logits.

    lg: (R, V) f32; temp: (R,) f32; topk: (R,) int32 (0 = full vocab);
    rng: one PRNG key (rows draw independent streams from it via the
    batched categorical). Rows with ``temp == 0`` return the greedy argmax
    bit-exactly — the where() keeps greedy rows on the identical argmax
    value even inside a mixed batch. All of temp/topk/rng are runtime
    values: changing them never recompiles the step."""
    v = lg.shape[-1]
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    # top-k as a sort threshold: keep logits >= the k-th largest, -inf the
    # rest. k is clamped per row; 0 means "no truncation" (k = V).
    k_eff = jnp.clip(jnp.where(topk > 0, topk, v), 1, v)
    sorted_lg = jnp.sort(lg, axis=-1)  # ascending
    thresh = jnp.take_along_axis(sorted_lg, (v - k_eff)[:, None], axis=-1)
    masked = jnp.where(lg >= thresh, lg, -jnp.inf)
    t = jnp.maximum(temp, 1e-6)[:, None]
    sampled = jax.random.categorical(rng, masked / t, axis=-1).astype(
        jnp.int32
    )
    return jnp.where(temp > 0.0, sampled, greedy)


class ServeExecutor:
    """Keyed compile cache for serving (the ``SliceExecutor`` idiom).

    ``scales`` is a runtime argument of both closures, so adapter churn
    (admission changes a row's effective alpha/r) never recompiles; jax's
    own shape specialization inside each jitted callable handles scalar- vs
    vector-``pos`` and varying prompt lengths."""

    def __init__(self):
        self._fns: Dict[Tuple, Callable] = {}

    @property
    def cache_size(self) -> int:
        return len(self._fns)

    def step_fn(self, cfg: ModelConfig, n_rows: int, *, dist=None, kcfg=None):
        """Jitted one-token decode: ``(base, lora, scales, caches, token
        (R,1), pos () or (R,)) -> (next_tok (R,), logits, caches)``."""
        key = ("step", cfg, n_rows, dist, kcfg)
        if key not in self._fns:

            def step(base, lora, scales, caches, token, pos):
                lg, caches = decode_step(
                    base, lora, scales, token, caches, pos, cfg,
                    n_pack=n_rows, dist=dist, kcfg=kcfg,
                )
                next_tok = jnp.argmax(lg[:, -1, :], axis=-1).astype(jnp.int32)
                return next_tok, lg, caches

            self._fns[key] = jax.jit(step, donate_argnums=(3,))
        return self._fns[key]

    def sample_step_fn(
        self, cfg: ModelConfig, n_rows: int, *, dist=None, kcfg=None
    ):
        """Jitted one-token decode with per-row temperature/top-k sampling:
        ``(base, lora, scales, caches, token, pos, temp (R,), topk (R,),
        rng key) -> (next_tok (R,), logits, caches)``. Compiled once per
        (cfg, n_rows, dist, kcfg) like ``step_fn`` — temp/topk/rng are
        runtime arguments, so per-request sampling churn never recompiles;
        rows with ``temp == 0`` stay greedy (``sample_tokens``)."""
        key = ("sample_step", cfg, n_rows, dist, kcfg)
        if key not in self._fns:

            def step(base, lora, scales, caches, token, pos, temp, topk, rng):
                lg, caches = decode_step(
                    base, lora, scales, token, caches, pos, cfg,
                    n_pack=n_rows, dist=dist, kcfg=kcfg,
                )
                next_tok = sample_tokens(lg[:, -1, :], temp, topk, rng)
                return next_tok, lg, caches

            self._fns[key] = jax.jit(step, donate_argnums=(3,))
        return self._fns[key]

    def prefill_fn(
        self, cfg: ModelConfig, n_rows: int, *, dist=None,
        chunk_q: int = 512, kcfg=None,
    ):
        """Jitted prefill: ``(base, lora, scales, batch) -> (last-pos logits
        (R,1,V), caches)``."""
        key = ("prefill", cfg, n_rows, dist, chunk_q, kcfg)
        if key not in self._fns:

            def prefill_(base, lora, scales, batch):
                return prefill(
                    base, lora, scales, batch, cfg,
                    n_pack=n_rows, dist=dist, chunk_q=chunk_q, kcfg=kcfg,
                )

            self._fns[key] = jax.jit(prefill_)
        return self._fns[key]

    def prefill_chunk_fn(
        self, cfg: ModelConfig, n_rows: int, *, dist=None, kcfg=None
    ):
        """Jitted chunk-resumable prefill step: ``(base, lora, scales,
        tokens (R,C), caches, pos) -> (last-pos logits (R,1,V), caches)``,
        caches donated (the engine advances a row's in-progress cache in
        place). One closure per (cfg, n_rows, dist, kcfg); jit's shape
        specialization keys the compiled executables on the (chunk, cache
        capacity) shapes, so a burst of same-shaped admissions reuses them —
        and each compiled unit is chunk-sized, unlike ``prefill_fn`` which
        specializes (and stalls) per full prompt length."""
        key = ("prefill_chunk", cfg, n_rows, dist, kcfg)
        if key not in self._fns:

            def chunk_(base, lora, scales, tokens, caches, pos):
                return prefill_chunk(
                    base, lora, scales, tokens, caches, pos, cfg,
                    n_pack=n_rows, dist=dist, kcfg=kcfg,
                )

            self._fns[key] = jax.jit(chunk_, donate_argnums=(4,))
        return self._fns[key]


_DEFAULT_EXECUTOR: Optional[ServeExecutor] = None


def default_executor() -> ServeExecutor:
    """Process-wide ServeExecutor — ``generate()`` and every engine that
    doesn't bring its own share one compile cache."""
    global _DEFAULT_EXECUTOR
    if _DEFAULT_EXECUTOR is None:
        _DEFAULT_EXECUTOR = ServeExecutor()
    return _DEFAULT_EXECUTOR


# ---------------------------------------------------------------------------
# Row-granular cache write
# ---------------------------------------------------------------------------


def write_row_caches(caches, row_caches, row):
    """Write a width-1 tree into row ``row`` of a width-R tree (decode
    caches *or* packed lora params — both share the layout convention).
    Under a scan-stacked ``"blocks"`` subtree every leaf carries an extra
    leading layer axis, shifting the batch/pack axis from 0 to 1; with that
    one shift a single ``dynamic_update_slice`` at batch-index ``row``
    (zeros elsewhere) covers every leaf kind — seq-indexed k/v/ckv/k_rope
    (update spans ``[0, s_prompt)`` of the seq axis, stale tail is masked by
    the row's position), fixed-size ssm conv/state, cross_kv, and lora a/b.
    jit-safe with ``row`` traced (the engine jits it with the width-R tree
    donated, so admission is an in-place device row write, not a host
    round trip)."""

    def walk(t, s, in_blocks):
        if isinstance(t, dict):
            return {
                k: walk(t[k], s[k], in_blocks or k == "blocks") for k in t
            }
        if t is None or s is None:
            return t
        start = [0] * t.ndim
        start[1 if in_blocks else 0] = row
        return jax.lax.dynamic_update_slice(
            t, s.astype(t.dtype), tuple(start)
        )

    return walk(caches, row_caches, False)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


@dataclass
class _PrefillState:
    """Per-row progress of a chunked, decode-interleaved prefill.

    The row owns a width-1 f32 cache sized *exactly* to its prompt — the
    shapes every chunk's attention sees are then identical to the one-shot
    prefill's, which is what makes the interleaved path bitwise equal to
    the synchronous one (see ``models.model.prefill_chunk``). The cache is
    zero-padded to ``smax`` and row-written (with the engine-wide bf16
    cast) only once the whole prompt is in."""

    lora1: Any  # width-1 device lora tree for this row's adapter
    scale: float
    caches: Any  # width-1 f32 cache tree, capacity == len(prompt)
    prompt: np.ndarray  # (S,) int32
    filled: int = 0  # tokens already written into the cache
    logits: Any = None  # last chunk's final-position logits (1,1,V)


@dataclass
class _ActiveRow:
    request: ServeRequest
    emitted: List[int]
    admitted_step: int
    admitted_wall: float
    n_prompt: int
    # wall (serve-relative) of this row's last emitted token: consecutive-
    # token gaps — the ITL each request actually observes, admission stalls
    # included — are measured against it
    last_emit_wall: float = 0.0
    # in-progress chunked prefill; None once the row is decoding
    prefill: Optional[_PrefillState] = None


class ServeEngine:
    """Continuous-batching decode over ``rows`` adapter slots.

    Also a :class:`~repro.cluster.api.Runner`: ``run()`` executes planned
    training segments through an inner ``ClusterRunner`` on this engine's
    ``device_pool``, so serving (which reserves capacity via
    ``serve_lease()``) and training share devices — the tune side of
    tune-then-serve runs concurrently with the serve side."""

    def __init__(
        self,
        cfg: ModelConfig,
        base_params,
        *,
        rows: int = 4,
        smax: int = 64,
        r_bucket: int = 8,
        slot_capacity: int = 8,
        prefill_chunk: Optional[int] = None,
        checkpoint_pool=None,
        device_pool=None,
        serve_executor: Optional[ServeExecutor] = None,
        train_executor=None,
        dist=None,
        impl: Optional[str] = None,
        remat: Optional[str] = None,
        base_dtype: Optional[str] = None,
        seed: int = 0,
        tracer=None,
    ):
        from repro.cluster.pool import DevicePool
        from repro.cluster.runner import ClusterRunner

        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.cfg = cfg
        self.rows = rows
        self.smax = smax
        self.dist = dist
        # chunked, decode-interleaved admission: at most this many prompt
        # tokens are prefilled per engine iteration (rounded up to the SSD
        # sub-chunk grid on SSM stacks — bitwise-safe resume boundaries);
        # None = legacy synchronous one-shot prefill at admission
        self.prefill_chunk = align_prefill_chunk(cfg, prefill_chunk)
        # uniform engine-wide rank bucket: every admitted adapter is
        # zero-padded to r_bucket at injection, so the pack shape — and the
        # compiled step — never changes across admissions
        self.meta = pack_meta(
            [LoraConfig(rank=r_bucket, alpha=float(r_bucket))] * rows
        )
        self.meta1 = pack_meta([LoraConfig(rank=r_bucket, alpha=float(r_bucket))])
        # per-adapter delta dispatch at row granularity: the pack's kernel
        # policy rides into prefill and every decode step. ``base_dtype``
        # marks a quantized base (kernels/quant.py): prefill, every decode
        # row, and the training Runner side all share the SAME quantized
        # base_params tree — quantize once, serve + tune from it.
        self.kcfg = (
            self.meta.kernel_config(impl=impl, remat=remat,
                                    base_dtype=base_dtype)
            if (impl or remat or base_dtype) else None
        )
        self.kcfg1 = (
            self.meta1.kernel_config(impl=impl, remat=remat,
                                     base_dtype=base_dtype)
            if (impl or remat or base_dtype) else None
        )
        self.base = base_params
        key = jax.random.PRNGKey(seed)
        _, lora = init_model(key, cfg, self.meta)
        # device-resident R-row pack + width-1 host template (B = 0: empty
        # rows contribute exactly zero delta even before their scale is
        # zeroed). Admission writes one pack row device-side.
        self._lora = lora
        _, lora1 = init_model(key, cfg, self.meta1)
        self._lora1_host = jax.tree.map(np.asarray, lora1)
        # one jitted row write per tree structure (caches / lora), width-R
        # argument donated: admission mutates device state in place
        self._row_write = jax.jit(write_row_caches, donate_argnums=(0,))
        self._scales = np.zeros((rows,), np.float32)
        self._caches = None  # allocated lazily on first serve()
        self._tok = np.zeros((rows, 1), np.int32)
        self._pos = np.zeros((rows,), np.int32)
        self._rows: List[Optional[_ActiveRow]] = [None] * rows
        # per-row sampling state (0 temperature = greedy row); the engine
        # only routes through the sample step while some row has temp > 0,
        # so an all-greedy drain runs the *identical* compiled step_fn —
        # the bit-exactness baseline is preserved by construction
        self._temp = np.zeros((rows,), np.float32)
        self._topk = np.zeros((rows,), np.int32)
        self._sample_key = jax.random.fold_in(
            jax.random.PRNGKey(seed), 0x5EED
        )

        self.slot_cache = AdapterSlotCache(
            slot_capacity, pool=checkpoint_pool,
            metrics=self.tracer.metrics,
        )
        self.queue: "deque[ServeRequest]" = deque()
        # absolute perf_counter at which each queued request entered the
        # engine, for the TTFT / queue-wait histograms. Absolute (not
        # serve-relative) so a request submit()ted before serve() starts
        # still measures from its true enqueue, not from serve-start.
        self._enq_abs: Dict[int, float] = {}
        self._serve_t0 = 0.0  # perf_counter origin of the live serve() call
        self.serve_executor = serve_executor or default_executor()

        # Runner surface: training side
        self.device_pool = device_pool or DevicePool()
        if train_executor is None:
            from repro.cluster.executor import SliceExecutor

            train_executor = SliceExecutor(tracer=self.tracer)
        self.executor = train_executor
        self._runner = ClusterRunner(
            self.executor, self.device_pool, concurrent=None,
            tracer=self.tracer,
        )
        self.concurrent = self._runner.concurrent

    # ---------------- Runner protocol (training side) ----------------------

    def run(
        self,
        segments: Sequence,
        configs_by_cid: Dict,
        total_steps: Dict[int, int],
        cfg,
        base_params,
        *,
        seq: int,
        pool=None,
        data_iter_fn: Optional[Callable] = None,
        seed: int = 0,
        estimator=None,
        impl: Optional[str] = None,
        remat: Optional[str] = None,
        base_dtype: Optional[str] = None,
    ):
        """Execute planned *training* segments on the shared device pool
        (delegates to the inner ``ClusterRunner``). A concurrent decode loop
        holding ``serve_lease()`` keeps its units; training segments planned
        onto the remaining units proceed in parallel and block — serve
        priority — if the planner oversubscribes."""
        return self._runner.run(
            segments, configs_by_cid, total_steps, cfg, base_params,
            seq=seq, pool=pool, data_iter_fn=data_iter_fn, seed=seed,
            estimator=estimator, impl=impl, remat=remat,
            base_dtype=base_dtype,
        )

    @contextmanager
    def serve_lease(self, n: int = 1):
        """Reserve the *last* ``n`` pool units for decoding. The training
        planner allocates units from 0 upward, so a schedule planned over
        ``device_pool.total - n`` units never touches the reserved ones."""
        total = self.device_pool.total
        assert 1 <= n <= total
        sl = self.device_pool.acquire_units(list(range(total - n, total)))
        try:
            yield sl
        finally:
            self.device_pool.release(sl)

    # ---------------- adapter staging --------------------------------------

    def publish(self, adapter_id: str, adapter_tree: dict, meta: dict) -> None:
        """Tune-then-serve handoff: stage a finished training job's adapter
        directly (no disk round trip)."""
        self.slot_cache.publish(adapter_id, adapter_tree, meta)

    def publish_from_packed_state(
        self, pool, state_id: str, idx: int, adapter_id: str,
        *, rank: int, alpha: float,
    ) -> None:
        """Stage adapter ``idx`` out of a whole-pack training snapshot
        (``CheckpointPool.save_packed_state``)."""
        lora, _opt, _meta = pool.load_packed_state(state_id)
        adapter = extract_adapter(lora, idx, ranks=None)
        self.publish(adapter_id, adapter, {"rank": rank, "alpha": alpha})

    # ---------------- admission / retirement --------------------------------

    def submit(self, req: ServeRequest) -> None:
        """Enqueue a request ahead of (or during) a ``serve()`` drain. The
        enqueue instant is recorded here — queue-wait and TTFT span from the
        moment the request entered the engine, not from serve-start."""
        self._enq_abs[req.request_id] = time.perf_counter()
        self.queue.append(req)

    def _deadline_blown(self, req: ServeRequest) -> bool:
        """Is ``req`` past its wall-clock SLO, measured from the instant it
        entered the engine's queue (``submit()`` or trace arrival)?"""
        if req.deadline_ms is None:
            return False
        enq = self._enq_abs.get(req.request_id)
        if enq is None:
            return False
        return (time.perf_counter() - enq) * 1e3 > req.deadline_ms

    def _scale_for(self, req: ServeRequest, meta: dict) -> float:
        rank = req.rank if req.rank is not None else meta.get("rank")
        alpha = req.alpha if req.alpha is not None else meta.get("alpha")
        if rank is None or alpha is None:
            raise ValueError(
                f"request {req.request_id} for adapter {req.adapter_id!r}: "
                "rank/alpha neither on the request nor in adapter metadata"
            )
        return float(alpha) / float(rank)

    def _admit(self, req: ServeRequest, row: int, step: int, wall: float,
               stats: Optional[ServeStats] = None) -> Optional[ServeResult]:
        """Admit ``req`` into free row ``row`` — or reject it.

        Validation (prompt budget, adapter resolution) runs *before* any
        latency accounting or pinning: a rejected request comes back as an
        errored :class:`ServeResult` (the drain keeps serving everything
        else), never records a queue-wait/TTFT sample, and never leaks a
        slot-cache pin. Returns None on successful admission — the row is
        then either decoding (synchronous one-shot prefill) or filling its
        cache chunk-by-chunk (``prefill_chunk`` set)."""
        prompt = np.asarray(req.prompt, np.int32)
        n_patch = self.cfg.n_patch_tokens or 0
        s_total = prompt.shape[0] + n_patch
        err = adapter = ameta = scale = None
        if s_total + req.max_new_tokens > self.smax:
            err = (
                f"request {req.request_id}: prompt {s_total} + "
                f"{req.max_new_tokens} new tokens exceeds smax={self.smax}"
            )
        else:
            try:
                adapter, ameta = self.slot_cache.get(req.adapter_id)
                scale = self._scale_for(req, ameta)
            except (KeyError, ValueError) as e:
                err = str(e)
        if err is not None:
            self._enq_abs.pop(req.request_id, None)
            return ServeResult(
                request_id=req.request_id,
                adapter_id=req.adapter_id,
                tokens=np.zeros((0,), np.int32),
                n_prompt=int(prompt.shape[0]),
                arrival=req.arrival,
                admitted_step=step,
                finished_step=step,
                admitted_wall=wall,
                finished_wall=wall,
                error=err,
            )
        if stats is not None:
            stats.queue_wait.record(
                max(0.0, time.perf_counter() - self._enq_abs[req.request_id])
            )
        with self.tracer.span(
            "serve.admit", cat="serve", track=f"row{row}",
            request_id=req.request_id, adapter=req.adapter_id, step=step,
        ):
            self.slot_cache.pin(req.adapter_id)
            # weights: rank-pad into the width-1 template (prefill — the
            # bit-identical twin of the sequential baseline's), then write
            # that row into the device-resident R-row pack; rows are
            # independent thereafter
            lora1 = jax.tree.map(
                jnp.asarray, inject_adapter(self._lora1_host, adapter, 0)
            )
            self._lora = self._row_write(self._lora, lora1, row)
            if (
                self.prefill_chunk is not None
                and not req.extra
                and not n_patch
                and not self.cfg.is_encdec
            ):
                # chunked interleaved admission: allocate the row's private
                # f32 cache at capacity == prompt length (the bitwise
                # invariant) and let the drain loop stream chunks into it
                # between decode steps; the row flips to decode — and the
                # first token / TTFT land — once the prompt is fully cached
                from repro.models.model import init_caches

                self._rows[row] = _ActiveRow(
                    request=req, emitted=[], admitted_step=step,
                    admitted_wall=wall, n_prompt=prompt.shape[0],
                    prefill=_PrefillState(
                        lora1=lora1, scale=scale,
                        caches=init_caches(
                            self.cfg, 1, s_total, dtype=jnp.float32
                        ),
                        prompt=prompt,
                    ),
                )
                return None
            batch = {"tokens": jnp.asarray(prompt[None, :])}
            if req.extra:
                batch.update(req.extra)
            # the prefill-stall span: decode is paused while this row fills
            with self.tracer.span(
                "serve.prefill", cat="serve", track=f"row{row}",
                request_id=req.request_id, n_prompt=int(prompt.shape[0]),
            ):
                pf = self.serve_executor.prefill_fn(
                    self.cfg, 1, dist=self.dist, kcfg=self.kcfg1
                )
                lg, c1 = pf(
                    self.base, lora1, jnp.full((1,), scale, jnp.float32),
                    batch,
                )
                c1 = pad_caches(c1, self.smax)
                self._caches = self._row_write(self._caches, c1, row)
                temp = float(req.temperature)
                topk = int(req.top_k)
                if temp > 0.0:
                    # the first token comes from prefill, outside the jitted
                    # step — sample it eagerly with the same formula, keyed
                    # by request id so admission order doesn't change it
                    first = int(sample_tokens(
                        lg[:, -1, :],
                        jnp.full((1,), temp, jnp.float32),
                        jnp.full((1,), topk, jnp.int32),
                        jax.random.fold_in(self._sample_key, req.request_id),
                    )[0])
                else:
                    first = int(jnp.argmax(lg[0, -1, :]))
        now = time.perf_counter()
        if stats is not None:
            # the prefill above emitted the request's first token
            stats.ttft.record(max(0.0, now - self._enq_abs[req.request_id]))
        self._scales[row] = scale
        self._temp[row] = temp
        self._topk[row] = topk
        self._tok[row, 0] = first
        self._pos[row] = s_total
        self._rows[row] = _ActiveRow(
            request=req, emitted=[first], admitted_step=step,
            admitted_wall=wall, n_prompt=prompt.shape[0],
            last_emit_wall=now - self._serve_t0,
        )
        return None

    def _prefill_advance(
        self, row: int, step: int, stats: ServeStats
    ) -> bool:
        """Run ONE prefill chunk for ``row``'s in-progress request.

        On the final chunk the row flips into the decode set: the exact-
        capacity f32 cache is zero-padded to ``smax`` and row-written (same
        pad + bf16-cast path as one-shot admission, so the engine state is
        bitwise identical), the first token is emitted, and TTFT is
        recorded. Returns True once the row is decoding."""
        a = self._rows[row]
        ps = a.prefill
        req = a.request
        c = min(self.prefill_chunk, len(ps.prompt) - ps.filled)
        with self.tracer.span(
            "serve.prefill_chunk", cat="serve", track=f"row{row}",
            request_id=req.request_id, step=step, pos=ps.filled,
            chunk=int(c), n_prompt=len(ps.prompt),
        ):
            fn = self.serve_executor.prefill_chunk_fn(
                self.cfg, 1, dist=self.dist, kcfg=self.kcfg1
            )
            lg, ps.caches = fn(
                self.base, ps.lora1,
                jnp.full((1,), ps.scale, jnp.float32),
                jnp.asarray(ps.prompt[None, ps.filled : ps.filled + c]),
                ps.caches, jnp.int32(ps.filled),
            )
            ps.filled += c
            if ps.filled < len(ps.prompt):
                # sync so the span measures the chunk (and the iteration's
                # overhead stays the one bounded chunk, not deferred work)
                jax.block_until_ready(lg)
                return False
            c1 = pad_caches(ps.caches, self.smax)
            self._caches = self._row_write(self._caches, c1, row)
            temp = float(req.temperature)
            topk = int(req.top_k)
            if temp > 0.0:
                first = int(sample_tokens(
                    lg[:, -1, :],
                    jnp.full((1,), temp, jnp.float32),
                    jnp.full((1,), topk, jnp.int32),
                    jax.random.fold_in(self._sample_key, req.request_id),
                )[0])
            else:
                first = int(jnp.argmax(lg[0, -1, :]))
        now = time.perf_counter()
        stats.ttft.record(max(0.0, now - self._enq_abs[req.request_id]))
        self._scales[row] = ps.scale
        self._temp[row] = temp
        self._topk[row] = topk
        self._tok[row, 0] = first
        self._pos[row] = len(ps.prompt)
        a.emitted.append(first)
        a.last_emit_wall = now - self._serve_t0
        a.prefill = None
        return True

    def _retire(
        self, row: int, step: int, wall: float,
        error: Optional[str] = None,
    ) -> ServeResult:
        active = self._rows[row]
        assert active is not None
        self._rows[row] = None
        self._scales[row] = 0.0
        self._temp[row] = 0.0
        self._topk[row] = 0
        self.slot_cache.unpin(active.request.adapter_id)
        self._enq_abs.pop(active.request.request_id, None)
        # the request's whole residency on its row, admit -> retire
        self.tracer.add_span(
            "serve.request",
            self._serve_t0 + active.admitted_wall,
            self._serve_t0 + wall,
            cat="serve",
            track=f"row{row}",
            request_id=active.request.request_id,
            adapter=active.request.adapter_id,
            tokens=len(active.emitted),
        )
        return ServeResult(
            request_id=active.request.request_id,
            adapter_id=active.request.adapter_id,
            tokens=np.asarray(active.emitted, np.int32),
            n_prompt=active.n_prompt,
            arrival=active.request.arrival,
            admitted_step=active.admitted_step,
            finished_step=step,
            admitted_wall=active.admitted_wall,
            finished_wall=wall,
            error=error,
        )

    # ---------------- the decode loop ---------------------------------------

    def serve(
        self,
        requests: Optional[Sequence[ServeRequest]] = None,
        *,
        max_steps: Optional[int] = None,
    ) -> ServeStats:
        """Drain a request trace (plus anything already ``submit()``ted).

        Virtual time is the decode-step counter: a request becomes
        admissible once ``step >= arrival``; freed rows are refilled before
        the next step, so the batch never drains while work is queued."""
        from repro.models.model import init_caches

        pending = deque(
            sorted(requests or (), key=lambda r: (r.arrival, r.request_id))
        )
        if self._caches is None:
            self._caches = init_caches(self.cfg, self.rows, self.smax)
        stats = ServeStats()
        with self.tracer.span(
            "serve.drain", cat="serve", track="serve",
            n_requests=len(pending) + len(self.queue), rows=self.rows,
        ):
            self._serve_drain(pending, stats, max_steps)
        stats.cache_hits = self.slot_cache.hits
        stats.cache_misses = self.slot_cache.misses
        stats.cache_evictions = self.slot_cache.evictions
        stats.results.sort(key=lambda r: r.request_id)
        return stats

    def _serve_drain(
        self,
        pending: "deque[ServeRequest]",
        stats: ServeStats,
        max_steps: Optional[int],
    ) -> None:
        tracer = self.tracer
        qdepth = tracer.metrics.gauge("serve.queue_depth")
        t0 = time.perf_counter()
        self._serve_t0 = t0
        step = 0
        while True:
            wall = time.perf_counter() - t0
            while pending and pending[0].arrival <= step:
                req = pending.popleft()
                self._enq_abs.setdefault(req.request_id, time.perf_counter())
                self.queue.append(req)
            qdepth.set(len(self.queue))
            for row in range(self.rows):
                while self._rows[row] is None and self.queue:
                    req = self.queue.popleft()
                    if self._deadline_blown(req):
                        # already overdue in the queue: no prefill is ever
                        # spent on it — reject crisply, try the next one
                        self._enq_abs.pop(req.request_id, None)
                        stats.results.append(ServeResult(
                            request_id=req.request_id,
                            adapter_id=req.adapter_id,
                            tokens=np.zeros((0,), np.int32),
                            n_prompt=int(np.asarray(req.prompt).shape[0]),
                            arrival=req.arrival,
                            admitted_step=step,
                            finished_step=step,
                            admitted_wall=wall,
                            finished_wall=wall,
                            error="deadline",
                        ))
                        continue
                    rejected = self._admit(req, row, step, wall, stats)
                    if rejected is not None:
                        # row is still free — surface the rejection and try
                        # the next queued request instead of aborting
                        stats.results.append(rejected)
                        continue
                    a = self._rows[row]
                    if (
                        a.prefill is None
                        and len(a.emitted) >= req.max_new_tokens
                    ):
                        # single-token request: prefill already emitted it
                        stats.tokens_emitted += len(a.emitted)
                        stats.results.append(self._retire(row, step, wall))
            # one prefill chunk per still-filling row: admission cost is
            # paid in bounded slices interleaved with decode steps, not as
            # one stall that freezes every in-flight row
            for row in range(self.rows):
                a = self._rows[row]
                if a is None or a.prefill is None:
                    continue
                if self._prefill_advance(row, step, stats):
                    if len(a.emitted) >= a.request.max_new_tokens:
                        wall = time.perf_counter() - t0
                        stats.tokens_emitted += len(a.emitted)
                        stats.results.append(self._retire(row, step, wall))
            # deadline SLO: an overdue in-flight row retires as a *partial*
            # result — tokens emitted so far kept, pins released — exactly
            # the bounded-drain (max_steps) early-exit contract; its row
            # refills from the queue on the next pass
            for row in range(self.rows):
                a = self._rows[row]
                if a is None or not self._deadline_blown(a.request):
                    continue
                wall = time.perf_counter() - t0
                stats.tokens_emitted += len(a.emitted)
                stats.results.append(
                    self._retire(row, step, wall, error="deadline")
                )
            active = [r for r in range(self.rows) if self._rows[r] is not None]
            if not active:
                if self.queue:
                    continue  # rows freed this pass; admit more
                if pending:
                    step = int(np.ceil(pending[0].arrival))
                    continue
                break
            if max_steps is not None and stats.steps >= max_steps:
                # bounded drain: retire in-flight rows into partial results
                # (tokens emitted so far, pins released) instead of
                # dropping them from stats with their adapters pinned
                wall = time.perf_counter() - t0
                for row in active:
                    stats.tokens_emitted += len(self._rows[row].emitted)
                    stats.results.append(self._retire(row, step, wall))
                break
            decoding = [r for r in active if self._rows[r].prefill is None]
            if not decoding:
                # chunk-only iteration: virtual time still advances, so
                # trace arrivals keep landing in free rows mid-prefill
                step += 1
                continue
            with tracer.span(
                "serve.step", cat="serve", track="serve",
                step=step, batch=len(decoding),
            ):
                if self._temp.any():
                    fn = self.serve_executor.sample_step_fn(
                        self.cfg, self.rows, dist=self.dist, kcfg=self.kcfg
                    )
                    next_tok, _lg, self._caches = fn(
                        self.base, self._lora, jnp.asarray(self._scales),
                        self._caches, jnp.asarray(self._tok),
                        jnp.asarray(self._pos), jnp.asarray(self._temp),
                        jnp.asarray(self._topk),
                        jax.random.fold_in(self._sample_key, step),
                    )
                else:
                    fn = self.serve_executor.step_fn(
                        self.cfg, self.rows, dist=self.dist, kcfg=self.kcfg
                    )
                    next_tok, _lg, self._caches = fn(
                        self.base, self._lora, jnp.asarray(self._scales),
                        self._caches, jnp.asarray(self._tok),
                        jnp.asarray(self._pos),
                    )
                next_tok = np.asarray(next_tok)
            step += 1
            stats.steps += 1
            stats.occupancy_sum += len(decoding)
            wall = time.perf_counter() - t0
            # each decoding row emitted exactly one token this iteration;
            # the gap since the row's previous token — admission/chunk work
            # in between included — is the inter-token latency that row's
            # request actually observed
            for row in decoding:
                a = self._rows[row]
                stats.itl.record(max(0.0, wall - a.last_emit_wall))
                a.last_emit_wall = wall
                a.emitted.append(int(next_tok[row]))
                self._tok[row, 0] = int(next_tok[row])
                self._pos[row] += 1
                if len(a.emitted) >= a.request.max_new_tokens:
                    stats.tokens_emitted += len(a.emitted)
                    stats.results.append(self._retire(row, step, wall))
        stats.wall_seconds = time.perf_counter() - t0

    # ---------------- sequential baseline -----------------------------------

    def serve_sequential(
        self, requests: Sequence[ServeRequest]
    ) -> ServeStats:
        """One request at a time at batch width 1 — the pre-engine serving
        path (``generate()`` semantics), through the same compile cache.
        The benchmark's baseline and the bit-exactness reference."""
        stats = ServeStats()
        t0 = time.perf_counter()
        order = sorted(requests, key=lambda r: (r.arrival, r.request_id))
        for req in order:
            # all requests are in hand at t0, so the time spent behind
            # earlier requests is this one's queue wait
            stats.queue_wait.record(time.perf_counter() - t0)
            adapter, ameta = self.slot_cache.get(req.adapter_id)
            scale = self._scale_for(req, ameta)
            lora1 = jax.tree.map(
                jnp.asarray, inject_adapter(self._lora1_host, adapter, 0)
            )
            prompt = np.asarray(req.prompt, np.int32)
            n_patch = self.cfg.n_patch_tokens or 0
            s_total = prompt.shape[0] + n_patch
            batch = {"tokens": jnp.asarray(prompt[None, :])}
            if req.extra:
                batch.update(req.extra)
            scales = jnp.full((1,), scale, jnp.float32)
            pf = self.serve_executor.prefill_fn(
                self.cfg, 1, dist=self.dist, kcfg=self.kcfg1
            )
            lg, caches = pf(self.base, lora1, scales, batch)
            caches = pad_caches(caches, s_total + req.max_new_tokens)
            admitted = time.perf_counter() - t0
            stats.ttft.record(admitted)  # prefill just emitted token one
            tok = jnp.argmax(lg[:, -1, :], -1).astype(jnp.int32)
            out = [int(tok[0])]
            fn = self.serve_executor.step_fn(
                self.cfg, 1, dist=self.dist, kcfg=self.kcfg1
            )
            t_prev = time.perf_counter()
            for i in range(req.max_new_tokens - 1):
                tok, _lg, caches = fn(
                    self.base, lora1, scales, caches, tok[:, None],
                    jnp.int32(s_total + i),
                )
                out.append(int(tok[0]))  # syncs the device step
                stats.steps += 1
                stats.occupancy_sum += 1
                t_now = time.perf_counter()
                stats.itl.record(t_now - t_prev)
                t_prev = t_now
            wall = time.perf_counter() - t0
            stats.tokens_emitted += len(out)
            stats.results.append(
                ServeResult(
                    request_id=req.request_id,
                    adapter_id=req.adapter_id,
                    tokens=np.asarray(out, np.int32),
                    n_prompt=prompt.shape[0],
                    arrival=req.arrival,
                    admitted_step=stats.steps,
                    finished_step=stats.steps,
                    admitted_wall=admitted,
                    finished_wall=wall,
                )
            )
        stats.wall_seconds = time.perf_counter() - t0
        stats.results.sort(key=lambda r: r.request_id)
        return stats

"""Multi-LoRA serving: prefill + decode steps over packed adapters.

The same packed-adapter machinery that accelerates tuning serves the tuned
adapters afterwards (the SLoRA/Punica setting the paper builds on): a decode
batch of (N*B) requests where requests [n*B, (n+1)*B) use adapter n runs one
grouped-kernel pass — no per-adapter dispatch.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.adapter import PackMeta
from repro.models.model import decode_step, init_caches, prefill
from repro.models.transformer import DistContext


def make_serve_step(
    cfg: ModelConfig,
    meta: Optional[PackMeta],
    *,
    dist: Optional[DistContext] = None,
    jit: bool = True,
):
    """One-token decode against a KV cache/SSM state of capacity seq_len."""
    scales = meta.scales() if meta else jnp.ones((1,), jnp.float32)
    n_pack = meta.n if meta else 1

    def serve_step(base, lora, caches, token, pos):
        lg, caches = decode_step(
            base, lora, scales, token, caches, pos, cfg,
            n_pack=n_pack, dist=dist,
        )
        next_tok = jnp.argmax(lg[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, lg, caches

    return jax.jit(serve_step, donate_argnums=(2,)) if jit else serve_step


def make_prefill(
    cfg: ModelConfig,
    meta: Optional[PackMeta],
    *,
    dist: Optional[DistContext] = None,
    chunk_q: int = 512,
    jit: bool = True,
):
    scales = meta.scales() if meta else jnp.ones((1,), jnp.float32)
    n_pack = meta.n if meta else 1

    def prefill_fn(base, lora, batch):
        return prefill(
            base, lora, scales, batch, cfg,
            n_pack=n_pack, dist=dist, chunk_q=chunk_q,
        )

    return jax.jit(prefill_fn) if jit else prefill_fn


def pad_caches(caches, target_len: int):
    """Grow prefill caches (seq axis) to `target_len` capacity for decode.
    Seq-indexed leaves: attn k/v (NB,S,KV,D) and MLA ckv/k_rope (NB,S,*);
    under a scan-stacked "blocks" subtree every leaf carries an extra leading
    layer axis, shifting the seq axis from 1 to 2. SSM and cross-attention
    caches are fixed-size."""

    def walk(t, in_blocks=False):
        if isinstance(t, dict):
            out = {}
            for k, v in t.items():
                if k in ("cross_kv", "ssm"):
                    out[k] = v  # fixed-size
                elif k in ("k", "v", "ckv", "k_rope"):
                    ax = 2 if in_blocks else 1
                    pad = target_len - v.shape[ax]
                    assert pad >= 0, (k, v.shape, target_len)
                    cfgpad = [(0, 0)] * v.ndim
                    cfgpad[ax] = (0, pad)
                    out[k] = jnp.pad(v, cfgpad)
                else:
                    out[k] = walk(v, in_blocks or k == "blocks")
            return out
        return t

    return walk(caches)


def align_prefill_chunk(cfg: ModelConfig, chunk: Optional[int]) -> Optional[int]:
    """Round a prefill chunk size up so resume boundaries stay bitwise-safe.

    Attention chunks commute with the causal mask at any boundary, but SSM
    (SSD) stacks are only bitwise-resumable when every boundary falls on the
    scan's sub-chunk grid (``cfg.ssm.chunk_size``) — off-grid boundaries
    regroup the chunked quadratic dual and drift by ulps. None/0 disables
    chunking (one-shot prefill)."""
    if not chunk or chunk <= 0:
        return None
    if cfg.ssm is not None and "ssm" in cfg.layer_kinds():
        q = cfg.ssm.chunk_size
        chunk = -(-chunk // q) * q
    return int(chunk)


def prefill_chunked(
    base,
    lora,
    scales,
    tokens: jnp.ndarray,  # (NB, S) int32
    cfg: ModelConfig,
    chunk: int,
    *,
    n_pack: int = 1,
    dist: Optional[DistContext] = None,
    kcfg=None,
    executor=None,
    capacity: Optional[int] = None,
):
    """Chunked prefill: ``prefill``'s contract, built from ``prefill_chunk``
    steps of at most ``chunk`` tokens. Returns (last-pos logits (NB,1,V),
    caches) with cache capacity ``capacity or S`` — capacity ``S`` (the
    default) makes the result *bitwise* identical to one-shot ``prefill``
    (every chunk attends a cache whose shapes match the one-shot attention
    operands exactly). Caches are f32, like the in-flight K/V of one-shot
    prefill; cast at the consumer like ``write_row_caches`` does."""
    from repro.serve.engine import default_executor

    ex = executor if executor is not None else default_executor()
    chunk = align_prefill_chunk(cfg, chunk)
    assert chunk, "prefill_chunked needs a positive chunk size"
    nb, s = tokens.shape
    caches = init_caches(cfg, nb, capacity or s, dtype=jnp.float32)
    fn = ex.prefill_chunk_fn(cfg, n_pack, dist=dist, kcfg=kcfg)
    lg, p0 = None, 0
    while p0 < s:
        c = min(chunk, s - p0)
        lg, caches = fn(
            base, lora, scales, tokens[:, p0 : p0 + c], caches, jnp.int32(p0)
        )
        p0 += c
    return lg, caches


def generate(
    base,
    lora,
    cfg: ModelConfig,
    meta: Optional[PackMeta],
    prompt_tokens: jnp.ndarray,  # (NB, S_prompt)
    n_new: int,
    *,
    dist=None,
    batch_extra=None,
    executor=None,
):
    """Greedy generation: prefill the prompt, then decode n_new tokens.

    Compilation goes through a :class:`~repro.serve.engine.ServeExecutor`
    (the process-default one unless ``executor`` is given), so repeated
    calls with the same config/pack shape reuse the jitted prefill/step
    instead of rebuilding and re-tracing the closures every invocation."""
    from repro.serve.engine import default_executor

    ex = executor if executor is not None else default_executor()
    scales = meta.scales() if meta else jnp.ones((1,), jnp.float32)
    n_pack = meta.n if meta else 1
    s_prompt = prompt_tokens.shape[1]
    # VLM prefixes extend the cached sequence by the patch count
    s_total = s_prompt + (cfg.n_patch_tokens if cfg.n_patch_tokens else 0)
    batch = {"tokens": prompt_tokens}
    if batch_extra:
        batch.update(batch_extra)
    lg, caches = ex.prefill_fn(cfg, n_pack, dist=dist)(base, lora, scales, batch)
    caches = pad_caches(caches, s_total + n_new)
    step_fn = ex.step_fn(cfg, n_pack, dist=dist)
    tok = jnp.argmax(lg[:, -1, :], -1).astype(jnp.int32)
    out = [tok]
    pos0 = s_total
    for i in range(n_new - 1):
        tok, lg, caches = step_fn(
            base, lora, scales, caches, tok[:, None], jnp.int32(pos0 + i)
        )
        out.append(tok)
    return jnp.stack(out, axis=1)  # (NB, n_new)

from repro.serve.decode import generate, make_prefill, make_serve_step, pad_caches

__all__ = ["generate", "make_prefill", "make_serve_step", "pad_caches"]

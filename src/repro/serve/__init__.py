from repro.serve.decode import generate, make_prefill, make_serve_step, pad_caches
from repro.serve.engine import (
    AdapterSlotCache,
    ServeEngine,
    ServeExecutor,
    ServeRequest,
    ServeResult,
    ServeStats,
    default_executor,
    poisson_requests,
    write_row_caches,
)

__all__ = [
    "generate",
    "make_prefill",
    "make_serve_step",
    "pad_caches",
    "AdapterSlotCache",
    "ServeEngine",
    "ServeExecutor",
    "ServeRequest",
    "ServeResult",
    "ServeStats",
    "default_executor",
    "poisson_requests",
    "write_row_caches",
]

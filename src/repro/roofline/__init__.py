from repro.roofline.analysis import RooflineReport, analyze, collective_bytes

"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE — with
lax.scan'd layer stacks that under-reports FLOPs/bytes/collectives by the
layer count. This module walks the optimized HLO text, multiplies loop-body
costs by the loop trip count (parsed from the loop condition's comparison
constant), and accounts:

  flops        — dot ops: 2 * numel(result) * contracted size
  bytes        — per instruction: result + operand shape bytes (fusions are
                 one instruction, so internal temporaries aren't counted —
                 matching the HBM-traffic intuition)
  collectives  — wire bytes per kind with ring multipliers

Cross-checked against XLA's own numbers on unrolled graphs in
tests/test_roofline.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-_]+)\s*(\(.*\))\s*->")
_OP_NAME_RE = re.compile(r"\s*([\w\-]+)\(")


def _parse_instr(line: str):
    """(name, result_shape_str, op) or None. Handles tuple result shapes
    containing ``/*index=N*/`` comments (which break naive regexes)."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    eq = s.find(" = ")
    if eq < 0 or not s or not (s[0] == "%" or s[0].isalpha()):
        return None
    name = s[:eq].strip().lstrip("%")
    rest = s[eq + 3 :]
    if rest.startswith("("):  # tuple shape: find matching close paren
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        else:
            return None
        result_str = rest[: i + 1]
        tail = rest[i + 1 :]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        result_str = rest[:sp]
        tail = rest[sp:]
    m = _OP_NAME_RE.match(tail)
    if not m:
        return None
    return name, result_str, m.group(1)
_PARAM_RE = re.compile(r"([\w.\-_]+):\s*((?:\([^)]*\))|[\w\[\],]+)")
_OPERAND_RE = re.compile(r"%([\w.\-_]+)")
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-_]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-_]+)")
_CONST_RE = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_WIRE_FACTOR = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}
_COLLECTIVES = set(_WIRE_FACTOR)
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "opt-barrier",
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _numel(shape_str: str) -> int:
    n = 1
    for d in _first_dims(shape_str):
        n *= d
    return n


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()})

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


class HloCostAnalyzer:
    def __init__(self, hlo_text: str):
        self.headers: Dict[str, str] = {}
        self.comps: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self._shapes: Dict[str, Dict[str, str]] = {}

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            if cur is None or line.rstrip().endswith("{"):
                m = _COMP_HDR.match(line)
                if m and line.rstrip().endswith("{"):
                    cur = m.group(1)
                    self.headers[cur] = m.group(2)
                    self.comps[cur] = []
                    if line.startswith("ENTRY"):
                        self.entry = cur
                    continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                    continue
                self.comps[cur].append(line)

    # ---------------- symbol table ----------------

    def _shape_table(self, name: str) -> Dict[str, str]:
        if name in self._shapes:
            return self._shapes[name]
        tab: Dict[str, str] = {}
        hdr = self.headers.get(name, "")
        for pname, pshape in _PARAM_RE.findall(hdr):
            tab[pname] = pshape
        for line in self.comps.get(name, ()):
            m = _parse_instr(line)
            if m:
                tab[m[0]] = m[1]
        self._shapes[name] = tab
        return tab

    def _operand_shapes(self, name: str, line: str) -> List[str]:
        tab = self._shape_table(name)
        try:
            inner = line.split("(", 1)[1]
        except IndexError:
            return []
        return [tab[o] for o in _OPERAND_RE.findall(inner) if o in tab]

    # ---------------- costs ----------------

    def _trip_count(self, cond_name: str) -> int:
        best = 1
        for line in self.comps.get(cond_name, ()):
            for c in _CONST_RE.findall(line):
                best = max(best, int(c))
        return best

    def _dot_flops(self, comp: str, result_str: str, line: str) -> float:
        ops = self._operand_shapes(comp, line)
        if not ops:
            return 0.0
        lhs_dims = _first_dims(ops[0])
        m = _CONTRACT_RE.search(line)
        contract = 1
        if m:
            for idx in m.group(1).split(","):
                if idx:
                    contract *= lhs_dims[int(idx)]
        return 2.0 * _numel(result_str) * contract

    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # cycle guard
        total = Cost()
        for line in self.comps.get(name, ()):
            m = _parse_instr(line)
            if not m:
                continue
            nm_, result_str, op = m
            if op == "while":
                cm, qm = _CALLS_RE.search(line), _COND_RE.search(line)
                trip = self._trip_count(qm.group(1)) if qm else 1
                if cm:
                    total += self.comp_cost(cm.group(1)).scaled(trip)
                continue
            if op in ("fusion", "call", "async-start", "custom-call"):
                cm = _CALLS_RE.search(line)
                if cm:
                    sub = self.comp_cost(cm.group(1))
                    # flops & collectives recurse; bytes counted at call site
                    total += Cost(sub.flops, 0.0, dict(sub.coll))
                ops_sh = self._operand_shapes(name, line)
                if "dynamic-update-slice" in nm_:
                    # in-place DUS fusion (scan cache write-back): XLA aliases
                    # the big buffer; traffic = read + write the UPDATE region
                    # (the smallest non-scalar operand), not 2x the buffer.
                    upd = min(
                        (b for b in map(_shape_bytes, ops_sh) if b > 512),
                        default=_shape_bytes(result_str),
                    )
                    total += Cost(0.0, 2.0 * upd, {})
                    continue
                total += Cost(
                    0.0,
                    _shape_bytes(result_str)
                    + sum(_shape_bytes(s) for s in ops_sh),
                    {},
                )
                continue
            if op == "conditional":
                branches = _OPERAND_RE.findall(line.split("(", 1)[1])
                subs = [self.comp_cost(b) for b in branches if b in self.comps]
                if subs:
                    total += max(subs, key=lambda c: c.flops + c.bytes)
                continue
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                total += Cost(
                    0.0, 0.0, {base: _shape_bytes(result_str) * _WIRE_FACTOR[base]}
                )
                continue
            if op in _FREE_OPS:
                continue
            if op in ("dynamic-slice", "slice", "gather"):
                # HBM touches the sliced region, not the whole buffer:
                # read slice + write slice. (Counting the full operand makes
                # every scan-sliced layer stack look like it is re-read per
                # step — a ~100x overstatement for decode KV caches.)
                total += Cost(0.0, 2.0 * _shape_bytes(result_str), {})
                continue
            if op in ("dynamic-update-slice", "scatter"):
                # in-place update: read + write the update region only
                ops_sh = self._operand_shapes(name, line)
                upd = _shape_bytes(ops_sh[1]) if len(ops_sh) > 1 else 0
                total += Cost(0.0, 2.0 * upd, {})
                continue
            byt = _shape_bytes(result_str) + sum(
                _shape_bytes(s) for s in self._operand_shapes(name, line)
            )
            if op == "dot":
                total += Cost(self._dot_flops(name, result_str, line), byt, {})
            else:
                total += Cost(0.0, byt, {})
        self._memo[name] = total
        return total

    def entry_cost(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze_hlo(hlo_text: str) -> Cost:
    return HloCostAnalyzer(hlo_text).entry_cost()

"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs_per_device / peak_FLOPs
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / link_bw

HLO_FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device, post-SPMD
module). collective bytes are parsed from the optimized HLO text: per-device
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, with ring-algorithm wire multipliers.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# TPU v5e (assignment constants)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

# wire-bytes multiplier relative to the RESULT shape, ring algorithms,
# n large: all-gather result is n x input (moves ~result bytes);
# all-reduce moves ~2 x size; reduce-scatter moves ~input = n x result.
_WIRE_FACTOR = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,  # applied to the (larger) operand, approximated
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes per collective kind, summed over the module.
    '-start' ops only are counted once ('-done' carries no shape transfer)."""
    out: Dict[str, float] = {}
    seen_done = 0
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        if "-done" in hlo_text[m.start() : m.end()]:
            seen_done += 1
            continue
        b = _shape_bytes(shape_str) * _WIRE_FACTOR[kind]
        out[kind] = out.get(kind, 0.0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective_per_device: float
    coll_breakdown: Dict[str, float] = field(default_factory=dict)
    peak_memory_bytes: Optional[float] = None
    model_flops: Optional[float] = None  # 6*N*D (or 2*N*D decode), global

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_per_device / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    def useful_flop_ratio(self, n_devices: int) -> Optional[float]:
        if not self.model_flops:
            return None
        return self.model_flops / (self.flops_per_device * n_devices)

    def row(self, n_devices: int) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_per_device,
            "peak_memory_gb": (self.peak_memory_bytes or 0) / 1e9,
            "model_flops": self.model_flops,
            "useful_flop_ratio": self.useful_flop_ratio(n_devices),
        }


def analyze(compiled, arch, shape, mesh_name, *, model_flops=None) -> RooflineReport:
    """Costs come from the trip-count-aware HLO walker (repro.roofline
    .hlo_cost) — XLA's own cost_analysis() counts scan bodies once and would
    under-report a 62-layer stack by ~62x."""
    from repro.roofline.hlo_cost import analyze_hlo

    text = compiled.as_text()
    cost = analyze_hlo(text)
    flops = cost.flops
    byt = cost.bytes
    coll = dict(cost.coll)
    coll["total"] = cost.coll_total
    peak = None
    try:
        ma = compiled.memory_analysis()
        peak = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
        )
    except Exception:
        pass
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        flops_per_device=flops,
        bytes_per_device=byt,
        collective_per_device=coll.get("total", 0.0),
        coll_breakdown=coll,
        peak_memory_bytes=peak,
        model_flops=model_flops,
    )

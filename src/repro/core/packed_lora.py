"""Packed-LoRA application, merging, and per-adapter extraction.

``lora_linear`` is the single entry point every model layer uses: a frozen
base matmul plus (optionally) the packed adapter delta computed by the
grouped kernels in ``repro.kernels.ops``. The activation carries the pack as
the outermost batch factor — x has shape (N*B, ..., d_in) with adapter n
owning the contiguous slice [n*B, (n+1)*B) — so packing never changes the
math of any single adapter (paper §3.2: per-adapter computation is identical
to single-adapter fine-tuning).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ops import KernelConfig, fused_lora_linear, packed_lora_delta
from repro.kernels.quant import dequantize, is_quantized, logical_shape


def lora_linear(
    x: jnp.ndarray,
    params: dict,
    lora: Optional[dict],
    scales: Optional[jnp.ndarray],
    n_pack: int = 1,
    *,
    impl: Optional[str] = None,
    kcfg: Optional[KernelConfig] = None,
) -> jnp.ndarray:
    """y = x @ W (+ bias) + packed-LoRA delta.

    x: (N*B, ..., d_in) — pack dim folded into the leading batch dim.
    params: {"w": (d_in, d_out)[, "b": (d_out,)]} — frozen base weights.
    lora: {"a": (N, d_in, r), "b": (N, r, d_out)} or None.
    scales: (N,) effective alpha/r multipliers.
    kcfg: static kernel policy (impl / remat / pack rank vector / Pallas
    blocks) threaded from the trainer; ``impl=`` overrides its backend.
    With a fused impl the frozen base projection and the packed delta run as
    ONE grid pass (kernels/fused.py) instead of two passes over x; the bias
    (when present) is then added after the fused result — the only float
    reassociation versus the two-pass path, which adds it before the delta.
    """
    kc = kcfg or KernelConfig()
    impl_r = kc.resolved_impl() if impl is None else KernelConfig(impl=impl).resolved_impl()
    w = params["w"]
    # a quantized base ({"codes","scales"}, kernels/quant.py) flows into the
    # fused tier as-is (in-kernel dequant); the two-pass/no-lora paths
    # dequantize up front — the bit-exactness reference formulation.
    quant = is_quantized(w)
    d_in, d_out = (logical_shape(w) if quant else w.shape)[-2:]
    if lora is not None and impl_r in ("fused_pallas", "fused_xla"):
        lead = x.shape[:-1]
        xp = x.reshape(n_pack, x.shape[0] // n_pack, *x.shape[1:-1], d_in)
        y = fused_lora_linear(
            xp,
            w,
            lora["a"].astype(x.dtype),
            lora["b"].astype(x.dtype),
            scales,
            impl=impl_r,
            remat=kc.remat,
            ranks=kc.ranks,
            blocks=kc.blocks,
        ).reshape(*lead, d_out)
        if "b" in params:
            y = y + params["b"].astype(x.dtype)
        return y
    if quant:
        w = dequantize(w)
    y = x @ w.astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    if lora is not None:
        lead = x.shape[:-1]
        # keep the per-adapter batch dim B un-merged: (N, B, ..., d_in).
        # Splitting NB -> (N, B) is always sharding-representable, whereas
        # merging (B, S) is not when B is sharded over the model axis (FSDP
        # execution mode) — an unrepresentable merge would make XLA insert a
        # full activation all-reduce per projection (EXPERIMENTS.md §Perf).
        xp = x.reshape(n_pack, x.shape[0] // n_pack, *x.shape[1:-1], d_in)
        delta = packed_lora_delta(
            xp,
            lora["a"].astype(x.dtype),
            lora["b"].astype(x.dtype),
            scales,
            impl=impl_r,
            remat=kc.remat,
            ranks=kc.ranks,
        )
        y = y + delta.reshape(*lead, d_out)
    return y


def merge_adapter(base_w: jnp.ndarray, lora: dict, scale: float, idx: int) -> jnp.ndarray:
    """Fold adapter `idx` into the base weight: W + scale * A_i @ B_i
    (paper Fig. 1 inference-time merge). Works for plain (N, d, r) packs and
    layer-stacked (L, N, d, r) packs — the pack axis is always ndim-3.
    A quantized base is dequantized first: the merged result is dense by
    definition (W absorbs the delta, so codes/scales no longer describe it).
    """
    if is_quantized(base_w):
        base_w = dequantize(base_w)
    a = lora["a"]
    b = lora["b"]
    a = jnp.take(a, idx, axis=a.ndim - 3)
    b = jnp.take(b, idx, axis=b.ndim - 3)
    delta = jnp.einsum("...dr,...rk->...dk", a, b)
    return (base_w + scale * delta.astype(base_w.dtype)).astype(base_w.dtype)


def merge_model(base_params, lora_params, scales, idx: int):
    """Return a new base param tree with adapter `idx` merged into every
    target projection (produces a plain, adapter-free checkpoint)."""

    def _merge(path, leaf, lora_leaf):
        if lora_leaf is None:
            return leaf
        return merge_adapter(leaf, lora_leaf, float(scales[idx]), idx)

    def walk(bp, lp):
        if isinstance(bp, dict):
            out = {}
            for k, v in bp.items():
                lsub = lp.get(k) if isinstance(lp, dict) else None
                if (
                    k == "w"
                    and isinstance(lp, dict)
                    and "a" in lp
                    and "b" in lp
                ):
                    out[k] = merge_adapter(v, lp, float(scales[idx]), idx)
                else:
                    out[k] = walk(v, lsub if lsub is not None else {})
            return out
        return bp

    return walk(base_params, lora_params or {})


def extract_adapter(lora_params, idx: int, ranks=None):
    """Slice one adapter's (unpadded if ranks given) weights out of a pack —
    what the execution engine stores in the checkpoint pool. The pack dim is
    axis 0 for plain leaves and axis 1 under a layer-stacked "blocks" subtree
    (axis 0 there is the scanned layer-block axis).

    Runs on the host in numpy: extraction is pure memory movement (slice +
    copy, no float math, so trivially bit-exact) and it sits on the
    preempt/checkpoint hot path — dispatching it as dozens of tiny eager XLA
    ops made every segment resume pay ~0.5s of pure overhead."""
    import numpy as np

    def take(path, leaf):
        in_blocks = any(getattr(k, "key", None) == "blocks" for k in path)
        return np.take(np.asarray(leaf), idx, axis=1 if in_blocks else 0)

    sliced = jax.tree_util.tree_map_with_path(take, lora_params)
    if ranks is not None:
        r = int(ranks[idx])

        def walk(t):
            if isinstance(t, dict) and set(t) == {"a", "b"}:
                return {"a": t["a"][..., :r], "b": t["b"][..., :r, :]}
            if isinstance(t, dict):
                return {k: walk(v) for k, v in t.items()}
            return t

        sliced = walk(sliced)
    return sliced


def inject_adapter(lora_params, adapter, idx: int):
    """Inverse of :func:`extract_adapter`: write one adapter's weights into
    slot ``idx`` of a pack, zero-padding rank dims up to the pack's bucket.

    This is how the online execution engine resumes a preempted adapter
    inside a *new* pack (possibly with different partners and a different
    bucket rank): extract -> CheckpointPool -> inject round-trips the real
    rank columns bit-exactly, and the re-introduced padding is zero — the
    same invariant fresh initialization guarantees.

    Like :func:`extract_adapter` this runs on the host in numpy (slice +
    zero-pad + assignment into a fresh copy, no float math): it is the other
    half of the segment-resume hot path. The pack leaf is copied, never
    mutated — callers may pass cached template trees.
    """
    import numpy as np

    def put(leaf, sub, path):
        ax = 1 if "blocks" in path else 0
        sub = np.asarray(sub)
        last = path[-1] if path else None
        if last == "a" and sub.shape[-1] < leaf.shape[-1]:
            pad = [(0, 0)] * sub.ndim
            pad[-1] = (0, leaf.shape[-1] - sub.shape[-1])
            sub = np.pad(sub, pad)
        if last == "b" and sub.shape[-2] < leaf.shape[-2]:
            pad = [(0, 0)] * sub.ndim
            pad[-2] = (0, leaf.shape[-2] - sub.shape[-2])
            sub = np.pad(sub, pad)
        out = np.array(np.asarray(leaf))  # host copy; template stays intact
        idxer = [slice(None)] * out.ndim
        idxer[ax] = idx
        out[tuple(idxer)] = sub.astype(out.dtype)
        return out

    # manual walk rather than tree_map over both trees: checkpoint
    # round-trips drop empty subtrees (npz stores leaves only), so the
    # adapter may be a sparse sub-structure of the pack
    def walk(pack, sub, path):
        if isinstance(pack, dict):
            return {
                k: (
                    walk(v, sub[k], path + (k,))
                    if isinstance(sub, dict) and k in sub
                    else v
                )
                for k, v in pack.items()
            }
        return put(pack, sub, path)

    return walk(lora_params, adapter, ())

"""Packed-LoRA core: the paper's primary contribution as a composable module."""
from repro.core.adapter import PackMeta, init_lora_pair, pack_meta, single_meta
from repro.core.packed_lora import (
    extract_adapter,
    lora_linear,
    merge_adapter,
    merge_model,
)

__all__ = [
    "PackMeta",
    "init_lora_pair",
    "pack_meta",
    "single_meta",
    "extract_adapter",
    "lora_linear",
    "merge_adapter",
    "merge_model",
]

"""Adapter-level definitions: pack metadata and per-adapter initialization.

A *pack* is the paper's unit of execution: N LoRA configurations fine-tuned in
one job over a shared frozen base model. Heterogeneous ranks are zero-padded
to the pack's bucket rank ``r_bucket`` (max rank in the pack, rounded up to a
multiple of 8 for TPU sublane alignment); the padding is exact — it
contributes 0 to outputs and all gradients (tests/test_kernels.py::test_rank_padding_exact proves it).

Effective per-adapter scale follows LoRA convention: scale_n = alpha_n / r_n
(paper Table 4 reports alpha as this ratio).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LoraConfig


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class PackMeta:
    """Static description of a pack of LoRA configurations."""

    ranks: Tuple[int, ...]
    alphas: Tuple[float, ...]
    learning_rates: Tuple[float, ...]
    batch_sizes: Tuple[int, ...]

    @property
    def n(self) -> int:
        return len(self.ranks)

    @property
    def r_bucket(self) -> int:
        return max(8, _round_up(max(self.ranks), 8))

    @property
    def max_batch(self) -> int:
        return max(self.batch_sizes)

    def scales(self) -> jnp.ndarray:
        """Effective multiplier alpha_n / r_n, padded ranks notwithstanding."""
        return jnp.asarray(
            [a / r for a, r in zip(self.alphas, self.ranks)], jnp.float32
        )

    def lr_vector(self) -> jnp.ndarray:
        return jnp.asarray(self.learning_rates, jnp.float32)

    def rank_mask(self) -> jnp.ndarray:
        """(N, r_bucket) 1.0 for real rank columns, 0.0 for padding."""
        r = self.r_bucket
        iota = jnp.arange(r)[None, :]
        return (iota < jnp.asarray(self.ranks)[:, None]).astype(jnp.float32)

    def kernel_config(self, impl=None, remat=None, blocks=None,
                      base_dtype=None):
        """Static kernel policy for this pack: carries the per-adapter rank
        vector down to the kernels so heterogeneous-rank packs run as ragged
        same-rank grid segments instead of computing every adapter at
        ``r_bucket`` (see ``repro.kernels.ops.KernelConfig``)."""
        from repro.kernels.ops import KernelConfig

        return KernelConfig(
            impl=impl, remat=remat, ranks=self.ranks, blocks=blocks,
            base_dtype=base_dtype,
        )


def pack_meta(configs: Sequence[LoraConfig]) -> PackMeta:
    return PackMeta(
        ranks=tuple(c.rank for c in configs),
        alphas=tuple(float(c.alpha) for c in configs),
        learning_rates=tuple(float(c.learning_rate) for c in configs),
        batch_sizes=tuple(int(c.batch_size) for c in configs),
    )


def single_meta(rank: int = 16, alpha: float = 16.0, lr: float = 1e-4, bs: int = 1) -> PackMeta:
    return pack_meta([LoraConfig(rank=rank, alpha=alpha, learning_rate=lr, batch_size=bs)])


def init_lora_pair(
    key: jax.Array, meta: PackMeta, d_in: int, d_out: int, dtype=jnp.float32
) -> dict:
    """Packed (A, B) for one target projection across all N adapters.

    A ~ N(0, 1/d_in) on the first r_n columns (rest zero); B = 0 so the delta
    starts at exactly zero (standard LoRA init, paper Fig. 1 convention).
    """
    n, r = meta.n, meta.r_bucket
    a = jax.random.normal(key, (n, d_in, r), dtype) / jnp.sqrt(d_in).astype(dtype)
    a = a * meta.rank_mask()[:, None, :].astype(dtype)
    b = jnp.zeros((n, r, d_out), dtype)
    return {"a": a, "b": b}

"""F(D, K): max-throughput packing of LoRA configs into ONE job (paper Eq 18).

The paper hands this to Gurobi as an ILP. Offline we solve the same problem
exactly under an additive-time surrogate with a Dinkelbach fractional-
programming loop over 0/1-knapsacks (numpy DP), then score candidates with
the TRUE (non-additive, roofline) cost model:

  maximize  (sum_k r_k) / T(H, D)   s.t.   mem(H) <= C * M_gpu * D

Dinkelbach: given lambda, maximize sum_k (r_k - lambda * t_k) via knapsack on
memory; iterate lambda <- best ratio until the optimal value hits ~0. For the
small instances of tests, ``brute_force`` verifies optimality.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import LoraConfig
from repro.sched.cost_model import CostEstimator


def _knapsack(values: np.ndarray, weights: np.ndarray, capacity: int):
    """0/1 knapsack, integer weights, float values; returns (best, chosen)."""
    n = len(values)
    dp = np.full(capacity + 1, -np.inf)
    dp[0] = 0.0
    take = np.zeros((n, capacity + 1), bool)
    for i in range(n):
        w, v = int(weights[i]), float(values[i])
        if v <= 0 or w > capacity:
            continue
        cand = dp[: capacity + 1 - w] + v
        tail = dp[w:]
        better = cand > tail
        dp[w:] = np.where(better, cand, tail)
        take[i, w:] = better
    j = int(np.argmax(dp))
    best = dp[j]
    chosen = []
    for i in range(n - 1, -1, -1):
        if j >= 0 and take[i, j]:
            chosen.append(i)
            j -= int(weights[i])
    return best, chosen[::-1]


def solve_pack(
    cm: CostEstimator,
    configs: Sequence[LoraConfig],
    d: int,
    seq: int,
    *,
    grain: int = 512,
    max_iter: int = 25,
    work_cap: Optional[float] = None,
) -> Optional[Tuple[List[int], float]]:
    """Best subset (indices into configs) for ONE job at parallelism d.
    Returns (indices, throughput r/T) or None if even the base doesn't fit."""
    cap_bytes = cm.load_factor * cm.hw.mem_bytes * d
    base_bytes = cm.base_weight_bytes()
    if base_bytes >= cap_bytes:
        return None
    unit = cap_bytes / grain
    mem = np.array(
        [
            (cm.lora_bytes(c, seq) + cm.base_act_bytes(c.batch_size, seq))
            / unit
            for c in configs
        ]
    )
    mem = np.maximum(1, np.ceil(mem)).astype(np.int64)
    capacity = int((cap_bytes - base_bytes) / unit)
    if capacity <= 0:
        return None
    # LoRA-FLOP proxy: the paper's Eq (13) uses rank via "LoRA FLOP is linear
    # in rank" (§2.1); with heterogeneous batch sizes in the space the
    # per-iteration LoRA FLOP is linear in rank * batch, so we weight by both.
    ranks = np.array([c.rank * c.batch_size for c in configs], float)
    # additive time surrogate: marginal iteration-time of each config alone
    t0 = cm.iter_time([], d, seq)
    tk = np.array(
        [max(cm.iter_time([c], d, seq) - t0, 1e-9) for c in configs]
    )

    lam = 0.0
    chosen: List[int] = []
    for _ in range(max_iter):
        vals = ranks - lam * tk
        best, chosen = _knapsack(vals, mem, capacity)
        if not chosen:
            break
        ratio = ranks[chosen].sum() / (t0 + tk[chosen].sum())
        if abs(best - lam * t0) < 1e-9 or abs(ratio - lam) < 1e-12:
            break
        lam = ratio
    # memory feasibility under the true model too
    while chosen and not cm.fits([configs[i] for i in chosen], d, seq):
        worst = max(chosen, key=lambda i: mem[i])
        chosen.remove(worst)

    # Local search on the TRUE (non-additive, saturating) cost model. The
    # additive Dinkelbach surrogate badly underestimates packing benefit when
    # the device is unsaturated (marginal cost of an extra adapter << its
    # standalone cost — the paper's core observation), so the seed is refined
    # by greedy add / drop moves scored with cm.throughput directly.
    n = len(configs)

    def thr(ids: List[int]) -> float:
        if not ids:
            return 0.0
        return cm.throughput([configs[i] for i in ids], d, seq)

    def work(ids) -> float:
        return float(ranks[list(ids)].sum()) if ids else 0.0

    cur = list(chosen)
    best_thr = thr(cur)
    improved = True
    while improved:
        improved = False
        # adds (respecting the DTM balance cap)
        outside = [i for i in range(n) if i not in cur]
        gains = []
        for i in outside:
            if work_cap is not None and work(cur) + ranks[i] > work_cap:
                continue
            trial = cur + [i]
            if not cm.fits([configs[k] for k in trial], d, seq):
                continue
            t = thr(trial)
            if t > best_thr * (1 + 1e-9):
                gains.append((t, i))
        if gains:
            t, i = max(gains)
            cur.append(i)
            best_thr = t
            improved = True
            continue
        # drops
        for i in list(cur):
            trial = [k for k in cur if k != i]
            t = thr(trial)
            if t > best_thr * (1 + 1e-9):
                cur = trial
                best_thr = t
                improved = True
                break
    if not cur:
        return None
    return sorted(cur), best_thr


def brute_force(
    cm: CostEstimator, configs: Sequence[LoraConfig], d: int, seq: int
) -> Optional[Tuple[List[int], float]]:
    """Exhaustive optimum (tests only; len(configs) <= ~15)."""
    n = len(configs)
    best, best_set = None, None
    for mask in range(1, 1 << n):
        sel_idx = [i for i in range(n) if mask >> i & 1]
        sel = [configs[i] for i in sel_idx]
        if not cm.fits(sel, d, seq):
            continue
        thr = cm.throughput(sel, d, seq)
        if best is None or thr > best:
            best, best_set = thr, sel_idx
    if best is None:
        return None
    return best_set, best

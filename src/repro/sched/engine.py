"""LoRA Execution Engine (paper §4, Fig. 3): resource monitor + job launcher.

Two modes:
  * ``simulate``   — play the planner's job queue against a simulated device
                     pool using cost-model durations (pod-scale what-ifs).
  * ``run_local``  — actually execute every packed job on this host (CPU
                     XLA): packed train_loop per job, per-adapter extraction
                     into the CheckpointPool, measured wall-clock durations
                     mapped back onto the simulated resource timeline. This
                     is the end-to-end driver used by examples/benchmarks.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import LoraConfig, ModelConfig
from repro.core.adapter import pack_meta
from repro.core.packed_lora import extract_adapter
from repro.sched.cost_model import CostModel
from repro.sched.planner import Schedule, ScheduledJob, plan
from repro.train.checkpoint import CheckpointPool


@dataclass
class ResourceMonitor:
    total: int
    free: int = -1

    def __post_init__(self):
        if self.free < 0:
            self.free = self.total

    def acquire(self, n: int) -> bool:
        if n <= self.free:
            self.free -= n
            return True
        return False

    def release(self, n: int):
        self.free += n
        assert self.free <= self.total


@dataclass
class JobRecord:
    job: ScheduledJob
    wall_seconds: float
    final_losses: Optional[np.ndarray] = None


class ExecutionEngine:
    def __init__(self, cm: CostModel, g: int):
        self.cm = cm
        self.monitor = ResourceMonitor(g)

    # ---------------- simulation ----------------

    def simulate(self, schedule: Schedule) -> float:
        """Replay a schedule through the resource monitor; returns makespan
        and validates that the plan never over-subscribes devices."""
        events = []  # (time, +1 release / -1 acquire, degree)
        for j in schedule.jobs:
            events.append((j.start, 1, j.degree))
            events.append((j.end, 0, j.degree))
        # process releases before acquires at equal timestamps
        for t, kind, d in sorted(events, key=lambda e: (e[0], e[1])):
            if kind == 0:
                self.monitor.release(d)
            else:
                ok = self.monitor.acquire(d)
                if not ok:
                    raise RuntimeError(
                        f"schedule oversubscribes devices at t={t:.2f}"
                    )
        return schedule.makespan

    # ---------------- real local execution ----------------

    def run_local(
        self,
        schedule: Schedule,
        configs: Sequence[LoraConfig],
        cfg: ModelConfig,
        base_params,
        *,
        n_steps: int,
        seq: int,
        pool: Optional[CheckpointPool] = None,
        data_iter_fn: Optional[Callable] = None,
        seed: int = 0,
    ) -> Tuple[List[JobRecord], float]:
        """Execute every job of the schedule on this host. Returns the job
        records and the *measured-duration* makespan (each job's simulated
        duration replaced by its measured wall time, replayed through the
        planner's resource timeline)."""
        from repro.models.model import init_model
        from repro.train.data import packed_batch_iterator
        from repro.train.trainer import make_train_step, train_loop
        from repro.train.optimizer import init_opt_state

        records: List[JobRecord] = []
        for j in schedule.jobs:
            job_cfgs = [configs[i] for i in j.config_ids]
            meta = pack_meta(job_cfgs)
            key = jax.random.PRNGKey(seed)
            _, lora = init_model(key, cfg, meta)
            it = (
                data_iter_fn(cfg, job_cfgs, seq)
                if data_iter_fn
                else packed_batch_iterator(cfg, job_cfgs, seq=seq)
            )
            step = make_train_step(cfg, meta)
            opt = init_opt_state(lora)
            # compile outside the timed region (the paper times steady state)
            b0 = next(it)
            lora, opt, m = step(base_params, lora, opt, b0)
            jax.block_until_ready(m["loss"])
            t0 = time.perf_counter()
            losses = None
            for _ in range(n_steps):
                lora, opt, m = step(base_params, lora, opt, next(it))
            jax.block_until_ready(m["loss"])
            wall = time.perf_counter() - t0
            losses = np.asarray(m["per_adapter_loss"])
            records.append(JobRecord(j, wall, losses))
            if pool is not None:
                for slot, cid in enumerate(j.config_ids):
                    adapter = extract_adapter(lora, slot, meta.ranks)
                    pool.save_adapter(
                        f"adapter_{cid:04d}",
                        adapter,
                        {
                            "rank": configs[cid].rank,
                            "alpha": configs[cid].alpha,
                            "learning_rate": configs[cid].learning_rate,
                            "batch_size": configs[cid].batch_size,
                            "final_loss": float(losses[slot]),
                        },
                    )
        makespan = replay_measured(schedule, records, self.monitor.total)
        return records, makespan


def replay_measured(
    schedule: Schedule, records: List[JobRecord], g: int
) -> float:
    """Re-run the schedule's resource timeline with measured durations."""
    free = g
    t = 0.0
    running: List[Tuple[float, int]] = []
    pending = [(r.job.degree, r.wall_seconds) for r in records]
    makespan = 0.0
    i = 0
    while i < len(pending) or running:
        launched = False
        while i < len(pending) and pending[i][0] <= free:
            d, dur = pending[i]
            heapq.heappush(running, (t + dur, d))
            makespan = max(makespan, t + dur)
            free -= d
            i += 1
            launched = True
        if not launched:
            if not running:
                break
            end, d = heapq.heappop(running)
            t, free = end, free + d
    return makespan

"""Event-driven concurrent LoRA execution engine (paper §4, Fig. 3).

The engine is a **virtual-clock event loop**: a heap of job-finish and
job-arrive events (a finish event *is* a device-free event) drives a single
scheduling loop that supports

  * **online admission** — ``LoraConfig`` s arrive mid-run on an arrival-time
    trace (:func:`poisson_trace` builds the paper-style Poisson workload);
    nothing is frozen at t=0;
  * **dynamic repacking** — on every admission and device-free event the
    engine re-invokes the planner's incremental API
    (:func:`repro.sched.planner.replan` -> DTM, Alg. 1) over the
    not-yet-started configs and currently free device units, instead of
    draining a statically planned queue;
  * **preemption-aware checkpointing** — with ``migration_budget > 0``, a
    running packed job can be preempted on an admission event: its finished
    adapters complete, its unfinished adapters re-enter the pending set with
    *residual* step counts and are repacked with the new arrivals (paper §4
    dynamic task migration). In real execution the preempted adapters
    round-trip through the :class:`~repro.train.checkpoint.CheckpointPool`
    (weights + Adam moments + step counts) and are injected into whatever
    pack the replanner chooses next.

Both modes share this one loop: ``plan_online``/``simulate`` play the trace
against cost-model durations (pod-scale what-ifs), and ``run_online_local``
executes the *same* planned segments for real on this host via the
``repro.cluster`` subsystem — each segment on the mesh slice backing its
planned device units, concurrently (thread-per-slice) when the host has
multiple devices (real, or CPU-forced via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``), serially on the
degenerate single-slice pool otherwise — with per-adapter state flowing
through the checkpoint pool. The static ``simulate(schedule)`` /
``run_local(schedule, ...)`` entry points are the degenerate no-arrivals
case and reuse the same executor.

The static baseline the benchmarks compare against is ``repack="drain"``:
admission still happens, but the engine only replans when *all* devices are
free — exactly the frozen-queue batch replayer this engine replaced.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster.pool import pick_class_units, pick_host_units
from repro.configs.base import LoraConfig, ModelConfig
from repro.sched.cost_model import CostEstimator
from repro.sched.planner import Schedule, ScheduledJob, replan
from repro.train.checkpoint import CheckpointPool


@dataclass
class ResourceMonitor:
    total: int
    free: int = -1

    def __post_init__(self):
        if self.free < 0:
            self.free = self.total

    def acquire(self, n: int) -> bool:
        if n <= self.free:
            self.free -= n
            return True
        return False

    def release(self, n: int):
        self.free += n
        assert self.free <= self.total


@dataclass
class JobRecord:
    job: ScheduledJob
    wall_seconds: float
    final_losses: Optional[np.ndarray] = None
    # wall-clock interval relative to the cluster runner's dispatch t0 —
    # overlapping intervals of different records are segments that really
    # ran concurrently on disjoint mesh slices
    real_start: float = 0.0
    real_end: float = 0.0


# ---------------------------------------------------------------------------
# Arrival traces
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Arrival:
    """One online job submission: a LoRA config arriving at ``time`` that
    needs ``steps`` training iterations (None = the run-level default)."""

    time: float
    config: LoraConfig
    steps: Optional[int] = None


def poisson_trace(
    configs: Sequence[LoraConfig],
    mean_interarrival: float,
    seed: int = 0,
    steps: Optional[Sequence[int]] = None,
) -> List[Arrival]:
    """Poisson arrival process over ``configs`` (order preserved): i.i.d.
    exponential inter-arrival gaps with the given mean, shifted so the first
    config arrives at t=0. Deterministic in ``seed``."""
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(mean_interarrival, size=len(configs))
    times = np.cumsum(gaps) - gaps[0]
    return [
        Arrival(float(t), c, None if steps is None else int(steps[i]))
        for i, (t, c) in enumerate(zip(times, configs))
    ]


# ---------------------------------------------------------------------------
# Online schedule (the event loop's output)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class JobSegment:
    """One contiguous run of a packed job on ``degree`` device units.

    A job that runs to completion is one segment; a preempted job is a
    ``preempted=True`` segment (cut at the migration point) followed — after
    repacking — by later segments of whatever new jobs its unfinished
    adapters land in. ``start_steps[i]`` is how many iterations
    ``config_ids[i]`` had already trained before this segment (0 = fresh;
    >0 = resumed from the checkpoint pool); ``run_steps`` is the number of
    packed iterations this segment executes; ``done_ids`` are the configs
    whose step budget completes within this segment.

    ``units`` is the segment's planned device group: which of the pool's
    ``g`` device units this job holds for [start, end). Units of segments
    that overlap in time are disjoint (``OnlineSchedule.validate`` checks
    this), and the cluster runner maps them onto real disjoint mesh slices —
    the executor honors exactly the groups the scheduler planned."""

    job_id: int
    config_ids: Tuple[int, ...]
    degree: int
    start: float
    end: float
    start_steps: Tuple[int, ...]
    run_steps: int
    done_ids: Tuple[int, ...]
    preempted: bool = False
    units: Tuple[int, ...] = ()

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclass
class OnlineSchedule:
    segments: List[JobSegment]
    makespan: float
    g: int
    completed: Dict[int, float]  # cid -> virtual completion time
    total_steps: Dict[int, int]  # cid -> total step budget
    n_repacks: int = 0
    n_migrations: int = 0
    n_f_calls: int = 0
    # adaptive real execution only (profile feedback loop): probe segments
    # dispatched, drift-triggered device-unit re-assignments, and the
    # measured-vs-predicted timing of every executed segment
    n_probes: int = 0
    n_reassignments: int = 0
    timings: List = field(default_factory=list)  # List[SegmentTiming]

    def utilization(self) -> float:
        """Busy device-seconds / (G * makespan)."""
        if not self.segments or self.makespan <= 0:
            return 0.0
        busy = sum(s.duration * s.degree for s in self.segments)
        return busy / (self.g * self.makespan)

    def validate(self, host_size: Optional[int] = None):
        """Raise if any instant oversubscribes the device pool, or if the
        planned device groups (``units``) are malformed: wrong width, out of
        range, shared between time-overlapping segments, or — when
        ``host_size`` is given — spanning more than one host (a mesh slice
        lives inside one host's device pool)."""
        _validate_intervals(
            [(s.start, s.end, s.degree) for s in self.segments], self.g
        )
        timed = [s for s in self.segments if s.units]
        for s in timed:
            if len(s.units) != s.degree or not all(
                0 <= u < self.g for u in s.units
            ):
                raise RuntimeError(
                    f"segment {s.job_id} has units {s.units} for degree "
                    f"{s.degree} on a {self.g}-unit pool"
                )
            if host_size is not None and len(
                {u // host_size for u in s.units}
            ) > 1:
                raise RuntimeError(
                    f"segment {s.job_id} units {s.units} span hosts "
                    f"(host_size={host_size})"
                )
        for i, a in enumerate(timed):
            for b in timed[i + 1:]:
                if a.start < b.end - _EPS and b.start < a.end - _EPS:
                    shared = set(a.units) & set(b.units)
                    if shared:
                        raise RuntimeError(
                            f"overlapping segments {a.job_id}/{b.job_id} "
                            f"share device units {sorted(shared)}"
                        )


def _validate_intervals(intervals: Sequence[Tuple[float, float, int]], g: int):
    monitor = ResourceMonitor(g)
    events = []
    for start, end, degree in intervals:
        events.append((start, 1, degree))
        events.append((end, 0, degree))
    # process releases before acquires at equal timestamps
    for t, kind, d in sorted(events, key=lambda e: (e[0], e[1])):
        if kind == 0:
            monitor.release(d)
        elif not monitor.acquire(d):
            raise RuntimeError(f"schedule oversubscribes devices at t={t:.2f}")


# ---------------------------------------------------------------------------
# Event loop internals
# ---------------------------------------------------------------------------


@dataclass
class _Pending:
    cid: int
    config: LoraConfig
    arrival: float
    steps_done: int
    total_steps: int

    @property
    def residual(self) -> int:
        return self.total_steps - self.steps_done


@dataclass
class _Running:
    job_id: int
    cids: Tuple[int, ...]
    sel: List[LoraConfig]
    degree: int
    start: float
    iter_time: float
    residuals: Tuple[int, ...]
    start_steps: Tuple[int, ...]
    run_steps: int  # max residual: iterations until the job finishes
    est_end: float
    units: Tuple[int, ...] = ()  # concrete device units this job holds


_EPS = 1e-9

# Fraction of the estimated wait-for-victim completion a preemption must
# beat before the engine migrates (guards against churn from the myopic
# single-victim estimate; see ExecutionEngine.plan_online).
MIGRATION_MARGIN = 0.25


class ExecutionEngine:
    """Resource monitor + event loop + job launcher over ``g`` device units.

    ``cm`` is any :class:`~repro.sched.cost_model.CostEstimator`. Virtual
    planning (``plan_online``/``simulate``) always runs against the pure
    prior (``cm.virtual_model()``) so simulation stays deterministic; real
    execution uses ``cm`` itself — give it a
    :class:`~repro.sched.profile.ProfiledCostModel` and
    ``run_online_local`` switches to the adaptive feedback loop
    (:meth:`_run_adaptive`): re-planning against live measurements on every
    device-free event and re-assigning device units on drift."""

    def __init__(self, cm: CostEstimator, g: int, *,
                 host_size: Optional[int] = None, tracer=None):
        """``host_size`` makes unit assignment host-aware: the ``g`` units
        are grouped into hosts of ``host_size`` (unit ``u`` lives on host
        ``u // host_size``), a single job's parallelism degree is capped at
        the host width (a mesh slice cannot span hosts), and every planned
        unit group stays within one host — which is what lets the
        :class:`repro.cluster.multihost.HostDispatcher` execute the plan
        process-per-host. ``None`` (default) is the single-host engine,
        byte-identical to the pre-multihost behavior."""
        if host_size is not None:
            if host_size <= 0 or g % host_size:
                raise ValueError(
                    f"host_size {host_size} must evenly divide g={g}"
                )
            if host_size & (host_size - 1):
                raise ValueError(
                    f"host_size {host_size} must be a power of two (planned "
                    "degrees are powers of two; other host widths strand "
                    "units that no job can ever use)"
                )
        from repro.obs import NULL_TRACER

        self.cm = cm
        self.host_size = host_size
        self.monitor = ResourceMonitor(g)
        self.tracer = tracer if tracer is not None else NULL_TRACER

    def _unschedulable(self, n_pending: int) -> RuntimeError:
        g = self.monitor.total
        host = (
            f", or exceeds the {self.host_size}-unit host width?)"
            if self.host_size is not None
            else "?)"
        )
        return RuntimeError(
            f"{n_pending} configs can never be scheduled on {g} free "
            f"device units (min degree exceeds the pool" + host
        )

    def _take_units(
        self, free_units: List[int], degree: int
    ) -> Optional[Tuple[int, ...]]:
        """Claim ``degree`` units from the sorted free list — all on one
        host when ``host_size`` is set (see ``pick_host_units``). Returns
        None (claiming nothing) when no single host can currently hold the
        job; the caller holds it for the next device-free event."""
        units = pick_host_units(free_units, degree, self.host_size)
        if units is None:
            return None
        for u in units:
            free_units.remove(u)
        return units

    # ---------------- static entry points (no-arrivals special case) -------

    def simulate(self, schedule: Schedule) -> float:
        """Replay a static schedule's timeline through the resource monitor;
        returns the makespan and raises if the plan ever over-subscribes."""
        _validate_intervals(
            [(j.start, j.end, j.degree) for j in schedule.jobs],
            self.monitor.total,
        )
        return schedule.makespan

    def run_local(
        self,
        schedule: Schedule,
        configs: Sequence[LoraConfig],
        cfg: ModelConfig,
        base_params,
        *,
        n_steps: int,
        seq: int,
        pool: Optional[CheckpointPool] = None,
        data_iter_fn: Optional[Callable] = None,
        seed: int = 0,
        runner=None,  # Optional[repro.cluster.ClusterRunner]
        impl: Optional[str] = None,
        remat: Optional[str] = None,
        base_dtype: Optional[str] = None,
    ) -> Tuple[List[JobRecord], float]:
        """Execute every job of a static schedule on this host through the
        cluster subsystem. Concurrent runners (multi-device hosts) return
        the *real* wall-clock makespan — overlapping groups genuinely
        overlap; the degenerate sequential runner returns the what-if
        makespan (each job's simulated duration replaced by its measured
        wall time, replayed through the resource timeline).

        ``impl``/``remat``/``base_dtype`` select the kernel policy for
        every job; the runner carries them to each segment (over the wire,
        for multi-host runners — ``base_dtype`` rides the KernelPolicy
        message so workers key their compile caches on it). ``impl=None``
        falls back to the caller's context-local default inside
        :meth:`Runner.run`."""
        from repro.cluster import assign_units

        with self.tracer.span(
            "engine.run_local", cat="engine",
            n_jobs=len(schedule.jobs), g=self.monitor.total,
        ):
            return self._run_local_inner(
                schedule, configs, cfg, base_params, n_steps=n_steps,
                seq=seq, pool=pool, data_iter_fn=data_iter_fn, seed=seed,
                runner=runner, impl=impl, remat=remat,
                base_dtype=base_dtype, assign_units=assign_units,
            )

    def _run_local_inner(self, schedule, configs, cfg, base_params, *,
                         n_steps, seq, pool, data_iter_fn, seed, runner,
                         impl, remat, base_dtype, assign_units):
        units = assign_units(
            [(j.start, j.end, j.degree) for j in schedule.jobs],
            self.monitor.total,
            host_size=self.host_size,
        )
        segments = [
            JobSegment(
                job_id=i,
                config_ids=j.config_ids,
                degree=j.degree,
                start=j.start,
                end=j.end,
                start_steps=(0,) * len(j.config_ids),
                run_steps=n_steps,
                done_ids=j.config_ids,
                units=units[i],
            )
            for i, j in enumerate(schedule.jobs)
        ]
        result = self._execute_segments(
            segments,
            {i: c for i, c in enumerate(configs)},
            {i: n_steps for i in range(len(configs))},
            cfg,
            base_params,
            seq=seq,
            pool=pool,
            data_iter_fn=data_iter_fn,
            seed=seed,
            runner=runner,
            impl=impl,
            remat=remat,
            base_dtype=base_dtype,
        )
        if result.concurrent:
            makespan = result.makespan
        else:
            makespan = replay_measured(
                schedule, result.records, self.monitor.total
            )
        return result.records, makespan

    # ---------------- the event loop ----------------

    def plan_online(
        self,
        trace: Sequence[Arrival],
        seq: int,
        n_steps: int,
        *,
        repack: str = "event",
        admission: str = "patient",
        migration_budget: int = 0,
        preempt_min_remaining: Optional[float] = None,
        lookahead_k: int = 3,
    ) -> OnlineSchedule:
        """Play an arrival trace through the virtual-clock event loop.

        ``repack="event"`` replans on every admission/device-free event (the
        online engine); ``repack="drain"`` only replans when the pool is
        fully idle (the frozen-queue static baseline). ``migration_budget``
        caps how many running jobs may be preempted over the whole run;
        ``preempt_min_remaining`` (default ``4 * setup_time``) is the minimum
        estimated remaining time that makes a victim worth re-paying setup
        for.

        ``admission="patient"`` guards against the online-greedy pathology:
        dispatching an arrival immediately onto a few free units can lose to
        waiting for the next job-finish and launching at higher parallelism.
        On every repack with jobs still running, the engine compares the
        estimated completion of launch-now-on-``free`` against
        wait-then-launch-on-``free + soon-freed`` and holds the pending set
        when waiting wins. ``admission="eager"`` always dispatches (exactly
        Algorithm 2's greedy rule, and the t=0 behavior of ``plan``).

        ``lookahead_k`` controls the migration estimator: the wait-option
        against which a preemption must win is evaluated at each of the next
        k finish events (with the devices they cumulatively free), not just
        the victim's own finish — see ``migration_pays``.

        Every launched job is also assigned its concrete device *units*
        (lowest-numbered free units first), carried on ``JobSegment.units``
        so the cluster runner executes each job on exactly the mesh slice
        the scheduler planned."""
        with self.tracer.span(
            "engine.plan_online", cat="engine",
            n_configs=len(trace), g=self.monitor.total,
        ):
            return self._plan_online_impl(
                trace, seq, n_steps, repack=repack, admission=admission,
                migration_budget=migration_budget,
                preempt_min_remaining=preempt_min_remaining,
                lookahead_k=lookahead_k,
            )

    def _plan_online_impl(
        self,
        trace: Sequence[Arrival],
        seq: int,
        n_steps: int,
        *,
        repack: str,
        admission: str,
        migration_budget: int,
        preempt_min_remaining: Optional[float],
        lookahead_k: int,
    ) -> OnlineSchedule:
        if repack not in ("event", "drain"):
            raise ValueError(f"unknown repack policy {repack!r}")
        if admission not in ("patient", "eager"):
            raise ValueError(f"unknown admission policy {admission!r}")
        g = self.monitor.total
        tracer = self.tracer
        # simulation contract: the virtual clock always ticks on the pure
        # prior, independent of any profile/measurement state
        cm = self.cm.virtual_model()
        if preempt_min_remaining is None:
            preempt_min_remaining = 4.0 * cm.setup_time

        heap: List[Tuple[float, int, int, str, int]] = []
        seqno = itertools.count()
        for cid, a in enumerate(trace):
            heapq.heappush(heap, (a.time, 1, next(seqno), "arrive", cid))

        pending: List[_Pending] = []
        running: Dict[int, _Running] = {}
        segments: List[JobSegment] = []
        completed: Dict[int, float] = {}
        total_steps = {
            cid: (a.steps if a.steps is not None else n_steps)
            for cid, a in enumerate(trace)
        }
        free = g
        free_units = list(range(g))  # sorted; lowest-first assignment
        next_job = itertools.count()
        n_repacks = n_migrations = n_f = 0

        def release_units(r: _Running):
            free_units.extend(r.units)
            free_units.sort()

        def finish_segment(r: _Running, end: float, steps_run: int, preempted: bool):
            done = tuple(
                cid
                for cid, resid in zip(r.cids, r.residuals)
                if resid <= steps_run
            )
            for cid, resid in zip(r.cids, r.residuals):
                if resid <= steps_run:
                    completed[cid] = r.start + cm.adapter_finish_offset(
                        r.sel, resid, r.degree, seq
                    )
            segments.append(
                JobSegment(
                    job_id=r.job_id,
                    config_ids=r.cids,
                    degree=r.degree,
                    start=r.start,
                    end=end,
                    start_steps=r.start_steps,
                    run_steps=steps_run,
                    done_ids=done,
                    preempted=preempted,
                    units=r.units,
                )
            )

        def do_repack(now: float):
            nonlocal free, n_repacks, n_f
            if not pending or free <= 0:
                return
            if repack == "drain" and running:
                return  # static baseline: wait for the full drain
            pending.sort(key=lambda e: e.cid)
            cfgs = [e.config for e in pending]
            resid = [e.residual for e in pending]
            with tracer.span(
                "engine.replan", cat="engine",
                pending=len(pending), free=free,
            ):
                res = replan(
                    cm, cfgs, free, seq, n_steps, residual_steps=resid,
                    max_degree=self.host_size,
                )
            n_repacks += 1
            n_f += res.n_f_calls
            if not res.jobs:
                return
            if admission == "patient" and running:
                # launch now at `free`, or wait for the next finish and
                # launch wider? Compare estimated completion times.
                t_next = min(r.est_end for r in running.values())
                freed = free + sum(
                    r.degree
                    for r in running.values()
                    if r.est_end <= t_next + _EPS
                )
                res_wait = replan(
                    cm, cfgs, freed, seq, n_steps, residual_steps=resid,
                    max_degree=self.host_size,
                )
                n_f += res_wait.n_f_calls
                covered_now = sum(len(j.config_ids) for j in res.jobs)
                covered_wait = sum(len(j.config_ids) for j in res_wait.jobs)
                finish_now = now + max(j.est_time for j in res.jobs)
                finish_wait = (
                    t_next + max(j.est_time for j in res_wait.jobs)
                    if res_wait.jobs
                    else float("inf")
                )
                if covered_wait >= covered_now and finish_wait <= finish_now:
                    tracer.instant(
                        "engine.admission_hold", cat="engine",
                        pending=len(pending), free=free,
                    )
                    return  # hold: the next device-free event re-evaluates
            launched = set()
            jobs = res.jobs
            if self.host_size is not None:
                # place wider jobs first (first-fit-decreasing): power-of-2
                # degrees then pack hosts without fragmentation
                jobs = sorted(jobs, key=lambda j: -j.degree)
            for jp in jobs:
                entries = [pending[i] for i in jp.config_ids]
                sel = [e.config for e in entries]
                units = self._take_units(free_units, jp.degree)
                if units is None:
                    # no single host currently has jp.degree free units
                    # (fragmentation across hosts): hold this job; the next
                    # device-free event re-plans and retries
                    continue
                r = _Running(
                    job_id=next(next_job),
                    cids=tuple(e.cid for e in entries),
                    sel=sel,
                    degree=jp.degree,
                    start=now,
                    iter_time=cm.iter_time(sel, jp.degree, seq),
                    residuals=tuple(e.residual for e in entries),
                    start_steps=tuple(e.steps_done for e in entries),
                    run_steps=max(e.residual for e in entries),
                    est_end=now + jp.est_time,
                    units=units,
                )
                running[r.job_id] = r
                heapq.heappush(
                    heap, (r.est_end, 0, next(seqno), "finish", r.job_id)
                )
                free -= jp.degree
                launched |= set(r.cids)
                tracer.instant(
                    "engine.launch", cat="engine", job_id=r.job_id,
                    degree=jp.degree, units=list(units),
                )
            if launched:
                pending[:] = [e for e in pending if e.cid not in launched]

        def steps_run_at(r: _Running, now: float) -> int:
            done = int((now - r.start - cm.setup_time) // r.iter_time)
            return max(0, min(done, r.run_steps))

        def preempt(r: _Running, now: float):
            nonlocal free, n_migrations
            steps_run = steps_run_at(r, now)
            finish_segment(r, now, steps_run, preempted=True)
            for cfg_c, cid, resid, st0 in zip(
                r.sel, r.cids, r.residuals, r.start_steps
            ):
                if resid > steps_run:
                    pending.append(
                        _Pending(
                            cid, cfg_c, now, st0 + steps_run, total_steps[cid]
                        )
                    )
            del running[r.job_id]  # its finish event becomes stale
            free += r.degree
            release_units(r)
            n_migrations += 1
            tracer.instant(
                "engine.preempt", cat="engine", job_id=r.job_id,
                steps_run=steps_run,
            )

        def migration_pays(victim: _Running, now: float) -> bool:
            """Cost-model estimate of the paper's dynamic-task-migration
            trade: preempt the victim and repack its unfinished adapters
            together with the pending set on its devices *now*, versus
            leaving it alone and scheduling the pending set later.

            The wait-option is a *lookahead over the next k finish events*:
            the pending set could launch at any upcoming device-free event
            with the devices those finishes cumulatively release, not only
            when the victim itself ends — the single-victim myopic estimate
            this replaces systematically overstated the cost of waiting and
            triggered preemptions that re-paid setup for nothing. With only
            one running job there is nothing to look ahead over, and the
            estimate falls back to the myopic rule guarded by
            ``MIGRATION_MARGIN``."""
            steps_run = steps_run_at(victim, now)
            unfinished = [
                (c, resid - steps_run)
                for c, resid in zip(victim.sel, victim.residuals)
                if resid > steps_run
            ]
            if not unfinished:
                return False
            avail = free + victim.degree
            merged = [e.config for e in pending] + [c for c, _ in unfinished]
            merged_resid = [e.residual for e in pending] + [
                s for _, s in unfinished
            ]
            res_m = replan(
                cm, merged, avail, seq, n_steps, residual_steps=merged_resid,
                max_degree=self.host_size,
            )
            miss_m = len(merged) - sum(len(j.config_ids) for j in res_m.jobs)
            fin_m = (
                now + max(j.est_time for j in res_m.jobs)
                if res_m.jobs
                else float("inf")
            )
            pend_cfgs = [e.config for e in pending]
            pend_resid = [e.residual for e in pending]
            ends = sorted({r.est_end for r in running.values()})[
                : max(1, lookahead_k)
            ]
            best: Optional[Tuple[int, float]] = None
            for t_i in ends:
                avail_i = free + sum(
                    r.degree
                    for r in running.values()
                    if r.est_end <= t_i + _EPS
                )
                res_i = replan(
                    cm, pend_cfgs, avail_i, seq, n_steps,
                    residual_steps=pend_resid, max_degree=self.host_size,
                )
                if res_i.jobs:
                    cand = (
                        len(pending)
                        - sum(len(j.config_ids) for j in res_i.jobs),
                        t_i + max(j.est_time for j in res_i.jobs),
                    )
                else:
                    cand = (len(pending), float(t_i))
                if best is None or cand < best:
                    best = cand
            assert best is not None  # the victim itself is running
            miss_w, fin_w = best
            if miss_m != miss_w:
                return miss_m < miss_w
            if len(ends) > 1:
                # true lookahead: intermediate frees are accounted for, so
                # the wait estimate is realistic — compare head to head
                return fin_m < fin_w - _EPS
            # single finish event: the myopic estimate is pessimistic, so
            # demand the preemption win clear a safety margin before
            # re-paying setup and churning the pack (fallback rule)
            return fin_m < now + (fin_w - now) * (1.0 - MIGRATION_MARGIN)

        while heap:
            t = heap[0][0]
            arrived = False
            while heap and heap[0][0] <= t + _EPS:
                _, _, _, kind, payload = heapq.heappop(heap)
                if kind == "finish":
                    r = running.pop(payload, None)
                    if r is None:
                        continue  # stale event of a preempted job
                    finish_segment(r, r.est_end, r.run_steps, preempted=False)
                    free += r.degree
                    release_units(r)
                else:
                    a = trace[payload]
                    pending.append(
                        _Pending(payload, a.config, a.time, 0, total_steps[payload])
                    )
                    arrived = True

            do_repack(t)
            # dynamic task migration (paper §4): on admission events, if work
            # is still stranded in the pending set, preempt the running job
            # with the most remaining time and repack everything together.
            while (
                repack == "event"
                and arrived
                and pending
                and running
                and n_migrations < migration_budget
            ):
                victims = [
                    r for r in running.values() if r.start < t - _EPS
                ]
                if not victims:
                    break
                victim = max(victims, key=lambda r: (r.est_end, r.job_id))
                if victim.est_end - t <= preempt_min_remaining:
                    break
                if not migration_pays(victim, t):
                    break
                preempt(victim, t)
                do_repack(t)

        if pending:
            raise self._unschedulable(len(pending))
        makespan = max(
            (s.end for s in segments),
            default=0.0,
        )
        sched = OnlineSchedule(
            segments=segments,
            makespan=makespan,
            g=g,
            completed=completed,
            total_steps=total_steps,
            n_repacks=n_repacks,
            n_migrations=n_migrations,
            n_f_calls=n_f,
        )
        sched.validate(host_size=self.host_size)
        return sched

    # ``simulate`` for the online mode is just the event loop itself.
    simulate_online = plan_online

    def run_online_local(
        self,
        trace: Sequence[Arrival],
        cfg: ModelConfig,
        base_params,
        *,
        n_steps: int,
        seq: int,
        pool: Optional[CheckpointPool] = None,
        repack: str = "event",
        admission: str = "patient",
        migration_budget: int = 0,
        preempt_min_remaining: Optional[float] = None,
        lookahead_k: int = 3,
        data_iter_fn: Optional[Callable] = None,
        seed: int = 0,
        runner=None,  # Optional[repro.cluster.ClusterRunner]
        adaptive: Optional[bool] = None,
        probe_steps: int = 4,
        drift_threshold: Optional[float] = None,
    ) -> Tuple[List[JobRecord], OnlineSchedule]:
        """Real execution of an online trace: the event loop above decides
        the segments (and their device groups); the cluster runner then
        trains every segment for real on its planned mesh slice — segments
        on disjoint slices overlapping in wall-clock time on multi-device
        hosts — with preempted adapters checkpointing through ``pool`` and
        resuming, possibly with different pack partners, via
        ``inject_adapter``.

        With an adaptive estimator (``self.cm.adaptive``, i.e. a
        :class:`~repro.sched.profile.ProfiledCostModel`; overridable via
        ``adaptive=``) the virtual pre-plan is skipped entirely and the
        engine runs the profile feedback loop instead: re-plan against live
        measurements on every real device-free event, probe unmeasured jobs
        for ``probe_steps`` iterations, and re-assign device units when a
        job's measured rate drifts beyond ``drift_threshold`` from plan —
        see :meth:`_run_adaptive` (``repack``/``admission``/
        ``migration_budget`` apply only to the virtual pre-planned path)."""
        if adaptive is None:
            adaptive = self.cm.adaptive
        if adaptive:
            return self._run_adaptive(
                trace,
                cfg,
                base_params,
                n_steps=n_steps,
                seq=seq,
                pool=pool,
                data_iter_fn=data_iter_fn,
                seed=seed,
                runner=runner,
                probe_steps=probe_steps,
                drift_threshold=drift_threshold,
            )
        sched = self.plan_online(
            trace,
            seq,
            n_steps,
            repack=repack,
            admission=admission,
            migration_budget=migration_budget,
            preempt_min_remaining=preempt_min_remaining,
            lookahead_k=lookahead_k,
        )
        if sched.n_migrations and pool is None:
            raise ValueError(
                "preemption occurred but no CheckpointPool was given to "
                "carry resumable adapter state"
            )
        result = self._execute_segments(
            sched.segments,
            {cid: a.config for cid, a in enumerate(trace)},
            sched.total_steps,
            cfg,
            base_params,
            seq=seq,
            pool=pool,
            data_iter_fn=data_iter_fn,
            seed=seed,
            runner=runner,
        )
        return result.records, sched

    # ---------------- adaptive real execution (profile feedback loop) ------

    def _run_adaptive(
        self,
        trace: Sequence[Arrival],
        cfg: ModelConfig,
        base_params,
        *,
        n_steps: int,
        seq: int,
        pool: Optional[CheckpointPool],
        data_iter_fn: Optional[Callable],
        seed: int,
        runner,
        probe_steps: int,
        drift_threshold: Optional[float],
    ) -> Tuple[List[JobRecord], OnlineSchedule]:
        """Profile-guided adaptive execution: plan -> measure -> re-plan.

        Unlike the virtual path (plan the whole trace, then execute), this
        loop schedules against *real* device-free events:

          * on every admission/completion it re-plans the pending set with
            the live (calibrated) estimator over the currently free units;
          * a job whose (pack shape, degree) has never been measured is
            dispatched as a ``probe_steps``-iteration *probe* segment first
            (the existing preempt machinery: the probe checkpoints its
            unfinished adapters through ``pool`` and they resume with exact
            step/data offsets, so splitting is bit-identical to an unbroken
            run);
          * when the probe's measured rate is within ``drift_threshold`` of
            plan, the job continues in place on the same units — no planner
            churn; when it drifts beyond the threshold, the residual re-
            enters the pending set and the next re-plan (now calibrated by
            the measurement) re-assigns device units — starved jobs land on
            units that actually free early, over-provisioned plans shrink.

        Observations recorded here persist on the estimator's store, so a
        profile saved afterwards (``launch.train --profile-out``) seeds the
        next run's planning."""
        import dataclasses
        import queue
        import time as _time
        from concurrent.futures import ThreadPoolExecutor

        from repro.cluster import ClusterRunner, SegmentTiming
        from repro.cluster.executor import _slice_track

        est = self.cm
        runner = runner or ClusterRunner(tracer=self.tracer)
        executor, dpool = runner.executor, runner.device_pool
        # -- heterogeneous / elastic fleet wiring (all optional) ------------
        # A multihost runner advertises per-host class tags, live membership
        # (join/drain events) and heartbeat states; local runners have none
        # of these and every hook below degrades to the homogeneous loop.
        class_aware = bool(getattr(est, "class_aware", False))
        host_classes: Dict[int, str] = {}
        for h, c in enumerate(getattr(runner, "host_classes", ()) or ()):
            host_classes[h] = str(c)
        host_state_fn = getattr(runner, "host_state", None)
        hs = self.host_size

        def unit_host(u: int) -> Optional[int]:
            return u // hs if hs else None

        def cls_of_units(units) -> str:
            h = unit_host(units[0]) if units else None
            return host_classes.get(h, "") if h is not None else ""

        def est_kw(units) -> dict:
            c = cls_of_units(units)
            return {"host_class": c} if (class_aware and c) else {}

        def host_suspect(h: Optional[int]) -> bool:
            if h is None or host_state_fn is None:
                return False
            try:
                return host_state_fn(h) == "SUSPECT"
            except Exception:
                return False

        drained_units: set = set()
        # kernel policy: capture the CALLER's context-local default here —
        # the submit() workers below run on executor threads that never see
        # this context's vars, so the impl must cross as an explicit
        # argument (same contract as ClusterRunner.run)
        from repro.kernels.ops import default_impl

        impl = default_impl()
        impl = None if impl == "auto" else impl
        if drift_threshold is None:
            drift_threshold = getattr(est, "drift_threshold", 0.5)
        g = self.monitor.total
        configs_by_cid = {cid: a.config for cid, a in enumerate(trace)}
        total_steps = {
            cid: (a.steps if a.steps is not None else n_steps)
            for cid, a in enumerate(trace)
        }
        order = sorted(range(len(trace)), key=lambda cid: (trace[cid].time, cid))
        next_arr = 0
        pending: List[_Pending] = []
        # job_id -> (segment, entries, predicted iter time, is_probe)
        running: Dict[int, Tuple[JobSegment, List[_Pending], float, bool]] = {}
        events: queue.Queue = queue.Queue()
        free_units = list(range(g))
        segments: List[JobSegment] = []
        records: List[JobRecord] = []
        timings: List = []
        completed: Dict[int, float] = {}
        n_repacks = n_probes = n_reassign = n_f = 0
        next_job = itertools.count()
        tpe = (
            # 2x headroom: hosts admitted mid-run (add_host) raise the
            # number of concurrently running segments beyond the initial g
            ThreadPoolExecutor(max_workers=2 * max(g, 1))
            if runner.concurrent
            else None
        )
        t0 = _time.perf_counter()
        tracer = self.tracer
        # the adaptive loop spans the whole method (multiple exits via the
        # finally below), so the root span is entered/exited manually
        root_cm = tracer.span(
            "engine.run_adaptive", cat="engine", n_configs=len(trace), g=g
        )
        root_id = root_cm.__enter__().span_id or None

        def now() -> float:
            return _time.perf_counter() - t0

        def submit(entries: List[_Pending], degree: int, units: Tuple[int, ...]):
            nonlocal n_probes
            sel = [e.config for e in entries]
            run_steps = max(e.residual for e in entries)
            probe = (
                pool is not None
                and 0 < probe_steps < run_steps
                and not est.observed(sel, degree, seq, **est_kw(units))
            )
            steps_this = probe_steps if probe else run_steps
            seg = JobSegment(
                job_id=next(next_job),
                config_ids=tuple(e.cid for e in entries),
                degree=degree,
                start=now(),
                end=now(),  # placeholder; replaced at completion
                start_steps=tuple(e.steps_done for e in entries),
                run_steps=steps_this,
                done_ids=tuple(
                    e.cid for e in entries if e.residual <= steps_this
                ),
                preempted=steps_this < run_steps,
                units=units,
            )
            pred = est.iter_time(sel, degree, seq, **est_kw(units))
            running[seg.job_id] = (seg, entries, pred, probe)
            if probe:
                n_probes += 1
            slice_ = dpool.acquire_units(dpool.map_units(units))
            tracer.instant(
                "engine.launch", cat="engine", job_id=seg.job_id,
                degree=degree, units=list(units), probe=probe,
            )
            tracer.metrics.gauge("cluster.free_units").set(dpool.free)

            def work():
                # pool threads never see the loop thread's span stack: the
                # explicit ``parent=`` stitches this segment under the
                # adaptive root
                rec = err = None
                try:
                    with dpool.held(slice_):
                        with tracer.span(
                            "runner.segment", cat="runner",
                            parent=root_id, track=_slice_track(slice_),
                            job_id=seg.job_id, probe=probe,
                        ):
                            rec = executor.run_segment(
                                seg,
                                configs_by_cid,
                                total_steps,
                                cfg,
                                base_params,
                                seq=seq,
                                pool=pool,
                                data_iter_fn=data_iter_fn,
                                seed=seed,
                                slice_=slice_,
                                impl=impl,
                            )
                except BaseException as e:  # noqa: BLE001 — re-raised below
                    err = e
                finally:
                    tracer.metrics.gauge("cluster.free_units").set(dpool.free)
                events.put((seg.job_id, rec, err))

            if tpe is not None:
                tpe.submit(work)
            else:
                work()

        def do_replan() -> bool:
            nonlocal n_repacks, n_f
            pending.sort(key=lambda e: e.cid)
            with tracer.span(
                "engine.replan", cat="engine",
                pending=len(pending), free=len(free_units),
            ):
                res = replan(
                    est,
                    [e.config for e in pending],
                    len(free_units),
                    seq,
                    n_steps,
                    residual_steps=[e.residual for e in pending],
                    max_degree=self.host_size,
                )
            n_repacks += 1
            n_f += res.n_f_calls
            if not res.jobs:
                return False
            picked = [
                (jp, [pending[i] for i in jp.config_ids]) for jp in res.jobs
            ]
            if self.host_size is not None:
                # wider jobs first: FFD keeps power-of-2 degrees host-packable
                picked.sort(key=lambda pe: -pe[0].degree)
            launched = set()
            for jp, entries in picked:
                units = take_units(jp.degree)
                if units is None:
                    continue  # fragmented across hosts: retry on next event
                submit(entries, jp.degree, units)
                launched |= {e.cid for e in entries}
            pending[:] = [e for e in pending if e.cid not in launched]
            return bool(launched)

        def take_units(degree: int) -> Optional[Tuple[int, ...]]:
            """Class- and health-aware unit claim: wide jobs to the fastest
            measured class, narrow jobs to the slowest, SUSPECT hosts last
            (see ``pick_class_units``); plain ``_take_units`` when the fleet
            is homogeneous/healthy-only."""
            if hs is not None and (host_classes or host_state_fn is not None):
                units = pick_class_units(
                    sorted(free_units), degree, hs,
                    class_of_host=lambda h: host_classes.get(h, ""),
                    ratio_of_class=lambda c: est.class_ratio(c, degree),
                    avoid_host=host_suspect,
                )
                if units is None:
                    return None
                for u in units:
                    free_units.remove(u)
                return units
            return self._take_units(free_units, degree)

        def on_membership(ev: dict) -> None:
            # called from the dispatcher's announcing thread: queue it into
            # the loop thread like any other real event
            events.put((None, ev, None))

        def handle_membership(ev: dict) -> None:
            action, host = ev.get("action"), ev.get("host")
            units = tuple(ev.get("units", ()))
            if action == "join":
                if hs is not None and len(units) != hs:
                    raise ValueError(
                        f"joining host {host} has {len(units)} units; this "
                        f"engine plans uniform {hs}-unit hosts"
                    )
                host_classes[host] = str(ev.get("host_class", ""))
                fresh = [
                    u for u in units
                    if u not in free_units and u not in drained_units
                ]
                free_units.extend(fresh)
                free_units.sort()
                tracer.instant(
                    "engine.host_join", cat="engine", host=host,
                    units=list(units), host_class=host_classes[host],
                )
            elif action == "drain":
                drained_units.update(units)
                free_units[:] = [u for u in free_units if u not in drained_units]
                tracer.instant(
                    "engine.host_drain", cat="engine", host=host,
                    units=list(units),
                )

        def on_completion(jid: int, rec):
            nonlocal n_reassign
            seg, entries, pred, probe = running.pop(jid)
            end = now()
            seg = dataclasses.replace(seg, end=end)
            segments.append(seg)
            rec.real_start -= t0  # loop-relative, like ClusterResult records
            rec.real_end -= t0
            records.append(rec)
            sel = [e.config for e in entries]
            measured = (
                rec.wall_seconds / seg.run_steps
                if seg.run_steps > 0
                else float("nan")
            )
            if seg.run_steps > 0:
                est.observe(sel, seg.degree, seq, measured,
                            **est_kw(seg.units))
            timing = SegmentTiming(
                job_id=seg.job_id,
                config_ids=seg.config_ids,
                degree=seg.degree,
                run_steps=seg.run_steps,
                seq=seq,
                measured_iter=measured,
                predicted_iter=pred,
            )
            timings.append(timing)
            for cid in seg.done_ids:
                completed[cid] = end
            resumed = []
            for e in entries:
                if e.residual > seg.run_steps:
                    e.steps_done += seg.run_steps
                    resumed.append(e)
            # NaN drift (no steps run / degenerate prediction) counts as
            # within threshold: nothing measurable to react to
            drift = timing.drift
            if drift != drift:
                drift = 0.0
            if resumed:
                # straggler detection: a SUSPECT host (missing heartbeat
                # deadlines) gets half the drift tolerance — work drifting
                # there re-enters the replan path before the host dies
                eff_threshold = drift_threshold * (
                    0.5 if host_suspect(unit_host(seg.units[0])) else 1.0
                )
                on_drained = any(u in drained_units for u in seg.units)
                if abs(drift) <= eff_threshold and not on_drained:
                    # plan confirmed within threshold: continue in place on
                    # the same units — no re-assignment, no planner churn
                    submit(resumed, seg.degree, seg.units)
                    return
                # drifted beyond threshold (or the host is draining): the
                # residual goes back to the planner, which — now calibrated
                # by this very measurement — re-assigns device units on the
                # next replan
                n_reassign += 1
                pending.extend(resumed)
            free_units.extend(
                u for u in seg.units if u not in drained_units
            )
            free_units.sort()

        subscribe = getattr(runner, "membership_subscribe", None)
        unsubscribe = subscribe(on_membership) if callable(subscribe) else None
        try:
            while next_arr < len(order) or pending or running:
                # membership (and any already-finished completion) events
                # queued while this thread was elsewhere: apply them before
                # replanning so the plan sees the current fleet
                while True:
                    try:
                        jid, rec, err = events.get_nowait()
                    except queue.Empty:
                        break
                    if err is not None:
                        raise err
                    if jid is None:
                        handle_membership(rec)
                    else:
                        on_completion(jid, rec)
                while (
                    next_arr < len(order)
                    and trace[order[next_arr]].time <= now() + _EPS
                ):
                    cid = order[next_arr]
                    next_arr += 1
                    pending.append(
                        _Pending(
                            cid,
                            trace[cid].config,
                            trace[cid].time,
                            0,
                            total_steps[cid],
                        )
                    )
                launched = (
                    do_replan() if pending and free_units else False
                )
                if running:
                    timeout = None
                    if next_arr < len(order):
                        timeout = (
                            max(trace[order[next_arr]].time - now(), 0.0)
                            + 1e-3
                        )
                    try:
                        jid, rec, err = events.get(timeout=timeout)
                    except queue.Empty:
                        continue  # the next arrival is due — admit it
                    if err is not None:
                        raise err
                    if jid is None:
                        handle_membership(rec)
                    else:
                        on_completion(jid, rec)
                elif pending and not launched:
                    raise self._unschedulable(len(pending))
                elif not pending and next_arr < len(order):
                    _time.sleep(
                        max(trace[order[next_arr]].time - now(), 0.0)
                    )
        finally:
            if unsubscribe is not None:
                unsubscribe()
            if tpe is not None:
                tpe.shutdown(wait=True)
            root_cm.__exit__(None, None, None)

        sched = OnlineSchedule(
            segments=segments,
            makespan=max((s.end for s in segments), default=0.0),
            g=g,
            completed=completed,
            total_steps=total_steps,
            n_repacks=n_repacks,
            n_migrations=0,
            n_f_calls=n_f,
            n_probes=n_probes,
            n_reassignments=n_reassign,
            timings=timings,
        )
        return records, sched

    # ---------------- shared segment executor (cluster subsystem) ----------

    def _execute_segments(
        self,
        segments: Sequence[JobSegment],
        configs_by_cid: Dict[int, LoraConfig],
        total_steps: Dict[int, int],
        cfg: ModelConfig,
        base_params,
        *,
        seq: int,
        pool: Optional[CheckpointPool],
        data_iter_fn: Optional[Callable],
        seed: int,
        runner=None,  # Optional[repro.cluster.ClusterRunner]
        impl: Optional[str] = None,
        remat: Optional[str] = None,
        base_dtype: Optional[str] = None,
    ):
        """Execute planned segments through ``repro.cluster``: each segment
        runs on the mesh slice backing its planned device units, thread-per-
        slice when the host has multiple (possibly CPU-forced) devices, and
        serially on the degenerate single-slice pool otherwise. Resumed
        adapters (``start_steps > 0``) are loaded from the pool and injected
        into the new pack (weights + Adam moments + per-adapter step count);
        per-adapter step *budgets* freeze an adapter once its own iteration
        count is met, even while longer-residual packmates keep training —
        so real execution matches the virtual accounting. Returns a
        ``repro.cluster.ClusterResult``."""
        from repro.cluster import ClusterRunner

        runner = runner or ClusterRunner(tracer=self.tracer)
        return runner.run(
            segments,
            configs_by_cid,
            total_steps,
            cfg,
            base_params,
            seq=seq,
            pool=pool,
            data_iter_fn=data_iter_fn,
            seed=seed,
            estimator=self.cm,
            impl=impl,
            remat=remat,
            base_dtype=base_dtype,
        )


def replay_measured(
    schedule: Schedule, records: List[JobRecord], g: int
) -> float:
    """Re-run the schedule's resource timeline with measured durations."""
    free = g
    t = 0.0
    running: List[Tuple[float, int]] = []
    pending = [(r.job.degree, r.wall_seconds) for r in records]
    makespan = 0.0
    i = 0
    while i < len(pending) or running:
        launched = False
        while i < len(pending) and pending[i][0] <= free:
            d, dur = pending[i]
            heapq.heappush(running, (t + dur, d))
            makespan = max(makespan, t + dur)
            free -= d
            i += 1
            launched = True
        if not launched:
            if not running:
                break
            end, d = heapq.heappop(running)
            t, free = end, free + d
    return makespan

"""Event-driven concurrent LoRA execution engine (paper §4, Fig. 3).

The engine is a **virtual-clock event loop**: a heap of job-finish and
job-arrive events (a finish event *is* a device-free event) drives a single
scheduling loop that supports

  * **online admission** — ``LoraConfig`` s arrive mid-run on an arrival-time
    trace (:func:`poisson_trace` builds the paper-style Poisson workload);
    nothing is frozen at t=0;
  * **dynamic repacking** — on every admission and device-free event the
    engine re-invokes the planner's incremental API
    (:func:`repro.sched.planner.replan` -> DTM, Alg. 1) over the
    not-yet-started configs and currently free device units, instead of
    draining a statically planned queue;
  * **preemption-aware checkpointing** — with ``migration_budget > 0``, a
    running packed job can be preempted on an admission event: its finished
    adapters complete, its unfinished adapters re-enter the pending set with
    *residual* step counts and are repacked with the new arrivals (paper §4
    dynamic task migration). In real execution the preempted adapters
    round-trip through the :class:`~repro.train.checkpoint.CheckpointPool`
    (weights + Adam moments + step counts) and are injected into whatever
    pack the replanner chooses next.

Both modes share this one loop: ``plan_online``/``simulate`` play the trace
against cost-model durations (pod-scale what-ifs), and ``run_online_local``
executes the *same* planned segments for real on this host (CPU XLA),
per-adapter state flowing through the checkpoint pool. The static
``simulate(schedule)`` / ``run_local(schedule, ...)`` entry points are the
degenerate no-arrivals case and reuse the same segment executor.

The static baseline the benchmarks compare against is ``repack="drain"``:
admission still happens, but the engine only replans when *all* devices are
free — exactly the frozen-queue batch replayer this engine replaced.
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import LoraConfig, ModelConfig
from repro.core.adapter import pack_meta
from repro.core.packed_lora import extract_adapter, inject_adapter
from repro.sched.cost_model import CostModel
from repro.sched.planner import Schedule, ScheduledJob, replan
from repro.train.checkpoint import CheckpointPool


@dataclass
class ResourceMonitor:
    total: int
    free: int = -1

    def __post_init__(self):
        if self.free < 0:
            self.free = self.total

    def acquire(self, n: int) -> bool:
        if n <= self.free:
            self.free -= n
            return True
        return False

    def release(self, n: int):
        self.free += n
        assert self.free <= self.total


@dataclass
class JobRecord:
    job: ScheduledJob
    wall_seconds: float
    final_losses: Optional[np.ndarray] = None


# ---------------------------------------------------------------------------
# Arrival traces
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Arrival:
    """One online job submission: a LoRA config arriving at ``time`` that
    needs ``steps`` training iterations (None = the run-level default)."""

    time: float
    config: LoraConfig
    steps: Optional[int] = None


def poisson_trace(
    configs: Sequence[LoraConfig],
    mean_interarrival: float,
    seed: int = 0,
    steps: Optional[Sequence[int]] = None,
) -> List[Arrival]:
    """Poisson arrival process over ``configs`` (order preserved): i.i.d.
    exponential inter-arrival gaps with the given mean, shifted so the first
    config arrives at t=0. Deterministic in ``seed``."""
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(mean_interarrival, size=len(configs))
    times = np.cumsum(gaps) - gaps[0]
    return [
        Arrival(float(t), c, None if steps is None else int(steps[i]))
        for i, (t, c) in enumerate(zip(times, configs))
    ]


# ---------------------------------------------------------------------------
# Online schedule (the event loop's output)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class JobSegment:
    """One contiguous run of a packed job on ``degree`` device units.

    A job that runs to completion is one segment; a preempted job is a
    ``preempted=True`` segment (cut at the migration point) followed — after
    repacking — by later segments of whatever new jobs its unfinished
    adapters land in. ``start_steps[i]`` is how many iterations
    ``config_ids[i]`` had already trained before this segment (0 = fresh;
    >0 = resumed from the checkpoint pool); ``run_steps`` is the number of
    packed iterations this segment executes; ``done_ids`` are the configs
    whose step budget completes within this segment."""

    job_id: int
    config_ids: Tuple[int, ...]
    degree: int
    start: float
    end: float
    start_steps: Tuple[int, ...]
    run_steps: int
    done_ids: Tuple[int, ...]
    preempted: bool = False

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclass
class OnlineSchedule:
    segments: List[JobSegment]
    makespan: float
    g: int
    completed: Dict[int, float]  # cid -> virtual completion time
    total_steps: Dict[int, int]  # cid -> total step budget
    n_repacks: int = 0
    n_migrations: int = 0
    n_f_calls: int = 0

    def utilization(self) -> float:
        """Busy device-seconds / (G * makespan)."""
        if not self.segments or self.makespan <= 0:
            return 0.0
        busy = sum(s.duration * s.degree for s in self.segments)
        return busy / (self.g * self.makespan)

    def validate(self):
        """Raise if any instant oversubscribes the device pool."""
        _validate_intervals(
            [(s.start, s.end, s.degree) for s in self.segments], self.g
        )


def _validate_intervals(intervals: Sequence[Tuple[float, float, int]], g: int):
    monitor = ResourceMonitor(g)
    events = []
    for start, end, degree in intervals:
        events.append((start, 1, degree))
        events.append((end, 0, degree))
    # process releases before acquires at equal timestamps
    for t, kind, d in sorted(events, key=lambda e: (e[0], e[1])):
        if kind == 0:
            monitor.release(d)
        elif not monitor.acquire(d):
            raise RuntimeError(f"schedule oversubscribes devices at t={t:.2f}")


# ---------------------------------------------------------------------------
# Event loop internals
# ---------------------------------------------------------------------------


@dataclass
class _Pending:
    cid: int
    config: LoraConfig
    arrival: float
    steps_done: int
    total_steps: int

    @property
    def residual(self) -> int:
        return self.total_steps - self.steps_done


@dataclass
class _Running:
    job_id: int
    cids: Tuple[int, ...]
    sel: List[LoraConfig]
    degree: int
    start: float
    iter_time: float
    residuals: Tuple[int, ...]
    start_steps: Tuple[int, ...]
    run_steps: int  # max residual: iterations until the job finishes
    est_end: float


_EPS = 1e-9

# Fraction of the estimated wait-for-victim completion a preemption must
# beat before the engine migrates (guards against churn from the myopic
# single-victim estimate; see ExecutionEngine.plan_online).
MIGRATION_MARGIN = 0.25


class ExecutionEngine:
    """Resource monitor + event loop + job launcher over ``g`` device units."""

    def __init__(self, cm: CostModel, g: int):
        self.cm = cm
        self.monitor = ResourceMonitor(g)

    # ---------------- static entry points (no-arrivals special case) -------

    def simulate(self, schedule: Schedule) -> float:
        """Replay a static schedule's timeline through the resource monitor;
        returns the makespan and raises if the plan ever over-subscribes."""
        _validate_intervals(
            [(j.start, j.end, j.degree) for j in schedule.jobs],
            self.monitor.total,
        )
        return schedule.makespan

    def run_local(
        self,
        schedule: Schedule,
        configs: Sequence[LoraConfig],
        cfg: ModelConfig,
        base_params,
        *,
        n_steps: int,
        seq: int,
        pool: Optional[CheckpointPool] = None,
        data_iter_fn: Optional[Callable] = None,
        seed: int = 0,
    ) -> Tuple[List[JobRecord], float]:
        """Execute every job of a static schedule on this host via the shared
        segment executor. Returns the job records and the measured-duration
        makespan (each job's simulated duration replaced by its wall time,
        replayed through the resource timeline)."""
        segments = [
            JobSegment(
                job_id=i,
                config_ids=j.config_ids,
                degree=j.degree,
                start=j.start,
                end=j.end,
                start_steps=(0,) * len(j.config_ids),
                run_steps=n_steps,
                done_ids=j.config_ids,
            )
            for i, j in enumerate(schedule.jobs)
        ]
        records = self._execute_segments(
            segments,
            {i: c for i, c in enumerate(configs)},
            {i: n_steps for i in range(len(configs))},
            cfg,
            base_params,
            seq=seq,
            pool=pool,
            data_iter_fn=data_iter_fn,
            seed=seed,
        )
        makespan = replay_measured(schedule, records, self.monitor.total)
        return records, makespan

    # ---------------- the event loop ----------------

    def plan_online(
        self,
        trace: Sequence[Arrival],
        seq: int,
        n_steps: int,
        *,
        repack: str = "event",
        admission: str = "patient",
        migration_budget: int = 0,
        preempt_min_remaining: Optional[float] = None,
    ) -> OnlineSchedule:
        """Play an arrival trace through the virtual-clock event loop.

        ``repack="event"`` replans on every admission/device-free event (the
        online engine); ``repack="drain"`` only replans when the pool is
        fully idle (the frozen-queue static baseline). ``migration_budget``
        caps how many running jobs may be preempted over the whole run;
        ``preempt_min_remaining`` (default ``4 * setup_time``) is the minimum
        estimated remaining time that makes a victim worth re-paying setup
        for.

        ``admission="patient"`` guards against the online-greedy pathology:
        dispatching an arrival immediately onto a few free units can lose to
        waiting for the next job-finish and launching at higher parallelism.
        On every repack with jobs still running, the engine compares the
        estimated completion of launch-now-on-``free`` against
        wait-then-launch-on-``free + soon-freed`` and holds the pending set
        when waiting wins. ``admission="eager"`` always dispatches (exactly
        Algorithm 2's greedy rule, and the t=0 behavior of ``plan``)."""
        if repack not in ("event", "drain"):
            raise ValueError(f"unknown repack policy {repack!r}")
        if admission not in ("patient", "eager"):
            raise ValueError(f"unknown admission policy {admission!r}")
        g = self.monitor.total
        cm = self.cm
        if preempt_min_remaining is None:
            preempt_min_remaining = 4.0 * cm.setup_time

        heap: List[Tuple[float, int, int, str, int]] = []
        seqno = itertools.count()
        for cid, a in enumerate(trace):
            heapq.heappush(heap, (a.time, 1, next(seqno), "arrive", cid))

        pending: List[_Pending] = []
        running: Dict[int, _Running] = {}
        segments: List[JobSegment] = []
        completed: Dict[int, float] = {}
        total_steps = {
            cid: (a.steps if a.steps is not None else n_steps)
            for cid, a in enumerate(trace)
        }
        free = g
        next_job = itertools.count()
        n_repacks = n_migrations = n_f = 0

        def finish_segment(r: _Running, end: float, steps_run: int, preempted: bool):
            done = tuple(
                cid
                for cid, resid in zip(r.cids, r.residuals)
                if resid <= steps_run
            )
            for cid, resid in zip(r.cids, r.residuals):
                if resid <= steps_run:
                    completed[cid] = r.start + cm.adapter_finish_offset(
                        r.sel, resid, r.degree, seq
                    )
            segments.append(
                JobSegment(
                    job_id=r.job_id,
                    config_ids=r.cids,
                    degree=r.degree,
                    start=r.start,
                    end=end,
                    start_steps=r.start_steps,
                    run_steps=steps_run,
                    done_ids=done,
                    preempted=preempted,
                )
            )

        def do_repack(now: float):
            nonlocal free, n_repacks, n_f
            if not pending or free <= 0:
                return
            if repack == "drain" and running:
                return  # static baseline: wait for the full drain
            pending.sort(key=lambda e: e.cid)
            cfgs = [e.config for e in pending]
            resid = [e.residual for e in pending]
            res = replan(cm, cfgs, free, seq, n_steps, residual_steps=resid)
            n_repacks += 1
            n_f += res.n_f_calls
            if not res.jobs:
                return
            if admission == "patient" and running:
                # launch now at `free`, or wait for the next finish and
                # launch wider? Compare estimated completion times.
                t_next = min(r.est_end for r in running.values())
                freed = free + sum(
                    r.degree
                    for r in running.values()
                    if r.est_end <= t_next + _EPS
                )
                res_wait = replan(
                    cm, cfgs, freed, seq, n_steps, residual_steps=resid
                )
                n_f += res_wait.n_f_calls
                covered_now = sum(len(j.config_ids) for j in res.jobs)
                covered_wait = sum(len(j.config_ids) for j in res_wait.jobs)
                finish_now = now + max(j.est_time for j in res.jobs)
                finish_wait = (
                    t_next + max(j.est_time for j in res_wait.jobs)
                    if res_wait.jobs
                    else float("inf")
                )
                if covered_wait >= covered_now and finish_wait <= finish_now:
                    return  # hold: the next device-free event re-evaluates
            launched = set()
            for jp in res.jobs:
                entries = [pending[i] for i in jp.config_ids]
                sel = [e.config for e in entries]
                r = _Running(
                    job_id=next(next_job),
                    cids=tuple(e.cid for e in entries),
                    sel=sel,
                    degree=jp.degree,
                    start=now,
                    iter_time=cm.iter_time(sel, jp.degree, seq),
                    residuals=tuple(e.residual for e in entries),
                    start_steps=tuple(e.steps_done for e in entries),
                    run_steps=max(e.residual for e in entries),
                    est_end=now + jp.est_time,
                )
                running[r.job_id] = r
                heapq.heappush(
                    heap, (r.est_end, 0, next(seqno), "finish", r.job_id)
                )
                free -= jp.degree
                launched |= set(r.cids)
            if launched:
                pending[:] = [e for e in pending if e.cid not in launched]

        def steps_run_at(r: _Running, now: float) -> int:
            done = int((now - r.start - cm.setup_time) // r.iter_time)
            return max(0, min(done, r.run_steps))

        def preempt(r: _Running, now: float):
            nonlocal free, n_migrations
            steps_run = steps_run_at(r, now)
            finish_segment(r, now, steps_run, preempted=True)
            for cfg_c, cid, resid, st0 in zip(
                r.sel, r.cids, r.residuals, r.start_steps
            ):
                if resid > steps_run:
                    pending.append(
                        _Pending(
                            cid, cfg_c, now, st0 + steps_run, total_steps[cid]
                        )
                    )
            del running[r.job_id]  # its finish event becomes stale
            free += r.degree
            n_migrations += 1

        def migration_pays(victim: _Running, now: float) -> bool:
            """Cost-model estimate of the paper's dynamic-task-migration
            trade: preempt the victim and repack its unfinished adapters
            together with the pending set on its devices *now*, versus
            leaving it alone and scheduling the pending set when it
            finishes. Preemption re-pays job setup, so it only wins when
            the victim still has a long run ahead of stranded arrivals."""
            steps_run = steps_run_at(victim, now)
            unfinished = [
                (c, resid - steps_run)
                for c, resid in zip(victim.sel, victim.residuals)
                if resid > steps_run
            ]
            if not unfinished:
                return False
            avail = free + victim.degree
            merged = [e.config for e in pending] + [c for c, _ in unfinished]
            merged_resid = [e.residual for e in pending] + [
                s for _, s in unfinished
            ]
            res_m = replan(
                cm, merged, avail, seq, n_steps, residual_steps=merged_resid
            )
            res_w = replan(
                cm,
                [e.config for e in pending],
                avail,
                seq,
                n_steps,
                residual_steps=[e.residual for e in pending],
            )
            miss_m = len(merged) - sum(len(j.config_ids) for j in res_m.jobs)
            miss_w = len(pending) - sum(len(j.config_ids) for j in res_w.jobs)
            fin_m = (
                now + max(j.est_time for j in res_m.jobs)
                if res_m.jobs
                else float("inf")
            )
            fin_w = (
                victim.est_end + max(j.est_time for j in res_w.jobs)
                if res_w.jobs
                else victim.est_end
            )
            if miss_m != miss_w:
                return miss_m < miss_w
            # the wait estimate is pessimistic (other jobs may free devices
            # first), so demand the preemption win clear a safety margin
            # before re-paying setup and churning the pack
            return fin_m < now + (fin_w - now) * (1.0 - MIGRATION_MARGIN)

        while heap:
            t = heap[0][0]
            arrived = False
            while heap and heap[0][0] <= t + _EPS:
                _, _, _, kind, payload = heapq.heappop(heap)
                if kind == "finish":
                    r = running.pop(payload, None)
                    if r is None:
                        continue  # stale event of a preempted job
                    finish_segment(r, r.est_end, r.run_steps, preempted=False)
                    free += r.degree
                else:
                    a = trace[payload]
                    pending.append(
                        _Pending(payload, a.config, a.time, 0, total_steps[payload])
                    )
                    arrived = True

            do_repack(t)
            # dynamic task migration (paper §4): on admission events, if work
            # is still stranded in the pending set, preempt the running job
            # with the most remaining time and repack everything together.
            while (
                repack == "event"
                and arrived
                and pending
                and running
                and n_migrations < migration_budget
            ):
                victims = [
                    r for r in running.values() if r.start < t - _EPS
                ]
                if not victims:
                    break
                victim = max(victims, key=lambda r: (r.est_end, r.job_id))
                if victim.est_end - t <= preempt_min_remaining:
                    break
                if not migration_pays(victim, t):
                    break
                preempt(victim, t)
                do_repack(t)

        if pending:
            raise RuntimeError(
                f"{len(pending)} configs can never be scheduled on "
                f"{g} free device units (min degree exceeds the pool?)"
            )
        makespan = max(
            (s.end for s in segments),
            default=0.0,
        )
        sched = OnlineSchedule(
            segments=segments,
            makespan=makespan,
            g=g,
            completed=completed,
            total_steps=total_steps,
            n_repacks=n_repacks,
            n_migrations=n_migrations,
            n_f_calls=n_f,
        )
        sched.validate()
        return sched

    # ``simulate`` for the online mode is just the event loop itself.
    simulate_online = plan_online

    def run_online_local(
        self,
        trace: Sequence[Arrival],
        cfg: ModelConfig,
        base_params,
        *,
        n_steps: int,
        seq: int,
        pool: Optional[CheckpointPool] = None,
        repack: str = "event",
        admission: str = "patient",
        migration_budget: int = 0,
        preempt_min_remaining: Optional[float] = None,
        data_iter_fn: Optional[Callable] = None,
        seed: int = 0,
    ) -> Tuple[List[JobRecord], OnlineSchedule]:
        """Real CPU-XLA execution of an online trace: the event loop above
        decides the segments; every segment then trains for real, preempted
        adapters checkpointing through ``pool`` and resuming — possibly with
        different pack partners — via ``inject_adapter``."""
        sched = self.plan_online(
            trace,
            seq,
            n_steps,
            repack=repack,
            admission=admission,
            migration_budget=migration_budget,
            preempt_min_remaining=preempt_min_remaining,
        )
        if sched.n_migrations and pool is None:
            raise ValueError(
                "preemption occurred but no CheckpointPool was given to "
                "carry resumable adapter state"
            )
        records = self._execute_segments(
            sched.segments,
            {cid: a.config for cid, a in enumerate(trace)},
            sched.total_steps,
            cfg,
            base_params,
            seq=seq,
            pool=pool,
            data_iter_fn=data_iter_fn,
            seed=seed,
        )
        return records, sched

    # ---------------- shared segment executor ----------------

    def _execute_segments(
        self,
        segments: Sequence[JobSegment],
        configs_by_cid: Dict[int, LoraConfig],
        total_steps: Dict[int, int],
        cfg: ModelConfig,
        base_params,
        *,
        seq: int,
        pool: Optional[CheckpointPool],
        data_iter_fn: Optional[Callable],
        seed: int,
    ) -> List[JobRecord]:
        """Execute planned segments in virtual-time order on this host.

        Resumed adapters (``start_steps > 0``) are loaded from the pool and
        injected into the new pack (weights + Adam moments + per-adapter step
        count); per-adapter step *budgets* freeze an adapter once its own
        iteration count is met, even while longer-residual packmates keep
        training — so real execution matches the virtual accounting."""
        from repro.models.model import init_model
        from repro.train.data import packed_batch_iterator
        from repro.train.optimizer import init_opt_state
        from repro.train.trainer import make_train_step

        records: List[JobRecord] = []
        order = sorted(segments, key=lambda s: (s.start, s.job_id))
        for seg in order:
            job_cfgs = [configs_by_cid[cid] for cid in seg.config_ids]
            meta = pack_meta(job_cfgs)
            key = jax.random.PRNGKey(seed)
            _, lora = init_model(key, cfg, meta)
            opt = init_opt_state(lora, n_pack=meta.n)
            for slot, (cid, st0) in enumerate(
                zip(seg.config_ids, seg.start_steps)
            ):
                if st0 == 0:
                    continue
                if pool is None or not pool.has_adapter_state(f"{cid:04d}"):
                    raise RuntimeError(
                        f"segment resumes config {cid} at step {st0} but the "
                        "pool holds no checkpointed state for it"
                    )
                state, smeta = pool.load_adapter_state(f"{cid:04d}")
                assert int(smeta["steps_done"]) == st0, (cid, smeta, st0)
                lora = inject_adapter(lora, state["w"], slot)
                opt["m"] = inject_adapter(opt["m"], state["m"], slot)
                opt["v"] = inject_adapter(opt["v"], state["v"], slot)
                opt["step"] = opt["step"].at[slot].set(st0)
            budgets = np.asarray(
                [total_steps[cid] for cid in seg.config_ids], np.int32
            )
            step = make_train_step(cfg, meta, step_budgets=budgets)
            it = (
                data_iter_fn(cfg, job_cfgs, seq)
                if data_iter_fn
                else packed_batch_iterator(cfg, job_cfgs, seq=seq)
            )
            wall = 0.0
            losses = None
            m = None
            if seg.run_steps > 0:
                b0 = next(it)
                # compile outside the timed region on throwaway copies (the
                # paper times steady state); the real loop then starts from
                # the same state and batch, so step accounting stays exact
                lora_w = jax.tree.map(lambda x: x.copy(), lora)
                opt_w = jax.tree.map(lambda x: x.copy(), opt)
                _, _, warm = step(base_params, lora_w, opt_w, b0)
                jax.block_until_ready(warm["loss"])
                t0 = time.perf_counter()
                for batch in itertools.islice(
                    itertools.chain([b0], it), seg.run_steps
                ):
                    lora, opt, m = step(base_params, lora, opt, batch)
                jax.block_until_ready(m["loss"])
                wall = time.perf_counter() - t0
                losses = np.asarray(m["per_adapter_loss"])
            done = set(seg.done_ids)
            for slot, cid in enumerate(seg.config_ids):
                c = configs_by_cid[cid]
                if cid in done:
                    if pool is None:
                        continue
                    adapter = extract_adapter(lora, slot, meta.ranks)
                    pool.save_adapter(
                        f"adapter_{cid:04d}",
                        adapter,
                        {
                            "rank": c.rank,
                            "alpha": c.alpha,
                            "learning_rate": c.learning_rate,
                            "batch_size": c.batch_size,
                            "final_loss": (
                                float(losses[slot]) if losses is not None
                                else float("nan")
                            ),
                            "total_steps": int(total_steps[cid]),
                        },
                    )
                else:  # preempted mid-training: checkpoint resumable state
                    assert pool is not None
                    state = {
                        "w": extract_adapter(lora, slot, meta.ranks),
                        "m": extract_adapter(opt["m"], slot, meta.ranks),
                        "v": extract_adapter(opt["v"], slot, meta.ranks),
                    }
                    pool.save_adapter_state(
                        f"{cid:04d}",
                        state,
                        {
                            "steps_done": int(seg.start_steps[slot] + seg.run_steps),
                            "rank": c.rank,
                            "total_steps": int(total_steps[cid]),
                        },
                    )
            records.append(
                JobRecord(
                    ScheduledJob(seg.config_ids, seg.degree, seg.start, seg.end),
                    wall,
                    losses,
                )
            )
        return records


def replay_measured(
    schedule: Schedule, records: List[JobRecord], g: int
) -> float:
    """Re-run the schedule's resource timeline with measured durations."""
    free = g
    t = 0.0
    running: List[Tuple[float, int]] = []
    pending = [(r.job.degree, r.wall_seconds) for r in records]
    makespan = 0.0
    i = 0
    while i < len(pending) or running:
        launched = False
        while i < len(pending) and pending[i][0] <= free:
            d, dur = pending[i]
            heapq.heappush(running, (t + dur, d))
            makespan = max(makespan, t + dur)
            free -= d
            i += 1
            launched = True
        if not launched:
            if not running:
                break
            end, d = heapq.heappop(running)
            t, free = end, free + d
    return makespan

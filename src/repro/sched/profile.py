"""Profile-guided cost estimation: measured segment timings fed back into
planning.

The paper calibrates its analytic roofline from ~10 profiled iterations and
then trusts it for the whole run; on oversubscribed or heterogeneous hardware
that prior drifts, and the cluster executor already measures every segment's
real wall-clock anyway. This module closes the loop:

  * :class:`ObservationStore` — a thread-safe online store of
    (model, pack width, bucket rank, batch, degree, seq) -> per-iteration
    wall-time observations, EWMA-smoothed with observation counts, JSON
    save/load so a profile survives across runs (``launch.train
    --profile-out/--profile-in``);
  * :class:`ProfiledCostModel` — a :class:`~repro.sched.cost_model
    .CostEstimator` that answers ``iter_time`` from measurements when it has
    them and falls back to the analytic prior (scaled by the observed
    prediction-error ratio) when it does not. Memory queries always delegate
    to the prior — measurements say nothing about feasibility.

Fallback ladder for an unmeasured key, most- to least-specific:

  1. exact key observed            -> its EWMA;
  2. same *degree* observed        -> prior * ratio[degree]   (TP overheads
     are the dominant per-degree modeling error on real hosts);
  3. nothing at this degree        -> the pure prior.

Step 3 is deliberately *optimistic*: an unmeasured degree keeps the
prior's (usually rosy) estimate rather than inheriting another degree's
error ratio. That optimism is what drives exploration — when the degree
the prior favored turns out slow, the planner's next-best degree still
looks cheap, gets tried, gets measured, and the comparison is honest from
then on. Scaling unseen degrees by a global ratio would preserve the
prior's (wrong) degree ordering forever. The cross-key global ratio is
still tracked (``ObservationStore.ratio()``) for diagnostics.

The virtual-clock simulator must never see any of this:
``ProfiledCostModel.virtual_model()`` returns the pure prior, keeping
``ExecutionEngine.plan_online``/``simulate`` byte-identical and
deterministic regardless of measurement state.
"""
from __future__ import annotations

import json
import threading
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

from repro.configs.base import LoraConfig
from repro.sched.cost_model import CostEstimator, CostModel

# EWMA weight of a NEW observation (responsive: two observations already
# weight the prior measurement down to 25%)
DEFAULT_ALPHA = 0.5

# |measured / predicted - 1| beyond which the engine treats a running job's
# rate as having drifted from plan and re-assigns device units (see
# ExecutionEngine._run_adaptive and ROADMAP "Profile feedback loop")
DEFAULT_DRIFT_THRESHOLD = 0.5

_SCHEMA = 1


@dataclass
class Observation:
    """EWMA of one key's measured per-iteration seconds + sample count."""

    ewma: float
    n: int = 1

    def update(self, x: float, alpha: float) -> None:
        self.ewma = (1.0 - alpha) * self.ewma + alpha * x
        self.n += 1


def obs_key(
    model_name: str, configs: Sequence[LoraConfig], d: int, seq: int
) -> Tuple[str, int, int, int, int, int]:
    """Observation key of one packed job: iteration time depends on the pack's
    *shape* — width, bucket rank, total batch — not on which adapters fill it
    (hyperparameters are runtime args; same-shape packs share executables)."""
    return (
        model_name,
        len(configs),
        CostModel.bucket_rank(configs) if configs else 0,
        sum(c.batch_size for c in configs),
        d,
        seq,
    )


class ObservationStore:
    """Thread-safe (key -> EWMA iter-time) store with prediction-error ratios.

    Besides the per-key EWMAs it maintains per-degree and global EWMAs of
    ``measured / prior_predicted`` — the calibration ratios the profiled
    estimator uses to price configurations it has never run (the planner
    constantly asks about packs/degrees that differ from what executed)."""

    def __init__(self, alpha: float = DEFAULT_ALPHA):
        self.alpha = alpha
        self._obs: Dict[Tuple, Observation] = {}
        self._ratio_by_degree: Dict[int, Observation] = {}
        self._ratio: Optional[Observation] = None
        self._lock = threading.Lock()

    # ---------------- updates / queries ----------------

    def update(self, key: Tuple, measured: float, predicted_prior: float) -> None:
        with self._lock:
            hit = self._obs.get(key)
            if hit is None:
                self._obs[key] = Observation(measured)
            else:
                hit.update(measured, self.alpha)
            if predicted_prior > 0.0:
                r = measured / predicted_prior
                d = int(key[4])
                rd = self._ratio_by_degree.get(d)
                if rd is None:
                    self._ratio_by_degree[d] = Observation(r)
                else:
                    rd.update(r, self.alpha)
                if self._ratio is None:
                    self._ratio = Observation(r)
                else:
                    self._ratio.update(r, self.alpha)

    def get(self, key: Tuple) -> Optional[Observation]:
        with self._lock:
            return self._obs.get(key)

    def ratio(self, d: Optional[int] = None) -> Optional[float]:
        """Calibration ratio for degree ``d``, or — with ``d=None`` — the
        global cross-key ratio (diagnostics only; see the module docstring
        on why unseen degrees do NOT inherit it). None before any
        observation at that degree."""
        with self._lock:
            if d is not None:
                rd = self._ratio_by_degree.get(d)
                return rd.ewma if rd is not None else None
            return self._ratio.ewma if self._ratio is not None else None

    def __len__(self) -> int:
        with self._lock:
            return len(self._obs)

    @property
    def n_observations(self) -> int:
        with self._lock:
            return sum(o.n for o in self._obs.values())

    # ---------------- persistence ----------------

    def to_json(self) -> Dict:
        with self._lock:
            return {
                "schema": _SCHEMA,
                "alpha": self.alpha,
                "observations": [
                    {"key": list(k), "ewma": o.ewma, "n": o.n}
                    for k, o in sorted(self._obs.items())
                ],
                "ratio_by_degree": {
                    str(d): {"ewma": o.ewma, "n": o.n}
                    for d, o in sorted(self._ratio_by_degree.items())
                },
                "ratio": (
                    {"ewma": self._ratio.ewma, "n": self._ratio.n}
                    if self._ratio is not None
                    else None
                ),
            }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)

    @classmethod
    def from_json(cls, blob: Dict) -> "ObservationStore":
        if blob.get("schema") != _SCHEMA:
            raise ValueError(f"unknown profile schema {blob.get('schema')!r}")
        store = cls(alpha=float(blob.get("alpha", DEFAULT_ALPHA)))
        for row in blob.get("observations", []):
            store._obs[tuple(row["key"])] = Observation(
                float(row["ewma"]), int(row["n"])
            )
        for d, row in blob.get("ratio_by_degree", {}).items():
            store._ratio_by_degree[int(d)] = Observation(
                float(row["ewma"]), int(row["n"])
            )
        if blob.get("ratio") is not None:
            store._ratio = Observation(
                float(blob["ratio"]["ewma"]), int(blob["ratio"]["n"])
            )
        return store

    @classmethod
    def load(cls, path: str) -> "ObservationStore":
        with open(path) as f:
            return cls.from_json(json.load(f))


class ProfiledCostModel(CostEstimator):
    """The analytic prior wrapped with an online observation store.

    Time queries prefer measurements (fallback ladder in the module
    docstring); memory/feasibility queries and every other attribute
    delegate to the prior, so the packing solver's memory accounting is
    identical whether planning runs calibrated or not — only *durations*
    adapt. ``virtual_model()`` returns the pure prior for simulation."""

    def __init__(
        self,
        prior: CostModel,
        store: Optional[ObservationStore] = None,
        *,
        drift_threshold: float = DEFAULT_DRIFT_THRESHOLD,
    ):
        self.prior = prior
        self.store = store if store is not None else ObservationStore()
        self.drift_threshold = drift_threshold

    def __getattr__(self, name):
        # memory model, hardware spec, setup_time, calibrate, ... — anything
        # not overridden here is the prior's business. (Guard 'prior' itself:
        # attribute lookup during unpickling/copy runs before __init__.)
        if name == "prior":
            raise AttributeError(name)
        return getattr(self.prior, name)

    def key(self, configs: Sequence[LoraConfig], d: int, seq: int) -> Tuple:
        return obs_key(self.prior.cfg.name, configs, d, seq)

    # ---------------- time ----------------

    def iter_time(self, configs: Sequence[LoraConfig], d: int, seq: int) -> float:
        obs = self.store.get(self.key(configs, d, seq))
        if obs is not None:
            return obs.ewma
        prior_t = self.prior.iter_time(configs, d, seq)
        ratio = self.store.ratio(d)
        return prior_t if ratio is None else prior_t * ratio

    # ---------------- memory (always the prior) ----------------

    def fits(self, configs: Sequence[LoraConfig], d: int, seq: int) -> bool:
        return self.prior.fits(configs, d, seq)

    def min_degree(self, configs: Sequence[LoraConfig], seq: int) -> Optional[int]:
        return self.prior.min_degree(configs, seq)

    # ---------------- feedback ----------------

    def observe(
        self,
        configs: Sequence[LoraConfig],
        d: int,
        seq: int,
        measured_iter_time: float,
    ) -> None:
        self.store.update(
            self.key(configs, d, seq),
            measured_iter_time,
            self.prior.iter_time(configs, d, seq),
        )

    def observed(self, configs: Sequence[LoraConfig], d: int, seq: int) -> bool:
        return self.store.get(self.key(configs, d, seq)) is not None

    def drift(
        self,
        configs: Sequence[LoraConfig],
        d: int,
        seq: int,
        measured_iter_time: float,
    ) -> float:
        """Signed relative error of the *current* prediction against a fresh
        measurement: ``measured / predicted - 1``. Positive = the job runs
        slower than planned (starved / oversubscribed); negative = faster
        (over-provisioned)."""
        pred = self.iter_time(configs, d, seq)
        if pred <= 0.0:
            return 0.0
        return measured_iter_time / pred - 1.0

    # ---------------- simulation contract ----------------

    @property
    def adaptive(self) -> bool:
        return True

    def virtual_model(self) -> CostModel:
        return self.prior

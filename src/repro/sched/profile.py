"""Profile-guided cost estimation: measured segment timings fed back into
planning.

The paper calibrates its analytic roofline from ~10 profiled iterations and
then trusts it for the whole run; on oversubscribed or heterogeneous hardware
that prior drifts, and the cluster executor already measures every segment's
real wall-clock anyway. This module closes the loop:

  * :class:`ObservationStore` — a thread-safe online store of
    (model, pack width, bucket rank, batch, degree, seq) -> per-iteration
    wall-time observations, EWMA-smoothed with observation counts, JSON
    save/load so a profile survives across runs (``launch.train
    --profile-out/--profile-in``);
  * :class:`ProfiledCostModel` — a :class:`~repro.sched.cost_model
    .CostEstimator` that answers ``iter_time`` from measurements when it has
    them and falls back to the analytic prior (scaled by the observed
    prediction-error ratio) when it does not. Memory queries always delegate
    to the prior — measurements say nothing about feasibility.

Fallback ladder for an unmeasured key, most- to least-specific:

  1. exact key observed            -> its EWMA;
  2. same *degree* observed        -> prior * ratio[degree]   (TP overheads
     are the dominant per-degree modeling error on real hosts);
  3. nothing at this degree        -> the pure prior.

Step 3 is deliberately *optimistic*: an unmeasured degree keeps the
prior's (usually rosy) estimate rather than inheriting another degree's
error ratio. That optimism is what drives exploration — when the degree
the prior favored turns out slow, the planner's next-best degree still
looks cheap, gets tried, gets measured, and the comparison is honest from
then on. Scaling unseen degrees by a global ratio would preserve the
prior's (wrong) degree ordering forever. The cross-key global ratio is
still tracked (``ObservationStore.ratio()``) for diagnostics.

The virtual-clock simulator must never see any of this:
``ProfiledCostModel.virtual_model()`` returns the pure prior, keeping
``ExecutionEngine.plan_online``/``simulate`` byte-identical and
deterministic regardless of measurement state.
"""
from __future__ import annotations

import json
import threading
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

from repro.configs.base import LoraConfig
from repro.sched.cost_model import CostEstimator, CostModel

# EWMA weight of a NEW observation (responsive: two observations already
# weight the prior measurement down to 25%)
DEFAULT_ALPHA = 0.5

# |measured / predicted - 1| beyond which the engine treats a running job's
# rate as having drifted from plan and re-assigns device units (see
# ExecutionEngine._run_adaptive and ROADMAP "Profile feedback loop")
DEFAULT_DRIFT_THRESHOLD = 0.5

_SCHEMA = 2  # 2: obs keys carry a host-class tag (schema-1 loads as "")


@dataclass
class Observation:
    """EWMA of one key's measured per-iteration seconds + sample count."""

    ewma: float
    n: int = 1

    def update(self, x: float, alpha: float) -> None:
        self.ewma = (1.0 - alpha) * self.ewma + alpha * x
        self.n += 1


def obs_key(
    model_name: str,
    configs: Sequence[LoraConfig],
    d: int,
    seq: int,
    host_class: str = "",
) -> Tuple[str, int, int, int, int, int, str]:
    """Observation key of one packed job: iteration time depends on the pack's
    *shape* — width, bucket rank, total batch — not on which adapters fill it
    (hyperparameters are runtime args; same-shape packs share executables).
    ``host_class`` is the hardware class tag of the host the pack ran on
    ("" = unclassed / homogeneous fleet): the same shape on a different
    hardware generation is a different measurement. The degree stays at
    index 4 — :meth:`ObservationStore.update` keys its ratio ladder on it."""
    return (
        model_name,
        len(configs),
        CostModel.bucket_rank(configs) if configs else 0,
        sum(c.batch_size for c in configs),
        d,
        seq,
        host_class,
    )


class ObservationStore:
    """Thread-safe (key -> EWMA iter-time) store with prediction-error ratios.

    Besides the per-key EWMAs it maintains per-degree and global EWMAs of
    ``measured / prior_predicted`` — the calibration ratios the profiled
    estimator uses to price configurations it has never run (the planner
    constantly asks about packs/degrees that differ from what executed)."""

    def __init__(self, alpha: float = DEFAULT_ALPHA):
        self.alpha = alpha
        self._obs: Dict[Tuple, Observation] = {}
        self._ratio_by_degree: Dict[int, Observation] = {}
        # heterogeneous fleets: calibration per host class, most-specific
        # first — (class, degree) then class-wide. The class-blind ratios
        # above still see every observation, so a homogeneous run ("" class
        # everywhere) behaves exactly as before.
        self._ratio_by_class: Dict[Tuple[str, int], Observation] = {}
        self._ratio_class_any: Dict[str, Observation] = {}
        self._ratio: Optional[Observation] = None
        self._lock = threading.Lock()

    @staticmethod
    def _bump(table: Dict, key, r: float, alpha: float) -> None:
        hit = table.get(key)
        if hit is None:
            table[key] = Observation(r)
        else:
            hit.update(r, alpha)

    # ---------------- updates / queries ----------------

    def update(self, key: Tuple, measured: float, predicted_prior: float) -> None:
        with self._lock:
            hit = self._obs.get(key)
            if hit is None:
                self._obs[key] = Observation(measured)
            else:
                hit.update(measured, self.alpha)
            if predicted_prior > 0.0:
                r = measured / predicted_prior
                d = int(key[4])
                self._bump(self._ratio_by_degree, d, r, self.alpha)
                cls = str(key[6]) if len(key) > 6 else ""
                if cls:
                    self._bump(self._ratio_by_class, (cls, d), r, self.alpha)
                    self._bump(self._ratio_class_any, cls, r, self.alpha)
                if self._ratio is None:
                    self._ratio = Observation(r)
                else:
                    self._ratio.update(r, self.alpha)

    def get(self, key: Tuple) -> Optional[Observation]:
        with self._lock:
            return self._obs.get(key)

    def ratio(self, d: Optional[int] = None) -> Optional[float]:
        """Calibration ratio for degree ``d``, or — with ``d=None`` — the
        global cross-key ratio (diagnostics only; see the module docstring
        on why unseen degrees do NOT inherit it). None before any
        observation at that degree."""
        with self._lock:
            if d is not None:
                rd = self._ratio_by_degree.get(d)
                return rd.ewma if rd is not None else None
            return self._ratio.ewma if self._ratio is not None else None

    def class_ratio(
        self, host_class: str, d: Optional[int] = None
    ) -> Optional[float]:
        """Measured slowdown of ``host_class`` vs the prior: the
        per-(class, degree) EWMA when ``d`` was observed on that class, else
        the class-wide EWMA, else None (class never measured)."""
        with self._lock:
            if d is not None:
                rc = self._ratio_by_class.get((host_class, d))
                if rc is not None:
                    return rc.ewma
            ra = self._ratio_class_any.get(host_class)
            return ra.ewma if ra is not None else None

    def __len__(self) -> int:
        with self._lock:
            return len(self._obs)

    @property
    def n_observations(self) -> int:
        with self._lock:
            return sum(o.n for o in self._obs.values())

    # ---------------- persistence ----------------

    def to_json(self) -> Dict:
        with self._lock:
            return {
                "schema": _SCHEMA,
                "alpha": self.alpha,
                "observations": [
                    {"key": list(k), "ewma": o.ewma, "n": o.n}
                    for k, o in sorted(self._obs.items())
                ],
                "ratio_by_degree": {
                    str(d): {"ewma": o.ewma, "n": o.n}
                    for d, o in sorted(self._ratio_by_degree.items())
                },
                "ratio_by_class": [
                    {"class": c, "degree": d, "ewma": o.ewma, "n": o.n}
                    for (c, d), o in sorted(self._ratio_by_class.items())
                ],
                "ratio_class_any": {
                    c: {"ewma": o.ewma, "n": o.n}
                    for c, o in sorted(self._ratio_class_any.items())
                },
                "ratio": (
                    {"ewma": self._ratio.ewma, "n": self._ratio.n}
                    if self._ratio is not None
                    else None
                ),
            }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)

    @classmethod
    def from_json(cls, blob: Dict) -> "ObservationStore":
        schema = blob.get("schema")
        if schema not in (1, _SCHEMA):
            raise ValueError(f"unknown profile schema {schema!r}")
        store = cls(alpha=float(blob.get("alpha", DEFAULT_ALPHA)))
        for row in blob.get("observations", []):
            key = tuple(row["key"])
            if schema == 1:  # pre-class keys: tag as unclassed
                key = key + ("",)
            store._obs[key] = Observation(float(row["ewma"]), int(row["n"]))
        for d, row in blob.get("ratio_by_degree", {}).items():
            store._ratio_by_degree[int(d)] = Observation(
                float(row["ewma"]), int(row["n"])
            )
        for row in blob.get("ratio_by_class", []):
            store._ratio_by_class[(str(row["class"]), int(row["degree"]))] = (
                Observation(float(row["ewma"]), int(row["n"]))
            )
        for c, row in blob.get("ratio_class_any", {}).items():
            store._ratio_class_any[str(c)] = Observation(
                float(row["ewma"]), int(row["n"])
            )
        if blob.get("ratio") is not None:
            store._ratio = Observation(
                float(blob["ratio"]["ewma"]), int(blob["ratio"]["n"])
            )
        return store

    @classmethod
    def load(cls, path: str) -> "ObservationStore":
        with open(path) as f:
            return cls.from_json(json.load(f))


class ProfiledCostModel(CostEstimator):
    """The analytic prior wrapped with an online observation store.

    Time queries prefer measurements (fallback ladder in the module
    docstring); memory/feasibility queries and every other attribute
    delegate to the prior, so the packing solver's memory accounting is
    identical whether planning runs calibrated or not — only *durations*
    adapt. ``virtual_model()`` returns the pure prior for simulation."""

    def __init__(
        self,
        prior: CostModel,
        store: Optional[ObservationStore] = None,
        *,
        drift_threshold: float = DEFAULT_DRIFT_THRESHOLD,
    ):
        self.prior = prior
        self.store = store if store is not None else ObservationStore()
        self.drift_threshold = drift_threshold

    def __getattr__(self, name):
        # memory model, hardware spec, setup_time, calibrate, ... — anything
        # not overridden here is the prior's business. (Guard 'prior' itself:
        # attribute lookup during unpickling/copy runs before __init__.)
        if name == "prior":
            raise AttributeError(name)
        return getattr(self.prior, name)

    # the engine passes host_class= to time/feedback queries only when the
    # estimator advertises it (plain CostModels stay class-blind)
    class_aware = True

    def key(
        self, configs: Sequence[LoraConfig], d: int, seq: int,
        host_class: str = "",
    ) -> Tuple:
        return obs_key(self.prior.cfg.name, configs, d, seq, host_class)

    # ---------------- time ----------------

    def iter_time(
        self, configs: Sequence[LoraConfig], d: int, seq: int,
        host_class: str = "",
    ) -> float:
        """Fallback ladder (module docstring), extended per host class:
        exact key (with class) -> that class's measured ratio (per-degree,
        then class-wide) -> the class-blind per-degree ratio -> prior."""
        obs = self.store.get(self.key(configs, d, seq, host_class))
        if obs is not None:
            return obs.ewma
        prior_t = self.prior.iter_time(configs, d, seq)
        if host_class:
            cr = self.store.class_ratio(host_class, d)
            if cr is not None:
                return prior_t * cr
        ratio = self.store.ratio(d)
        return prior_t if ratio is None else prior_t * ratio

    def class_ratio(self, host_class: str, d: Optional[int] = None) -> float:
        """Measured slowdown of a host class vs the prior (1.0 when the
        class is unmeasured or unclassed) — the engine's placement ranking."""
        if not host_class:
            return 1.0
        r = self.store.class_ratio(host_class, d)
        return 1.0 if r is None else r

    # ---------------- memory (always the prior) ----------------

    def fits(self, configs: Sequence[LoraConfig], d: int, seq: int) -> bool:
        return self.prior.fits(configs, d, seq)

    def min_degree(self, configs: Sequence[LoraConfig], seq: int) -> Optional[int]:
        return self.prior.min_degree(configs, seq)

    # ---------------- feedback ----------------

    def observe(
        self,
        configs: Sequence[LoraConfig],
        d: int,
        seq: int,
        measured_iter_time: float,
        host_class: str = "",
    ) -> None:
        self.store.update(
            self.key(configs, d, seq, host_class),
            measured_iter_time,
            self.prior.iter_time(configs, d, seq),
        )

    def observed(
        self, configs: Sequence[LoraConfig], d: int, seq: int,
        host_class: str = "",
    ) -> bool:
        return self.store.get(self.key(configs, d, seq, host_class)) is not None

    def drift(
        self,
        configs: Sequence[LoraConfig],
        d: int,
        seq: int,
        measured_iter_time: float,
        host_class: str = "",
    ) -> float:
        """Signed relative error of the *current* prediction against a fresh
        measurement: ``measured / predicted - 1``. Positive = the job runs
        slower than planned (starved / oversubscribed); negative = faster
        (over-provisioned)."""
        pred = self.iter_time(configs, d, seq, host_class)
        if pred <= 0.0:
            return 0.0
        return measured_iter_time / pred - 1.0

    # ---------------- simulation contract ----------------

    @property
    def adaptive(self) -> bool:
        return True

    def virtual_model(self) -> CostModel:
        return self.prior

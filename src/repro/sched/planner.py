"""The Job Planner — Algorithm 2 — plus baselines and the Thm 6.1 AR bound.

Greedy event loop: whenever device units are free, run DTM on the remaining
configs, enqueue the returned concurrent jobs, then advance simulated time to
the next completion. Produces the LoRA Job Queue consumed by the execution
engine, a full (start, end, devices) timeline, the makespan, and the
approximation-ratio bound AR <= F / (F - T_last * (G - D)/G).

Baselines (paper §7.1): Min GPU (each config alone on the smallest degree
that fits, list-scheduled) and Max GPU (each config alone on all G units,
sequential).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.configs.base import LoraConfig
from repro.sched.cost_model import CostEstimator, CostModel
from repro.sched.dtm import DTMResult, JobPlan, dtm


@dataclass
class ScheduledJob:
    config_ids: Tuple[int, ...]
    degree: int
    start: float
    end: float
    throughput: float = 0.0

    @property
    def duration(self):
        return self.end - self.start


@dataclass
class Schedule:
    jobs: List[ScheduledJob]
    makespan: float
    g: int
    n_f_calls: int = 0

    def ar_bound(self) -> float:
        """Theorem 6.1: AR <= F / (F - T_last * (G-D)/G)."""
        if not self.jobs:
            return 1.0
        last = max(self.jobs, key=lambda j: j.end)
        f = self.makespan
        denom = f - last.duration * (self.g - last.degree) / self.g
        return f / max(denom, 1e-12)

    def total_device_seconds(self) -> float:
        return sum(j.duration * j.degree for j in self.jobs)

    def ar_bound_work(self) -> float:
        """Work-conservation bound: OPT >= max(W/G, longest job), so
        AR <= F / that. Tighter than Thm 6.1 for single-wave schedules."""
        if not self.jobs:
            return 1.0
        lb = max(
            self.total_device_seconds() / self.g,
            max(j.duration for j in self.jobs),
        )
        return self.makespan / max(lb, 1e-12)

    def ar(self) -> float:
        return min(self.ar_bound(), self.ar_bound_work())


def replan(
    cm: CostEstimator,
    configs: Sequence[LoraConfig],
    free: int,
    seq: int,
    n_steps: int,
    *,
    residual_steps: Optional[Sequence[int]] = None,
    max_policies: int = 4096,
    max_degree: Optional[int] = None,
) -> DTMResult:
    """Incremental replanning API (online engine hook): one DTM invocation
    over the *currently pending* configs and the *currently free* device
    units. The event-driven engine calls this on every admission and
    device-free event instead of draining a frozen queue; ``residual_steps``
    carries the remaining iteration counts of adapters preempted out of
    running jobs (paper §4 dynamic task migration). ``max_degree`` caps a
    single job's parallelism (multi-host engines pass the per-host device
    count: a mesh slice cannot span hosts)."""
    return dtm(
        cm,
        configs,
        free,
        seq,
        n_steps,
        residual_steps=residual_steps,
        max_policies=max_policies,
        max_degree=max_degree,
    )


def plan(
    cm: CostEstimator,
    configs: Sequence[LoraConfig],
    g: int,
    seq: int,
    n_steps: int,
    *,
    max_degree: Optional[int] = None,
) -> Schedule:
    """Algorithm 2: the offline special case of online replanning — every
    config is known at t=0, so the loop below is exactly `replan` on each
    device-free event over the not-yet-started remainder."""
    remaining = set(range(len(configs)))
    free = g
    t = 0.0
    running: List[Tuple[float, int]] = []  # (end_time, degree)
    out: List[ScheduledJob] = []
    n_calls = 0
    while remaining or running:
        launched = False
        if remaining and free > 0:
            res: DTMResult = replan(
                cm, [configs[i] for i in sorted(remaining)], free, seq,
                n_steps, max_degree=max_degree,
            )
            n_calls += res.n_f_calls
            idx_map = sorted(remaining)
            for j in res.jobs:
                ids = tuple(idx_map[i] for i in j.config_ids)
                out.append(
                    ScheduledJob(ids, j.degree, t, t + j.est_time, j.throughput)
                )
                heapq.heappush(running, (t + j.est_time, j.degree))
                free -= j.degree
                remaining -= set(ids)
                launched = True
        if not launched or not remaining:
            if not running:
                break
            end, d = heapq.heappop(running)
            t = end
            free += d
            # release every job ending at the same instant
            while running and running[0][0] <= t + 1e-12:
                _, d2 = heapq.heappop(running)
                free += d2
    makespan = max((j.end for j in out), default=0.0)
    return Schedule(out, makespan, g, n_calls)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------


def _list_schedule(durations_degrees, g) -> Schedule:
    """Greedy list scheduling of (duration, degree) single-config jobs."""
    free = g
    t = 0.0
    running: List[Tuple[float, int]] = []
    out: List[ScheduledJob] = []
    pending = list(enumerate(durations_degrees))
    while pending or running:
        launched = False
        for item in list(pending):
            i, (dur, d) = item
            if d <= free:
                out.append(ScheduledJob((i,), d, t, t + dur))
                heapq.heappush(running, (t + dur, d))
                free -= d
                pending.remove(item)
                launched = True
        if not launched:
            if not running:
                break
            end, d = heapq.heappop(running)
            t, free = end, free + d
            while running and running[0][0] <= t + 1e-12:
                _, d2 = heapq.heappop(running)
                free += d2
    return Schedule(out, max((j.end for j in out), default=0.0), g)


def min_gpu_schedule(
    cm: CostEstimator, configs: Sequence[LoraConfig], g: int, seq: int, n_steps: int
) -> Schedule:
    jobs = []
    for c in configs:
        d = cm.min_degree([c], seq)
        if d is None:
            raise ValueError(f"config {c} does not fit on {g} units")
        jobs.append((cm.job_time([c], d, seq, n_steps), d))
    return _list_schedule(jobs, g)


def max_gpu_schedule(
    cm: CostEstimator, configs: Sequence[LoraConfig], g: int, seq: int, n_steps: int
) -> Schedule:
    jobs = [(cm.job_time([c], g, seq, n_steps), g) for c in configs]
    return _list_schedule(jobs, g)


class _SequentialCostModel(CostModel):
    """Cost model whose packed jobs run adapters sequentially (paper §5.1
    naive execution: batched base pass + per-adapter LoRA kernel loop)."""

    def iter_time(self, configs, d, seq):
        return CostModel.iter_time_sequential(self, configs, d, seq)


def sequential_plora_schedule(
    cm: CostModel, configs: Sequence[LoraConfig], g: int, seq: int, n_steps: int
) -> Schedule:
    """Ablation (paper Fig. 6 'Sequential PLoRA'): PLoRA's planner, but jobs
    execute adapters one at a time (no packed kernels). The planner re-plans
    under the sequential iteration cost, so pack sizes shrink to what the
    naive execution can still amortize (base-pass sharing + setup)."""
    import dataclasses as _dc

    cms = _SequentialCostModel(**{f.name: getattr(cm, f.name) for f in _dc.fields(cm)})
    return plan(cms, configs, g, seq, n_steps)

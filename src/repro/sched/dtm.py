"""Decomposed Throughput Maximization — Algorithm 1 of the paper.

DTMHelper enumerates power-of-2 parallelism degrees (largest-first), calls the
packing solver F(d, K) per degree, and recurses on the remaining devices and
configs; DTM returns the policy with the best objective among all collected
policies. F-calls are memoized on (d, remaining-config ids) — the paper's
"286 ILP calls for 8 GPUs" collapses the same way.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.configs.base import LoraConfig
from repro.sched.cost_model import CostEstimator
from repro.sched.knapsack import solve_pack


@dataclass(frozen=True)
class JobPlan:
    """One packed fine-tuning job: configs (by index), parallelism, est time."""

    config_ids: Tuple[int, ...]
    degree: int
    est_time: float  # seconds for n_steps
    throughput: float  # sum(rank)/iter_time


@dataclass
class DTMResult:
    jobs: List[JobPlan]
    n_f_calls: int


def dtm(
    cm: CostEstimator,
    configs: Sequence[LoraConfig],
    g: int,
    seq: int,
    n_steps: int,
    *,
    residual_steps: Optional[Sequence[int]] = None,
    max_policies: int = 4096,
    max_degree: Optional[int] = None,
) -> DTMResult:
    """Best set of concurrent jobs for `g` free device units.

    ``residual_steps`` (online engine) gives each config its own remaining
    iteration count — adapters resumed after a preemption need fewer steps
    than fresh arrivals. A packed job's est_time is then
    ``cm.job_time_residual`` (setup + max residual * iter_time). ``None``
    means every config runs the uniform ``n_steps``.

    ``max_degree`` caps the parallelism degree of any single job — the
    multi-host engine passes its per-host device count here, because a
    packed job's mesh slice cannot span hosts even when the *total* free
    unit count is larger.
    """
    all_ids = frozenset(range(len(configs)))
    steps = (
        list(residual_steps)
        if residual_steps is not None
        else [n_steps] * len(configs)
    )
    assert len(steps) == len(configs)
    f_cache: Dict[Tuple[int, FrozenSet[int]], Optional[Tuple[Tuple[int, ...], float]]] = {}
    n_calls = [0]
    policies: List[List[JobPlan]] = []
    seen_states = set()

    total_work = sum(c.rank * c.batch_size for c in configs)

    def f(d: int, ids: FrozenSet[int], g_rem: int):
        key = (d, ids)
        if key not in f_cache:
            n_calls[0] += 1
            sub = sorted(ids)
            # balance hint: a d-unit job should absorb ~its device share of
            # the remaining work, or the final wave leaves a long tail
            # (the Thm 6.1 bubble). 1.25x headroom for granularity.
            work_rem = sum(configs[i].rank * configs[i].batch_size for i in sub)
            cap = 1.25 * work_rem * d / max(g_rem, 1)
            res = solve_pack(
                cm, [configs[i] for i in sub], d, seq, work_cap=cap
            )
            if res is None:
                f_cache[key] = None
            else:
                chosen_local, _ = res
                chosen = tuple(sub[i] for i in chosen_local)
                sel = [configs[i] for i in chosen]
                thr = cm.throughput(sel, d, seq)
                t = cm.job_time_residual(sel, [steps[i] for i in chosen], d, seq)
                f_cache[key] = (chosen, (thr, t))
        return f_cache[key]

    def helper(g_rem: int, acc: List[JobPlan], ids: FrozenSet[int]):
        if len(policies) >= max_policies:
            return
        state = (g_rem, ids, tuple(sorted((j.config_ids, j.degree) for j in acc)))
        if state in seen_states:
            return
        seen_states.add(state)
        if g_rem <= 0 or not ids:
            policies.append(list(acc))
            return
        gp = 1 << (g_rem.bit_length() - 1)  # round down to power of 2
        if max_degree is not None:
            gp = min(gp, 1 << (max_degree.bit_length() - 1))
        d = gp
        expanded = False
        while d >= 1:
            res = f(d, ids, g_rem)
            if res is not None:
                chosen, (thr, t) = res
                job = JobPlan(chosen, d, t, thr)
                helper(g_rem - d, acc + [job], ids - set(chosen))
                expanded = True
            d //= 2
        if not expanded:
            policies.append(list(acc))

    helper(g, [], all_ids)
    if not policies:
        return DTMResult([], n_calls[0])

    n_total = len(configs)

    def score(p: List[JobPlan]):
        # Paper Alg. 1 line 11: argmin T(p). When a policy schedules every
        # remaining config, T(p) is the wave makespan — minimize it (this is
        # what keeps the Thm 6.1 tail small). Otherwise rank by instantaneous
        # throughput (Eq 13), the streaming-optimal criterion.
        #
        # Online-aware tie-break: among otherwise-equal policies prefer the
        # one holding fewer busy device-seconds (shorter jobs first) — its
        # devices free *earlier*, so the engine's next repack-on-free event
        # comes sooner and late arrivals wait less. Offline this is a pure
        # tie-break (primary keys unchanged); online it is what lets
        # repack-on-free win on more traces.
        covered = sum(len(j.config_ids) for j in p)
        dev_seconds = sum(j.est_time * j.degree for j in p)
        if covered == n_total and p:
            return (
                0,
                max(j.est_time for j in p),
                dev_seconds,
                -sum(j.throughput for j in p),
            )
        return (1, -sum(j.throughput for j in p), -covered, dev_seconds)

    best = min(policies, key=score)
    if best and sum(len(j.config_ids) for j in best) == n_total:
        best = _rebalance(cm, configs, best, seq, steps)
    return DTMResult(best, n_calls[0])


def _rebalance(
    cm: CostEstimator,
    configs: Sequence[LoraConfig],
    jobs: List[JobPlan],
    seq: int,
    steps: Sequence[int],
) -> List[JobPlan]:
    """LPT rebalance of a covering wave: keep each job's parallelism degree,
    reassign configs (largest marginal time first) to the job that minimizes
    the running max — this is what makes argmin T(p) (Alg. 1 line 11) tight
    and keeps the Thm 6.1 tail at the ~1.1x the paper reports. The LPT loads
    balance per-iteration time; heterogeneous residual step counts only enter
    the final est_time (a residual-weighted LPT would need per-pair
    max-coupling and buys little at wave granularity)."""
    ids = sorted({i for j in jobs for i in j.config_ids})
    degrees = [j.degree for j in jobs]
    t0 = {d: cm.iter_time([], d, seq) for d in set(degrees)}
    marg = {
        (i, d): max(cm.iter_time([configs[i]], d, seq) - t0[d], 1e-9)
        for i in ids
        for d in set(degrees)
    }
    loads = [t0[d] for d in degrees]
    assign: List[List[int]] = [[] for _ in jobs]
    order = sorted(ids, key=lambda i: -marg[(i, degrees[0])])
    for i in order:
        cand = sorted(range(len(jobs)), key=lambda j: loads[j] + marg[(i, degrees[j])])
        placed = False
        for j in cand:
            sel = [configs[k] for k in assign[j] + [i]]
            if cm.fits(sel, degrees[j], seq):
                assign[j].append(i)
                loads[j] += marg[(i, degrees[j])]
                placed = True
                break
        if not placed:  # memory-tight: leave with the original owner
            owner = next(k for k, jb in enumerate(jobs) if i in jb.config_ids)
            assign[owner].append(i)
            loads[owner] += marg[(i, degrees[owner])]
    out = []
    for j, jb in enumerate(jobs):
        if not assign[j]:
            continue
        sel = [configs[k] for k in assign[j]]
        out.append(
            JobPlan(
                tuple(assign[j]),
                jb.degree,
                cm.job_time_residual(
                    sel, [steps[k] for k in assign[j]], jb.degree, seq
                ),
                cm.throughput(sel, jb.degree, seq),
            )
        )
    # rebalance must not beat memory: fall back if anything went infeasible
    for jp in out:
        if not cm.fits([configs[k] for k in jp.config_ids], jp.degree, seq):
            return jobs
    return out

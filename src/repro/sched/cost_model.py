"""Cost model for packed LoRA fine-tuning jobs (paper §4 + Appendix A).

Memory follows Appendix A exactly: base weights + base activations (on the
max packed batch) + per-adapter params/grads/optimizer-moments/activations,
all divided by the parallelism degree d (TP sharding); a user load factor C
guards fragmentation.

Time is a three-term roofline per iteration — compute, HBM, interconnect —
so the paper's core observation (tiny batches underutilize hardware; packing
raises throughput at nearly constant cost) *emerges* from the model instead
of being hard-coded: at bs=1 the weight-traffic term dominates and packing
more adapters is almost free until the compute term takes over.

``calibrate`` fits a single efficiency scalar from a few profiled iterations
(the paper profiles 10 iterations on the testbed).

The estimation layer is pluggable: every consumer (DTM, knapsack, planner,
engine, cluster runner) programs against :class:`CostEstimator`; the analytic
roofline :class:`CostModel` below is the *prior* implementation, and
:class:`repro.sched.profile.ProfiledCostModel` layers measured segment
timings on top of it for real execution.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

from repro.configs.base import LoraConfig, ModelConfig


class CostEstimator:
    """Interface of the estimation layer (tentpole of the profile feedback
    loop): what the packing solver, DTM, planner, and execution engine are
    allowed to ask about a candidate packed job.

    Subclasses provide the three core queries — per-iteration time, memory
    feasibility, minimum degree — plus a ``setup_time`` attribute; the
    job-level queries below derive from those, so a subclass that changes
    ``iter_time`` (e.g. by consulting measured timings) automatically
    re-prices every downstream planning decision.

    The analytic :class:`CostModel` is the pure *prior*: deterministic,
    state-free, used by the virtual-clock simulator. The profiled layer
    (:class:`repro.sched.profile.ProfiledCostModel`) additionally implements
    the measurement-feedback hooks (``observe``/``observed``) and reports
    ``adaptive = True``, which switches the engine's real execution path to
    re-plan on live device-free events.
    """

    # ---------------- core queries (subclass responsibility) ----------------

    def iter_time(self, configs: Sequence[LoraConfig], d: int, seq: int) -> float:
        """Seconds per packed training iteration on ``d`` device units."""
        raise NotImplementedError

    def fits(self, configs: Sequence[LoraConfig], d: int, seq: int) -> bool:
        raise NotImplementedError

    def min_degree(self, configs: Sequence[LoraConfig], seq: int) -> Optional[int]:
        raise NotImplementedError

    # ---------------- derived job-level queries ----------------

    def job_time(
        self, configs: Sequence[LoraConfig], d: int, seq: int, n_steps: int
    ) -> float:
        return self.job_time_residual(configs, [n_steps] * len(configs), d, seq)

    def job_time_residual(
        self,
        configs: Sequence[LoraConfig],
        steps: Sequence[int],
        d: int,
        seq: int,
    ) -> float:
        """Per-job residual-step cost query (online engine): adapters resumed
        from a preempted job carry fewer remaining steps than fresh arrivals,
        and a packed job holds its devices until its longest-residual adapter
        finishes. ``steps[i]`` is the remaining iteration count of
        ``configs[i]``; the job pays setup once plus ``max(steps)``
        packed iterations."""
        if not configs:
            return self.setup_time
        return self.setup_time + max(steps) * self.iter_time(configs, d, seq)

    def adapter_finish_offset(
        self, configs: Sequence[LoraConfig], steps: int, d: int, seq: int
    ) -> float:
        """Seconds from job launch until an adapter with ``steps`` residual
        iterations is done training (it may ride along until the pack's
        longest adapter finishes, but its own weights stop changing here)."""
        return self.setup_time + steps * self.iter_time(configs, d, seq)

    def throughput(self, configs: Sequence[LoraConfig], d: int, seq: int) -> float:
        """Paper Eq (13): LoRA FLOP per unit time. LoRA FLOP is linear in
        rank (§2.1) and, with heterogeneous batch sizes, in rank * batch."""
        return sum(c.rank * c.batch_size for c in configs) / self.iter_time(
            configs, d, seq
        )

    # ---------------- measurement feedback (no-op for pure priors) ----------

    def observe(
        self,
        configs: Sequence[LoraConfig],
        d: int,
        seq: int,
        measured_iter_time: float,
    ) -> None:
        """Feed one measured per-iteration wall time back into the estimator.
        The analytic prior ignores it; the profiled layer folds it into its
        observation store."""

    def observed(self, configs: Sequence[LoraConfig], d: int, seq: int) -> bool:
        """Whether this exact (pack shape, degree, seq) has been measured."""
        return False

    # ---------------- heterogeneous fleets (class-blind by default) ---------

    #: estimators that price per host class (extra ``host_class=`` kwarg on
    #: iter_time/observe/observed/drift) advertise True; the engine only
    #: passes class tags when this is set
    class_aware = False

    def class_ratio(self, host_class: str, d: Optional[int] = None) -> float:
        """Measured slowdown of a host class vs this estimator's baseline
        (1.0 = unknown/identical) — placement ranking for heterogeneous
        fleets. Pure priors have no measurements: always 1.0."""
        return 1.0

    # ---------------- simulation contract ----------------

    @property
    def adaptive(self) -> bool:
        """True when real execution should re-plan against live measurements."""
        return False

    def virtual_model(self) -> "CostEstimator":
        """The pure prior used by the virtual-clock simulator — simulation
        must stay deterministic and independent of any measurement state."""
        return self


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    mem_bytes: float  # per device unit
    peak_flops: float  # per device unit (bf16)
    hbm_bw: float  # bytes/s per device unit
    link_bw: float  # bytes/s per link (TP collective)
    n_devices: int = 8
    efficiency: float = 0.5  # asymptotic fraction of peak in large GEMMs
    # tokens-per-device at which GEMM efficiency reaches half its asymptote —
    # THE paper effect: tiny per-device batches run far below peak (SM
    # occupancy 16.7%, §3.1), so adding packed adapters is nearly free until
    # the device saturates. eff(tpd) = efficiency * tpd / (tpd + sat_tokens).
    sat_tokens: float = 600.0
    # per-layer fixed overhead per iteration (kernel launch / dispatch /
    # framework); not divided by the parallelism degree. Calibrated so a
    # bs=1 short-seq iteration is overhead-dominated (paper §5.1: iteration
    # time grows only ~10% from bs 1 -> 8 on GLUE-scale sequences).
    layer_overhead: float = 12.5e-3
    # extra per-adapter per-iteration cost of the NAIVE sequential adapter
    # loop (paper §5.1: packing 8 adapters naively is 3.6x slower than one
    # adapter — small launches + low arithmetic intensity). PLoRA's packed
    # kernels eliminate this term.
    seq_adapter_overhead: float = 0.14

    def eff(self, tokens_per_device: float) -> float:
        t = max(tokens_per_device, 1.0)
        return self.efficiency * t / (t + self.sat_tokens)

    def scaled(self, **kw) -> "HardwareSpec":
        import dataclasses

        return dataclasses.replace(self, **kw)


# Presets: the paper's testbeds + our target. sat_tokens/layer_overhead are
# fitted to the paper's §5.1 anchors (see EXPERIMENTS.md §Calibration).
A100_40G = HardwareSpec("a100-40g", 40e9, 312e12, 2.0e12, 300e9, 8,
                        sat_tokens=600.0, layer_overhead=12.5e-3,
                        seq_adapter_overhead=0.14)
A10_24G = HardwareSpec("a10-24g", 24e9, 125e12, 0.6e12, 32e9, 8,
                       sat_tokens=300.0, layer_overhead=18e-3,
                       seq_adapter_overhead=0.2)
TPU_V5E = HardwareSpec("tpu-v5e", 16e9, 197e12, 819e9, 50e9, 256,
                       sat_tokens=1_500.0, layer_overhead=0.2e-3,
                       seq_adapter_overhead=0.01)


def model_param_count(cfg: ModelConfig) -> float:
    """Total parameters (embeddings + stack), honest per-family accounting."""
    a = cfg.attention
    d = cfg.d_model
    total = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    for mixer, ffn in zip(cfg.layer_kinds(), cfg.ffn_kinds()):
        if mixer == "attn":
            if a.is_mla:
                qk = a.qk_nope_head_dim + a.qk_rope_head_dim
                total += d * a.q_lora_rank + a.q_lora_rank * a.n_heads * qk
                total += d * (a.kv_lora_rank + a.qk_rope_head_dim)
                total += a.kv_lora_rank * a.n_heads * (a.qk_nope_head_dim + a.v_head_dim)
                total += a.n_heads * a.v_head_dim * d
            else:
                hd = a.head_dim
                total += d * hd * (a.n_heads + 2 * a.n_kv_heads) + a.n_heads * hd * d
        else:
            di = cfg.ssm.d_inner(d)
            n = cfg.ssm.d_state
            total += d * 2 * di + d * 2 * n + d * cfg.ssm.n_heads(d) + di * d
        if ffn == "dense":
            mats = 2 if cfg.mlp_kind == "gelu2" else 3
            total += mats * d * cfg.d_ff
        elif ffn == "moe":
            total += cfg.moe.n_experts * 3 * d * cfg.moe.d_expert + d * cfg.moe.n_experts
    if cfg.is_encdec:
        mats = 2 if cfg.mlp_kind == "gelu2" else 3
        per_enc = d * a.head_dim * (a.n_heads + 2 * a.n_kv_heads) + a.n_heads * a.head_dim * d
        per_enc += mats * d * cfg.d_ff
        # decoder cross-attention blocks
        total += cfg.encoder_layers * per_enc
        total += cfg.n_layers * (d * a.head_dim * (a.n_heads + 2 * a.n_kv_heads) + a.n_heads * a.head_dim * d)
    return float(total)


def active_param_count(cfg: ModelConfig) -> float:
    """Parameters touched per token (MoE: top_k of E experts)."""
    total = model_param_count(cfg)
    if cfg.moe.enabled:
        moe_layers = sum(1 for f in cfg.ffn_kinds() if f == "moe")
        expert_params = moe_layers * 3 * cfg.d_model * cfg.moe.d_expert
        total -= expert_params * (cfg.moe.n_experts - cfg.moe.top_k)
    return float(total)


def lora_param_count(cfg: ModelConfig, rank: int) -> float:
    """Packed-LoRA params for one adapter over cfg.lora_targets."""
    a, d = cfg.attention, cfg.d_model
    shapes = {
        "q": (d, a.n_heads * a.head_dim),
        "k": (d, a.n_kv_heads * a.head_dim),
        "v": (d, a.n_kv_heads * a.head_dim),
        "o": (a.n_heads * a.head_dim, d),
        "gate": (d, cfg.d_ff),
        "up": (d, cfg.d_ff),
        "down": (cfg.d_ff, d),
        "kv": (d, a.kv_lora_rank + a.qk_rope_head_dim),
        "ssm_in": (d, 2 * cfg.ssm.d_inner(d)),
        "ssm_out": (cfg.ssm.d_inner(d), d),
    }
    if a.is_mla:
        shapes["q"] = (d, a.q_lora_rank)
    per_layer = 0.0
    for t in cfg.lora_targets:
        if t in shapes:
            din, dout = shapes[t]
            per_layer += rank * (din + dout)
    n_layers = cfg.n_layers + cfg.encoder_layers
    return float(per_layer * n_layers)


@dataclass
class CostModel(CostEstimator):
    cfg: ModelConfig
    hw: HardwareSpec
    prec_bytes: int = 2  # bf16 training
    opt_factor: float = 3.0  # AdamW: grads + 2 moments (paper's c_grad)
    act_factor: float = 12.0  # activation bytes per (token x d_model), no remat
    load_factor: float = 0.9  # paper's C
    calib: float = 1.0  # fitted efficiency scalar
    # fixed per-adapter memory overhead (optimizer workspace, allocator
    # fragmentation, autograd bookkeeping). Fitted to the paper's §3.2 anchor:
    # +2.2 GB for the second adapter on Qwen-2.5-7B/A100-40G, "up to 10
    # concurrent adapters without OOM".
    adapter_overhead_bytes: float = 1.0e9
    # Padding-aware costing (beyond-paper, DESIGN.md §9): the packed executor
    # zero-pads every adapter to the pack's bucket rank (max rank rounded up
    # to 8), so a rank-8 adapter packed with a rank-128 one COMPUTES at rank
    # 128. With this flag the cost model charges the bucket rank, which makes
    # the DTM packer prefer rank-homogeneous packs. False = the paper's
    # padding-naive model (each adapter billed at its own rank).
    pad_aware: bool = True
    # Ragged-kernel accounting (kernels/ops.py rank segments): the kernels
    # group same-rank adapters into grid segments and compute each adapter at
    # its OWN rank (8-aligned), so mixed-rank packs stop paying bucket-
    # padding FLOPs. The autotuner's ``KernelProfile.calibrate`` sets this —
    # it supersedes pad_aware for the *time* model (memory stays bucketed:
    # the pack still allocates padded weights).
    ragged: bool = False
    # Measured LoRA-kernel rate scale (autotune feedback): the fused
    # base+delta megakernel's measured speedup over the two-pass formulation
    # on this backend. The LoRA compute term is divided by it — 1.0 = the
    # uncalibrated analytic prior (bit-identical to the pre-autotune model).
    lora_rate_scale: float = 1.0
    # Frozen-base storage scheme (kernels/quant.py): None keeps the dense
    # ``prec_bytes`` footprint (bit-identical to the pre-quant model);
    # "int8"/"nf4" shrink the base-weight term of the Appendix-A memory
    # model — and the HBM weight-traffic term of the roofline — to the
    # quantized bytes/param, which is what lets the knapsack packer put
    # more packs on a device (the planner-shift this tier claims).
    base_dtype: Optional[str] = None

    @staticmethod
    def bucket_rank(configs: Sequence[LoraConfig]) -> int:
        r = max((c.rank for c in configs), default=8)
        return max(8, (r + 7) // 8 * 8)

    def _eff_rank(self, c: LoraConfig, configs: Sequence[LoraConfig]) -> int:
        if self.ragged:
            return max(8, (c.rank + 7) // 8 * 8)
        return self.bucket_rank(configs) if self.pad_aware else c.rank

    # ---------------- memory (Appendix A) ----------------

    def base_bytes_per_param(self) -> float:
        """Resident bytes per frozen-base parameter under ``base_dtype``.

        Quantized schemes include the amortized f32 scale overhead: int8
        carries one scale per output channel (~1/256 of params on typical
        d_in >= 256 projections), nf4 one scale per 64-element block. The
        analytic constants are deliberately slightly conservative; the
        measured ratio on real quantized trees is what ``bench_quant``
        reports against the paper-claim threshold."""
        if self.base_dtype in (None, "f32", "bf16"):
            return float(self.prec_bytes)
        if self.base_dtype == "int8":
            return 1.0 + 4.0 / 256.0
        if self.base_dtype == "nf4":
            return 0.5 + 4.0 / 64.0
        raise ValueError(f"unknown base_dtype {self.base_dtype!r}")

    def base_weight_bytes(self) -> float:
        return model_param_count(self.cfg) * self.base_bytes_per_param()

    def base_act_bytes(self, total_batch: int, seq: int) -> float:
        return (
            self.act_factor * total_batch * seq * self.cfg.d_model * self.prec_bytes
        )

    def lora_bytes(self, c: LoraConfig, seq: Optional[int] = None) -> float:
        seq = seq or c.seq_len
        p = lora_param_count(self.cfg, c.rank) * self.prec_bytes
        grads_opt = self.opt_factor * p
        act = c.batch_size * seq * c.rank * self.prec_bytes * (
            self.cfg.n_layers + self.cfg.encoder_layers
        )
        return p + grads_opt + act + self.adapter_overhead_bytes

    def job_mem_bytes(self, configs: Sequence[LoraConfig], d: int, seq: int) -> float:
        total_batch = sum(c.batch_size for c in configs)
        base = self.base_weight_bytes() + self.base_act_bytes(total_batch, seq)
        if self.pad_aware:
            import dataclasses as _dc

            rb = self.bucket_rank(configs)
            loras = sum(
                self.lora_bytes(_dc.replace(c, rank=rb), seq) for c in configs
            )
        else:
            loras = sum(self.lora_bytes(c, seq) for c in configs)
        return (base + loras) / d

    def fits(self, configs: Sequence[LoraConfig], d: int, seq: int) -> bool:
        return self.job_mem_bytes(configs, d, seq) <= (
            self.load_factor * self.hw.mem_bytes
        )

    def min_degree(self, configs: Sequence[LoraConfig], seq: int) -> Optional[int]:
        d = 1
        while d <= self.hw.n_devices:
            if self.fits(configs, d, seq):
                return d
            d *= 2
        return None

    # ---------------- time (three-term roofline) ----------------

    def iter_time(self, configs: Sequence[LoraConfig], d: int, seq: int) -> float:
        """Seconds per packed training iteration on d device units."""
        tokens = sum(c.batch_size for c in configs) * seq
        n_active = active_param_count(self.cfg)
        # frozen base: fwd 2ND + act-grad bwd 2ND = 4ND
        base_flops = 4.0 * n_active * tokens
        # padding-aware: each adapter computes at the pack's bucket rank
        lora_flops = sum(
            6.0 * lora_param_count(self.cfg, self._eff_rank(c, configs))
            * c.batch_size * seq
            for c in configs
        )
        # per-device GEMM granularity shrinks with TP degree: tokens don't
        # split under TP but each device's slice of every GEMM does, so the
        # efficiency argument is tokens/d (penalizes Max-GPU, §7.2.1).
        eff = self.hw.eff(tokens / d)
        # lora_rate_scale is the autotuner's measured fused-kernel speedup
        # (1.0 = uncalibrated; division by 1.0 is bit-exact, so the default
        # model is unchanged)
        compute_t = (base_flops + lora_flops / self.lora_rate_scale) / (
            d * self.hw.peak_flops * eff
        )
        # weight traffic: weights read in fwd + bwd; adapters updated
        wbytes = 2.0 * self.base_weight_bytes()
        wbytes += sum(
            (2.0 + 2.0 * self.opt_factor)
            * lora_param_count(self.cfg, c.rank)
            * self.prec_bytes
            for c in configs
        )
        act_bytes = 2.0 * self.base_act_bytes(
            sum(c.batch_size for c in configs), seq
        )
        mem_t = (wbytes + act_bytes) / (d * self.hw.hbm_bw)
        # TP collectives: 2 all-reduces of (tokens, d_model) per layer, ring
        coll_t = 0.0
        if d > 1:
            layer_count = self.cfg.n_layers + self.cfg.encoder_layers
            coll_bytes = (
                4.0  # fwd+bwd, attn+mlp
                * layer_count
                * tokens
                * self.cfg.d_model
                * self.prec_bytes
                * 2.0
                * (d - 1)
                / d
            )
            coll_t = coll_bytes / (d * self.hw.link_bw)
        fixed_t = self.hw.layer_overhead * (
            self.cfg.n_layers + self.cfg.encoder_layers
        )
        return (max(compute_t, mem_t) + coll_t + fixed_t) * self.calib

    def iter_time_sequential(
        self, configs: Sequence[LoraConfig], d: int, seq: int
    ) -> float:
        """Naive packed execution (paper §5.1 / Fig. 6 'Sequential PLoRA'):
        the BASE pass is batched over all adapters' inputs, but each adapter's
        LoRA computation runs as its own small kernel sequence — per-adapter
        launch overhead plus LoRA GEMMs at single-adapter efficiency.
        (Calls CostModel.iter_time explicitly so subclasses that alias
        iter_time -> iter_time_sequential don't recurse.)"""
        t = CostModel.iter_time(self, configs, d, seq)
        for c in configs:
            tokens_k = c.batch_size * seq
            lora_flops = 6.0 * lora_param_count(self.cfg, c.rank) * tokens_k
            t += self.calib * (
                self.hw.seq_adapter_overhead
                + lora_flops / (d * self.hw.peak_flops * self.hw.eff(tokens_k / d))
            )
        return t

    # per-job fixed cost: base-checkpoint load + process/compile warmup.
    # Min-GPU pays it once per CONFIG (120x); packed jobs amortize it —
    # this is the planner-only gain visible in the Fig. 6 ablation.
    setup_time: float = 60.0

    # job_time / job_time_residual / adapter_finish_offset / throughput are
    # inherited from CostEstimator, derived from iter_time + setup_time.

    # ---------------- calibration ----------------

    def calibrate(self, measured_iter_time: float, configs, d: int, seq: int):
        """Fit the time scalar so predicted == measured (one-point fit from
        ~10 profiled iterations, as in the paper)."""
        pred = self.iter_time(configs, d, seq)
        self.calib = self.calib * measured_iter_time / pred
        return self.calib

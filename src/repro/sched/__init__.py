from repro.sched.cost_model import (
    A10_24G,
    A100_40G,
    TPU_V5E,
    CostEstimator,
    CostModel,
    HardwareSpec,
)
from repro.sched.dtm import DTMResult, JobPlan, dtm
from repro.sched.profile import ObservationStore, ProfiledCostModel, obs_key
from repro.sched.engine import (
    Arrival,
    ExecutionEngine,
    JobSegment,
    OnlineSchedule,
    ResourceMonitor,
    poisson_trace,
)
from repro.sched.knapsack import brute_force, solve_pack
from repro.sched.planner import (
    Schedule,
    max_gpu_schedule,
    min_gpu_schedule,
    plan,
    replan,
    sequential_plora_schedule,
)

__all__ = [
    "A10_24G", "A100_40G", "TPU_V5E", "CostEstimator", "CostModel",
    "HardwareSpec", "DTMResult", "JobPlan", "dtm", "Arrival",
    "ExecutionEngine", "JobSegment", "OnlineSchedule", "ResourceMonitor",
    "poisson_trace", "ObservationStore", "ProfiledCostModel", "obs_key",
    "brute_force", "solve_pack", "Schedule", "max_gpu_schedule",
    "min_gpu_schedule", "plan", "replan", "sequential_plora_schedule",
]

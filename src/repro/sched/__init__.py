from repro.sched.cost_model import A10_24G, A100_40G, TPU_V5E, CostModel, HardwareSpec
from repro.sched.dtm import DTMResult, JobPlan, dtm
from repro.sched.engine import (
    Arrival,
    ExecutionEngine,
    JobSegment,
    OnlineSchedule,
    ResourceMonitor,
    poisson_trace,
)
from repro.sched.knapsack import brute_force, solve_pack
from repro.sched.planner import (
    Schedule,
    max_gpu_schedule,
    min_gpu_schedule,
    plan,
    replan,
    sequential_plora_schedule,
)

__all__ = [
    "A10_24G", "A100_40G", "TPU_V5E", "CostModel", "HardwareSpec",
    "DTMResult", "JobPlan", "dtm", "Arrival", "ExecutionEngine",
    "JobSegment", "OnlineSchedule", "ResourceMonitor", "poisson_trace",
    "brute_force", "solve_pack", "Schedule", "max_gpu_schedule",
    "min_gpu_schedule", "plan", "replan", "sequential_plora_schedule",
]

"""AdamW over packed adapter parameters with PER-ADAPTER learning rates.

Only LoRA parameters carry optimizer state — the base model is frozen (the
paper's memory argument, §3.2/Appendix A: no base grads, no base moments).
The pack dimension N sits at axis 0 of unstacked leaves and axis 1 of
layer-stacked ("blocks") leaves; each adapter n is stepped with its own
learning rate lr_n from the hyperparameter configuration — hyperparameter
heterogeneity inside a single jitted update.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def init_opt_state(lora_params, n_pack: int = 0) -> Dict[str, Any]:
    """``n_pack > 0`` makes ``step`` a per-adapter (N,) vector instead of a
    scalar — required by the online engine, where a pack can mix fresh
    adapters (step 0) with adapters resumed from a preempted job (step k):
    each adapter's Adam bias correction continues from its own count."""
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
    return {
        "m": zeros(lora_params),
        "v": zeros(lora_params),
        "step": jnp.zeros((n_pack,) if n_pack else (), jnp.int32),
    }


def _lr_shape(path, leaf, n_pack: int):
    """Axis of the pack dim for this leaf: 1 under a 'blocks' stack, else 0."""
    in_blocks = any(getattr(k, "key", None) == "blocks" for k in path)
    ax = 1 if in_blocks else 0
    assert leaf.shape[ax] == n_pack, (path, leaf.shape, n_pack)
    shape = [1] * leaf.ndim
    shape[ax] = n_pack
    return shape


def adamw_update(
    grads,
    opt_state,
    params,
    lr_vector: jnp.ndarray,  # (N,)
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    step_budget: Optional[jnp.ndarray] = None,  # (N,) max steps per adapter
) -> Tuple[Any, Dict[str, Any]]:
    """``step_budget`` (online engine) freezes adapter n — params, moments
    and step count — once it has trained its own budgeted iterations, while
    packmates with longer residuals keep updating: packed jobs can then mix
    adapters with heterogeneous remaining-step counts and real execution
    matches the virtual scheduler's per-adapter accounting."""
    active = None
    if step_budget is not None:
        active = (opt_state["step"] < step_budget).astype(jnp.float32)  # (N,)
        step = opt_state["step"] + active.astype(opt_state["step"].dtype)
    else:
        step = opt_state["step"] + 1
    n_pack = lr_vector.shape[0]
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat_g = jax.tree_util.tree_flatten_with_path(grads)[0]
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    flat_p = jax.tree.leaves(params)
    new_p, new_m, new_v = [], [], []
    for (path, g), m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        shape = _lr_shape(path, p, n_pack)
        # per-adapter step vector (online engine): broadcast bias correction
        # along the pack axis, same as the learning rate
        c1l = c1.reshape(shape) if c1.ndim else c1
        c2l = c2.reshape(shape) if c2.ndim else c2
        if active is not None:
            g = g * active.reshape(shape).astype(g.dtype)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * (g * g)
        if active is not None:
            act = active.reshape(shape)
            m_new = act * m_new + (1 - act) * m
            v_new = act * v_new + (1 - act) * v
        mh = m_new / jnp.maximum(c1l, 1e-12)
        vh = v_new / jnp.maximum(c2l, 1e-12)
        lr = lr_vector.reshape(shape).astype(p.dtype)
        upd = mh / (jnp.sqrt(vh) + eps)
        if weight_decay:
            upd = upd + weight_decay * p
        if active is not None:
            upd = upd * active.reshape(shape).astype(p.dtype)
        new_p.append(p - lr * upd)
        new_m.append(m_new)
        new_v.append(v_new)
    treedef = jax.tree.structure(params)
    return (
        jax.tree.unflatten(treedef, new_p),
        {
            "m": jax.tree.unflatten(treedef, new_m),
            "v": jax.tree.unflatten(treedef, new_v),
            "step": step,
        },
    )

"""AdamW over packed adapter parameters with PER-ADAPTER learning rates.

Only LoRA parameters carry optimizer state — the base model is frozen (the
paper's memory argument, §3.2/Appendix A: no base grads, no base moments).
The pack dimension N sits at axis 0 of unstacked leaves and axis 1 of
layer-stacked ("blocks") leaves; each adapter n is stepped with its own
learning rate lr_n from the hyperparameter configuration — hyperparameter
heterogeneity inside a single jitted update.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def init_opt_state(lora_params) -> Dict[str, Any]:
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
    return {
        "m": zeros(lora_params),
        "v": zeros(lora_params),
        "step": jnp.zeros((), jnp.int32),
    }


def _lr_shape(path, leaf, n_pack: int):
    """Axis of the pack dim for this leaf: 1 under a 'blocks' stack, else 0."""
    in_blocks = any(getattr(k, "key", None) == "blocks" for k in path)
    ax = 1 if in_blocks else 0
    assert leaf.shape[ax] == n_pack, (path, leaf.shape, n_pack)
    shape = [1] * leaf.ndim
    shape[ax] = n_pack
    return shape


def adamw_update(
    grads,
    opt_state,
    params,
    lr_vector: jnp.ndarray,  # (N,)
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Tuple[Any, Dict[str, Any]]:
    step = opt_state["step"] + 1
    n_pack = lr_vector.shape[0]
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat_g = jax.tree_util.tree_flatten_with_path(grads)[0]
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    flat_p = jax.tree.leaves(params)
    new_p, new_m, new_v = [], [], []
    for (path, g), m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * (g * g)
        mh = m / c1
        vh = v / c2
        lr = lr_vector.reshape(_lr_shape(path, p, n_pack)).astype(p.dtype)
        upd = mh / (jnp.sqrt(vh) + eps)
        if weight_decay:
            upd = upd + weight_decay * p
        new_p.append(p - lr * upd)
        new_m.append(m)
        new_v.append(v)
    treedef = jax.tree.structure(params)
    return (
        jax.tree.unflatten(treedef, new_p),
        {
            "m": jax.tree.unflatten(treedef, new_m),
            "v": jax.tree.unflatten(treedef, new_v),
            "step": step,
        },
    )

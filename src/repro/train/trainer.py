"""Packed-LoRA training step and loop.

``make_train_step`` builds the jitted step for a pack of N adapters on one
frozen base model: forward with packed-LoRA deltas, chunked CE with
per-adapter reduction, grads w.r.t. adapter params only, AdamW with the
per-adapter learning-rate vector. Base params enter as inputs but are never
differentiated — XLA sees them as constants of the step (no base grads, no
base optimizer state: the paper's packing-memory property).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.adapter import PackMeta
from repro.kernels.ops import KernelConfig
from repro.models.model import forward, unembed_w
from repro.models.transformer import DistContext
from repro.train.losses import chunked_cross_entropy
from repro.train.optimizer import adamw_update, init_opt_state


def packed_loss_fn(
    lora,
    base,
    batch,
    cfg: ModelConfig,
    n_pack: int,
    scales,
    *,
    dist: Optional[DistContext] = None,
    chunk_q: int = 512,
    vocab_chunk: int = 512,
    aux_weight: float = 0.01,
    kcfg: Optional[KernelConfig] = None,
):
    """Pack loss with the per-adapter scale vector as a runtime value (a
    traced argument under ``make_packed_step``, a constant under
    ``make_train_step``). ``kcfg`` is the static kernel policy (backend
    impl, backward remat, the pack's rank vector for ragged grouping)."""
    h, _, aux = forward(
        base, lora, scales, batch, cfg,
        n_pack=n_pack, dist=dist, chunk_q=chunk_q, kcfg=kcfg,
    )
    per_adapter, total = chunked_cross_entropy(
        h, unembed_w(base, cfg), batch["labels"], n_pack,
        chunk=vocab_chunk, vocab=cfg.vocab_size,
    )
    return total + aux_weight * aux, per_adapter


def loss_fn(
    lora,
    base,
    batch,
    cfg: ModelConfig,
    meta: PackMeta,
    *,
    dist: Optional[DistContext] = None,
    chunk_q: int = 512,
    vocab_chunk: int = 512,
    aux_weight: float = 0.01,
    kcfg: Optional[KernelConfig] = None,
):
    return packed_loss_fn(
        lora, base, batch, cfg, meta.n, meta.scales(),
        dist=dist, chunk_q=chunk_q, vocab_chunk=vocab_chunk,
        aux_weight=aux_weight,
        kcfg=kcfg if kcfg is not None else meta.kernel_config(),
    )


def make_packed_step(
    cfg: ModelConfig,
    n_pack: int,
    *,
    dist: Optional[DistContext] = None,
    chunk_q: int = 512,
    vocab_chunk: int = 512,
    weight_decay: float = 0.0,
    jit: bool = True,
    impl: Optional[str] = None,
    remat: Optional[str] = None,
    ranks: Optional[tuple] = None,
    blocks: Optional[tuple] = None,
    base_dtype: Optional[str] = None,
):
    """Shape-keyed packed train step (cluster executor's compile unit).

    Unlike :func:`make_train_step`, the per-adapter hyperparameter vectors —
    ``scales`` (alpha/r), ``lr_vec`` and ``budgets`` (per-adapter step
    caps) — enter as *runtime arguments* rather than closed-over constants,
    so one compiled executable serves every pack with the same
    (n, r_bucket, batch, seq) shape regardless of which alphas / learning
    rates / step budgets the pack carries. ``repro.cluster.SliceExecutor``
    caches the returned callable per (model-config, pack-width, slice-shape).

    ``impl``/``remat`` select the kernel backend and backward xA policy
    (kernels/ops.py) — plumbed *explicitly* because the context-local
    default does not cross the cluster runner's worker threads; ``ranks``
    is the pack's static per-adapter rank tuple, which switches
    heterogeneous-rank packs onto ragged same-rank kernel segments (no
    bucket-padding FLOPs). ``base_dtype`` marks a quantized frozen base
    ("int8"/"nf4", kernels/quant.py) — the base argument then carries
    {"codes","scales"} dicts in its "w" slots. All are part of the
    executor's cache key.
    """
    # homogeneous rank tuples normalize to None: they trace identically
    # (ragged segmentation only engages on mixed ranks), so same-width packs
    # of different uniform ranks keep sharing one executor cache entry
    ranks = tuple(ranks) if ranks and len(set(ranks)) > 1 else None
    kcfg = KernelConfig(
        impl=impl, remat=remat, ranks=ranks,
        blocks=tuple(blocks) if blocks is not None else None,
        base_dtype=base_dtype,
    )

    def train_step(base, lora, opt_state, batch, scales, lr_vec, budgets):
        (total, per_adapter), grads = jax.value_and_grad(
            packed_loss_fn, has_aux=True
        )(lora, base, batch, cfg, n_pack, scales,
          dist=dist, chunk_q=chunk_q, vocab_chunk=vocab_chunk, kcfg=kcfg)
        lora_new, opt_state = adamw_update(
            grads, opt_state, lora, lr_vec, weight_decay=weight_decay,
            step_budget=budgets,
        )
        metrics = {"loss": total, "per_adapter_loss": per_adapter}
        return lora_new, opt_state, metrics

    return jax.jit(train_step, donate_argnums=(1, 2)) if jit else train_step


def make_train_step(
    cfg: ModelConfig,
    meta: PackMeta,
    *,
    dist: Optional[DistContext] = None,
    chunk_q: int = 512,
    vocab_chunk: int = 512,
    weight_decay: float = 0.0,
    step_budgets=None,  # (N,) per-adapter max step counts (online engine)
    jit: bool = True,
    impl: Optional[str] = None,
    remat: Optional[str] = None,
    base_dtype: Optional[str] = None,
):
    lr_vec = meta.lr_vector()
    budgets = (
        jnp.asarray(step_budgets, jnp.int32) if step_budgets is not None else None
    )
    kcfg = meta.kernel_config(impl=impl, remat=remat, base_dtype=base_dtype)

    def train_step(base, lora, opt_state, batch):
        (total, per_adapter), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(lora, base, batch, cfg, meta,
          dist=dist, chunk_q=chunk_q, vocab_chunk=vocab_chunk, kcfg=kcfg)
        lora_new, opt_state = adamw_update(
            grads, opt_state, lora, lr_vec, weight_decay=weight_decay,
            step_budget=budgets,
        )
        metrics = {"loss": total, "per_adapter_loss": per_adapter}
        return lora_new, opt_state, metrics

    return jax.jit(train_step, donate_argnums=(1, 2)) if jit else train_step


def train_loop(
    base,
    lora,
    cfg: ModelConfig,
    meta: PackMeta,
    data_iter,
    n_steps: int,
    *,
    dist=None,
    chunk_q: int = 512,
    vocab_chunk: int = 512,
    log_every: int = 0,
) -> Dict[str, Any]:
    """Run n_steps; returns final state + loss history."""
    step_fn = make_train_step(
        cfg, meta, dist=dist, chunk_q=chunk_q, vocab_chunk=vocab_chunk
    )
    opt_state = init_opt_state(lora)
    history = []
    for i in range(n_steps):
        batch = next(data_iter)
        lora, opt_state, m = step_fn(base, lora, opt_state, batch)
        history.append(jax.device_get(m["per_adapter_loss"]))
        if log_every and (i % log_every == 0):
            print(f"step {i}: loss={float(m['loss']):.4f}")
    return {"lora": lora, "opt_state": opt_state, "history": history}

"""Synthetic data pipeline.

Offline container => no GSM8K/GLUE; instead a *learnable* synthetic family
whose difficulty and hyperparameter sensitivity are controlled:

  permutation-LM task: a fixed random permutation pi over the vocab defines
  x_{t+1} = pi(x_t) with probability (1-noise), uniform otherwise. A base
  model that never saw pi gets ~chance accuracy; a LoRA adapter can learn pi,
  at a rate depending on rank/lr/batch — so the hyperparameter sweep is
  meaningful (quality benchmarks reproduce the paper's Tables 2/3/6 shape).

Data streams are keyed by the *adapter's* config, not by the pack: a given
adapter sees the identical sample sequence whether trained alone or packed —
required for the packing-identity test.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LoraConfig, ModelConfig
from repro.train.losses import IGNORE


def task_permutation(task_seed: int, vocab: int) -> np.ndarray:
    rng = np.random.RandomState(task_seed)
    return rng.permutation(vocab)


def sample_perm_lm(
    rng: np.random.RandomState,
    perm: np.ndarray,
    batch: int,
    seq: int,
    vocab: int,
    noise: float = 0.1,
) -> np.ndarray:
    x = np.empty((batch, seq), np.int32)
    x[:, 0] = rng.randint(0, vocab, batch)
    for t in range(1, seq):
        nxt = perm[x[:, t - 1]]
        flip = rng.rand(batch) < noise
        nxt = np.where(flip, rng.randint(0, vocab, batch), nxt)
        x[:, t] = nxt
    return x


def packed_batch_iterator(
    cfg: ModelConfig,
    configs: Sequence[LoraConfig],
    *,
    seq: int,
    task_seed: int = 0,
    noise: float = 0.1,
    seed: int = 1234,
    start_steps: Optional[Sequence[int]] = None,
) -> Iterator[Dict[str, jnp.ndarray]]:
    """Yields {"tokens": (N*Bmax, S), "labels": (N*Bmax, S)} with per-adapter
    sample masking: adapter n uses its own batch_size b_n <= Bmax; padded rows
    have labels == IGNORE (zero gradient), so heterogeneous batch sizes pack
    into one rectangular tensor.

    ``start_steps[n]`` fast-forwards adapter n's stream past the batches it
    already consumed in earlier segments (one draw per packed iteration), so
    a preempted adapter resumed mid-run sees *exactly* the sample sequence it
    would have seen uninterrupted — what makes segmented execution (probe /
    preempt / resume) bit-identical to a single unbroken run."""
    vocab = cfg.vocab_size
    perm = task_permutation(task_seed, vocab)
    bmax = max(c.batch_size for c in configs)
    rngs = [
        np.random.RandomState(seed + 7919 * hash(c.key()) % 100_000)
        for c in configs
    ]
    n_patch = cfg.n_patch_tokens or 0
    s_text = seq - n_patch  # VLM: patch prefix consumes part of the budget
    if start_steps is not None:
        assert len(start_steps) == len(configs)
        for n, c in enumerate(configs):
            for _ in range(start_steps[n]):
                sample_perm_lm(rngs[n], perm, c.batch_size, s_text, vocab, noise)
    while True:
        toks = np.zeros((len(configs), bmax, s_text), np.int32)
        labs = np.full((len(configs), bmax, seq), IGNORE, np.int32)
        for n, c in enumerate(configs):
            x = sample_perm_lm(rngs[n], perm, c.batch_size, s_text, vocab, noise)
            toks[n, : c.batch_size] = x
            labs[n, : c.batch_size, n_patch : seq - 1] = x[:, 1:]
        batch = {
            "tokens": jnp.asarray(toks.reshape(len(configs) * bmax, s_text)),
            "labels": jnp.asarray(labs.reshape(len(configs) * bmax, seq)),
        }
        batch.update(_frontend_stubs(cfg, len(configs) * bmax, seed))
        yield batch


def _frontend_stubs(cfg: ModelConfig, nb: int, seed: int):
    """Precomputed frame/patch embeddings for audio/vlm families (stubs per
    the assignment: the ViT/conv codec is out of scope, the backbone is not)."""
    out = {}
    if cfg.is_encdec:
        k = jax.random.PRNGKey(seed)
        out["frames"] = 0.1 * jax.random.normal(
            k, (nb, cfg.encoder_seq_len, cfg.d_model), jnp.float32
        )
    if cfg.n_patch_tokens:
        k = jax.random.PRNGKey(seed + 1)
        out["patches"] = 0.1 * jax.random.normal(
            k, (nb, cfg.n_patch_tokens, cfg.d_model), jnp.float32
        )
    return out


def eval_batch(
    cfg: ModelConfig,
    n_pack: int,
    *,
    seq: int,
    batch: int = 4,
    task_seed: int = 0,
    noise: float = 0.0,
    seed: int = 999,
):
    """Held-out eval batch on the same task (noise-free for clean accuracy)."""
    perm = task_permutation(task_seed, cfg.vocab_size)
    rng = np.random.RandomState(seed)
    n_patch = cfg.n_patch_tokens or 0
    s_text = seq - n_patch
    x = sample_perm_lm(rng, perm, n_pack * batch, s_text, cfg.vocab_size, noise)
    labs = np.full((n_pack * batch, seq), IGNORE, np.int32)
    labs[:, n_patch : seq - 1] = x[:, 1:]
    out = {"tokens": jnp.asarray(x), "labels": jnp.asarray(labs)}
    out.update(_frontend_stubs(cfg, n_pack * batch, seed))
    return out

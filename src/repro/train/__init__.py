from repro.train.checkpoint import CheckpointPool, load_tree, save_tree
from repro.train.data import eval_batch, packed_batch_iterator
from repro.train.losses import IGNORE, chunked_cross_entropy, top1_accuracy
from repro.train.optimizer import adamw_update, init_opt_state
from repro.train.trainer import loss_fn, make_train_step, train_loop

__all__ = [
    "CheckpointPool",
    "load_tree",
    "save_tree",
    "eval_batch",
    "packed_batch_iterator",
    "IGNORE",
    "chunked_cross_entropy",
    "top1_accuracy",
    "adamw_update",
    "init_opt_state",
    "loss_fn",
    "make_train_step",
    "train_loop",
]

"""Checkpoint pool: per-adapter save/load (npz) + merged-weight export.

At the end of a packed fine-tuning job the execution engine extracts each
adapter from the pack and stores it here (paper Fig. 3 "Checkpoint Pool").
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    tree: Dict[str, Any] = {}
    for k, v in flat.items():
        parts = k.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(v)
    return tree


def save_tree(path: str, tree, meta: Optional[dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    if meta is not None:
        with open(path + ".json", "w") as f:
            json.dump(meta, f, indent=2)


def load_tree(path: str):
    if not path.endswith(".npz"):
        path = path + ".npz"
    with np.load(path) as z:
        return _unflatten({k: z[k] for k in z.files})


class CheckpointPool:
    """Directory of fine-tuned adapters keyed by adapter id."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, adapter_id: str) -> str:
        return os.path.join(self.root, f"{adapter_id}.npz")

    def save_adapter(self, adapter_id: str, adapter_tree, config_meta: dict):
        save_tree(self._path(adapter_id), adapter_tree, config_meta)

    def load_adapter(self, adapter_id: str):
        return load_tree(self._path(adapter_id))

    def load_meta(self, adapter_id: str) -> dict:
        with open(self._path(adapter_id) + ".json") as f:
            return json.load(f)

    def list(self):
        return sorted(
            f[:-4] for f in os.listdir(self.root) if f.endswith(".npz")
        )

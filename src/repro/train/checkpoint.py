"""Checkpoint pool: per-adapter save/load (npz) + merged-weight export.

At the end of a packed fine-tuning job the execution engine extracts each
adapter from the pack and stores it here (paper Fig. 3 "Checkpoint Pool").
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    tree: Dict[str, Any] = {}
    for k, v in flat.items():
        parts = k.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(v)
    return tree


def save_tree(path: str, tree, meta: Optional[dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    if meta is not None:
        with open(path + ".json", "w") as f:
            json.dump(meta, f, indent=2)


def load_tree(path: str):
    if not path.endswith(".npz"):
        path = path + ".npz"
    with np.load(path) as z:
        return _unflatten({k: z[k] for k in z.files})


class CheckpointPool:
    """Directory of fine-tuned adapters keyed by adapter id."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _path(self, adapter_id: str) -> str:
        return os.path.join(self.root, f"{adapter_id}.npz")

    def save_adapter(self, adapter_id: str, adapter_tree, config_meta: dict):
        save_tree(self._path(adapter_id), adapter_tree, config_meta)

    def load_adapter(self, adapter_id: str):
        return load_tree(self._path(adapter_id))

    def load_meta(self, adapter_id: str) -> dict:
        with open(self._path(adapter_id) + ".json") as f:
            return json.load(f)

    def has(self, adapter_id: str) -> bool:
        return os.path.exists(self._path(adapter_id))

    # "state_" / "part_" are reserved prefixes: whole-pack snapshots and
    # preempted-adapter training state live in the same directory but are
    # NOT finished adapters, so list() (whose callers read final_loss meta)
    # must not return them.
    _RESERVED = ("state_", "part_")

    def list(self):
        return sorted(
            f[:-4]
            for f in os.listdir(self.root)
            if f.endswith(".npz") and not f.startswith(self._RESERVED)
        )

    def list_states(self):
        """Ids of resumable snapshots: packed states and per-adapter
        preempted-training state (the reserved-prefix files)."""
        return sorted(
            f[:-4]
            for f in os.listdir(self.root)
            if f.endswith(".npz") and f.startswith(self._RESERVED)
        )

    # ---------------- resumable packed state (online engine) ----------------
    #
    # Two granularities:
    #   * whole-pack snapshots — resume the SAME job after an interruption
    #     (launch/train.py --save-state/--resume-state);
    #   * per-adapter training state (weights + Adam moments + step count) —
    #     a preempted job checkpoints each unfinished adapter here, and the
    #     engine re-injects it into whatever pack the replanner puts it in
    #     next (paper §4 dynamic task migration).

    def save_packed_state(self, state_id: str, lora, opt_state, meta: dict):
        save_tree(
            self._path(f"state_{state_id}"),
            {"lora": lora, "opt": opt_state},
            meta,
        )

    def load_packed_state(self, state_id: str):
        tree = load_tree(self._path(f"state_{state_id}"))
        meta = self.load_meta(f"state_{state_id}")
        return tree["lora"], tree["opt"], meta

    def save_adapter_state(self, adapter_id: str, state_tree, meta: dict):
        """``state_tree`` = {"w": adapter, "m": moments, "v": moments}."""
        save_tree(self._path(f"part_{adapter_id}"), state_tree, meta)

    def load_adapter_state(self, adapter_id: str):
        tree = load_tree(self._path(f"part_{adapter_id}"))
        return tree, self.load_meta(f"part_{adapter_id}")

    def has_adapter_state(self, adapter_id: str) -> bool:
        return self.has(f"part_{adapter_id}")

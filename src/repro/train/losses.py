"""Losses: vocab-chunked cross-entropy with per-adapter reduction.

The CE never materializes the full (NB, S, V) logits tensor: the sequence is
scanned in chunks, each chunk's logits computed, reduced, and discarded
(rematerialized in backward). With a 262k vocab (gemma3) at 4k x 256 tokens
this is the difference between ~550 GB and ~0.5 GB of logits live at once.

Per-adapter reduction: total = sum_n mean-CE_n, so each adapter's gradient is
exactly what it would be when fine-tuned alone (the paper's packing-identity
property, tested in tests/test_train_packed.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

IGNORE = -100


def _chunk_ce(h, w, labels, mask, vocab=None):
    """h: (NB, c, d); w: (d, Vpad); labels: (NB, c). Returns (nll_sum, cnt)."""
    lg = (h @ w.astype(h.dtype)).astype(jnp.float32)  # (NB, c, Vpad)
    if vocab is not None and vocab < lg.shape[-1]:
        lg = jnp.where(jnp.arange(lg.shape[-1]) < vocab, lg, -1e30)
    lse = jax.nn.logsumexp(lg, axis=-1)
    safe = jnp.maximum(labels, 0)
    tgt = jnp.take_along_axis(lg, safe[..., None], axis=-1)[..., 0]
    nll = (lse - tgt) * mask
    return nll.sum(-1), mask.sum(-1)


def chunked_cross_entropy(
    hidden: jnp.ndarray,
    unembed: jnp.ndarray,
    labels: jnp.ndarray,
    n_pack: int,
    *,
    chunk: int = 512,
    vocab: int = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (per_adapter_mean (N,), total scalar = sum of per-adapter means).

    hidden: (NB, S, d); labels: (NB, S) with IGNORE for masked positions.
    `vocab`: true vocabulary size when `unembed` is padded.
    """
    nb, s, d = hidden.shape
    mask = (labels != IGNORE).astype(jnp.float32)
    if s <= chunk:
        nll, cnt = _chunk_ce(hidden, unembed, labels, mask, vocab)
    else:
        pad = (-s) % chunk
        if pad:
            hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=IGNORE)
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
        n = hidden.shape[1] // chunk
        hc = jnp.moveaxis(hidden.reshape(nb, n, chunk, d), 1, 0)
        lc = jnp.moveaxis(labels.reshape(nb, n, chunk), 1, 0)
        mc = jnp.moveaxis(mask.reshape(nb, n, chunk), 1, 0)

        @jax.checkpoint
        def body(carry, inp):
            h, l, m = inp
            a, b = _chunk_ce(h, unembed, l, m, vocab)
            return (carry[0] + a, carry[1] + b), None

        (nll, cnt), _ = jax.lax.scan(
            body, (jnp.zeros((nb,), jnp.float32), jnp.zeros((nb,), jnp.float32)),
            (hc, lc, mc),
        )
    # fold (N*B,) -> per-adapter means
    nll_n = nll.reshape(n_pack, -1).sum(-1)
    cnt_n = cnt.reshape(n_pack, -1).sum(-1)
    per_adapter = nll_n / jnp.maximum(cnt_n, 1.0)
    return per_adapter, per_adapter.sum()


def top1_accuracy(logits: jnp.ndarray, labels: jnp.ndarray, n_pack: int):
    """Per-adapter next-token top-1 accuracy (quality benchmarks)."""
    pred = jnp.argmax(logits, -1)
    mask = labels != IGNORE
    hit = ((pred == labels) & mask).astype(jnp.float32)
    hit_n = hit.reshape(n_pack, -1).sum(-1)
    cnt_n = mask.astype(jnp.float32).reshape(n_pack, -1).sum(-1)
    return hit_n / jnp.maximum(cnt_n, 1.0)

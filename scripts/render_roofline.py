"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun.jsonl.

  PYTHONPATH=src python scripts/render_roofline.py [results/dryrun.jsonl]
"""
import json
import sys


def load(path):
    latest = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            r = json.loads(line)
            latest[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return sorted(latest.values(), key=lambda r: (r.get("arch") or "", r.get("shape") or "", r.get("mesh") or ""))


def fmt_ms(s):
    if s is None:
        return "-"
    return f"{1e3 * s:,.1f}"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl"
    rows = load(path)
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    failed = [r for r in rows if r.get("status") == "fail"]

    print(f"### Dry-run matrix: {len(ok)} ok / {len(skipped)} documented skips"
          f" / {len(failed)} failed\n")
    print("| arch | shape | mesh | compile s | peak GB/dev | FLOPs/dev | bytes/dev | coll B/dev |")
    print("|---|---|---|---|---|---|---|---|")
    for r in ok:
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('compile_s', 0):.0f} | {r.get('peak_memory_gb', 0):.2f} | "
            f"{r.get('flops_per_device', 0):.3g} | {r.get('bytes_per_device', 0):.3g} | "
            f"{r.get('collective_bytes_per_device', 0):.3g} |"
        )
    if skipped:
        print("\nskips (full-attention archs at long_500k, DESIGN.md §6):")
        for r in skipped:
            print(f"  - {r['arch']} x {r['shape']} x {r['mesh']}")
    if failed:
        print("\nFAILED:")
        for r in failed:
            print(f"  - {r['arch']} x {r['shape']} x {r['mesh']}: {r.get('error', '')[:200]}")

    # roofline table: single-pod only per assignment
    print("\n### Roofline (single-pod 16x16, per device)\n")
    print("| arch | shape | compute ms | memory ms | collective ms | bottleneck | useful-FLOP ratio |")
    print("|---|---|---|---|---|---|---|")
    for r in ok:
        if r.get("mesh") != "16x16":
            continue
        ufr = r.get("useful_flop_ratio")
        print(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(r.get('t_compute_s'))} | "
            f"{fmt_ms(r.get('t_memory_s'))} | {fmt_ms(r.get('t_collective_s'))} | "
            f"{r.get('bottleneck')} | {ufr if ufr is None else round(ufr, 3)} |"
        )


if __name__ == "__main__":
    main()

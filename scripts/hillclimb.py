"""§Perf hillclimb driver: compile one (arch x shape) combo under a named
variant and print the full roofline row + collective breakdown + memory
analysis — the measurement half of the hypothesis->change->measure loop.

  PYTHONPATH=src python scripts/hillclimb.py ARCH SHAPE [variant ...]

variants: baseline | seq_parallel | decode_seq_shard  (combinable)
"""
import json
import sys


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    variants = set(sys.argv[3:]) or {"baseline"}
    from repro.launch.dryrun import lower_combo

    rep, info = lower_combo(
        arch, shape,
        seq_parallel="seq_parallel" in variants,
        decode_seq_shard="decode_seq_shard" in variants,
        fsdp="fsdp" in variants,
    )
    row = rep.row(info["n_devices"])
    row["variant"] = "+".join(sorted(variants))
    row["coll_breakdown"] = {
        k: f"{v:.3g}" for k, v in rep.coll_breakdown.items()
    }
    row.update(compile_s=round(info["compile_s"], 1))
    print("RESULT=" + json.dumps(row, default=str))
    print(
        f"\n{arch} x {shape} [{row['variant']}]\n"
        f"  compute    {rep.t_compute*1e3:10.1f} ms\n"
        f"  memory     {rep.t_memory*1e3:10.1f} ms\n"
        f"  collective {rep.t_collective*1e3:10.1f} ms   <- {rep.bottleneck} bound\n"
        f"  peak mem   {row['peak_memory_gb']:10.2f} GB/dev\n"
        f"  coll kinds {row['coll_breakdown']}\n"
        f"  useful-FLOP ratio {row['useful_flop_ratio']}"
    )


if __name__ == "__main__":
    main()

"""CI gate for Chrome trace-event artifacts (ISSUE 7 satellite).

Parses a trace produced by ``--trace-out`` (launch/train.py or
benchmarks/bench_serve.py), validates every event against the trace-event
schema (``repro.obs.validate_chrome_trace``), and requires spans from at
least ``--min-tiers`` distinct tiers — a trace that silently lost a tier's
instrumentation fails the build, not just the viewer.

  PYTHONPATH=src python scripts/check_trace.py TRACE.json --min-tiers 3
"""
import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--min-tiers", type=int, default=3,
                    help="require spans from at least this many distinct "
                         "tiers (engine/runner/executor/dispatch/host/"
                         "serve/autotune)")
    ap.add_argument("--min-spans", type=int, default=1,
                    help="require at least this many complete (ph=X) spans")
    ap.add_argument("--require-cat", action="append", default=[],
                    metavar="CAT",
                    help="require this tier (span cat) to be present; "
                         "repeatable — e.g. --require-cat host asserts the "
                         "membership/heartbeat instrumentation survived")
    args = ap.parse_args()

    from repro.obs import validate_chrome_trace, trace_tiers

    try:
        with open(args.trace) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_trace: cannot parse {args.trace}: {e}")
        return 1

    problems = validate_chrome_trace(obj)
    if problems:
        print(f"check_trace: {args.trace} has {len(problems)} schema "
              "problem(s):")
        for p in problems[:20]:
            print(f"  - {p}")
        return 1

    events = obj.get("traceEvents", [])
    n_spans = sum(1 for e in events if e.get("ph") == "X")
    tiers = trace_tiers(obj)
    print(f"check_trace: {args.trace}: {len(events)} event(s), "
          f"{n_spans} span(s), tiers={tiers}")
    if n_spans < args.min_spans:
        print(f"check_trace: expected >= {args.min_spans} span(s), "
              f"got {n_spans}")
        return 1
    if len(tiers) < args.min_tiers:
        print(f"check_trace: expected spans from >= {args.min_tiers} tiers, "
              f"got {len(tiers)}: {tiers}")
        return 1
    missing = [c for c in args.require_cat if c not in tiers]
    if missing:
        print(f"check_trace: required tier(s) absent: {missing} "
              f"(present: {tiers})")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Optimized-variant sweep: apply the §Perf flags across every combo where
they are applicable and record the optimized roofline rows next to the
baselines (EXPERIMENTS.md §Perf sweep-wide table).

  PYTHONPATH=src python scripts/optimized_sweep.py [--out results/optimized]

Variant policy (DESIGN.md §9):
  - train/prefill, dense or SSM arch < 10B params  -> fsdp
  - train/prefill, dense arch >= 10B               -> seq_parallel
  - MoE archs (EP shard_map needs the model axis)  -> seq_parallel
  - decode/long shapes, attention archs            -> decode_seq_shard
  - decode, pure-SSM archs                         -> baseline (nothing to fix)
"""
import argparse
import json
import os
import sys
import time
import traceback


def pick_variant(arch_cfg, shape_kind, n_params):
    moe = arch_cfg.moe.enabled
    if shape_kind in ("train", "prefill"):
        if moe:
            return {"seq_parallel": True}
        if n_params < 10e9:
            return {"fsdp": True}
        return {"seq_parallel": True}
    # decode shapes
    if arch_cfg.family == "ssm":
        return {}
    return {"decode_seq_shard": True}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/optimized")
    ap.add_argument("--archs", default=None, help="comma-separated subset")
    args = ap.parse_args()

    from repro.configs import INPUT_SHAPES, get_config, list_archs
    from repro.launch.dryrun import applicable, lower_combo
    from repro.sched.cost_model import model_param_count

    archs = args.archs.split(",") if args.archs else [
        a for a in list_archs() if a != "qwen25-7b"
    ]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    out_path = args.out + ".jsonl"
    n_ok = n_all = 0
    for arch in archs:
        cfg = get_config(arch)
        n_params = model_param_count(cfg)
        for shape_name, shape in INPUT_SHAPES.items():
            if not applicable(arch, shape_name):
                continue
            kw = pick_variant(cfg, shape.kind, n_params)
            variant = "+".join(sorted(k for k, v in kw.items() if v)) or "baseline"
            if variant == "baseline":
                continue  # baseline already in dryrun2.jsonl
            n_all += 1
            tag = f"{arch} x {shape_name} [{variant}]"
            t0 = time.time()
            try:
                rep, info = lower_combo(arch, shape_name, **kw)
                row = rep.row(info["n_devices"])
                row.update(status="ok", variant=variant,
                           compile_s=round(info["compile_s"], 1))
                n_ok += 1
                print(f"[ok] {tag}: compute {rep.t_compute*1e3:.1f}ms "
                      f"memory {rep.t_memory*1e3:.1f}ms "
                      f"coll {rep.t_collective*1e3:.1f}ms -> {rep.bottleneck} "
                      f"| peak {row['peak_memory_gb']:.2f} GB "
                      f"({time.time()-t0:.0f}s)", flush=True)
            except Exception as e:
                row = {"arch": arch, "shape": shape_name, "variant": variant,
                       "status": "fail", "error": str(e)[:300]}
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
            with open(out_path, "a") as f:
                f.write(json.dumps(row) + "\n")
    print(f"\n{n_ok}/{n_all} optimized combos ok")


if __name__ == "__main__":
    main()

"""Multi-host dispatch tier vs the single-host runner on the same workload.

The scale-out claim: a workload that oversubscribes one host should finish
faster when the *same* segment protocol is dispatched across more hosts.
This bench runs an 8-job schedule (single-config width-1 jobs, the 4-group
grid doubled) twice through the dispatch tier:

  * 1 host x 4 devices  — the plan needs two waves;
  * 2 hosts x 4 devices — twice the hardware, one wave, jobs overlapping
    across *processes* (each simulated host is a subprocess self-forcing its
    own CPU device count, so this runs on any machine with no XLA_FLAGS).

Reported per layout: wall-clock elapsed (warm workers; cold startup+compile
reported separately), real makespan, peak overlap — plus the speedup and
per-adapter loss bit-exactness between the layouts. Small per-step compute
(seq 16, batch 1) keeps the single-process baseline honest: its 4 concurrent
slices contend on one GIL for dispatch, exactly the regime where one process
per host pays off even on a 2-core box.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List


def run(fast: bool = False) -> List[Dict]:
    import jax
    import numpy as np

    from repro.cluster import HostDispatcher
    from repro.configs.base import LoraConfig, get_config, reduced
    from repro.core.adapter import pack_meta
    from repro.models.model import init_model
    from repro.sched.cost_model import A100_40G, CostModel
    from repro.sched.engine import ExecutionEngine
    from repro.sched.planner import Schedule, ScheduledJob

    cfg = reduced(get_config("qwen25-7b"))
    cm = CostModel(cfg, A100_40G)
    seq = 16
    steps = 60 if fast else 150
    grid = [
        LoraConfig(rank=r, alpha=a, learning_rate=lr, batch_size=1, seq_len=seq)
        for r, a, lr in [
            (8, 8.0, 1e-3), (8, 16.0, 5e-4), (16, 16.0, 1e-3),
            (16, 32.0, 2e-4), (8, 4.0, 2e-3), (8, 12.0, 8e-4),
            (16, 8.0, 6e-4), (16, 24.0, 3e-4),
        ]
    ]
    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta(grid))

    def schedule(g: int) -> Schedule:
        """Width-1 jobs in as many waves as ``g`` units require."""
        jobs = [
            ScheduledJob((i,), 1, float(i // g), float(i // g) + 1.0)
            for i in range(len(grid))
        ]
        return Schedule(jobs, float(-(-len(grid) // g)), g)

    def run_layout(hosts: List[int]):
        g = sum(hosts)
        eng = ExecutionEngine(cm, g, host_size=hosts[0])
        sched = schedule(g)
        with HostDispatcher(hosts) as disp:
            def once():
                t0 = time.perf_counter()
                records, makespan = eng.run_local(
                    sched, grid, cfg, base, n_steps=steps, seq=seq,
                    runner=disp,
                )
                return (
                    time.perf_counter() - t0,
                    makespan,
                    np.concatenate(
                        [r.final_losses for r in sorted(
                            records, key=lambda r: r.job.config_ids
                        )]
                    ).astype(np.float64),
                    disp.last_result.max_overlap(),
                )

            t0 = time.perf_counter()
            once()  # cold: worker startup + every compile
            cold = time.perf_counter() - t0
            a, b = once(), once()  # warm, best-of-2 (noisy small boxes)
            best = min(a, b, key=lambda r: r[0])
            return cold, best

    rows: List[Dict] = []
    out = {}
    for name, hosts in (("1x4", [4]), ("2x4", [4, 4])):
        cold, (elapsed, makespan, losses, overlap) = run_layout(hosts)
        out[name] = (elapsed, losses)
        rows.append(
            {
                "bench": "multihost",
                "mode": name,
                "hosts": len(hosts),
                "devices_per_host": hosts[0],
                "steps": steps,
                "elapsed_s": round(elapsed, 3),
                "cold_s": round(cold, 3),
                "makespan_s": round(makespan, 3),
                "peak_overlap": overlap,
            }
        )
    speed = out["1x4"][0] / out["2x4"][0]
    bitexact = bool(np.array_equal(out["1x4"][1], out["2x4"][1]))
    rows.append(
        {
            "bench": "multihost",
            "mode": "speedup",
            "steps": steps,
            "speedup_multihost": round(speed, 3),
            "losses_bitexact": bitexact,
        }
    )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default=None,
                    help="also dump rows to this JSON file")
    args = ap.parse_args()
    rows = run(args.fast)
    for r in rows:
        if r["mode"] == "speedup":
            print(
                f"multihost: 2x4 hosts x{r['speedup_multihost']:.2f} vs "
                f"1x4 on the same 8-job workload, losses bit-exact: "
                f"{r['losses_bitexact']}"
            )
        else:
            print(
                f"multihost,{r['mode']}: {r['elapsed_s']:.2f}s warm "
                f"({r['cold_s']:.1f}s cold), peak overlap "
                f"{r['peak_overlap']}"
            )
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "multihost", "rows": rows}, f, indent=1)


if __name__ == "__main__":
    main()

"""Paper Fig. 6: speedup breakdown — Min GPU vs Sequential PLoRA (planner
only, naive per-adapter execution) vs full PLoRA (planner + packed kernels)."""
from __future__ import annotations

from typing import Dict, List

from benchmarks.model_zoo import PAPER_MODELS, PAPER_SEQ, PAPER_STEPS
from repro.configs.base import default_search_space
from repro.sched.cost_model import A100_40G, CostModel
from repro.sched.planner import (
    min_gpu_schedule,
    plan,
    sequential_plora_schedule,
)


def run(fast: bool = False) -> List[Dict]:
    rows = []
    n_cfg = 24 if fast else 120
    space = default_search_space(n_cfg, PAPER_SEQ)
    for name in ["qwen2.5-3b", "qwen2.5-7b"]:
        cfg = PAPER_MODELS[name]()
        cm = CostModel(cfg, A100_40G)
        s_min = min_gpu_schedule(cm, space, 8, PAPER_SEQ, PAPER_STEPS)
        s_seq = sequential_plora_schedule(cm, space, 8, PAPER_SEQ, PAPER_STEPS)
        s_p = plan(cm, space, 8, PAPER_SEQ, PAPER_STEPS)
        rows.append(
            {
                "bench": "breakdown",
                "model": name,
                "planner_only_speedup": s_min.makespan / s_seq.makespan,
                "kernels_extra_speedup": s_seq.makespan / s_p.makespan,
                "total_speedup": s_min.makespan / s_p.makespan,
            }
        )
    return rows


def main():
    for r in run():
        print(
            f"breakdown,{r['model']},planner={r['planner_only_speedup']:.2f}x,"
            f"kernels={r['kernels_extra_speedup']:.2f}x,"
            f"total={r['total_speedup']:.2f}x"
        )


if __name__ == "__main__":
    main()

"""Assignment §Roofline: per (arch x shape x mesh) three-term roofline from
the compiled dry-run.

The full 512-device sweep takes hours of XLA compile time, so this benchmark
(a) loads cached rows from results/dryrun.jsonl when present (produced by
``python -m repro.launch.dryrun --all --out results/dryrun``), and (b) in
fast mode compiles one representative combo live to prove the pipeline.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# dryrun.jsonl: full both-mesh sweep; dryrun2.jsonl: single-pod re-sweep with
# the final slice/DUS-aware byte accounting (overrides where present).
CACHES = [
    os.path.join(ROOT, "results", "dryrun.jsonl"),
    os.path.join(ROOT, "results", "dryrun2.jsonl"),
]


def load_cached() -> List[Dict]:
    rows = []
    for cache in CACHES:
        if not os.path.exists(cache):
            continue
        with open(cache) as f:
            for line in f:
                line = line.strip()
                if line:
                    rows.append(json.loads(line))
    # keep the latest row per (arch, shape, mesh)
    latest = {}
    for r in rows:
        latest[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return list(latest.values())


def run_live_combo(arch="internvl2-1b", shape="decode_32k") -> Dict:
    """Compile one combo in a subprocess (512 forced devices must not leak
    into this process)."""
    code = (
        "from repro.launch.dryrun import lower_combo\n"
        f"rep, info = lower_combo({arch!r}, {shape!r})\n"
        "import json; row = rep.row(info['n_devices']); row.update(status='ok')\n"
        "print('ROW=' + json.dumps(row))\n"
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"), JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=1200, env=env,
    )
    for line in out.stdout.splitlines():
        if line.startswith("ROW="):
            return json.loads(line[4:])
    raise RuntimeError(f"live combo failed: {out.stderr[-2000:]}")


def run(fast: bool = False) -> List[Dict]:
    rows = load_cached()
    ok = [r for r in rows if r.get("status") == "ok"]
    if not ok:
        ok = [run_live_combo()]
    out = []
    for r in sorted(ok, key=lambda r: (r.get("arch", ""), r.get("shape", ""), r.get("mesh", ""))):
        out.append(
            {
                "bench": "roofline",
                "arch": r["arch"],
                "shape": r["shape"],
                "mesh": r.get("mesh", "16x16"),
                "t_compute_ms": 1e3 * r.get("t_compute_s", 0.0),
                "t_memory_ms": 1e3 * r.get("t_memory_s", 0.0),
                "t_collective_ms": 1e3 * r.get("t_collective_s", 0.0),
                "bottleneck": r.get("bottleneck"),
                "useful_flop_ratio": r.get("useful_flop_ratio"),
                "peak_memory_gb": r.get("peak_memory_gb"),
            }
        )
    return out


def main():
    for r in run():
        print(
            f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
            f"compute={r['t_compute_ms']:.1f}ms,memory={r['t_memory_ms']:.1f}ms,"
            f"coll={r['t_collective_ms']:.1f}ms,{r['bottleneck']}"
        )


if __name__ == "__main__":
    main()

"""ModelConfigs for the paper's evaluated base models (cost-model inputs).

Only qwen25-7b is a registered arch (it is exercised end-to-end); the others
exist so the makespan/throughput benchmarks can sweep the paper's §7 model
grid through the cost model. Dimensions from the published configs.
"""
from repro.configs.base import AttentionConfig, ModelConfig


def qwen25(size: str) -> ModelConfig:
    dims = {
        # name: (L, d_model, d_ff, heads, kv, head_dim)
        "3b": (36, 2048, 11_008, 16, 2, 128),
        "7b": (28, 3584, 18_944, 28, 4, 128),
        "14b": (48, 5120, 13_824, 40, 8, 128),
        "32b": (64, 5120, 27_648, 40, 8, 128),
    }[size]
    L, d, ff, h, kv, hd = dims
    return ModelConfig(
        name=f"qwen2.5-{size}",
        family="dense",
        n_layers=L, d_model=d, d_ff=ff, vocab_size=152_064,
        attention=AttentionConfig(n_heads=h, n_kv_heads=kv, head_dim=hd, use_bias=True),
        citation="arXiv:2412.15115",
    )


def llama3(size: str) -> ModelConfig:
    dims = {
        "3b": (28, 3072, 8192, 24, 8, 128),   # LLaMa-3.2-3B
        "8b": (32, 4096, 14_336, 32, 8, 128),  # LLaMa-3.1-8B
    }[size]
    L, d, ff, h, kv, hd = dims
    return ModelConfig(
        name=f"llama-3-{size}",
        family="dense",
        n_layers=L, d_model=d, d_ff=ff, vocab_size=128_256,
        attention=AttentionConfig(n_heads=h, n_kv_heads=kv, head_dim=hd),
        citation="arXiv:2407.21783",
    )


PAPER_MODELS = {
    "qwen2.5-3b": lambda: qwen25("3b"),
    "qwen2.5-7b": lambda: qwen25("7b"),
    "qwen2.5-14b": lambda: qwen25("14b"),
    "qwen2.5-32b": lambda: qwen25("32b"),
    "llama-3.2-3b": lambda: llama3("3b"),
    "llama-3.1-8b": lambda: llama3("8b"),
}

# GLUE-scale effective sequence length: the paper caps seq at 1024 but its
# §5.1 timing anchors (bs 1->8 = +10%) are only consistent with the short
# sequences of its GLUE-dominated task mix. All cost-model benchmarks use
# this unless overridden (EXPERIMENTS.md §Calibration).
PAPER_SEQ = 128
PAPER_STEPS = 100

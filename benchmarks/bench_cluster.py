"""Concurrent mesh-slice execution vs the sequential single-slice baseline.

The cluster subsystem's claim: segments scheduled on disjoint device groups
should *overlap in wall-clock time*. This bench executes the same multi-group
schedule twice through ``ExecutionEngine.run_local`` — once with a sequential
runner (the old one-segment-at-a-time path) and once with the concurrent
thread-per-slice runner — on a forced 8-device CPU host, and reports

  * wall-clock elapsed per mode (compile + steady-state, everything),
  * the concurrent runner's real makespan and peak segment overlap,
  * bit-exactness of per-adapter final losses between the two modes.

Scenarios: 4 groups of width-1 slices (pure concurrency) and 2 groups of
width-2 slices (each job tensor-parallel inside its slice). The bench
re-executes itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so it works no matter
how the parent process initialized jax.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Dict, List

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_TAG = "CLUSTER_ROWS_JSON:"


def run(fast: bool = False) -> List[Dict]:
    """Spawn the forced-8-device worker and collect its rows."""
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        JAX_PLATFORMS="cpu",
        PYTHONPATH=os.pathsep.join(
            p for p in (os.path.join(_ROOT, "src"), _ROOT,
                        os.environ.get("PYTHONPATH", "")) if p
        ),
    )
    cmd = [sys.executable, "-m", "benchmarks.bench_cluster", "--worker"]
    if fast:
        cmd.append("--fast")
    proc = subprocess.run(
        cmd, capture_output=True, text=True, env=env, cwd=_ROOT, timeout=1800
    )
    for line in proc.stdout.splitlines():
        if line.startswith(_TAG):
            return json.loads(line[len(_TAG):])
    raise RuntimeError(
        f"cluster worker produced no rows (exit {proc.returncode}):\n"
        f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}"
    )


def _worker(fast: bool) -> List[Dict]:
    import jax
    import numpy as np

    from repro.cluster import ClusterRunner, DevicePool, SliceExecutor
    from repro.configs.base import LoraConfig, get_config, reduced
    from repro.models.model import init_model
    from repro.core.adapter import pack_meta
    from repro.sched.cost_model import A100_40G, CostModel
    from repro.sched.engine import ExecutionEngine
    from repro.sched.planner import Schedule, ScheduledJob

    assert jax.device_count() >= 8, jax.device_count()
    cfg = reduced(get_config("qwen25-7b"))
    cm = CostModel(cfg, A100_40G)
    # per-step compute must dominate the GIL-bound Python dispatch for
    # threads to overlap, hence seq 32 x batch 2 (measured: bs=1/seq=16
    # steps are dispatch-bound and concurrency gains vanish)
    seq = 32
    steps = 50 if fast else 100
    grid = [
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=2, seq_len=seq),
        LoraConfig(rank=8, alpha=16.0, learning_rate=5e-4, batch_size=2, seq_len=seq),
        LoraConfig(rank=16, alpha=16.0, learning_rate=1e-3, batch_size=2, seq_len=seq),
        LoraConfig(rank=16, alpha=32.0, learning_rate=2e-4, batch_size=2, seq_len=seq),
    ]

    def scenario(n_groups: int, degree: int):
        """One packed job per group, all launched at t=0 on disjoint units."""
        per = len(grid) // n_groups
        jobs = [
            ScheduledJob(
                tuple(range(i * per, (i + 1) * per)), degree, 0.0, 1.0
            )
            for i in range(n_groups)
        ]
        return Schedule(jobs, 1.0, n_groups * degree)

    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta(grid))
    rows: List[Dict] = []
    cases = [(4, 1)] if fast else [(4, 1), (2, 2)]
    for n_groups, degree in cases:
        g = n_groups * degree
        sched = scenario(n_groups, degree)
        eng = ExecutionEngine(cm, g)
        # one executor across modes: the first (cold) run pays every
        # compile; the timed runs below then compare pure dispatch — the
        # steady state of a long-running tuning service, where the
        # executor's compile cache is already warm.
        ex = SliceExecutor()
        devices = jax.devices()[:g]

        def run_mode(concurrent: bool):
            from repro.cluster import peak_overlap

            runner = ClusterRunner(
                ex, DevicePool(devices), concurrent=concurrent
            )
            t0 = time.perf_counter()
            records, _ = eng.run_local(
                sched, grid, cfg, base, n_steps=steps, seq=seq, runner=runner
            )
            elapsed = time.perf_counter() - t0
            losses = np.concatenate(
                [r.final_losses for r in records]
            ).astype(np.float64)
            overlap = peak_overlap(
                [(r.real_start, r.real_end) for r in records]
            )
            return elapsed, losses, overlap

        t0 = time.perf_counter()
        run_mode(True)  # cold: compile every (shape, device) executable
        cold = time.perf_counter() - t0
        # two timed passes per mode, best-of (2-core CI boxes are noisy)
        out = {}
        for mode, conc in (("sequential", False), ("concurrent", True)):
            a, b = run_mode(conc), run_mode(conc)
            out[mode] = min(a, b, key=lambda r: r[0])
        for mode, (elapsed, _, overlap) in out.items():
            rows.append(
                {
                    "bench": "cluster",
                    "scenario": f"{n_groups}x deg{degree}",
                    "mode": mode,
                    "n_groups": n_groups,
                    "degree": degree,
                    "steps": steps,
                    "elapsed_s": round(elapsed, 3),
                    "cold_s": round(cold, 3),
                    "peak_overlap": overlap,
                }
            )
        speed = out["sequential"][0] / out["concurrent"][0]
        bitexact = bool(np.array_equal(out["sequential"][1], out["concurrent"][1]))
        rows.append(
            {
                "bench": "cluster",
                "scenario": f"{n_groups}x deg{degree}",
                "mode": "speedup",
                "n_groups": n_groups,
                "degree": degree,
                "steps": steps,
                "speedup_concurrent": round(speed, 3),
                "losses_bitexact": bitexact,
                "peak_overlap": out["concurrent"][2],
            }
        )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    if args.worker:
        rows = _worker(args.fast)
        print(_TAG + json.dumps(rows))
        return
    rows = run(args.fast)
    for r in rows:
        if r["mode"] == "speedup":
            print(
                f"cluster,{r['scenario']}: concurrent "
                f"x{r['speedup_concurrent']:.2f} vs sequential, "
                f"peak overlap {r['peak_overlap']}, "
                f"losses bit-exact: {r['losses_bitexact']}"
            )
        else:
            print(
                f"cluster,{r['scenario']},{r['mode']}: "
                f"{r['elapsed_s']:.2f}s elapsed"
            )


if __name__ == "__main__":
    main()

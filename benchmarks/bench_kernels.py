"""Paper Tables 7/8: packed-LoRA kernel throughput vs sequential per-adapter
computation, N in {2, 8, 32}, hidden dims from the 3B/7B attention/MLP
projections.

On this CPU container the packed path is the XLA grouped batched GEMM (the
same semantics the Pallas TPU kernel implements; its interpret-mode execution
is a correctness oracle, not a timing path) and the baseline is the paper's
naive per-adapter loop — N separate jitted GEMM pairs.

IMPORTANT CPU caveat: the paper's near-linear speedup comes from accelerator
launch/occupancy economics (a rank-64 GEMM can't fill an A100/TPU, so N of
them in one kernel are nearly free). A CPU has neither idle SMs nor multi-us
launch overhead, so packed-vs-sequential wall-clock here mostly reflects XLA
batching quality, not the paper's effect. We therefore report BOTH:
  - wall-clock speedups at a dispatch-bound size (seq=16: per-GEMM compute
    ~launch cost, the regime that actually resembles an accelerator), and
  - structural metrics: dispatches per iteration (1 vs 3N) — the quantity
    the TPU grid-over-adapters kernel collapses by construction.
The TPU-side near-linearity is validated structurally: one pallas_call with
the adapter index as a grid dimension (src/repro/kernels/packed_matmul.py),
bit-equivalent to the sequential loop (tests/test_kernels.py).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.kernels.ops import packed_lora_delta
from repro.kernels import ref

# (label, d_in) from the paper's Table 7: Qwen-2.5 3B/7B attn & MLP dims.
DIMS = [
    ("3b-attn", 2048),
    ("3b-mlp", 11_008),
    ("7b-attn", 3584),
    ("7b-mlp", 18_944),
]
RANK = 64
SEQ = 16  # dispatch-bound on CPU ~= occupancy-bound on GPU; paper uses 512-2048


def _time(fn, *args, iters=3) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _setup(n, d, r=RANK, seq=SEQ, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (n, seq, d), dtype)
    a = jax.random.normal(ks[1], (n, d, r), dtype) * 0.02
    b = jax.random.normal(ks[2], (n, r, d), dtype) * 0.02
    alpha = jnp.ones((n,))
    return x, a, b, alpha


@jax.jit
def _packed_fwd(x, a, b, alpha):
    return packed_lora_delta(x, a, b, alpha, impl="xla")


@jax.jit
def _packed_bwd(x, a, b, alpha):
    return jax.grad(
        lambda a, b: (packed_lora_delta(x, a, b, alpha, impl="xla") ** 2).sum(),
        argnums=(0, 1),
    )(a, b)


def _seq_fwd_one(x1, a1, b1, al1):
    return al1 * ((x1 @ a1) @ b1)


_seq_fwd_one_j = jax.jit(_seq_fwd_one)
_seq_bwd_one_j = jax.jit(
    lambda x1, a1, b1, al1: jax.grad(
        lambda a, b: ((al1 * ((x1 @ a) @ b)) ** 2).sum(), argnums=(0, 1)
    )(a1, b1)
)


def _sequential_fwd(x, a, b, alpha):
    return [_seq_fwd_one_j(x[i], a[i], b[i], alpha[i]) for i in range(x.shape[0])]


def _sequential_bwd(x, a, b, alpha):
    return [_seq_bwd_one_j(x[i], a[i], b[i], alpha[i]) for i in range(x.shape[0])]


def run(fast: bool = False) -> List[Dict]:
    rows = []
    ns = [2, 8] if fast else [2, 8, 32]
    dims = DIMS[:2] if fast else DIMS
    for label, d in dims:
        for n in ns:
            x, a, b, alpha = _setup(n, d, seq=SEQ)
            t_pf = _time(_packed_fwd, x, a, b, alpha)
            t_sf = _time(_sequential_fwd, x, a, b, alpha)
            t_pb = _time(_packed_bwd, x, a, b, alpha)
            t_sb = _time(_sequential_bwd, x, a, b, alpha)
            rows.append(
                {
                    "bench": "kernels",
                    "dims": label,
                    "d": d,
                    "n_pack": n,
                    "fwd_speedup": t_sf / t_pf,
                    "bwd_speedup": t_sb / t_pb,
                    "packed_fwd_us": t_pf * 1e6,
                    "packed_bwd_us": t_pb * 1e6,
                    # structural: XLA dispatches per iteration
                    "dispatches_packed": 1,
                    "dispatches_sequential": n,
                }
            )
    return rows


def main():
    for r in run():
        print(
            f"kernels,{r['dims']},N={r['n_pack']},"
            f"fwd={r['fwd_speedup']:.2f}x,bwd={r['bwd_speedup']:.2f}x"
        )


if __name__ == "__main__":
    main()

"""Paper Tables 7/8 + the fused kernel tier: packed-LoRA kernel throughput.

Four row families:

  * ``packed``  — packed grouped GEMM vs the paper's naive per-adapter loop
    (N separate jitted GEMM pairs), the original Tables 7/8 comparison.
  * ``fused``   — the base+delta megakernel (one dispatch computing
    ``x@W + alpha*(x@A)@B``, kernels/fused.py) vs the two-pass formulation
    at *pass-dispatch granularity*: base GEMM, delta, and add each dispatch
    separately, exactly as they launch as separate kernels on an
    accelerator. Forward and backward rows.
  * ``remat``   — backward xA policy crossover: ``remat="save"`` vs
    ``"recompute"`` inside one jitted grad (bit-identical outputs; this row
    is why ``ops.DEFAULT_REMAT`` is what it is).
  * ``ragged``  — structural FLOP accounting of heterogeneous-rank packs:
    bucket-padded delta FLOPs vs ragged same-rank segments
    (``ops.delta_flops``), plus measured wall-clock of both, plus a
    training-loss parity row (fused vs two-pass ``make_train_step`` on a
    reduced model; per-adapter losses bit-exact or the max ulp distance is
    reported, as in bench_adaptive).

IMPORTANT CPU caveat: the paper's near-linear speedup comes from accelerator
launch/occupancy economics (a rank-64 GEMM can't fill an A100/TPU, so N of
them in one kernel are nearly free). A CPU has neither idle SMs nor multi-us
launch overhead, so wall-clock here mostly reflects XLA batching quality and
per-dispatch overhead at the seq=16 dispatch-bound sizes — the regime that
actually resembles an accelerator. Structural metrics (dispatches per
iteration, delta FLOPs) are the quantities the TPU kernels collapse by
construction; the TPU-side kernels are validated bit-equivalent in
interpret mode (tests/test_kernels.py, tests/test_fused.py).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import delta_flops, packed_lora_delta
from repro.kernels.fused import fused_lora

# (label, d_in) from the paper's Table 7: Qwen-2.5 3B/7B attn & MLP dims.
DIMS = [
    ("3b-attn", 2048),
    ("3b-mlp", 11_008),
    ("7b-attn", 3584),
    ("7b-mlp", 18_944),
]
# dispatch-bound set for the fused comparison: small enough that per-pass
# dispatch overhead is comparable to per-pass compute (the accelerator
# launch-bound regime); the larger DIMS are compute-bound on CPU and fusing
# passes cannot win there by construction.
FUSED_DIMS = [("3b-attn", 2048)]
RANK = 64
SEQ = 16  # dispatch-bound on CPU ~= occupancy-bound on GPU; paper uses 512-2048


def _time(fn, *args, iters=3) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def _setup(n, d, r=RANK, seq=SEQ, dtype=jnp.float32, with_w=False):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (n, seq, d), dtype)
    a = jax.random.normal(ks[1], (n, d, r), dtype) * 0.02
    b = jax.random.normal(ks[2], (n, r, d), dtype) * 0.02
    alpha = jnp.ones((n,))
    if with_w:
        w = jax.random.normal(ks[3], (d, d), dtype) * 0.02
        return x, w, a, b, alpha
    return x, a, b, alpha


# ---------------------------------------------------------------------------
# packed vs sequential (paper Tables 7/8)
# ---------------------------------------------------------------------------


@jax.jit
def _packed_fwd(x, a, b, alpha):
    return packed_lora_delta(x, a, b, alpha, impl="xla")


@jax.jit
def _packed_bwd(x, a, b, alpha):
    return jax.grad(
        lambda a, b: (packed_lora_delta(x, a, b, alpha, impl="xla") ** 2).sum(),
        argnums=(0, 1),
    )(a, b)


def _seq_fwd_one(x1, a1, b1, al1):
    return al1 * ((x1 @ a1) @ b1)


_seq_fwd_one_j = jax.jit(_seq_fwd_one)
_seq_bwd_one_j = jax.jit(
    lambda x1, a1, b1, al1: jax.grad(
        lambda a, b: ((al1 * ((x1 @ a) @ b)) ** 2).sum(), argnums=(0, 1)
    )(a1, b1)
)


def _sequential_fwd(x, a, b, alpha):
    return [_seq_fwd_one_j(x[i], a[i], b[i], alpha[i]) for i in range(x.shape[0])]


def _sequential_bwd(x, a, b, alpha):
    return [_seq_bwd_one_j(x[i], a[i], b[i], alpha[i]) for i in range(x.shape[0])]


def _packed_rows(fast: bool) -> List[Dict]:
    rows = []
    ns = [2, 8] if fast else [2, 8, 32]
    dims = DIMS[:2] if fast else DIMS
    for label, d in dims:
        for n in ns:
            x, a, b, alpha = _setup(n, d, seq=SEQ)
            t_pf = _time(_packed_fwd, x, a, b, alpha)
            t_sf = _time(_sequential_fwd, x, a, b, alpha)
            t_pb = _time(_packed_bwd, x, a, b, alpha)
            t_sb = _time(_sequential_bwd, x, a, b, alpha)
            rows.append(
                {
                    "bench": "kernels",
                    "mode": "packed",
                    "dims": label,
                    "d": d,
                    "n_pack": n,
                    "fwd_speedup": t_sf / t_pf,
                    "bwd_speedup": t_sb / t_pb,
                    "packed_fwd_us": t_pf * 1e6,
                    "packed_bwd_us": t_pb * 1e6,
                    # structural: XLA dispatches per iteration
                    "dispatches_packed": 1,
                    "dispatches_sequential": n,
                }
            )
    return rows


# ---------------------------------------------------------------------------
# fused megakernel vs two-pass lora_linear (pass-dispatch granularity)
# ---------------------------------------------------------------------------

_base_j = jax.jit(lambda x, w: x @ w)
_delta_j = jax.jit(lambda x, a, b, al: packed_lora_delta(x, a, b, al, impl="xla"))
_add_j = jax.jit(lambda y, d: y + d)
_fused_j = jax.jit(
    lambda x, w, a, b, al: fused_lora(x, w, a, b, al, impl="fused_xla")
)


def _two_pass(x, w, a, b, al):
    # base pass, delta pass, combine — each its own dispatch, exactly the
    # kernel-launch structure of the unfused path on an accelerator
    return _add_j(_base_j(x, w), _delta_j(x, a, b, al))


def _two_pass_bwd(x, w, a, b, al):
    # grads through the python composition: every jitted pass differentiates
    # (and dispatches) separately
    return jax.grad(
        lambda a, b: (_two_pass(x, w, a, b, al) ** 2).sum(), argnums=(0, 1)
    )(a, b)


_fused_bwd_j = jax.jit(
    lambda x, w, a, b, al: jax.grad(
        lambda a, b: (fused_lora(x, w, a, b, al, impl="fused_xla") ** 2).sum(),
        argnums=(0, 1),
    )(a, b)
)


def _fused_rows(fast: bool) -> List[Dict]:
    rows = []
    ns = [2, 8] if fast else [2, 8, 32]
    for label, d in FUSED_DIMS:
        for n in ns:
            x, w, a, b, alpha = _setup(n, d, seq=SEQ, with_w=True)
            # value parity first (ulp-bounded; the einsum orders differ only
            # in the final bias-free add)
            got = np.asarray(_fused_j(x, w, a, b, alpha), np.float64)
            want = np.asarray(_two_pass(x, w, a, b, alpha), np.float64)
            denom = np.maximum(np.abs(want), 1e-30)
            rel = float(np.max(np.abs(got - want) / denom))
            t_f = _time(_fused_j, x, w, a, b, alpha, iters=9)
            t_2 = _time(_two_pass, x, w, a, b, alpha, iters=9)
            t_fb = _time(_fused_bwd_j, x, w, a, b, alpha, iters=9)
            t_2b = _time(_two_pass_bwd, x, w, a, b, alpha, iters=9)
            rows.append(
                {
                    "bench": "kernels",
                    "mode": "fused",
                    "dims": label,
                    "d": d,
                    "n_pack": n,
                    "fwd_speedup": t_2 / t_f,
                    "bwd_speedup": t_2b / t_fb,
                    "fused_fwd_us": t_f * 1e6,
                    "fused_bwd_us": t_fb * 1e6,
                    "max_rel_err": rel,
                    # structural: kernel dispatches per projection fwd
                    "dispatches_fused": 1,
                    "dispatches_two_pass": 3,
                }
            )
    return rows


# ---------------------------------------------------------------------------
# remat policy crossover (backward xA: save vs recompute)
# ---------------------------------------------------------------------------


def _remat_rows(fast: bool) -> List[Dict]:
    rows = []
    shapes = [(8, 2048)] if fast else [(8, 2048), (8, 11_008), (32, 3584)]
    for n, d in shapes:
        x, a, b, alpha = _setup(n, d, seq=SEQ)

        def bwd(policy):
            return jax.jit(
                lambda x, a, b: jax.grad(
                    lambda a, b: (
                        packed_lora_delta(x, a, b, alpha, impl="xla", remat=policy) ** 2
                    ).sum(),
                    argnums=(0, 1),
                )(a, b)
            )

        t_rec = _time(bwd("recompute"), x, a, b, iters=5)
        t_sav = _time(bwd("save"), x, a, b, iters=5)
        # compare BOTH grads — dB is the one that actually consumes the
        # remat'd xA (dA only sees d(xA)), so a dA-only check would be
        # vacuous for the policy under test
        rec_a, rec_b = bwd("recompute")(x, a, b)
        sav_a, sav_b = bwd("save")(x, a, b)
        identical = bool(
            (np.asarray(rec_a) == np.asarray(sav_a)).all()
            and (np.asarray(rec_b) == np.asarray(sav_b)).all()
        )
        rows.append(
            {
                "bench": "kernels",
                "mode": "remat",
                "d": d,
                "n_pack": n,
                "recompute_bwd_us": t_rec * 1e6,
                "save_bwd_us": t_sav * 1e6,
                "save_speedup": t_rec / t_sav,
                "bit_identical": identical,
            }
        )
    return rows


# ---------------------------------------------------------------------------
# ragged mixed-rank packs: structural FLOPs + wall-clock + loss parity
# ---------------------------------------------------------------------------


def _ragged_rows(fast: bool) -> List[Dict]:
    rows = []
    rank_sets = [(8, 8, 64, 64), (8, 16, 32, 128)]
    if not fast:
        rank_sets.append((8,) * 6 + (128,) * 2)
    d = 2048
    for ranks in rank_sets:
        n = len(ranks)
        bucket = max(8, (max(ranks) + 7) // 8 * 8)
        x, a, b, alpha = _setup(n, d, r=bucket, seq=SEQ)
        mask_a = jnp.arange(bucket)[None, None, :] < jnp.asarray(ranks)[:, None, None]
        mask_b = jnp.arange(bucket)[None, :, None] < jnp.asarray(ranks)[:, None, None]
        a, b = a * mask_a, b * mask_b
        padded = jax.jit(lambda x, a, b: packed_lora_delta(x, a, b, alpha, impl="xla"))
        ragged = jax.jit(
            lambda x, a, b: packed_lora_delta(
                x, a, b, alpha, impl="xla", ranks=ranks
            )
        )
        same = np.allclose(
            np.asarray(padded(x, a, b)), np.asarray(ragged(x, a, b)),
            rtol=1e-6, atol=1e-6,
        )
        t_pad = _time(padded, x, a, b, iters=5)
        t_rag = _time(ragged, x, a, b, iters=5)
        f_pad = delta_flops(ranks, d, d, SEQ, ragged=False)
        f_rag = delta_flops(ranks, d, d, SEQ, ragged=True)
        rows.append(
            {
                "bench": "kernels",
                "mode": "ragged",
                "d": d,
                "n_pack": n,
                "ranks": "/".join(str(r) for r in ranks),
                "r_bucket": bucket,
                "delta_flops_padded": f_pad,
                "delta_flops_ragged": f_rag,
                "flops_saved_frac": 1.0 - f_rag / f_pad,
                "padded_us": t_pad * 1e6,
                "ragged_us": t_rag * 1e6,
                "ragged_speedup": t_pad / t_rag,
                "values_match": bool(same),
            }
        )
    return rows


def _loss_parity_row() -> Dict:
    """Train a tiny heterogeneous-rank pack twice — two-pass xla vs fused —
    and compare the per-adapter loss trajectories (the acceptance metric:
    bit-exact, or the ulp distance reported)."""
    from repro.configs.base import LoraConfig, get_config, reduced
    from repro.core.adapter import pack_meta
    from repro.models.model import init_model
    from repro.train.data import packed_batch_iterator
    from repro.train.optimizer import init_opt_state
    from repro.train.trainer import make_train_step

    cfg = reduced(get_config("qwen25-7b"))
    configs = [
        LoraConfig(rank=8, alpha=16.0, learning_rate=1e-3, batch_size=1, seq_len=32),
        LoraConfig(rank=16, alpha=32.0, learning_rate=5e-4, batch_size=1, seq_len=32),
    ]
    meta = pack_meta(configs)
    base, lora0 = init_model(jax.random.PRNGKey(0), cfg, meta)
    n_steps = 4
    histories = {}
    for impl in ("xla", "fused"):
        step = make_train_step(cfg, meta, impl=impl)
        # real copies: the train step donates lora/opt buffers
        lora = jax.tree.map(lambda v: v + 0, lora0)
        opt = init_opt_state(lora, n_pack=meta.n)
        it = packed_batch_iterator(cfg, configs, seq=32)
        hist = []
        for _ in range(n_steps):
            lora, opt, m = step(base, lora, opt, next(it))
            hist.append(np.asarray(m["per_adapter_loss"], np.float64))
        histories[impl] = np.stack(hist)
    a, b = histories["xla"], histories["fused"]
    bitexact = bool((a == b).all())
    # ulp distance in float32 (the training dtype)
    ulp = int(
        np.max(
            np.abs(
                np.asarray(a, np.float32).view(np.int32).astype(np.int64)
                - np.asarray(b, np.float32).view(np.int32).astype(np.int64)
            )
        )
    )
    return {
        "bench": "kernels",
        "mode": "loss_parity",
        "n_pack": meta.n,
        "steps": n_steps,
        "losses_bitexact": bitexact,
        "max_ulp": ulp,
        "max_rel_err": float(np.max(np.abs(a - b) / np.maximum(np.abs(a), 1e-30))),
    }


def run(fast: bool = False) -> List[Dict]:
    rows = _packed_rows(fast)
    rows += _fused_rows(fast)
    rows += _remat_rows(fast)
    rows += _ragged_rows(fast)
    rows.append(_loss_parity_row())
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default=None, help="dump rows to this file")
    args = ap.parse_args()
    rows = run(args.fast)
    for r in rows:
        if r["mode"] in ("packed", "fused"):
            print(
                f"kernels,{r['mode']},{r.get('dims', '-')},N={r['n_pack']},"
                f"fwd={r['fwd_speedup']:.2f}x,bwd={r['bwd_speedup']:.2f}x"
            )
        elif r["mode"] == "remat":
            print(
                f"kernels,remat,d={r['d']},N={r['n_pack']},"
                f"save={r['save_speedup']:.2f}x,bit={r['bit_identical']}"
            )
        elif r["mode"] == "ragged":
            print(
                f"kernels,ragged,ranks={r['ranks']},"
                f"flops_saved={100 * r['flops_saved_frac']:.0f}%,"
                f"wall={r['ragged_speedup']:.2f}x,match={r['values_match']}"
            )
        else:
            print(
                f"kernels,loss_parity,bitexact={r['losses_bitexact']},"
                f"max_ulp={r['max_ulp']}"
            )
    if args.json:
        import json

        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()

"""Online serving-style orchestration (paper §4 dynamic scheduling): makespan
of Poisson arrival traces under the event-driven engine's policies —

  * static  — frozen-queue baseline (``repack="drain"``): arrivals wait for
              the pool to fully drain before the planner runs again;
  * online  — dynamic repacking (``repack="event"``): replan on every
              admission/device-free event;
  * online+migration — additionally preempt running jobs (budget-capped)
              and repack their unfinished adapters with new arrivals.

Each row is one (model, mean-interarrival, seed) trace; residual step counts
are heterogeneous (200..4000), the regime where waves split across degrees
and repack-on-free matters.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.model_zoo import PAPER_MODELS
from repro.configs.base import default_search_space
from repro.sched.cost_model import A100_40G, CostModel
from repro.sched.engine import ExecutionEngine, poisson_trace

SEQ = 1024
N_STEPS = 1000
STEP_CHOICES = [200, 500, 1000, 2000, 4000]
MIGRATION_BUDGET = 4


def run(fast: bool = False) -> List[Dict]:
    rows = []
    models = (
        ["qwen2.5-7b", "qwen2.5-14b"]
        if fast
        else ["qwen2.5-7b", "qwen2.5-14b", "qwen2.5-32b", "llama-3.1-8b"]
    )
    n_cfg = 16 if fast else 24
    interarrivals = [400.0, 800.0] if fast else [200.0, 400.0, 800.0, 1600.0]
    seeds = [1] if fast else [1, 2]
    for name in models:
        cm = CostModel(PAPER_MODELS[name](), A100_40G)
        eng = ExecutionEngine(cm, 8)
        configs = default_search_space(n_cfg, SEQ)
        steps = np.random.RandomState(0).choice(STEP_CHOICES, size=n_cfg)
        for mi in interarrivals:
            for seed in seeds:
                trace = poisson_trace(configs, mi, seed=seed, steps=steps)
                static = eng.plan_online(trace, SEQ, N_STEPS, repack="drain")
                online = eng.plan_online(trace, SEQ, N_STEPS, repack="event")
                mig = eng.plan_online(
                    trace,
                    SEQ,
                    N_STEPS,
                    repack="event",
                    migration_budget=MIGRATION_BUDGET,
                )
                rows.append(
                    {
                        "bench": "online",
                        "model": name,
                        "interarrival_s": mi,
                        "seed": seed,
                        "n_configs": n_cfg,
                        "static_s": static.makespan,
                        "online_s": online.makespan,
                        "online_mig_s": mig.makespan,
                        "speedup_online": static.makespan / online.makespan,
                        "speedup_mig": static.makespan / mig.makespan,
                        "n_repacks": online.n_repacks,
                        "n_migrations": mig.n_migrations,
                        "util_static": static.utilization(),
                        "util_online": online.utilization(),
                    }
                )
    return rows


def main():
    rows = run()
    best = max(rows, key=lambda r: r["speedup_mig"])
    for r in rows:
        print(
            f"online,{r['model']},mi={r['interarrival_s']:.0f}s,seed={r['seed']},"
            f"static={r['static_s']:.0f}s,online=x{r['speedup_online']:.2f},"
            f"online+mig=x{r['speedup_mig']:.2f},"
            f"nmig={r['n_migrations']},util={r['util_online']:.2f}"
        )
    print(
        f"best,{best['model']},mi={best['interarrival_s']:.0f}s: online repack "
        f"x{best['speedup_online']:.2f}, +migration x{best['speedup_mig']:.2f} "
        f"over the static plan"
    )


if __name__ == "__main__":
    main()

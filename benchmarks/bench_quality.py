"""Paper Tables 2/3/4/6 analogue: REAL hyperparameter sweep on CPU.

A reduced Qwen-family model is LoRA-fine-tuned on the synthetic permutation-
LM task over a grid of LoRA configurations, PACKED into one job (the system's
own machinery), and evaluated on held-out data. Reported:

  - per-hyperparameter quality spread (Table 2 analogue),
  - base vs worst vs best vs default accuracy (Tables 3/6 analogue),
  - the best configuration found (Table 4 analogue).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import numpy as np

from repro.configs.base import LoraConfig, get_config, reduced
from repro.core.adapter import pack_meta
from repro.models import model as M
from repro.train.data import eval_batch, packed_batch_iterator
from repro.train.losses import top1_accuracy
from repro.train.optimizer import init_opt_state
from repro.train.trainer import make_train_step

SEQ = 32


def _grid(fast: bool) -> List[LoraConfig]:
    ranks = [4, 16] if fast else [4, 8, 16, 32]
    lrs = [2e-4, 5e-3] if fast else [2e-5, 2e-4, 1e-3, 5e-3]
    bss = [2] if fast else [1, 4]
    alphas = [0.5, 2.0]
    out = []
    for r in ranks:
        for lr in lrs:
            for bs in bss:
                for am in alphas:
                    out.append(
                        LoraConfig(rank=r, alpha=am * r, learning_rate=lr, batch_size=bs)
                    )
    return out


def run(fast: bool = False) -> List[Dict]:
    cfg = reduced(get_config("qwen25-7b"))
    grid = _grid(fast)
    steps = 15 if fast else 60
    # default config: Unsloth-style defaults (r=16, alpha=16, lr=2e-4, bs=2)
    default = LoraConfig(rank=16, alpha=16.0, learning_rate=2e-4, batch_size=2)
    configs = grid + [default]
    meta = pack_meta(configs)
    base, lora = M.init_model(jax.random.PRNGKey(0), cfg, meta)
    it = packed_batch_iterator(cfg, configs, seq=SEQ, noise=0.05)
    step = make_train_step(cfg, meta)
    opt = init_opt_state(lora)
    for _ in range(steps):
        lora, opt, m = step(base, lora, opt, next(it))
    ev = eval_batch(cfg, meta.n, seq=SEQ, batch=4, noise=0.0)
    h, _, _ = M.forward(base, lora, meta.scales(), {"tokens": ev["tokens"]}, cfg, n_pack=meta.n)
    lg = M.logits(base, h, cfg)
    acc = np.asarray(top1_accuracy(lg, ev["labels"], meta.n))
    # base model (no adapter) accuracy
    h0, _, _ = M.forward(base, {}, meta.scales()[:1], {"tokens": ev["tokens"][:4]}, cfg, n_pack=1)
    acc_base = float(np.asarray(top1_accuracy(M.logits(base, h0, cfg), ev["labels"][:4], 1))[0])

    grid_acc = acc[: len(grid)]
    best_i = int(np.argmax(grid_acc))
    rows = [
        {
            "bench": "quality",
            "metric": "summary",
            "base_acc": acc_base,
            "worst_acc": float(grid_acc.min()),
            "best_acc": float(grid_acc.max()),
            "default_acc": float(acc[-1]),
            "best_minus_default": float(grid_acc.max() - acc[-1]),
            "best_config": str(grid[best_i].key()),
            "n_configs": len(grid),
            "steps": steps,
        }
    ]
    # Table 2 analogue: per-hyperparameter max spread holding others at best
    best = grid[best_i]
    for knob in ("rank", "learning_rate", "batch_size", "alpha"):
        vals = sorted({getattr(c, knob) for c in grid})
        accs = []
        for v in vals:
            match = [
                (i, c) for i, c in enumerate(grid)
                if getattr(c, knob) == v
                and all(
                    getattr(c, k) == getattr(best, k)
                    for k in ("rank", "learning_rate", "batch_size", "alpha")
                    if k != knob
                )
            ]
            if match:
                accs.append(float(grid_acc[match[0][0]]))
        if len(accs) >= 2:
            rows.append(
                {
                    "bench": "quality",
                    "metric": f"spread_{knob}",
                    "max_acc_diff": max(accs) - min(accs),
                    "n_values": len(accs),
                }
            )
    return rows


def main():
    for r in run():
        if r["metric"] == "summary":
            print(
                f"quality,base={r['base_acc']:.3f},worst={r['worst_acc']:.3f},"
                f"best={r['best_acc']:.3f},default={r['default_acc']:.3f},"
                f"best_cfg={r['best_config']}"
            )
        else:
            print(f"quality,{r['metric']},diff={r['max_acc_diff']:.3f}")


if __name__ == "__main__":
    main()

"""Paper Fig. 5 (+ §7.5 Fig. 7): packed-job LoRA throughput vs Min GPU /
Max GPU, per model size and per-adapter batch size, rank fixed at 32.

Throughput metric follows Eq (13): LoRA-FLOP-weight (rank x batch) completed
per second, including the per-job setup amortized over PAPER_STEPS (Min GPU
pays setup once per config; a packed job amortizes it across the pack)."""
from __future__ import annotations

from typing import Dict, List

from benchmarks.model_zoo import PAPER_MODELS, PAPER_SEQ, PAPER_STEPS
from repro.configs.base import LoraConfig
from repro.sched.cost_model import A10_24G, A100_40G, CostModel, HardwareSpec


def _rate(cm: CostModel, configs, d: int, seq: int) -> float:
    """rank*bs per second incl. amortized setup for one job on d devices."""
    t_iter = cm.iter_time(configs, d, seq)
    t_total = cm.setup_time + PAPER_STEPS * t_iter
    work = sum(c.rank * c.batch_size for c in configs) * PAPER_STEPS
    return work / t_total


def _pack_to_memory(cm: CostModel, c: LoraConfig, d: int, seq: int, cap=64):
    """Largest homogeneous pack of `c` that fits d devices (paper: 'pack as
    many as memory allows')."""
    n = 1
    while n < cap and cm.fits([c] * (n + 1), d, seq):
        n += 1
    return [c] * n


def run(fast: bool = False, hw: HardwareSpec = A100_40G) -> List[Dict]:
    rows = []
    models = ["qwen2.5-3b", "qwen2.5-7b"] if fast else list(PAPER_MODELS)
    bss = [1, 4] if fast else [1, 2, 4]
    for name in models:
        cfg = PAPER_MODELS[name]()
        cm = CostModel(cfg, hw)
        for bs in bss:
            c = LoraConfig(rank=32, alpha=32.0, batch_size=bs, seq_len=PAPER_SEQ)
            d_min = cm.min_degree([c], PAPER_SEQ)
            if d_min is None:
                continue
            # Min GPU: G/d_min concurrent single-config jobs
            n_conc = hw.n_devices // d_min
            r_min = n_conc * _rate(cm, [c], d_min, PAPER_SEQ)
            # Max GPU: one job on all G devices
            r_max = _rate(cm, [c], hw.n_devices, PAPER_SEQ)
            # PLoRA: packed jobs on the min degree, memory-bound pack size
            pack = _pack_to_memory(cm, c, d_min, PAPER_SEQ)
            r_plora = n_conc * _rate(cm, pack, d_min, PAPER_SEQ)
            rows.append(
                {
                    "bench": "job_throughput",
                    "hw": hw.name,
                    "model": name,
                    "batch_size": bs,
                    "pack_size": len(pack),
                    "tp_degree": d_min,
                    "speedup_vs_min": r_plora / r_min,
                    "speedup_vs_max": r_plora / r_max,
                }
            )
    return rows


def run_a10(fast: bool = False) -> List[Dict]:
    """§7.5: same benchmark on the A10-24G instance (3B/7B only)."""
    rows = []
    for name in ["qwen2.5-3b", "qwen2.5-7b"]:
        cfg = PAPER_MODELS[name]()
        cm = CostModel(cfg, A10_24G)
        c = LoraConfig(rank=32, alpha=32.0, batch_size=1, seq_len=PAPER_SEQ)
        d_min = cm.min_degree([c], PAPER_SEQ)
        if d_min is None:
            continue
        n_conc = A10_24G.n_devices // d_min
        pack = _pack_to_memory(cm, c, d_min, PAPER_SEQ)
        r_min = n_conc * _rate(cm, [c], d_min, PAPER_SEQ)
        r_plora = n_conc * _rate(cm, pack, d_min, PAPER_SEQ)
        rows.append(
            {
                "bench": "job_throughput_a10",
                "hw": "a10-24g",
                "model": name,
                "batch_size": 1,
                "pack_size": len(pack),
                "tp_degree": d_min,
                "speedup_vs_min": r_plora / r_min,
            }
        )
    # QLoRA variant: 4-bit base halves->quarters the base weights, freeing
    # memory for more adapters (paper reports 4.72x on 7B)
    cfg = PAPER_MODELS["qwen2.5-7b"]()
    cm = CostModel(cfg, A10_24G)
    cm_q = CostModel(cfg, A10_24G, prec_bytes=2)
    # QLoRA: base weights at 0.5 B/param; adapters still bf16
    base_bytes = cm_q.base_weight_bytes()
    cm_q.base_weight_bytes = lambda: base_bytes * 0.25  # type: ignore
    c = LoraConfig(rank=32, alpha=32.0, batch_size=1, seq_len=PAPER_SEQ)
    d_min = cm_q.min_degree([c], PAPER_SEQ)
    if d_min is not None:
        pack = _pack_to_memory(cm_q, c, d_min, PAPER_SEQ)
        n_conc = A10_24G.n_devices // d_min
        r_min = n_conc * _rate(cm_q, [c], d_min, PAPER_SEQ)
        r_plora = n_conc * _rate(cm_q, pack, d_min, PAPER_SEQ)
        rows.append(
            {
                "bench": "job_throughput_a10",
                "hw": "a10-24g",
                "model": "qwen2.5-7b+qlora",
                "batch_size": 1,
                "pack_size": len(pack),
                "tp_degree": d_min,
                "speedup_vs_min": r_plora / r_min,
            }
        )
    return rows


def main():
    for r in run() + run_a10():
        print(
            f"{r['bench']},{r['model']},bs={r['batch_size']},"
            f"pack={r['pack_size']},vs_min={r['speedup_vs_min']:.2f}x"
        )


if __name__ == "__main__":
    main()

"""Profile-guided adaptive re-planning vs trusting a mis-calibrated prior.

The feedback loop's claim: when the analytic cost model is wrong about real
hardware, the planner keeps making the same wrong decision for every job,
and real execution pays for it again and again. Here the deliberate
mis-calibration (``sat_tokens`` x3, ``efficiency`` /3, ``layer_overhead``
/4) makes the prior believe per-iteration time is tiny and shrinks ~linearly
with TP degree — so the trusted plan runs every arriving job at degree 4.
On CPU-XLA the sharding dispatch overhead makes a degree-4 slice ~1.4x
*slower* per step than degree 2. The adaptive engine (``ExecutionEngine``
with a ``ProfiledCostModel``) probes the first job at degree 4, measures the
drift, re-assigns its residual to a *narrower* device group (the paper's
over-provisioning case), probes degree 2 once, and from then on plans every
job with measured step times — recovering the makespan the prior throws
away on every single job.

Workload: six singleton LoRA jobs arriving on a fixed cadence on a 4-unit
pool (forced CPU devices). Memory is sized so one adapter needs degree >= 2
(degree 1 is infeasible for *both* modes) and packs of two never fit, so
every plan is singleton jobs at degree 2 or 4.

Loss guarantees, stated precisely:

  * the adaptive *machinery* is bit-exact — probes, checkpoint splits,
    resumes with exact step/data offsets, and drift re-assignments only
    move work in time and space; the bench re-executes the adaptive run's
    own segments unperturbed (sequentially, no re-planning) and asserts
    per-adapter losses are bit-identical;
  * across the trust/adaptive comparison the *degree* differs by design
    (that is the recovered waste), and XLA's sharded reductions on a
    4-device mesh agree with the 2-device mesh only to float rounding —
    the bench reports that divergence (~1 ulp) and fails if it ever
    exceeds rounding noise.

Like ``bench_cluster``, the bench re-executes itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Dict, List

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_TAG = "ADAPTIVE_ROWS_JSON:"

PROBE_STEPS = 4


def run(fast: bool = False) -> List[Dict]:
    """Spawn the forced-8-device worker and collect its rows."""
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        JAX_PLATFORMS="cpu",
        PYTHONPATH=os.pathsep.join(
            p for p in (os.path.join(_ROOT, "src"), _ROOT,
                        os.environ.get("PYTHONPATH", "")) if p
        ),
    )
    cmd = [sys.executable, "-m", "benchmarks.bench_adaptive", "--worker"]
    if fast:
        cmd.append("--fast")
    proc = subprocess.run(
        cmd, capture_output=True, text=True, env=env, cwd=_ROOT, timeout=1800
    )
    for line in proc.stdout.splitlines():
        if line.startswith(_TAG):
            return json.loads(line[len(_TAG):])
    raise RuntimeError(
        f"adaptive worker produced no rows (exit {proc.returncode}):\n"
        f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}"
    )


def _losses_by_cid(segments, records) -> Dict[int, float]:
    """Final per-adapter loss of every finished config, keyed by cid.
    ``segments``/``records`` must be index-aligned (both engine paths
    return them that way)."""
    out: Dict[int, float] = {}
    for seg, rec in zip(segments, records):
        done = set(seg.done_ids)
        for slot, cid in enumerate(seg.config_ids):
            if cid in done and rec.final_losses is not None:
                out[cid] = float(rec.final_losses[slot])
    return out


def _worker(fast: bool) -> List[Dict]:
    import jax
    import numpy as np

    from repro.cluster import ClusterRunner, DevicePool, SliceExecutor
    from repro.configs.base import LoraConfig, get_config, reduced
    from repro.core.adapter import pack_meta
    from repro.models.model import init_model
    from repro.sched.cost_model import A100_40G, CostModel
    from repro.sched.engine import Arrival, ExecutionEngine
    from repro.sched.profile import ProfiledCostModel
    from repro.train.checkpoint import CheckpointPool

    assert jax.device_count() >= 8, jax.device_count()
    cfg = reduced(get_config("qwen25-7b"))
    seq = 32
    g = 4
    scale = 1 if fast else 2
    steps = 60 * scale

    jobs = [
        LoraConfig(rank=8, alpha=8.0 + i, learning_rate=1e-3, batch_size=2,
                   seq_len=seq)
        for i in range(6)
    ]

    # Hardware spec of the PRIOR, shaped for the demonstration:
    #   * memory sized so a single adapter needs degree >= 2 — every plan
    #     in both modes is singleton jobs at degree 2 or 4 (see the module
    #     docstring for the loss guarantees across that degree difference);
    #   * sat_tokens x3 / efficiency /3 / layer_overhead /4 (the deliberate
    #     mis-calibration): per-step predictions come out tiny and
    #     ~linearly improved by TP degree, so the trusted planner widens
    #     every job to degree 4 — the real machine pays ~1.4x per step for
    #     the extra sharding dispatch.
    hw = A100_40G
    cm0 = CostModel(cfg, hw)
    need1 = max(cm0.job_mem_bytes([c], 1, seq) for c in jobs)
    hw = hw.scaled(mem_bytes=0.7 * need1 / cm0.load_factor)
    hw_bad = hw.scaled(
        sat_tokens=3.0 * hw.sat_tokens,
        efficiency=hw.efficiency / 3.0,
        layer_overhead=hw.layer_overhead / 4.0,
    )

    def make_prior() -> CostModel:
        cm = CostModel(cfg, hw_bad)
        cm.setup_time = 0.0  # virtual seconds, not CPU wall time
        return cm

    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta([jobs[0]]))
    ex = SliceExecutor()  # shared: both modes compare warm dispatch
    devices = jax.devices()[:g]

    # Warm the degree-2/degree-4 executables AND calibrate the arrival
    # cadence to this box's current speed: arrivals land a bit slower than
    # the real degree-2 job duration, so the narrowed (adaptive) schedule
    # keeps up with the queue while the trusted degree-4 schedule falls
    # behind on every job. Runtime calibration (not hard-coded seconds)
    # keeps the scenario meaningful on hosts of any speed/load.
    def measured_iter(units, n: int = 16) -> float:
        dp = DevicePool(devices)
        s = dp.acquire_units(units)
        ex.train_pack(cfg, [jobs[0]], n_steps=2, seq=seq, base=base, slice_=s)
        r = ex.train_pack(cfg, [jobs[0]], n_steps=n, seq=seq, base=base,
                          slice_=s)
        dp.release(s)
        return r.wall_seconds / n

    # every mesh slice the two schedules can use gets its executable built
    # here, outside the timed runs (slice devices are part of the compile
    # cache key — an unwarmed (2, 3) pair would pay XLA compile mid-run)
    t2 = measured_iter((0, 1))
    measured_iter((2, 3), n=2)
    t4 = measured_iter((0, 1, 2, 3))
    spacing = 1.15 * steps * t2
    trace = [Arrival(i * spacing, c, steps) for i, c in enumerate(jobs)]

    def check_shape(segments):
        assert all(
            s.degree in (2, 4) and len(s.config_ids) == 1 for s in segments
        ), "bench invariant: singleton degree-2/4 jobs only"

    def run_trust():
        eng = ExecutionEngine(make_prior(), g)
        runner = ClusterRunner(ex, DevicePool(devices), concurrent=True)
        t0 = time.perf_counter()
        records, sched = eng.run_online_local(
            trace, cfg, base, n_steps=1, seq=seq, runner=runner
        )
        elapsed = time.perf_counter() - t0
        check_shape(sched.segments)
        order = sorted(sched.segments, key=lambda s: (s.start, s.job_id))
        makespan = max(r.real_end for r in records)
        drifts = [
            t.drift for t in runner.last_result.timings if t.run_steps > 0
        ]
        return {
            "makespan": makespan,
            "elapsed": elapsed,
            "losses": _losses_by_cid(order, records),
            "mean_drift": float(np.mean(drifts)),
            "max_drift": float(np.max(drifts)),
        }

    def run_adaptive(pool_dir: str):
        eng = ExecutionEngine(ProfiledCostModel(make_prior()), g)
        runner = ClusterRunner(ex, DevicePool(devices), concurrent=True)
        pool = CheckpointPool(pool_dir)
        t0 = time.perf_counter()
        records, sched = eng.run_online_local(
            trace, cfg, base, n_steps=1, seq=seq, runner=runner,
            pool=pool, probe_steps=PROBE_STEPS,
        )
        elapsed = time.perf_counter() - t0
        check_shape(sched.segments)
        if os.environ.get("ADAPTIVE_BENCH_DEBUG"):
            for s_, r_ in zip(sched.segments, records):
                print(
                    f"  adaptive cid{s_.config_ids} d{s_.degree} "
                    f"u{s_.units} {s_.start_steps[0]}+{s_.run_steps} "
                    f"[{s_.start:6.2f},{s_.end:6.2f}] "
                    f"iter={r_.wall_seconds / max(s_.run_steps, 1):.4f}",
                    file=sys.stderr,
                )
        return {
            "makespan": sched.makespan,
            "elapsed": elapsed,
            "losses": _losses_by_cid(sched.segments, records),
            "n_probes": sched.n_probes,
            "n_reassignments": sched.n_reassignments,
            "n_repacks": sched.n_repacks,
            "segments": sched.segments,
            "total_steps": sched.total_steps,
        }

    def run_replay(adapt, pool_dir: str):
        """Re-execute the adaptive run's OWN segments unperturbed (one at a
        time, no re-planning, fresh checkpoint pool): the bit-exactness
        reference. Probes / mid-run re-assignments / degree changes must
        only move work in time and space, never change what is trained."""
        eng = ExecutionEngine(make_prior(), g)
        runner = ClusterRunner(ex, DevicePool(devices), concurrent=False)
        result = eng._execute_segments(
            adapt["segments"],
            {cid: a.config for cid, a in enumerate(trace)},
            adapt["total_steps"],
            cfg,
            base,
            seq=seq,
            pool=CheckpointPool(pool_dir),
            data_iter_fn=None,
            seed=0,
            runner=runner,
        )
        order = sorted(
            adapt["segments"], key=lambda s: (s.start, s.job_id)
        )
        return {"losses": _losses_by_cid(order, result.records)}

    with tempfile.TemporaryDirectory() as tmp:
        # best-of-2 per mode: 2-core CI boxes are noisy, and the cadence
        # comparison compounds any transient load spike
        trust = min(
            (run_trust() for _ in range(2)), key=lambda r: r["makespan"]
        )
        adapt = min(
            (run_adaptive(os.path.join(tmp, f"timed{i}")) for i in range(2)),
            key=lambda r: r["makespan"],
        )
        replay = run_replay(adapt, os.path.join(tmp, "replay"))

    cids = sorted(trust["losses"])
    assert cids == sorted(adapt["losses"]) == list(range(len(trace)))
    lt = np.asarray([trust["losses"][c] for c in cids], np.float64)
    la = np.asarray([adapt["losses"][c] for c in cids], np.float64)
    lr = np.asarray([replay["losses"][c] for c in cids], np.float64)
    # the machinery guarantee: probe/split/resume/re-assign is bit-exact
    # against an unperturbed replay of the same segments
    bitexact = bool(np.array_equal(la, lr))
    # across the trust/adaptive *degree* difference (deg-4 vs deg-2 mesh
    # slices) XLA's sharded reductions only agree to float rounding —
    # report the divergence rather than pretending it away
    trust_diff = float(np.abs(lt - la).max())
    speedup = trust["makespan"] / adapt["makespan"]
    step_info = {"steps": steps, "spacing": round(spacing, 3),
                 "t2_iter": round(t2, 4), "t4_iter": round(t4, 4)}
    rows = [
        {
            "bench": "adaptive",
            "mode": "trust",
            "g": g,
            "n_jobs": len(trace),
            "steps": json.dumps(step_info),
            "makespan_s": round(trust["makespan"], 3),
            "elapsed_s": round(trust["elapsed"], 3),
            "mean_drift": round(trust["mean_drift"], 3),
            "max_drift": round(trust["max_drift"], 3),
        },
        {
            "bench": "adaptive",
            "mode": "adaptive",
            "g": g,
            "n_jobs": len(trace),
            "steps": json.dumps(step_info),
            "makespan_s": round(adapt["makespan"], 3),
            "elapsed_s": round(adapt["elapsed"], 3),
            "n_probes": adapt["n_probes"],
            "n_reassignments": adapt["n_reassignments"],
            "n_repacks": adapt["n_repacks"],
        },
        {
            "bench": "adaptive",
            "mode": "speedup",
            "g": g,
            "n_jobs": len(trace),
            "speedup_adaptive": round(speedup, 3),
            "losses_bitexact": bitexact,
            "max_loss_diff_vs_trust": trust_diff,
            "n_reassignments": adapt["n_reassignments"],
        },
    ]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    if args.worker:
        rows = _worker(args.fast)
        print(_TAG + json.dumps(rows))
        return 0
    rows = run(args.fast)
    for r in rows:
        if r["mode"] == "speedup":
            print(
                f"adaptive: x{r['speedup_adaptive']:.2f} makespan vs "
                f"plan-and-trust (mis-calibrated prior), "
                f"{r['n_reassignments']} drift re-assignment(s), "
                f"losses bit-exact vs unperturbed replay: "
                f"{r['losses_bitexact']}, vs trust (deg-4 mesh): "
                f"max |diff| {r['max_loss_diff_vs_trust']:.1e}"
            )
        else:
            print(
                f"adaptive,{r['mode']}: makespan {r['makespan_s']:.2f}s "
                f"(elapsed {r['elapsed_s']:.2f}s)"
            )
    # hard guarantee of the feedback loop: re-planning must never change
    # what is trained, only where/when — fail loudly if the probe/split/
    # resume machinery perturbs training (bit-compared against an
    # unperturbed replay of the same segments), or if the trust run (whose
    # jobs execute on wider mesh slices, where XLA's sharded reductions
    # only agree to float rounding) diverges beyond rounding noise
    sp = next(r for r in rows if r["mode"] == "speedup")
    if not sp["losses_bitexact"] or sp["max_loss_diff_vs_trust"] > 1e-5:
        print(f"ERROR: per-adapter losses diverged "
              f"(bitexact={sp['losses_bitexact']}, "
              f"max |diff| vs trust {sp['max_loss_diff_vs_trust']:.3e})")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

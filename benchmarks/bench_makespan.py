"""Paper Fig. 4: makespan of 120-config LoRA hyperparameter tuning —
PLoRA vs Min GPU vs Max GPU, across the paper's §7 model grid, on the
A100-40G x8 cost model (EXPERIMENTS.md §Calibration for the fit)."""
from __future__ import annotations

from typing import Dict, List

from benchmarks.model_zoo import PAPER_MODELS, PAPER_SEQ, PAPER_STEPS
from repro.configs.base import default_search_space
from repro.sched.cost_model import A100_40G, CostModel
from repro.sched.planner import max_gpu_schedule, min_gpu_schedule, plan


def run(fast: bool = False) -> List[Dict]:
    rows = []
    n_cfg = 24 if fast else 120
    models = (
        ["qwen2.5-3b", "qwen2.5-7b"]
        if fast
        else list(PAPER_MODELS)
    )
    space = default_search_space(n_cfg, PAPER_SEQ)
    for name in models:
        cfg = PAPER_MODELS[name]()
        cm = CostModel(cfg, A100_40G)
        s_p = plan(cm, space, 8, PAPER_SEQ, PAPER_STEPS)
        s_min = min_gpu_schedule(cm, space, 8, PAPER_SEQ, PAPER_STEPS)
        s_max = max_gpu_schedule(cm, space, 8, PAPER_SEQ, PAPER_STEPS)
        rows.append(
            {
                "bench": "makespan",
                "model": name,
                "plora_s": s_p.makespan,
                "min_gpu_s": s_min.makespan,
                "max_gpu_s": s_max.makespan,
                "speedup_vs_min": s_min.makespan / s_p.makespan,
                "speedup_vs_max": s_max.makespan / s_p.makespan,
                "ar_bound": s_p.ar(),
                "n_configs": n_cfg,
            }
        )
    return rows


def main():
    for r in run():
        print(
            f"makespan,{r['model']},plora={r['plora_s']:.0f}s,"
            f"vs_min={r['speedup_vs_min']:.2f}x,vs_max={r['speedup_vs_max']:.2f}x,"
            f"AR={r['ar_bound']:.3f}"
        )


if __name__ == "__main__":
    main()

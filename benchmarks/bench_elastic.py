"""Elastic membership: graceful drain, heartbeat recovery, class-aware
placement.

Three suites, one per robustness claim:

* ``drain`` — REAL subprocess hosts (2 x 1 device): the same staggered
  two-config adaptive workload runs once statically and once with host 1
  gracefully drained while its segment is in flight. The drain announces,
  the in-flight probe finishes through the normal success-atomic
  checkpoint path, the residual replans onto host 0, and the units retire.
  Claims: zero training steps lost, the residual actually migrated, and
  per-adapter final losses + adapter trees are bit-exact vs the static
  run — preempt/checkpoint/resume is loss-neutral even across a shrinking
  fleet.

* ``hang`` — emulated fleet (in-memory fake workers): a worker wedges
  mid-segment, going silent while ``alive()`` stays True — the failure
  mode process liveness cannot see and only the heartbeat watchdog can.
  Measures wall-clock from dispatch to the watchdog's DEAD verdict and to
  full recovery (respawn + re-run), and asserts ``run()`` returned a
  complete result instead of hanging.

* ``class`` — emulated 2-fast + 1-slow fleet (real-time fakes; the slow
  class sleeps 4x longer per fabricated step): the same arrival sequence
  — four short narrow jobs, then two long wide jobs — is placed by the
  class-aware unit picker vs the class-blind one, and both placements
  execute for real through the dispatcher. Class-aware parks the narrow
  work on the slow host and keeps a fast host whole, so the wide jobs
  never strand on slow hardware; blind best-fit gives a wide job the slow
  host and eats its 4x tail. The measured makespan gap is the claim.

  PYTHONPATH=src python -m benchmarks.bench_elastic --fast
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from typing import Dict, List

SEQ = 16


# ---------------------------------------------------------------------------
# drain: real hosts, static vs mid-run graceful drain
# ---------------------------------------------------------------------------

def _tree_equal(a, b) -> bool:
    import numpy as np

    from jax import tree_util

    la, lb = tree_util.tree_leaves(a), tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


def _run_drain(fast: bool) -> List[Dict]:
    import jax

    from repro.cluster import HostDispatcher
    from repro.cluster.testing import DictPool
    from repro.configs.base import LoraConfig, get_config, reduced
    from repro.core.adapter import pack_meta
    from repro.models.model import init_model
    from repro.sched.cost_model import A100_40G, CostModel
    from repro.sched.engine import Arrival, ExecutionEngine
    from repro.sched.profile import ProfiledCostModel

    cfg = reduced(get_config("qwen25-7b"))
    steps = 8 if fast else 16
    grid = [
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3,
                   batch_size=1, seq_len=SEQ),
        LoraConfig(rank=8, alpha=16.0, learning_rate=5e-4,
                   batch_size=1, seq_len=SEQ),
    ]
    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta(grid))

    def once(drain: bool):
        prior = CostModel(cfg, A100_40G)
        prior.setup_time = 0.0
        est = ProfiledCostModel(prior, drift_threshold=0.5)
        eng = ExecutionEngine(est, 2, host_size=1)
        pool = DictPool()
        # staggered so the planner cannot pack both configs into one job:
        # config 0 holds unit 0 (host 0) when config 1 arrives -> host 1
        arrivals = [Arrival(0.0, grid[0], steps),
                    Arrival(0.1, grid[1], steps)]
        info = {"drained": False}
        with HostDispatcher([1, 1]) as disp:
            th = None
            if drain:
                def trigger():
                    t0 = time.perf_counter()
                    while disp.in_flight(1) == 0:
                        if time.perf_counter() - t0 > 600:
                            return
                        time.sleep(0.01)
                    # host 1's probe is compiling/running (seconds of
                    # wall) — the drain lands while it is in flight
                    time.sleep(1.0)
                    disp.drain_host(1, timeout=600)
                    info["drained"] = True

                th = threading.Thread(target=trigger, daemon=True)
                th.start()
            t0 = time.perf_counter()
            records, sched = eng.run_online_local(
                arrivals, cfg, base, n_steps=steps, seq=SEQ, pool=pool,
                runner=disp, probe_steps=min(4, steps),
            )
            elapsed = time.perf_counter() - t0
            if th is not None:
                th.join(timeout=600)
                info["state1"] = disp.host_state(1)
                info["retired"] = tuple(disp.device_pool.retired)
            host0_units = set(disp.units_of_host(0))
        executed = {
            cid: sum(s.run_steps for s in sched.segments
                     if cid in s.config_ids)
            for cid in (0, 1)
        }
        # did config 1's residual resume on host 0 after the drain?
        migrated = any(
            1 in s.config_ids and any(st > 0 for st in s.start_steps)
            and set(s.units) <= host0_units
            for s in sched.segments
        )
        return {
            "elapsed": elapsed,
            "makespan": sched.makespan,
            "executed": executed,
            "migrated": migrated,
            "adapters": {k: pool.adapters[k] for k in sorted(pool.adapters)},
            "info": info,
        }

    ref = once(drain=False)
    dr = once(drain=True)
    budget = 2 * steps
    rows: List[Dict] = []
    for mode, r in (("drain_static", ref), ("drain", dr)):
        rows.append({
            "bench": "elastic",
            "mode": mode,
            "steps": steps,
            "elapsed_s": round(r["elapsed"], 3),
            "makespan_s": round(r["makespan"], 3),
            "executed_steps": sum(r["executed"].values()),
            "migrated": r["migrated"],
        })
    same_keys = sorted(ref["adapters"]) == sorted(dr["adapters"])
    bitexact = same_keys and all(
        _tree_equal(ref["adapters"][k][0], dr["adapters"][k][0])
        and ref["adapters"][k][1]["final_loss"]
        == dr["adapters"][k][1]["final_loss"]
        for k in ref["adapters"]
    )
    rows.append({
        "bench": "elastic",
        "mode": "drain_check",
        "steps": steps,
        "steps_lost": budget - sum(dr["executed"].values()),
        "losses_bitexact": bool(bitexact),
        "migrated": dr["migrated"],
        "drained": dr["info"].get("drained", False),
        "host1_state": dr["info"].get("state1", "?"),
        "units_retired": str(dr["info"].get("retired", ())),
    })
    return rows


# ---------------------------------------------------------------------------
# hang: heartbeat watchdog vs a wedged (silent-but-alive) worker
# ---------------------------------------------------------------------------

def _run_hang(tracer=None) -> List[Dict]:
    from repro.cluster import HostDispatcher
    from repro.cluster.multihost import HOST_DEAD
    from repro.cluster.testing import DictPool, FakeHostTransport
    from repro.configs.base import LoraConfig
    from repro.sched.engine import JobSegment

    interval, timeout, dead_after = 0.05, 0.15, 2
    made: List[FakeHostTransport] = []

    def factory(host_id, n_devices):
        # only the FIRST worker instance wedges; the respawn is healthy
        kw = {"hang_on": (lambda idx, payload: idx == 0)} if not made else {}
        tr = FakeHostTransport(host_id, n_devices, **kw)
        made.append(tr)
        return tr

    seg = JobSegment(
        job_id=0, config_ids=(0,), degree=1, start=0.0, end=1.0,
        start_steps=(0,), run_steps=6, done_ids=(0,), units=(0,),
    )
    cfg0 = LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3,
                      batch_size=1, seq_len=SEQ)
    transitions: List = []
    with HostDispatcher(
        [1], transport_factory=factory,
        heartbeat_interval=interval, heartbeat_timeout=timeout,
        heartbeat_dead_after=dead_after, tracer=tracer,
    ) as disp:
        orig = disp._set_host_state

        def spy(host, state, **why):
            transitions.append((time.perf_counter(), state,
                                why.get("reason")))
            orig(host, state, **why)

        disp._set_host_state = spy
        t0 = time.perf_counter()
        result = disp.run(
            [seg], {0: cfg0}, {0: 6}, None, None, seq=SEQ, pool=DictPool(),
        )
        recover_s = time.perf_counter() - t0
        restarts = disp.n_restarts
        final_state = disp.host_state(0)
    dead = [t for t, state, reason in transitions
            if state == HOST_DEAD and reason == "heartbeat_expired"]
    detect_s = (dead[0] - t0) if dead else float("nan")
    recovered = (
        len(result.records) == 1
        and made[0].error is None  # wedged, not crashed on a contract assert
        and restarts == 1
        and len(made) == 2
        and final_state != HOST_DEAD
    )
    return [{
        "bench": "elastic",
        "mode": "hang",
        "heartbeat_interval_s": interval,
        "detect_s": round(detect_s, 3),
        "recover_s": round(recover_s, 3),
        "restarts": restarts,
        "recovered": bool(recovered),
    }]


# ---------------------------------------------------------------------------
# join: mid-run add_host shortens the makespan
# ---------------------------------------------------------------------------

def _run_join(tracer=None) -> List[Dict]:
    from repro.cluster import HostDispatcher
    from repro.cluster.testing import DictPool, FakeHostTransport
    from repro.configs.base import LoraConfig, get_config, reduced
    from repro.sched.cost_model import A100_40G, CostModel
    from repro.sched.engine import Arrival, ExecutionEngine
    from repro.sched.profile import ProfiledCostModel

    cfg = reduced(get_config("qwen25-7b"))
    steps, scale = 12, 0.02
    grid = [
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3,
                   batch_size=1, seq_len=SEQ),
        LoraConfig(rank=8, alpha=16.0, learning_rate=5e-4,
                   batch_size=1, seq_len=SEQ),
    ]

    def once(join: bool) -> float:
        box = {}

        def factory(host_id, n_devices):
            kw = {}
            if host_id == 0 and join:
                # the moment host 0 starts its first segment, a second
                # host joins — the engine replans the queued job onto it
                kw["on_run"] = lambda idx, payload: (
                    box["disp"].add_host(1) if idx == 0 else None
                )
            return FakeHostTransport(
                host_id, n_devices, real_time=True, iter_scale=scale, **kw
            )

        prior = CostModel(cfg, A100_40G)
        prior.setup_time = 0.0
        est = ProfiledCostModel(prior, drift_threshold=0.5)
        with HostDispatcher(
            [1], transport_factory=factory, tracer=tracer,
        ) as disp:
            box["disp"] = disp
            eng = ExecutionEngine(est, disp.total_units, host_size=1)
            # staggered so the planner cannot pack both configs into one job
            arrivals = [Arrival(0.0, grid[0], steps),
                        Arrival(0.05, grid[1], steps)]
            t0 = time.perf_counter()
            records, sched = eng.run_online_local(
                arrivals, cfg, None, n_steps=steps, seq=SEQ,
                pool=DictPool(), runner=disp, probe_steps=4,
            )
            elapsed = time.perf_counter() - t0
        assert sorted(sched.completed) == [0, 1]
        return elapsed

    static = once(join=False)
    joined = once(join=True)
    return [
        {"bench": "elastic", "mode": "join_static", "steps": steps,
         "makespan_s": round(static, 3)},
        {"bench": "elastic", "mode": "join", "steps": steps,
         "makespan_s": round(joined, 3)},
        {"bench": "elastic", "mode": "join_check", "steps": steps,
         "speedup_join": round(static / joined, 3)},
    ]


# ---------------------------------------------------------------------------
# class: class-aware vs class-blind placement on 2 fast + 1 slow hosts
# ---------------------------------------------------------------------------

def _assign(jobs, picker, step_s_of_host, n_units: int, host_size: int):
    """Greedy list-schedule on a virtual clock: place each job, in arrival
    order, at the earliest instant its picker finds a feasible host. The
    virtual start/end times fix the dispatch order; the real run then
    serializes on actual unit leases, so wall-clock makespan is measured,
    not simulated."""
    from repro.sched.engine import JobSegment

    free_at = {u: 0.0 for u in range(n_units)}
    segs = []
    for jid, (degree, steps) in enumerate(jobs):
        units, t = None, 0.0
        for t in sorted(set(free_at.values())):
            free = sorted(u for u, ft in free_at.items() if ft <= t)
            units = picker(free, degree)
            if units is not None:
                break
        assert units is not None, (jid, free_at)
        end = t + steps * step_s_of_host(units[0] // host_size)
        for u in units:
            free_at[u] = end
        segs.append(JobSegment(
            job_id=jid, config_ids=(jid,), degree=degree, start=t, end=end,
            start_steps=(0,), run_steps=steps, done_ids=(jid,),
            units=units,
        ))
    return segs


def _run_class(fast: bool, tracer=None) -> List[Dict]:
    from repro.cluster import HostDispatcher
    from repro.cluster.pool import pick_class_units, pick_host_units
    from repro.cluster.testing import FakeHostTransport
    from repro.configs.base import LoraConfig

    host_size, n_hosts = 2, 3
    classes = ("fast", "fast", "slow")
    ratios = {"fast": 1.0, "slow": 4.0}
    base_s = 0.004  # fabricated seconds per step on a fast host
    s = 15 if fast else 25
    # arrival order: four short narrow jobs, then two long wide jobs —
    # the regime where parking narrow work on slow hosts pays off
    jobs = [(1, s)] * 4 + [(host_size, 3 * s)] * 2
    cfgs = {
        jid: LoraConfig(rank=8, alpha=8.0 + jid, learning_rate=1e-3,
                        batch_size=1, seq_len=SEQ)
        for jid in range(len(jobs))
    }
    total = {jid: st for jid, (_, st) in enumerate(jobs)}

    def step_s(host: int) -> float:
        return base_s * ratios[classes[host]]

    def picker_aware(free, degree):
        return pick_class_units(
            free, degree, host_size,
            class_of_host=lambda h: classes[h],
            ratio_of_class=lambda c: ratios[c],
        )

    def picker_blind(free, degree):
        return pick_host_units(free, degree, host_size)

    rows: List[Dict] = []
    out = {}
    for mode, picker in (("class_aware", picker_aware),
                         ("class_blind", picker_blind)):
        segs = _assign(jobs, picker, step_s, n_hosts * host_size, host_size)
        wide_on_slow = sum(
            1 for g in segs
            if g.degree == host_size and classes[g.units[0] // host_size]
            == "slow"
        )

        def factory(host_id, n_devices):
            return FakeHostTransport(
                host_id, n_devices, real_time=True,
                iter_scale=base_s * ratios[classes[host_id]],
            )

        with HostDispatcher(
            [host_size] * n_hosts, transport_factory=factory,
            host_classes=list(classes), tracer=tracer,
        ) as disp:
            t0 = time.perf_counter()
            result = disp.run(segs, cfgs, total, None, None, seq=SEQ)
            elapsed = time.perf_counter() - t0
        assert len(result.records) == len(jobs)
        out[mode] = elapsed
        rows.append({
            "bench": "elastic",
            "mode": mode,
            "jobs": len(jobs),
            "steps_narrow": s,
            "steps_wide": 3 * s,
            "slow_ratio": ratios["slow"],
            "wide_on_slow": wide_on_slow,
            "makespan_s": round(elapsed, 3),
        })
    rows.append({
        "bench": "elastic",
        "mode": "class_speedup",
        "jobs": len(jobs),
        "speedup_class_aware": round(
            out["class_blind"] / out["class_aware"], 3
        ),
    })
    return rows


def run(fast: bool = False, trace_out: str = None) -> List[Dict]:
    from repro.obs import NULL_TRACER, Tracer

    # the traced suites run over the dispatcher, so the exported trace
    # carries host-tier worker spans plus membership-transition instants —
    # CI gates on `check_trace.py --require-cat host`
    tracer = Tracer() if trace_out else NULL_TRACER
    rows = _run_hang(tracer)
    rows += _run_join(tracer)
    rows += _run_class(fast, tracer)
    rows += _run_drain(fast)
    if trace_out:
        tracer.export(trace_out)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default=None,
                    help="also dump rows to this JSON file")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace of the emulated-fleet suites")
    args = ap.parse_args()
    rows = run(args.fast, trace_out=args.trace_out)
    for r in rows:
        if r["mode"] == "hang":
            print(
                f"elastic,hang: detected in {r['detect_s']:.2f}s "
                f"(heartbeat {r['heartbeat_interval_s']}s), recovered in "
                f"{r['recover_s']:.2f}s with {r['restarts']} restart "
                f"(ok: {r['recovered']})"
            )
        elif r["mode"] == "join_check":
            print(
                f"elastic,join: mid-run add_host x{r['speedup_join']:.2f} "
                f"vs the static 1-host fleet"
            )
        elif r["mode"] in ("join_static", "join"):
            print(f"elastic,{r['mode']}: {r['makespan_s']:.2f}s makespan")
        elif r["mode"] == "class_speedup":
            print(
                f"elastic,class: class-aware x{r['speedup_class_aware']:.2f} "
                f"vs class-blind on 2-fast+1-slow"
            )
        elif r["mode"] == "drain_check":
            print(
                f"elastic,drain: {r['steps_lost']} step(s) lost, losses "
                f"bit-exact: {r['losses_bitexact']}, residual migrated: "
                f"{r['migrated']}, host1 {r['host1_state']}, retired "
                f"{r['units_retired']}"
            )
        elif r["mode"] in ("class_aware", "class_blind"):
            print(
                f"elastic,{r['mode']}: {r['makespan_s']:.2f}s makespan, "
                f"{r['wide_on_slow']} wide job(s) on the slow host"
            )
        else:
            print(
                f"elastic,{r['mode']}: {r['elapsed_s']:.2f}s, "
                f"{r['executed_steps']} steps executed"
            )
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "elastic", "rows": rows}, f, indent=1)
    if args.trace_out:
        print(f"saved Chrome trace to {args.trace_out}")


if __name__ == "__main__":
    main()

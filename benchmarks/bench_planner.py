"""Thm 6.1 validation: approximation-ratio bound of the job planner across
search-space sizes and device counts (paper: AR in [1.05, 1.14]; 286 F-calls
per DTM on 8 GPUs; planning 120 configs under 10 minutes)."""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.model_zoo import PAPER_MODELS, PAPER_SEQ, PAPER_STEPS
from repro.configs.base import default_search_space
from repro.sched.cost_model import A100_40G, CostModel
from repro.sched.planner import plan


def run(fast: bool = False) -> List[Dict]:
    rows = []
    sizes = [24, 60] if fast else [24, 60, 120]
    gs = [4, 8]
    cfg = PAPER_MODELS["qwen2.5-7b"]()
    cm = CostModel(cfg, A100_40G)
    for n_cfg in sizes:
        for g in gs:
            space = default_search_space(n_cfg, PAPER_SEQ)
            t0 = time.perf_counter()
            sched = plan(cm, space, g, PAPER_SEQ, PAPER_STEPS)
            wall = time.perf_counter() - t0
            rows.append(
                {
                    "bench": "planner",
                    "n_configs": n_cfg,
                    "g": g,
                    "ar_bound": sched.ar(),
                    "thm61_bound": sched.ar_bound(),
                    "n_jobs": len(sched.jobs),
                    "n_f_calls": sched.n_f_calls,
                    "plan_wall_s": wall,
                }
            )
    return rows


def main():
    for r in run():
        print(
            f"planner,K={r['n_configs']},G={r['g']},AR={r['ar_bound']:.3f},"
            f"f_calls={r['n_f_calls']},wall={r['plan_wall_s']:.1f}s"
        )


if __name__ == "__main__":
    main()

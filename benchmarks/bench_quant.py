"""Quantized frozen-base tier: memory, packing density, throughput, parity.

Four row families, one per ISSUE claim:

  * ``memory``     — measured bytes of the quantized projection tensors of a
    real (reduced) model tree vs their f32 storage: the int8 row must show
    >= 1.8x reduction (per-channel scales cost ~1/256 extra), nf4 ~7x.
  * ``density``    — cost-model packing: how many LoRA configs co-reside on
    one memory-capped device under f32 / int8 / nf4 base pricing, and the
    planner's job count for a fixed config set (fewer jobs = denser packs).
  * ``throughput`` — fused_xla decode-shaped step (seq=16, dispatch-bound)
    on a quantized base vs the dense base: in-kernel dequant must cost
    <= ~10% (the >= 0.9x tokens/s claim) since the quantized path reads 4x
    fewer weight bytes but adds the dequant epilogue.
  * ``loss_parity``— train a tiny pack on the int8-quantized base and on the
    explicitly dequantized copy of the SAME codes: per-adapter loss
    trajectories must be bit-exact (in-kernel dequant commutes with tiling).

CPU caveat (same as bench_kernels): wall-clock here reflects XLA dispatch
economics, not HBM bandwidth — on an accelerator the quantized path gains
from reading 4x fewer weight bytes; here we only claim it does not LOSE
more than the dequant arithmetic costs. Memory/density/parity rows are
platform-independent.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LoraConfig, get_config, reduced
from repro.kernels.fused import fused_lora
from repro.kernels.quant import (
    dequantize,
    dequantize_base_params,
    is_quantized,
    quantize_base_params,
    quantize_weight,
    quantized_nbytes,
)
from repro.sched.cost_model import A100_40G, CostModel

SEQ = 16  # dispatch-bound (see bench_kernels) — the serving decode regime


def _time(fn, *args, iters=5) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# memory: measured quantized bytes on a real model tree
# ---------------------------------------------------------------------------


def _tree_proj_bytes(params) -> Dict[str, int]:
    """(quantized_bytes, dense_f32_bytes) over every quantized leaf."""
    qbytes = dense = 0

    def walk(node):
        nonlocal qbytes, dense
        if is_quantized(node):
            qbytes += quantized_nbytes(node)
            dense += int(np.prod(np.asarray(dequantize(node)).shape)) * 4
            return
        if isinstance(node, dict):
            for v in node.values():
                walk(v)

    walk(params)
    return {"quant": qbytes, "f32": dense}


def _memory_rows(fast: bool) -> List[Dict]:
    from repro.core.adapter import pack_meta
    from repro.models.model import init_model

    cfg = reduced(get_config("qwen25-7b"))
    meta = pack_meta([LoraConfig(rank=8, alpha=16.0)])
    base, _ = init_model(jax.random.PRNGKey(0), cfg, meta)
    rows = []
    for mode in ("int8", "nf4"):
        qb = quantize_base_params(base, mode)
        b = _tree_proj_bytes(qb)
        rows.append(
            {
                "bench": "quant",
                "mode": "memory",
                "quant": mode,
                "arch": cfg.name,
                "proj_bytes_f32": b["f32"],
                "proj_bytes_quant": b["quant"],
                "memory_ratio": b["f32"] / b["quant"],
            }
        )
    return rows


# ---------------------------------------------------------------------------
# density: cost-model packs per memory-capped device + planner job count
# ---------------------------------------------------------------------------


def _density_rows(fast: bool) -> List[Dict]:
    from repro.sched.planner import plan

    cfg = get_config("qwen25-7b")
    n_cfg = 4 if fast else 8
    configs = [
        LoraConfig(rank=8, alpha=16.0, learning_rate=1e-3 / (i + 1),
                   batch_size=1, seq_len=512)
        for i in range(n_cfg)
    ]
    # cap the device so the dense base + ~2 adapters saturates it: density
    # differences then show up directly in max co-resident configs
    dense0 = CostModel(cfg, A100_40G)
    cap = dense0.job_mem_bytes(configs[:2], 1, 512) * 1.02 / dense0.load_factor
    hw = A100_40G.scaled(mem_bytes=cap)
    rows = []
    base_packs = None
    for quant in (None, "int8", "nf4"):
        cm = CostModel(cfg, hw, base_dtype=quant)
        packs = 0
        while packs < len(configs) and cm.fits(configs[: packs + 1], 1, 512):
            packs += 1
        sched = plan(cm, configs, 2, 512, 200)
        if quant is None:
            base_packs = packs
        rows.append(
            {
                "bench": "quant",
                "mode": "density",
                "quant": quant or "f32",
                "n_configs": n_cfg,
                "max_copack_one_device": packs,
                "planner_jobs": len(sched.jobs),
                "base_bytes_per_param": cm.base_bytes_per_param(),
                "copack_vs_f32": packs - (base_packs or 0),
            }
        )
    return rows


# ---------------------------------------------------------------------------
# throughput: fused step on quantized vs dense base (dispatch-bound shapes)
# ---------------------------------------------------------------------------


def _throughput_rows(fast: bool) -> List[Dict]:
    # the >= 0.9x claim is checked on the WIDEST int8 row: wider packs
    # amortize the per-call dequant over more tokens, which is the regime
    # an accelerator always sits in (tiles dequantized in-register while
    # the next weight tile loads) — so n=32 must be in the fast set too
    d = 2048
    ns = [8, 32] if fast else [8, 16, 32]
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    rows = []
    for n in ns:
        x = jax.random.normal(ks[0], (n, SEQ, d), jnp.float32)
        w = np.asarray(jax.random.normal(ks[1], (d, d), jnp.float32)) * 0.02
        a = jax.random.normal(ks[2], (n, d, 64), jnp.float32) * 0.02
        b = jax.random.normal(ks[3], (n, 64, d), jnp.float32) * 0.02
        alpha = jnp.ones((n,))
        dense_j = jax.jit(
            lambda x, w, a, b, al: fused_lora(x, w, a, b, al, impl="fused_xla")
        )
        for mode in ("int8", "nf4"):
            q = quantize_weight(w, mode)
            quant_j = jax.jit(
                lambda x, q, a, b, al: fused_lora(
                    x, q, a, b, al, impl="fused_xla")
            )
            wd = dequantize(q)
            t_d = _time(dense_j, x, wd, a, b, alpha, iters=9)
            t_q = _time(quant_j, x, q, a, b, alpha, iters=9)
            tokens = n * SEQ
            rows.append(
                {
                    "bench": "quant",
                    "mode": "throughput",
                    "quant": mode,
                    "d": d,
                    "n_pack": n,
                    "dense_us": t_d * 1e6,
                    "quant_us": t_q * 1e6,
                    "tokens_per_s_dense": tokens / t_d,
                    "tokens_per_s_quant": tokens / t_q,
                    "throughput_ratio": t_d / t_q,
                }
            )
    return rows


# ---------------------------------------------------------------------------
# loss parity: quantized base vs dequantized copy, bit-exact trajectories
# ---------------------------------------------------------------------------


def _loss_parity_row() -> Dict:
    from repro.core.adapter import pack_meta
    from repro.models.model import init_model
    from repro.train.data import packed_batch_iterator
    from repro.train.optimizer import init_opt_state
    from repro.train.trainer import make_train_step

    cfg = reduced(get_config("qwen25-7b"))
    configs = [
        LoraConfig(rank=8, alpha=16.0, learning_rate=1e-3, batch_size=1,
                   seq_len=32),
        LoraConfig(rank=16, alpha=32.0, learning_rate=5e-4, batch_size=1,
                   seq_len=32),
    ]
    meta = pack_meta(configs)
    base, lora0 = init_model(jax.random.PRNGKey(0), cfg, meta)
    qbase = quantize_base_params(base, "int8")
    dbase = dequantize_base_params(qbase)  # same VALUES, dense storage
    n_steps = 4
    histories = {}
    for label, bp, bd in (("quant", qbase, "int8"), ("dense", dbase, None)):
        step = make_train_step(cfg, meta, impl="fused_xla", base_dtype=bd)
        lora = jax.tree.map(lambda v: v + 0, lora0)
        opt = init_opt_state(lora, n_pack=meta.n)
        it = packed_batch_iterator(cfg, configs, seq=32)
        hist = []
        for _ in range(n_steps):
            lora, opt, m = step(bp, lora, opt, next(it))
            hist.append(np.asarray(m["per_adapter_loss"], np.float64))
        histories[label] = np.stack(hist)
    a, b = histories["quant"], histories["dense"]
    bitexact = bool((a == b).all())
    ulp = int(
        np.max(
            np.abs(
                np.asarray(a, np.float32).view(np.int32).astype(np.int64)
                - np.asarray(b, np.float32).view(np.int32).astype(np.int64)
            )
        )
    )
    return {
        "bench": "quant",
        "mode": "loss_parity",
        "quant": "int8",
        "n_pack": meta.n,
        "steps": n_steps,
        "losses_bitexact": bitexact,
        "max_ulp": ulp,
    }


def run(fast: bool = False) -> List[Dict]:
    rows = _memory_rows(fast)
    rows += _density_rows(fast)
    rows += _throughput_rows(fast)
    rows.append(_loss_parity_row())
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default=None, help="dump rows to this file")
    args = ap.parse_args()
    rows = run(args.fast)
    for r in rows:
        if r["mode"] == "memory":
            print(
                f"quant,memory,{r['quant']},ratio={r['memory_ratio']:.2f}x "
                f"({r['proj_bytes_f32']}B -> {r['proj_bytes_quant']}B)"
            )
        elif r["mode"] == "density":
            print(
                f"quant,density,{r['quant']},copack={r['max_copack_one_device']},"
                f"jobs={r['planner_jobs']},B/param={r['base_bytes_per_param']:.3f}"
            )
        elif r["mode"] == "throughput":
            print(
                f"quant,throughput,{r['quant']},N={r['n_pack']},"
                f"ratio={r['throughput_ratio']:.2f}x "
                f"({r['tokens_per_s_quant']:.0f} vs {r['tokens_per_s_dense']:.0f} tok/s)"
            )
        else:
            print(
                f"quant,loss_parity,bitexact={r['losses_bitexact']},"
                f"max_ulp={r['max_ulp']}"
            )
    if args.json:
        import json

        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()

"""Benchmark driver: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # full sweep
  PYTHONPATH=src python -m benchmarks.run --fast     # CI-sized
  PYTHONPATH=src python -m benchmarks.run --only kernels,makespan

Prints one CSV block per benchmark and a summary of the paper-claim checks.
Each suite additionally persists a machine-readable ``BENCH_<name>.json``
(rows + numeric-column means + git SHA) under ``--out-dir`` so the perf
trajectory is comparable across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import subprocess
import sys
import time


def _section(title):
    print(f"\n==== {title} " + "=" * max(0, 60 - len(title)))


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _summarize(rows):
    """Means of every numeric column (bools counted as 0/1 fractions) plus
    every per-row speedup value — the machine-readable perf trajectory."""
    num = {}
    for r in rows:
        for k, v in r.items():
            if isinstance(v, bool) or isinstance(v, (int, float)):
                num.setdefault(k, []).append(float(v))
    summary = {f"mean_{k}": sum(v) / len(v) for k, v in num.items() if v}
    speedups = {
        k: v for k, v in num.items() if "speedup" in k or k.startswith("ar_")
    }
    for k, v in speedups.items():
        summary[f"all_{k}"] = v
    return summary


def _peak_rss_bytes() -> int:
    """Process peak RSS so far (ru_maxrss: KiB on Linux, bytes on macOS) —
    a high-water mark over every suite run before this one, recorded per
    suite so memory claims ride in the same JSON as the throughput rows."""
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return int(rss if sys.platform == "darwin" else rss * 1024)


def _persist(out_dir, name, title, rows, wall, fast, sha):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(
            {
                "bench": name,
                "title": title,
                "git_sha": sha,
                "fast": fast,
                "created_unix": time.time(),
                "wall_seconds": round(wall, 2),
                "peak_rss_bytes": _peak_rss_bytes(),
                "n_rows": len(rows),
                "summary": _summarize(rows),
                "rows": rows,
            },
            f,
            indent=1,
        )
    return path


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--json", default=None, help="dump all rows to this file")
    ap.add_argument(
        "--out-dir",
        default="benchmarks/results",
        help="directory for per-suite BENCH_<name>.json files "
             "('' disables persistence)",
    )
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_adaptive,
        bench_breakdown,
        bench_cluster,
        bench_elastic,
        bench_job_throughput,
        bench_kernels,
        bench_makespan,
        bench_multihost,
        bench_online,
        bench_planner,
        bench_quality,
        bench_quant,
        bench_roofline,
        bench_serve,
    )

    benches = {
        "kernels": ("Table 7/8: packed-kernel speedup", bench_kernels.run),
        "quant": ("Quantized frozen base: memory / density / parity", bench_quant.run),
        "makespan": ("Fig. 4: hyperparameter-tuning makespan", bench_makespan.run),
        "online": ("§4 dynamic scheduling: online admission + repacking", bench_online.run),
        "cluster": ("Cluster executor: concurrent mesh slices vs sequential", bench_cluster.run),
        "adaptive": ("Profile feedback loop: adaptive re-planning vs mis-calibrated prior", bench_adaptive.run),
        "multihost": ("Multi-host dispatch tier: 2x4 hosts vs 1x4 on one workload", bench_multihost.run),
        "elastic": ("Elastic membership: drain / heartbeat recovery / class-aware placement", bench_elastic.run),
        "serve": ("Serve tier: continuous multi-LoRA batching vs sequential decode", bench_serve.run),
        "job_throughput": ("Fig. 5: packed-job throughput", bench_job_throughput.run),
        "job_throughput_a10": ("Fig. 7 / §7.5: A10 + QLoRA", lambda fast: bench_job_throughput.run_a10(fast)),
        "breakdown": ("Fig. 6: speedup breakdown", bench_breakdown.run),
        "planner": ("Thm 6.1: AR bound / planner cost", bench_planner.run),
        "quality": ("Tables 2/3/6: quality sweep (real training)", bench_quality.run),
        "roofline": ("Assignment: roofline terms (from dry-run)", bench_roofline.run),
    }
    selected = list(benches) if not args.only else args.only.split(",")

    sha = _git_sha()
    all_rows = []
    checks = []
    for name in selected:
        title, fn = benches[name]
        _section(title)
        t0 = time.perf_counter()
        try:
            rows = fn(args.fast)
        except Exception as e:  # keep the driver alive across benches
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            continue
        wall = time.perf_counter() - t0
        all_rows.extend(rows)
        if rows:
            last_keys = None
            for r in rows:
                keys = list(r.keys())
                if keys != last_keys:
                    print(",".join(keys))
                    last_keys = keys
                print(",".join(_fmt(r.get(k)) for k in keys))
        print(f"# {name}: {len(rows)} rows in {wall:.1f}s")
        if args.out_dir:
            path = _persist(
                args.out_dir, name, title, rows, wall, args.fast, sha
            )
            print(f"# wrote {path}")

        # paper-claim checks
        if name == "makespan" and rows:
            best = max(r["speedup_vs_min"] for r in rows)
            checks.append(("makespan speedup vs MinGPU (paper <=7.52x)", f"{best:.2f}x"))
        if name == "online" and rows:
            best = max(r["speedup_mig"] for r in rows)
            wins = sum(1 for r in rows if r["speedup_online"] > 1.001)
            checks.append(("online repack beats static plan (traces won)", f"{wins}/{len(rows)}"))
            checks.append(("best online+migration speedup vs static", f"{best:.2f}x"))
        if name == "cluster" and rows:
            sp = [r for r in rows if r["mode"] == "speedup"]
            if sp:
                best = max(r["speedup_concurrent"] for r in sp)
                exact = all(r["losses_bitexact"] for r in sp)
                checks.append(("concurrent slices vs sequential (forced 8-dev host)", f"{best:.2f}x"))
                checks.append(("concurrent per-adapter losses bit-exact", str(exact)))
        if name == "multihost" and rows:
            sp = [r for r in rows if r["mode"] == "speedup"]
            if sp:
                checks.append(("multi-host 2x4 vs 1x4 makespan (>=1.1x)", f"{sp[0]['speedup_multihost']:.2f}x"))
                checks.append(("multi-host per-adapter losses bit-exact vs 1-host", str(all(r["losses_bitexact"] for r in sp))))
        if name == "elastic" and rows:
            dc = [r for r in rows if r["mode"] == "drain_check"]
            if dc:
                checks.append(("graceful drain: training steps lost (must be 0)", str(dc[0]["steps_lost"])))
                checks.append(("drained-run losses bit-exact vs static run", str(dc[0]["losses_bitexact"])))
            hg = [r for r in rows if r["mode"] == "hang"]
            if hg:
                checks.append(
                    ("hung worker: heartbeat-detected + recovered without hanging run()",
                     f"{hg[0]['recovered']} (detect {hg[0]['detect_s']:.2f}s, "
                     f"{hg[0]['restarts']} restart)"))
            jn = [r for r in rows if r["mode"] == "join_check"]
            if jn:
                checks.append(("mid-run host join shortens makespan (>=1.1x)", f"{jn[0]['speedup_join']:.2f}x"))
            sp = [r for r in rows if r["mode"] == "class_speedup"]
            if sp:
                checks.append(("class-aware vs class-blind makespan, 2-fast+1-slow (>=1.2x)", f"{sp[0]['speedup_class_aware']:.2f}x"))
        if name == "serve" and rows:
            sp = [r for r in rows if r["mode"] == "speedup"]
            if sp:
                checks.append(("continuous batching vs sequential decode, tokens/s (>=1.5x)", f"{sp[0]['speedup_serve']:.2f}x"))
                checks.append(("served tokens bit-exact vs per-request baseline", str(all(r["tokens_bitexact"] for r in sp))))
                checks.append(("distinct adapters served in one batch", str(sp[0]["adapters_served"])))
            psp = [r for r in rows if r["mode"] == "prefill_speedup"]
            if psp:
                checks.append(("chunked admission cuts p95 ITL vs synchronous prefill, bursty long prompts (>=1x)", f"{psp[0]['itl_p95_speedup']:.2f}x"))
                checks.append(("bursty-trace tokens bit-exact vs sequential baseline", str(all(r["tokens_bitexact"] for r in psp))))
        if name == "adaptive" and rows:
            sp = [r for r in rows if r["mode"] == "speedup"]
            if sp:
                checks.append(("adaptive re-planning vs mis-calibrated prior (>=1.1x)", f"{sp[0]['speedup_adaptive']:.2f}x"))
                checks.append(("adaptive machinery bit-exact vs unperturbed replay", str(all(r["losses_bitexact"] for r in sp))))
        if name == "job_throughput" and rows:
            best = max(r["speedup_vs_min"] for r in rows)
            checks.append(("job throughput vs MinGPU (paper <=12.8x)", f"{best:.2f}x"))
        if name == "kernels" and rows:
            n32 = [r for r in rows if r["mode"] == "packed" and r["n_pack"] == 32]
            if n32:
                best = max(r["fwd_speedup"] for r in n32)
                checks.append(("packed-kernel N=32 fwd speedup (paper ~26-31x on GPU; CPU-XLA differs)", f"{best:.2f}x"))
            fused = [r for r in rows if r["mode"] == "fused"]
            if fused:
                best = max(
                    max(r["fwd_speedup"], r["bwd_speedup"]) for r in fused
                )
                checks.append(("fused megakernel vs two-pass, dispatch-bound seq=16 (>=1.15x)", f"{best:.2f}x"))
            ragged = [r for r in rows if r["mode"] == "ragged"]
            if ragged:
                best = max(r["flops_saved_frac"] for r in ragged)
                ok = all(r["values_match"] for r in ragged)
                checks.append(("ragged mixed-rank delta FLOPs saved vs bucket padding", f"{100 * best:.0f}% (values match: {ok})"))
            parity = [r for r in rows if r["mode"] == "loss_parity"]
            if parity:
                p = parity[0]
                checks.append(("fused-vs-two-pass per-adapter losses", "bit-exact" if p["losses_bitexact"] else f"max {p['max_ulp']} ulp"))
        if name == "quant" and rows:
            mem = [r for r in rows if r["mode"] == "memory"]
            i8 = [r for r in mem if r["quant"] == "int8"]
            if i8:
                checks.append(("int8 base-weight memory reduction (>=1.8x)", f"{i8[0]['memory_ratio']:.2f}x"))
            dens = {r["quant"]: r for r in rows if r["mode"] == "density"}
            if "f32" in dens and "int8" in dens:
                up = dens["int8"]["max_copack_one_device"] > dens["f32"]["max_copack_one_device"]
                checks.append(
                    ("int8 packs strictly denser than f32 (copack/jobs)",
                     f"{dens['f32']['max_copack_one_device']}->"
                     f"{dens['int8']['max_copack_one_device']} configs, "
                     f"{dens['f32']['planner_jobs']}->"
                     f"{dens['int8']['planner_jobs']} jobs (up: {up})"))
            thr = [r for r in rows
                   if r["mode"] == "throughput" and r["quant"] == "int8"]
            if thr:
                widest = max(thr, key=lambda r: r["n_pack"])
                checks.append(
                    (f"int8 tokens/s vs dense, widest pack N={widest['n_pack']} (>=0.9x)",
                     f"{widest['throughput_ratio']:.2f}x"))
            parity = [r for r in rows if r["mode"] == "loss_parity"]
            if parity:
                p = parity[0]
                checks.append(("quantized-vs-dequantized per-adapter losses", "bit-exact" if p["losses_bitexact"] else f"max {p['max_ulp']} ulp"))
        if name == "planner" and rows:
            ar = max(r["ar_bound"] for r in rows)
            checks.append(("planner AR bound (paper 1.05-1.14)", f"{ar:.3f}"))
        if name == "quality" and rows:
            s = rows[0]
            checks.append(
                ("best vs default accuracy gain (paper +2.9..23.4pp)",
                 f"+{100 * s['best_minus_default']:.1f}pp"),
            )

    _section("paper-claim summary")
    for k, v in checks:
        print(f"{k}: {v}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(all_rows, f, indent=1)
    return 0


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


if __name__ == "__main__":
    sys.exit(main())

"""Benchmark driver: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # full sweep
  PYTHONPATH=src python -m benchmarks.run --fast     # CI-sized
  PYTHONPATH=src python -m benchmarks.run --only kernels,makespan

Prints one CSV block per benchmark and a summary of the paper-claim checks.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _section(title):
    print(f"\n==== {title} " + "=" * max(0, 60 - len(title)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--json", default=None, help="dump all rows to this file")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_breakdown,
        bench_cluster,
        bench_job_throughput,
        bench_kernels,
        bench_makespan,
        bench_online,
        bench_planner,
        bench_quality,
        bench_roofline,
    )

    benches = {
        "kernels": ("Table 7/8: packed-kernel speedup", bench_kernels.run),
        "makespan": ("Fig. 4: hyperparameter-tuning makespan", bench_makespan.run),
        "online": ("§4 dynamic scheduling: online admission + repacking", bench_online.run),
        "cluster": ("Cluster executor: concurrent mesh slices vs sequential", bench_cluster.run),
        "job_throughput": ("Fig. 5: packed-job throughput", bench_job_throughput.run),
        "job_throughput_a10": ("Fig. 7 / §7.5: A10 + QLoRA", lambda fast: bench_job_throughput.run_a10(fast)),
        "breakdown": ("Fig. 6: speedup breakdown", bench_breakdown.run),
        "planner": ("Thm 6.1: AR bound / planner cost", bench_planner.run),
        "quality": ("Tables 2/3/6: quality sweep (real training)", bench_quality.run),
        "roofline": ("Assignment: roofline terms (from dry-run)", bench_roofline.run),
    }
    selected = list(benches) if not args.only else args.only.split(",")

    all_rows = []
    checks = []
    for name in selected:
        title, fn = benches[name]
        _section(title)
        t0 = time.perf_counter()
        try:
            rows = fn(args.fast)
        except Exception as e:  # keep the driver alive across benches
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            continue
        wall = time.perf_counter() - t0
        all_rows.extend(rows)
        if rows:
            last_keys = None
            for r in rows:
                keys = list(r.keys())
                if keys != last_keys:
                    print(",".join(keys))
                    last_keys = keys
                print(",".join(_fmt(r.get(k)) for k in keys))
        print(f"# {name}: {len(rows)} rows in {wall:.1f}s")

        # paper-claim checks
        if name == "makespan" and rows:
            best = max(r["speedup_vs_min"] for r in rows)
            checks.append(("makespan speedup vs MinGPU (paper <=7.52x)", f"{best:.2f}x"))
        if name == "online" and rows:
            best = max(r["speedup_mig"] for r in rows)
            wins = sum(1 for r in rows if r["speedup_online"] > 1.001)
            checks.append(("online repack beats static plan (traces won)", f"{wins}/{len(rows)}"))
            checks.append(("best online+migration speedup vs static", f"{best:.2f}x"))
        if name == "cluster" and rows:
            sp = [r for r in rows if r["mode"] == "speedup"]
            if sp:
                best = max(r["speedup_concurrent"] for r in sp)
                exact = all(r["losses_bitexact"] for r in sp)
                checks.append(("concurrent slices vs sequential (forced 8-dev host)", f"{best:.2f}x"))
                checks.append(("concurrent per-adapter losses bit-exact", str(exact)))
        if name == "job_throughput" and rows:
            best = max(r["speedup_vs_min"] for r in rows)
            checks.append(("job throughput vs MinGPU (paper <=12.8x)", f"{best:.2f}x"))
        if name == "kernels" and rows:
            n32 = [r for r in rows if r["n_pack"] == 32]
            if n32:
                best = max(r["fwd_speedup"] for r in n32)
                checks.append(("packed-kernel N=32 fwd speedup (paper ~26-31x on GPU; CPU-XLA differs)", f"{best:.2f}x"))
        if name == "planner" and rows:
            ar = max(r["ar_bound"] for r in rows)
            checks.append(("planner AR bound (paper 1.05-1.14)", f"{ar:.3f}"))
        if name == "quality" and rows:
            s = rows[0]
            checks.append(
                ("best vs default accuracy gain (paper +2.9..23.4pp)",
                 f"+{100 * s['best_minus_default']:.1f}pp"),
            )

    _section("paper-claim summary")
    for k, v in checks:
        print(f"{k}: {v}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(all_rows, f, indent=1)
    return 0


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


if __name__ == "__main__":
    sys.exit(main())

"""Serve tier: continuous batching vs sequential per-request decode.

The serving claim: a width-R continuous batch whose rows each carry their
own adapter (admission/retirement per token step) serves a Poisson request
trace at higher tokens/s than the pre-engine path — one request at a time
at width 1 — while emitting *bit-identical* tokens per request. Both modes
run with a real training job executing concurrently through the engine's
Runner interface on the same DevicePool (the tune side of tune-then-serve),
and both are measured warm (pass 1 compiles, pass 2 is reported).

Non-MoE config (gemma3-style): MoE capacity couples decode rows, which
would break the row-independence the bit-exactness claim rests on. Width is
pinned at 4 rows: row results are bitwise width-invariant up to moderate
batch widths (verified), but much wider batches can change XLA's batched-
matmul tiling — and with it reduction order — at the ulp level.

A second, bursty long-prompt trace measures the prefill-stall fix (ISSUE
9): synchronous one-shot admission vs chunked decode-interleaved admission
(``prefill_chunk``), p95 ITL/TTFT head to head, tokens still bit-exact vs
the sequential baseline.
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from typing import Dict, List


def run(fast: bool = False, trace_out: str = None) -> List[Dict]:
    import jax
    import numpy as np

    from repro.configs.base import LoraConfig, get_config, reduced
    from repro.core.adapter import pack_meta
    from repro.core.packed_lora import extract_adapter
    from repro.models.model import init_model
    from repro.obs import NULL_TRACER, Tracer
    from repro.sched.cost_model import A100_40G, CostModel
    from repro.sched.engine import ExecutionEngine
    from repro.sched.planner import Schedule, ScheduledJob
    from repro.serve.engine import ServeEngine, poisson_requests

    tracer = Tracer() if trace_out else NULL_TRACER

    cfg = reduced(get_config("gemma3-1b"))
    seq = 16
    rank, alpha = 8, 16.0
    rows = 4
    n_adapters = 4 if fast else 6
    n_requests = 12 if fast else 20
    max_new = 8 if fast else 12
    train_steps = 8 if fast else 24

    # "trained" adapters: one nudged pack, one slot each
    meta = pack_meta([LoraConfig(rank=rank, alpha=alpha)] * n_adapters)
    base, lora = init_model(jax.random.PRNGKey(0), cfg, meta)
    lora = jax.tree.map(lambda x: x + 0.02, lora)

    eng = ServeEngine(
        cfg, base, rows=rows, smax=32, r_bucket=rank,
        slot_capacity=n_adapters + 1, tracer=tracer,
    )
    for i in range(n_adapters):
        eng.publish(f"ad{i}", extract_adapter(jax.tree.map(np.asarray, lora), i),
                    {"rank": rank, "alpha": alpha})

    rng = np.random.RandomState(7)
    prompts = [
        rng.randint(0, cfg.vocab_size, size=(6 if i % 2 else 8)).astype(np.int32)
        for i in range(n_requests)
    ]
    reqs = poisson_requests(
        [f"ad{i % n_adapters}" for i in range(n_requests)], prompts,
        mean_interarrival=1.0, max_new_tokens=max_new, seed=11,
    )

    # concurrent training job through the engine's Runner interface, on the
    # shared pool; serving reserves one unit when the pool has more than one
    train_cfgs = [
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1,
                   seq_len=seq),
        LoraConfig(rank=8, alpha=16.0, learning_rate=5e-4, batch_size=1,
                   seq_len=seq),
    ]
    reserve = 1 if eng.device_pool.total > 1 else 0
    g = max(1, eng.device_pool.total - reserve)
    cm = CostModel(cfg, A100_40G)
    exec_eng = ExecutionEngine(cm, g, tracer=tracer)
    jobs = [
        ScheduledJob((i,), 1, float(i // g), float(i // g) + 1.0)
        for i in range(len(train_cfgs))
    ]
    sched = Schedule(jobs, float(-(-len(train_cfgs) // g)), g)

    def measure(mode: str):
        train_done = {}

        def train():
            t0 = time.perf_counter()
            records, _ = exec_eng.run_local(
                sched, train_cfgs, cfg, base, n_steps=train_steps, seq=seq,
                runner=eng,
            )
            train_done["wall"] = time.perf_counter() - t0
            train_done["jobs"] = len(records)

        th = threading.Thread(target=train)
        # the serve lease spans the whole concurrent window (acquired before
        # training dispatch starts, released after it drains): the training
        # runner sees a stable foreign lease, not one appearing mid-run
        if mode == "continuous" and reserve:
            with eng.serve_lease(reserve):
                th.start()
                try:
                    stats = eng.serve(reqs)
                finally:
                    th.join()
        else:
            th.start()
            try:
                stats = (
                    eng.serve(reqs) if mode == "continuous"
                    else eng.serve_sequential(reqs)
                )
            finally:
                th.join()
        return stats, train_done

    out = {}
    rows_out: List[Dict] = []
    for mode in ("continuous", "sequential"):
        measure(mode)  # cold: compiles
        a, b = measure(mode), measure(mode)  # warm, best-of-2 (noisy boxes)
        stats, train_done = max(a, b, key=lambda r: r[0].tokens_per_s)
        out[mode] = stats

        def _ms(summary, q):
            v = summary[q]
            return round(1e3 * v, 3) if v == v else None  # NaN -> null

        lat = stats.latency_summaries()
        rows_out.append(
            {
                "bench": "serve",
                "mode": mode,
                "rows": rows if mode == "continuous" else 1,
                "requests": n_requests,
                "adapters": n_adapters,
                "max_new_tokens": max_new,
                "decode_steps": stats.steps,
                "tokens": stats.tokens_emitted,
                "elapsed_s": round(stats.wall_seconds, 3),
                "tokens_per_s": round(stats.tokens_per_s, 2),
                "mean_occupancy": round(stats.mean_occupancy, 2),
                "adapters_served": stats.adapters_served,
                "train_jobs_concurrent": train_done.get("jobs", 0),
                "train_wall_s": round(train_done.get("wall", 0.0), 3),
                "ttft_ms_p50": _ms(lat["ttft"], "p50"),
                "ttft_ms_p95": _ms(lat["ttft"], "p95"),
                "ttft_ms_p99": _ms(lat["ttft"], "p99"),
                "itl_ms_p50": _ms(lat["itl"], "p50"),
                "itl_ms_p95": _ms(lat["itl"], "p95"),
                "itl_ms_p99": _ms(lat["itl"], "p99"),
                "queue_wait_ms_p50": _ms(lat["queue_wait"], "p50"),
                "queue_wait_ms_p95": _ms(lat["queue_wait"], "p95"),
                "queue_wait_ms_p99": _ms(lat["queue_wait"], "p99"),
            }
        )
    cont, seqs = out["continuous"], out["sequential"]
    bitexact = len(cont.results) == len(seqs.results) and all(
        np.array_equal(a.tokens, b.tokens)
        for a, b in zip(cont.results, seqs.results)
    )
    rows_out.append(
        {
            "bench": "serve",
            "mode": "speedup",
            "requests": n_requests,
            "adapters_served": cont.adapters_served,
            "speedup_serve": round(
                cont.tokens_per_s / seqs.tokens_per_s, 3
            ) if seqs.tokens_per_s else float("nan"),
            "tokens_bitexact": bool(bitexact),
        }
    )

    # ---- bursty long-prompt trace: the prefill-stall fix ------------------
    # Bursts of long prompts are the pathological case for synchronous
    # admission: every one-shot prefill freezes all in-flight rows, and the
    # frozen rows' inter-token gaps blow out p95 ITL. Chunked admission pays
    # the same prefill in bounded slices interleaved with decode steps.
    n_burst = 8 if fast else 12
    burst_every = 6  # virtual steps between bursts of 4 arrivals
    # Dedicated RNG + a fixed length grid: lengths from a small set bound
    # the prefill compile shapes, and the pinned seed is a trace verified
    # bitwise width-invariant — batched decode flips argmax near-ties at
    # the ulp level on *some* prompt draws (same caveat as the 4-row pin),
    # so the baseline comparison needs a checked trace, not a lucky one.
    brng = np.random.RandomState(100)
    long_prompts = [
        brng.randint(
            0, cfg.vocab_size, size=int(brng.choice([20, 22, 24, 26, 28]))
        ).astype(np.int32)
        for _ in range(n_burst)
    ]
    import dataclasses

    burst_reqs = [
        dataclasses.replace(r, arrival=float((i // rows) * burst_every))
        for i, r in enumerate(
            poisson_requests(
                [f"ad{i % n_adapters}" for i in range(n_burst)],
                long_prompts, 1.0, max_new_tokens=max_new, seed=23,
            )
        )
    ]
    chunk = 8

    def bursty_engine(prefill_chunk):
        e = ServeEngine(
            cfg, base, rows=rows, smax=48, r_bucket=rank,
            slot_capacity=n_adapters + 1, tracer=tracer,
            prefill_chunk=prefill_chunk,
        )
        for i in range(n_adapters):
            e.publish(
                f"ad{i}",
                extract_adapter(jax.tree.map(np.asarray, lora), i),
                {"rank": rank, "alpha": alpha},
            )
        return e

    ref = bursty_engine(None).serve_sequential(burst_reqs)
    bursty = {}
    for mode, pc in (("sync_admission", None), ("chunked_admission", chunk)):
        e = bursty_engine(pc)
        e.serve(burst_reqs)  # cold: compiles
        a, b = e.serve(burst_reqs), e.serve(burst_reqs)  # warm, best-of-2
        stats = min(
            a, b, key=lambda s: s.latency_summaries()["itl"]["p95"]
        )
        bursty[mode] = stats
        lat = stats.latency_summaries()
        rows_out.append(
            {
                "bench": "serve",
                "mode": mode,
                "rows": rows,
                "requests": n_burst,
                "prefill_chunk": pc,
                "decode_steps": stats.steps,
                "tokens": stats.tokens_emitted,
                "elapsed_s": round(stats.wall_seconds, 3),
                "tokens_per_s": round(stats.tokens_per_s, 2),
                "ttft_ms_p50": _ms(lat["ttft"], "p50"),
                "ttft_ms_p95": _ms(lat["ttft"], "p95"),
                "itl_ms_p50": _ms(lat["itl"], "p50"),
                "itl_ms_p95": _ms(lat["itl"], "p95"),
                "itl_ms_p99": _ms(lat["itl"], "p99"),
                "queue_wait_ms_p95": _ms(lat["queue_wait"], "p95"),
            }
        )
    sync_s, chnk = bursty["sync_admission"], bursty["chunked_admission"]
    burst_bitexact = all(
        len(s.results) == len(ref.results) and all(
            np.array_equal(x.tokens, y.tokens)
            for x, y in zip(s.results, ref.results)
        )
        for s in (sync_s, chnk)
    )
    sp95 = sync_s.latency_summaries()["itl"]["p95"]
    cp95 = chnk.latency_summaries()["itl"]["p95"]
    rows_out.append(
        {
            "bench": "serve",
            "mode": "prefill_speedup",
            "requests": n_burst,
            "prefill_chunk": chunk,
            "itl_p95_sync_ms": round(1e3 * sp95, 3),
            "itl_p95_chunked_ms": round(1e3 * cp95, 3),
            "itl_p95_speedup": round(sp95 / cp95, 3) if cp95 else float("nan"),
            "tokens_bitexact": bool(burst_bitexact),
        }
    )
    if trace_out:
        tracer.export(trace_out)
    return rows_out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default=None,
                    help="also dump rows to this JSON file")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON of the serve runs "
                         "(Perfetto-loadable: serve steps, per-row request "
                         "residency, admissions/prefills)")
    args = ap.parse_args()
    rows = run(args.fast, trace_out=args.trace_out)
    for r in rows:
        if r["mode"] == "speedup":
            print(
                f"serve: continuous batching x{r['speedup_serve']:.2f} "
                f"tokens/s vs sequential, {r['adapters_served']} adapters "
                f"served, tokens bit-exact: {r['tokens_bitexact']}"
            )
        elif r["mode"] == "prefill_speedup":
            print(
                f"serve,bursty: chunked admission p95 ITL "
                f"{r['itl_p95_chunked_ms']} ms vs sync "
                f"{r['itl_p95_sync_ms']} ms "
                f"(x{r['itl_p95_speedup']:.2f}), tokens bit-exact: "
                f"{r['tokens_bitexact']}"
            )
        elif r["mode"] in ("sync_admission", "chunked_admission"):
            print(
                f"serve,bursty,{r['mode']}: {r['tokens']} tokens in "
                f"{r['elapsed_s']:.2f}s, ttft p95 {r['ttft_ms_p95']} ms, "
                f"itl p95 {r['itl_ms_p95']} ms "
                f"(prefill_chunk={r['prefill_chunk']})"
            )
        else:
            print(
                f"serve,{r['mode']}: {r['tokens']} tokens in "
                f"{r['elapsed_s']:.2f}s ({r['tokens_per_s']:.1f} tok/s, "
                f"occupancy {r['mean_occupancy']}), "
                f"ttft p95 {r['ttft_ms_p95']} ms, itl p50 {r['itl_ms_p50']} "
                f"ms, {r['train_jobs_concurrent']} training jobs concurrent"
            )
    if args.trace_out:
        print(f"saved Chrome trace to {args.trace_out}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "serve", "rows": rows}, f, indent=1)


if __name__ == "__main__":
    main()

"""Online hyperparameter sweep: jobs ARRIVE over time instead of being known
up front — the serving-style orchestration the event-driven engine enables.

Simulated what-if (default, cost-model virtual time, a pod-scale A100x8):

  PYTHONPATH=src python examples/online_sweep.py

Real execution of the same event loop on this host (CPU XLA, reduced model;
includes a preemption + checkpoint-pool resume):

  PYTHONPATH=src python examples/online_sweep.py --real
"""
import argparse
import shutil
import tempfile

import numpy as np

from repro.configs.base import LoraConfig, default_search_space, get_config, reduced
from repro.sched.cost_model import A100_40G, CostModel
from repro.sched.engine import Arrival, ExecutionEngine, poisson_trace


def simulated_whatif():
    cfg = get_config("command-r-35b")  # memory-bound: waves split degrees
    cm = CostModel(cfg, A100_40G)
    eng = ExecutionEngine(cm, 8)
    seq, n = 1024, 16
    configs = default_search_space(n, seq)
    steps = np.random.RandomState(0).choice([200, 500, 1000, 2000, 4000], size=n)
    trace = poisson_trace(configs, mean_interarrival=800.0, seed=1, steps=steps)
    print(f"{n} LoRA configs arrive Poisson(mean 800s) on {cfg.name}, A100-40G x8")
    for label, kw in (
        ("static frozen-queue", dict(repack="drain")),
        ("online repack", dict(repack="event")),
        ("online + migration", dict(repack="event", migration_budget=4)),
    ):
        s = eng.plan_online(trace, seq, 1000, **kw)
        print(
            f"  {label:<22} makespan {s.makespan/3600:6.2f} h   "
            f"util {s.utilization():.2f}   segments {len(s.segments)}   "
            f"repacks {s.n_repacks}   migrations {s.n_migrations}"
        )


def real_run():
    import jax

    from repro.core.adapter import pack_meta
    from repro.models.model import init_model
    from repro.train.checkpoint import CheckpointPool

    cfg = reduced(get_config("qwen25-7b"))
    cm = CostModel(cfg, A100_40G)
    cm.setup_time = 0.0  # virtual seconds, not CPU wall time
    eng = ExecutionEngine(cm, 1)
    a = LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1, seq_len=16)
    b = LoraConfig(rank=16, alpha=16.0, learning_rate=5e-4, batch_size=1, seq_len=16)
    it = cm.iter_time([a], 1, 16)
    trace = [Arrival(0.0, a, 8), Arrival(3.5 * it, b, 6)]
    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta([a]))
    tmp = tempfile.mkdtemp(prefix="online_pool_")
    try:
        pool = CheckpointPool(tmp)
        records, sched = eng.run_online_local(
            trace, cfg, base, n_steps=8, seq=16, pool=pool,
            migration_budget=1, preempt_min_remaining=0.0,
        )
        print(f"real run on {cfg.name}: {len(sched.segments)} segments, "
              f"{sched.n_migrations} migration(s)")
        for seg, rec in zip(sorted(sched.segments, key=lambda s: s.start), records):
            tag = "preempted" if seg.preempted else "finished"
            print(f"  job {seg.job_id}: configs {seg.config_ids} "
                  f"ran {seg.run_steps} steps, {tag} "
                  f"(wall {rec.wall_seconds:.2f}s)")
        for name in pool.list():
            if name.startswith("adapter_"):
                m = pool.load_meta(name)
                print(f"  {name}: rank={m['rank']} steps={m['total_steps']} "
                      f"final_loss={m['final_loss']:.3f}")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--real", action="store_true",
                    help="execute the event loop for real on this host")
    args = ap.parse_args()
    simulated_whatif()
    if args.real:
        real_run()


if __name__ == "__main__":
    main()

"""Tune-then-serve with continuous batching: train adapters, hand their
final weights straight to the serving engine (no disk round trip), and
drain a Poisson request trace where every decode row carries its own
adapter and freed rows are refilled per token step — the batch never
drains. The same trace is then replayed through the sequential width-1
baseline to show the throughput gap and the bit-identical tokens.

  PYTHONPATH=src python examples/serve_multilora.py
"""
import jax
import numpy as np

from repro.configs.base import LoraConfig, get_config, reduced
from repro.core.adapter import pack_meta
from repro.core.packed_lora import extract_adapter
from repro.models.model import init_model
from repro.serve.engine import ServeEngine, poisson_requests
from repro.train.data import packed_batch_iterator
from repro.train.trainer import train_loop


def main():
    cfg = reduced(get_config("gemma3-1b"))  # sliding-window family, non-MoE
    print(f"serving arch: {cfg.name} (window={cfg.attention.sliding_window}, "
          f"global every {cfg.attention.global_every})")

    # 1. tune two adapters in one packed job
    configs = [
        LoraConfig(rank=8, alpha=16.0, learning_rate=5e-3, batch_size=2),
        LoraConfig(rank=16, alpha=8.0, learning_rate=2e-3, batch_size=2),
    ]
    meta = pack_meta(configs)
    base, lora = init_model(jax.random.PRNGKey(0), cfg, meta)
    out = train_loop(
        base, lora, cfg, meta,
        packed_batch_iterator(cfg, configs, seq=32), n_steps=10,
    )
    print(f"tuned {meta.n} adapters "
          f"(final losses: {np.round(np.asarray(out['history'][-1]), 3)})")

    # 2. tune-then-serve handoff: extract each adapter from the trained pack
    # and publish it into an engine slot — memory to memory, no checkpoints
    eng = ServeEngine(cfg, base, rows=4, smax=32,
                      r_bucket=meta.r_bucket, slot_capacity=4)
    trained = jax.tree.map(np.asarray, out["lora"])
    for n, c in enumerate(configs):
        eng.publish(f"tuned{n}", extract_adapter(trained, n),
                    {"rank": c.rank, "alpha": c.alpha})
    print(f"published {meta.n} adapters into serve slots "
          f"({eng.slot_cache.capacity} slots, LRU)")

    # 3. continuous batching over a Poisson trace: mixed adapters, staggered
    # arrivals, per-token admission/retirement on 4 rows
    rng = np.random.RandomState(7)
    n_req = 10
    prompts = [rng.randint(0, cfg.vocab_size, size=(6 if i % 2 else 8))
               .astype(np.int32) for i in range(n_req)]
    reqs = poisson_requests(
        [f"tuned{i % meta.n}" for i in range(n_req)], prompts,
        mean_interarrival=1.5, max_new_tokens=8, seed=3,
    )
    stats = eng.serve(reqs)
    print(f"\ncontinuous: {stats.tokens_emitted} tokens for "
          f"{len(stats.results)} requests across "
          f"{stats.adapters_served} adapters in {stats.steps} decode steps "
          f"(mean occupancy {stats.mean_occupancy:.2f}/{eng.rows} rows, "
          f"{stats.tokens_per_s:.0f} tok/s)")
    for r in stats.results[:3]:
        print(f"  req {r.request_id} [{r.adapter_id}] queued "
              f"{r.queue_steps:.0f} steps -> {r.tokens[:6]}")

    # 4. the same trace, one request at a time at width 1 — slower, but the
    # emitted tokens are bit-identical per request (row independence)
    seq_stats = eng.serve_sequential(reqs)
    exact = all(np.array_equal(a.tokens, b.tokens)
                for a, b in zip(stats.results, seq_stats.results))
    print(f"\nsequential: {seq_stats.steps} decode steps "
          f"({seq_stats.tokens_per_s:.0f} tok/s) — "
          f"continuous used {stats.steps} "
          f"({stats.steps / seq_stats.steps:.0%} of the steps); "
          f"tokens bit-exact: {exact}")


if __name__ == "__main__":
    main()

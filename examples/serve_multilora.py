"""Multi-LoRA serving: load tuned adapters from a checkpoint pool and serve a
batched request stream where different requests use different adapters — the
SLoRA/Punica setting the paper's tuning output feeds into.

  PYTHONPATH=src python examples/serve_multilora.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LoraConfig, get_config, reduced
from repro.core.adapter import pack_meta
from repro.models.model import init_model
from repro.serve.decode import generate, make_prefill, make_serve_step, pad_caches
from repro.train.data import packed_batch_iterator
from repro.train.trainer import train_loop


def main():
    cfg = reduced(get_config("gemma3-1b"))  # sliding-window family
    print(f"serving arch: {cfg.name} (window={cfg.attention.sliding_window}, "
          f"global every {cfg.attention.global_every})")

    # 1. quickly tune two adapters (stand-in for the checkpoint pool)
    configs = [
        LoraConfig(rank=8, alpha=16.0, learning_rate=5e-3, batch_size=2),
        LoraConfig(rank=16, alpha=8.0, learning_rate=2e-3, batch_size=2),
    ]
    meta = pack_meta(configs)
    base, lora = init_model(jax.random.PRNGKey(0), cfg, meta)
    out = train_loop(
        base, lora, cfg, meta,
        packed_batch_iterator(cfg, configs, seq=32), n_steps=10,
    )
    lora = out["lora"]
    print(f"tuned {meta.n} adapters "
          f"(final losses: {np.round(np.asarray(out['history'][-1]), 3)})")

    # 2. batched multi-adapter serving: requests [n*B, (n+1)*B) ride adapter n
    b_per_adapter = 2
    nb = meta.n * b_per_adapter
    prompts = jax.random.randint(jax.random.PRNGKey(7), (nb, 8), 0, cfg.vocab_size)

    t0 = time.perf_counter()
    tokens = generate(base, lora, cfg, meta, prompts, n_new=12)
    wall = time.perf_counter() - t0
    print(f"\ngenerated {tokens.shape} tokens for {nb} requests "
          f"({meta.n} adapters x {b_per_adapter} requests) in {wall:.1f}s")
    for n in range(meta.n):
        row = tokens[n * b_per_adapter]
        print(f"  adapter {n} sample: {np.asarray(row)[:8]}")

    # 3. explicit prefill -> step-by-step decode loop (server shape)
    prefill_fn = make_prefill(cfg, meta)
    step_fn = make_serve_step(cfg, meta)
    lg, caches = prefill_fn(base, lora, {"tokens": prompts})
    caches = pad_caches(caches, prompts.shape[1] + 4)
    tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
    for i in range(3):
        tok, lg, caches = step_fn(base, lora, caches, tok[:, None],
                                  jnp.int32(prompts.shape[1] + i))
    print(f"\nmanual decode loop OK, last tokens: {np.asarray(tok)}")


if __name__ == "__main__":
    main()

"""End-to-end driver: the full PLoRA pipeline on a ~100M-param model.

  offline:  cost model -> DTM packing (Alg. 1) -> job planner (Alg. 2)
  online:   execution engine runs every packed job for real on this host,
            adapters land in the checkpoint pool, best config is reported.

  PYTHONPATH=src python examples/hyperparam_sweep.py [--configs 12] [--steps 60]

This is the paper's Figure 3 loop end to end, scaled to CPU: a ~100M-param
Qwen-family model, a grid of LoRA configurations, a simulated 2-device pool
for planning, real packed fine-tuning for execution.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import LoraConfig, get_config, reduced
from repro.core.adapter import pack_meta
from repro.models.model import init_model
from repro.sched.cost_model import A100_40G, CostModel
from repro.sched.engine import ExecutionEngine
from repro.sched.planner import min_gpu_schedule, plan
from repro.train.checkpoint import CheckpointPool


def build_model_100m():
    """~100M-parameter member of the qwen family (real training, CPU)."""
    cfg = get_config("qwen25-7b").replace(
        name="qwen-100m",
        n_layers=4,
        d_model=512,
        d_ff=1536,
        vocab_size=8192,
    )
    import dataclasses

    cfg = cfg.replace(
        attention=dataclasses.replace(
            cfg.attention, n_heads=8, n_kv_heads=2, head_dim=64
        )
    )
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--configs", type=int, default=12)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--pool", default="/tmp/plora_pool")
    args = ap.parse_args()

    cfg = build_model_100m()
    from repro.sched.cost_model import model_param_count

    print(f"model: {cfg.name}, {model_param_count(cfg)/1e6:.0f}M params")

    # hyperparameter search space (paper Table 1 ranges, subsampled)
    space = []
    for rank in (4, 8, 16):
        for lr in (3e-4, 1e-3, 4e-3):
            for bs in (1, 2):
                space.append(LoraConfig(rank=rank, alpha=float(2 * rank),
                                        learning_rate=lr, batch_size=bs,
                                        seq_len=args.seq))
    space = space[: args.configs]
    print(f"search space: {len(space)} LoRA configurations")

    # ---- offline planning (Alg. 1 + 2) on a 2-unit pool ----
    g = 2
    cm = CostModel(cfg, A100_40G.scaled(n_devices=g))
    t0 = time.perf_counter()
    sched = plan(cm, space, g, args.seq, args.steps)
    print(
        f"planner: {len(sched.jobs)} packed jobs in {time.perf_counter()-t0:.2f}s, "
        f"predicted makespan {sched.makespan:.0f}s, AR bound {sched.ar():.3f}"
    )
    s_min = min_gpu_schedule(cm, space, g, args.seq, args.steps)
    print(
        f"predicted speedup vs Min-GPU sequential tuning: "
        f"{s_min.makespan / sched.makespan:.2f}x"
    )
    for j in sched.jobs:
        print(f"  job: {len(j.config_ids)} adapters on {j.degree} device(s)")

    # ---- online execution (real packed training on this host) ----
    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta(space))
    pool = CheckpointPool(args.pool)
    engine = ExecutionEngine(cm, g)
    t0 = time.perf_counter()
    records, measured_makespan = engine.run_local(
        sched, space, cfg, base, n_steps=args.steps, seq=args.seq, pool=pool
    )
    wall = time.perf_counter() - t0
    print(f"\nexecuted {len(records)} jobs in {wall:.1f}s wall "
          f"(measured-timeline makespan {measured_makespan:.1f}s)")

    # ---- results: per-adapter final loss -> best configuration ----
    print("\ncheckpoint pool:")
    best = None
    for aid in pool.list():
        meta = pool.load_meta(aid)
        print(
            f"  {aid}: r={meta['rank']:>3} lr={meta['learning_rate']:.0e} "
            f"bs={meta['batch_size']} alpha={meta['alpha']:>4} "
            f"loss={meta['final_loss']:.4f}"
        )
        if best is None or meta["final_loss"] < best[1]["final_loss"]:
            best = (aid, meta)
    print(
        f"\nbest configuration: {best[0]} "
        f"(rank={best[1]['rank']}, lr={best[1]['learning_rate']}, "
        f"bs={best[1]['batch_size']}, alpha={best[1]['alpha']}) "
        f"loss={best[1]['final_loss']:.4f}"
    )


if __name__ == "__main__":
    main()

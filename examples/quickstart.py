"""Quickstart: pack two LoRA configurations, fine-tune them concurrently on
one frozen base model, and inspect per-adapter losses.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.base import LoraConfig, get_config, reduced
from repro.core.adapter import pack_meta
from repro.core.packed_lora import extract_adapter
from repro.models.model import init_model
from repro.train.data import packed_batch_iterator
from repro.train.trainer import train_loop


def main():
    # 1. pick an architecture (any of the 10 assigned + the paper's qwen25-7b)
    cfg = reduced(get_config("qwen25-7b"))  # reduced = CPU-sized same family
    print(f"arch: {cfg.name}  d_model={cfg.d_model}  layers={cfg.n_layers}")

    # 2. define LoRA configurations to evaluate — each is one point of the
    #    hyperparameter search space (rank, alpha, lr, batch size)
    configs = [
        LoraConfig(rank=8, alpha=16.0, learning_rate=5e-3, batch_size=2),
        LoraConfig(rank=32, alpha=16.0, learning_rate=1e-3, batch_size=2),
    ]
    meta = pack_meta(configs)
    print(f"pack: N={meta.n}, rank bucket={meta.r_bucket}")

    # 3. init one frozen base + the packed adapters
    base, lora = init_model(jax.random.PRNGKey(0), cfg, meta)

    # 4. train both adapters in ONE job (shared base, packed kernels)
    data = packed_batch_iterator(cfg, configs, seq=32)
    out = train_loop(base, lora, cfg, meta, data, n_steps=20, log_every=5)

    hist = np.asarray(out["history"])  # (steps, N)
    print("\nper-adapter loss trajectory:")
    for n, c in enumerate(configs):
        print(
            f"  adapter {n} (r={c.rank}, lr={c.learning_rate}): "
            f"{hist[0, n]:.3f} -> {hist[-1, n]:.3f}"
        )

    # 5. extract each adapter from the pack (what goes in the checkpoint pool)
    a0 = extract_adapter(out["lora"], 0, meta.ranks)
    n_params = sum(x.size for x in jax.tree.leaves(a0))
    print(f"\nadapter 0 extracted: {n_params:,} params at rank {configs[0].rank}")


if __name__ == "__main__":
    main()

"""Multi-host dispatch demo: one schedule, executed process-per-host.

Two simulated hosts of 2 CPU devices each — every host is a *subprocess*
that forces its own device count via XLA_FLAGS, so the parent process needs
no flags at all; just run it on any machine:

  PYTHONPATH=src python examples/multihost_cluster.py

Four LoRA configs are planned host-aware (``ExecutionEngine(host_size=2)``:
per-job parallelism capped at the host width, every job's device units on
one host) and the :class:`~repro.cluster.HostDispatcher` ships the planned
segments — and their checkpoint traffic — to the host workers over the
message protocol. The printed timeline shows jobs on different *hosts*
overlapping in wall-clock time, with real per-adapter losses coming back
over the wire; at the end the same schedule runs again with a worker
kill+restart to show the fault path recovering mid-run.
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402


def main():
    import threading
    import time

    import jax

    from repro.cluster import HostDispatcher
    from repro.configs.base import LoraConfig, get_config, reduced
    from repro.core.adapter import pack_meta
    from repro.models.model import init_model
    from repro.sched.cost_model import A100_40G, CostModel
    from repro.sched.engine import ExecutionEngine
    from repro.sched.planner import Schedule, ScheduledJob

    cfg = reduced(get_config("qwen25-7b"))
    cm = CostModel(cfg, A100_40G)
    seq = 16
    steps = 30
    grid = [
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1, seq_len=seq),
        LoraConfig(rank=8, alpha=16.0, learning_rate=5e-4, batch_size=1, seq_len=seq),
        LoraConfig(rank=16, alpha=16.0, learning_rate=1e-3, batch_size=1, seq_len=seq),
        LoraConfig(rank=16, alpha=32.0, learning_rate=2e-4, batch_size=1, seq_len=seq),
    ]
    jobs = [ScheduledJob((i,), 1, 0.0, 1.0) for i in range(4)]
    sched = Schedule(jobs, 1.0, 4)
    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta(grid))
    eng = ExecutionEngine(cm, 4, host_size=2)

    print(f"2 hosts x 2 devices, {len(grid)} width-1 jobs, {steps} steps "
          f"each (host workers start + compile on first use)")
    with HostDispatcher([2, 2]) as disp:
        t0 = time.perf_counter()
        records, makespan = eng.run_local(
            sched, grid, cfg, base, n_steps=steps, seq=seq, runner=disp
        )
        elapsed = time.perf_counter() - t0
        result = disp.last_result

        print(f"\nwall {elapsed:.1f}s, makespan {makespan:.2f}s, peak "
              f"overlap {result.max_overlap()}")
        print("timeline (host = unit // 2):")
        scale = 40.0 / max(r.real_end for r in records)
        for rec, (job_id, s, e, units) in zip(records, result.timeline):
            host = units[0] // 2 if units else -1
            bar = " " * int(s * scale) + "#" * max(int((e - s) * scale), 1)
            print(f"  job {job_id} host {host} units={units} "
                  f"[{s:5.2f}s -> {e:5.2f}s] {bar}")
        for rec in records:
            print(f"  job cids={rec.job.config_ids} "
                  f"losses={np.round(np.asarray(rec.final_losses), 3)}")

        # fault injection: SIGKILL host 0 mid-run; the dispatcher respawns
        # it and re-dispatches the lost segment — same losses, no lost steps
        print("\nre-running with a worker kill mid-run...")
        stop = threading.Event()

        def killer():
            time.sleep(0.5)
            if not stop.is_set():
                disp.kill_host(0)

        th = threading.Thread(target=killer)
        th.start()
        records2, _ = eng.run_local(
            sched, grid, cfg, base, n_steps=steps, seq=seq, runner=disp
        )
        stop.set()
        th.join()
        same = np.array_equal(
            np.concatenate([r.final_losses for r in records]),
            np.concatenate([r.final_losses for r in records2]),
        )
        print(f"recovered with {disp.n_restarts} worker restart(s); "
              f"losses bit-identical to the unkilled run: {same}")


if __name__ == "__main__":
    main()

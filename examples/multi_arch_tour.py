"""Tour of all 10 assigned architectures (+ the paper's base model):
instantiate the reduced variant of each family, run one packed forward and
one packed train step, and print shapes/losses — a living demonstration that
packed-LoRA fine-tuning applies across dense / MoE / SSM / hybrid / MLA /
sliding-window / enc-dec / VLM families.

  PYTHONPATH=src python examples/multi_arch_tour.py
"""
import time

import jax
import numpy as np

from repro.configs.base import LoraConfig, get_config, list_archs, reduced
from repro.core.adapter import pack_meta
from repro.models.model import init_model
from repro.train.data import packed_batch_iterator
from repro.train.optimizer import init_opt_state
from repro.train.trainer import make_train_step


def main():
    configs = [
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1),
        LoraConfig(rank=16, alpha=16.0, learning_rate=5e-4, batch_size=1),
    ]
    meta = pack_meta(configs)
    print(f"{'arch':<22} {'family':<7} {'params':>8}  loss    step-time")
    for arch in list_archs():
        cfg = reduced(get_config(arch))
        base, lora = init_model(jax.random.PRNGKey(0), cfg, meta)
        n_par = sum(x.size for x in jax.tree.leaves(base))
        it = packed_batch_iterator(cfg, configs, seq=24)
        step = make_train_step(cfg, meta)
        opt = init_opt_state(lora)
        lora2, opt, m = step(base, lora, opt, next(it))  # compile + step
        t0 = time.perf_counter()
        lora2, opt, m = step(base, lora2, opt, next(it))
        jax.block_until_ready(m["loss"])
        dt = time.perf_counter() - t0
        print(
            f"{arch:<22} {get_config(arch).family:<7} {n_par/1e6:>7.1f}M  "
            f"{float(m['loss']):.3f}  {dt*1e3:7.0f}ms"
        )


if __name__ == "__main__":
    main()

"""Concurrent mesh-slice execution of an online trace — the cluster
subsystem demo.

Four LoRA configs arrive over time; the event-driven engine plans segments
*and their device groups* (``JobSegment.units``), and the cluster runner
executes each segment on the mesh slice backing its group — concurrently,
thread-per-slice, on 4 CPU devices forced via XLA_FLAGS (set below before
jax loads, so just run it):

  PYTHONPATH=src python examples/cluster_concurrent.py

The demo prints the real wall-clock timeline of both modes: in sequential
mode segments run back to back; in concurrent mode segments planned on
disjoint slices overlap, and per-adapter losses are bit-identical anyway.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402


def main():
    import jax

    from repro.cluster import ClusterRunner, DevicePool, SliceExecutor
    from repro.configs.base import LoraConfig, get_config, reduced
    from repro.core.adapter import pack_meta
    from repro.models.model import init_model
    from repro.sched.cost_model import A100_40G, CostModel
    from repro.sched.engine import Arrival, ExecutionEngine

    n_dev = jax.device_count()
    cfg = reduced(get_config("qwen25-7b"))
    # Tiny modeled link bandwidth: TP collectives swamp any d>1 gain, so the
    # planner carves degree-1 device groups — the regime where concurrent
    # arrivals land on separate slices and genuinely overlap.
    cm = CostModel(cfg, A100_40G.scaled(link_bw=1.0))
    cm.setup_time = 0.0  # virtual seconds, not CPU wall time
    g = min(4, n_dev)
    eng = ExecutionEngine(cm, g)
    seq, steps = 32, 30  # batch 2 x seq 32: per-step compute large enough
    grid = [             # to dominate dispatch, so slices really overlap
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=2, seq_len=seq),
        LoraConfig(rank=8, alpha=16.0, learning_rate=5e-4, batch_size=2, seq_len=seq),
        LoraConfig(rank=16, alpha=16.0, learning_rate=1e-3, batch_size=2, seq_len=seq),
        LoraConfig(rank=16, alpha=32.0, learning_rate=2e-4, batch_size=2, seq_len=seq),
    ]
    it = cm.iter_time([grid[0]], 1, seq)
    trace = [Arrival(i * 0.5 * it, c, steps) for i, c in enumerate(grid)]
    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta(grid))
    print(f"{len(grid)} LoRA configs arriving online, {n_dev} host device(s), "
          f"{g}-unit pool\n")

    # one executor for every run: the first (discarded) run compiles each
    # (pack shape, device) executable, so the displayed runs compare warm
    # dispatch — the steady state of a long-running tuning service
    ex = SliceExecutor()
    print("warming compile caches (one discarded concurrent run) ...\n")
    eng.run_online_local(
        trace, cfg, base, n_steps=steps, seq=seq,
        runner=ClusterRunner(ex, DevicePool(), concurrent=True),
    )
    outcomes = {}
    for mode in ("sequential", "concurrent"):
        runner = ClusterRunner(
            ex, DevicePool(), concurrent=(mode == "concurrent")
        )
        records, sched = eng.run_online_local(
            trace, cfg, base, n_steps=steps, seq=seq, runner=runner,
        )
        order = sorted(sched.segments, key=lambda s: (s.start, s.job_id))
        # measured-vs-predicted per segment (the profile feedback loop's
        # raw signal), surfaced by the cluster runner on its last result in
        # the same virtual-start order as the records
        makespan = max(r.real_end for r in records)
        print(f"{mode}: wall-clock makespan {makespan:.2f}s")
        for seg, rec, t in zip(order, records, runner.last_result.timings):
            bar_w = 40
            scale = bar_w / max(makespan, 1e-9)
            lo = int(rec.real_start * scale)
            hi = max(lo + 1, int(rec.real_end * scale))
            bar = " " * lo + "#" * (hi - lo)
            print(f"  job {seg.job_id} units={seg.units} "
                  f"[{rec.real_start:6.2f}s -> {rec.real_end:6.2f}s] "
                  f"|{bar:<{bar_w}}| "
                  f"{1e3 * t.measured_iter:6.1f} ms/step "
                  f"(pred {1e3 * t.predicted_iter:5.1f}, "
                  f"drift {100.0 * t.drift:+6.1f}%)")
        losses = np.concatenate([r.final_losses for r in records])
        outcomes[mode] = (makespan, losses)
        print()

    seq_mk, seq_losses = outcomes["sequential"]
    conc_mk, conc_losses = outcomes["concurrent"]
    print(f"concurrent speedup: x{seq_mk / conc_mk:.2f}   "
          f"per-adapter losses bit-exact: "
          f"{bool(np.array_equal(seq_losses, conc_losses))}")


if __name__ == "__main__":
    main()

"""Autotuner: cache round-trips, sweep mechanics, and the planner feedback.

The acceptance-critical case is ``test_autotuned_profile_shifts_planner``:
an autotuned profile (measured fused rates -> ragged FLOP accounting + LoRA
rate scale on the prior) must measurably change at least one planner
decision versus the uncalibrated pad-aware prior.
"""
import threading

import pytest

from repro.configs.base import LoraConfig, get_config
from repro.kernels import ops
from repro.kernels.autotune import (
    CANDIDATES,
    KernelProfile,
    _bucket_key,
    autotune_shape,
    fused_flops,
    shape_bucket,
    tune,
)
from repro.sched.cost_model import A100_40G, CostModel
from repro.sched.dtm import dtm
from repro.sched.planner import plan
from repro.sched.profile import ObservationStore, ProfiledCostModel


def _cfgs(ranks, seq=512, bs=1):
    return [
        LoraConfig(rank=r, alpha=2.0 * r, learning_rate=1e-4, batch_size=bs,
                   seq_len=seq)
        for r in ranks
    ]


def _fake_measure(best=(256, 256, 512), fused_t=1e-3, twopass_t=1.4e-3):
    """Deterministic measure_fn: candidate `best` is 2x faster than the
    rest; records calls so cache hits are observable."""
    calls = []

    def measure(n, m, k, l, r, blocks, backend, twopass=True):
        calls.append((n, m, k, l, r, blocks, backend))
        t = fused_t if (blocks is None or tuple(blocks) == best) else 2 * fused_t
        return t, (twopass_t if twopass else None)

    measure.calls = calls
    return measure


def test_shape_bucket_pow2():
    assert shape_bucket(3, 200, 2048, 1000, 12) == (4, 256, 2048, 1024, 16)
    assert shape_bucket(1, 1, 1, 1, 1) == (1, 1, 1, 1, 8)


def test_autotune_picks_best_candidate():
    m = _fake_measure(best=CANDIDATES[2])
    entry = autotune_shape(4, 256, 512, 512, 64, backend="tpu", measure_fn=m)
    assert tuple(entry["blocks"]) == CANDIDATES[2]
    assert entry["speedup_vs_twopass"] == pytest.approx(1.4)
    assert entry["flops_per_s"] == pytest.approx(
        fused_flops(4, 256, 512, 512, 64) / entry["seconds"]
    )
    assert len(m.calls) == len(CANDIDATES)


def test_non_tpu_backend_times_xla_once():
    m = _fake_measure()
    entry = autotune_shape(4, 256, 512, 512, 64, backend="cpu", measure_fn=m)
    assert entry["blocks"] is None
    assert len(m.calls) == 1


def test_cache_roundtrip_and_hit(tmp_path):
    path = str(tmp_path / "autotune.json")
    m = _fake_measure()
    shapes = [(4, 256, 512, 512, 64), (8, 256, 2048, 2048, 64)]
    prof = tune(shapes, cache_path=path, backend="cpu", measure_fn=m)
    assert len(prof.entries) == 2
    n_calls = len(m.calls)
    # reload: every shape is a cache hit, zero new measurements
    prof2 = tune(shapes, cache_path=path, backend="cpu", measure_fn=m)
    assert len(m.calls) == n_calls
    assert prof2.entries == prof.entries
    # same bucket, different exact shape -> still a hit
    tune([(4, 250, 500, 510, 60)], cache_path=path, backend="cpu", measure_fn=m)
    assert len(m.calls) == n_calls
    # other backend gets its own namespace in the same file
    tune(shapes[:1], cache_path=path, backend="tpu", measure_fn=m)
    assert len(m.calls) == n_calls + len(CANDIDATES)
    loaded = KernelProfile.load(path, backend="cpu")
    assert loaded.best_blocks(4, 256, 512, 512, 64) is None
    assert loaded.rate() is not None


def test_profile_lookup_by_bucket():
    prof = KernelProfile(backend="tpu")
    prof.entries[_bucket_key("tpu", shape_bucket(4, 256, 512, 512, 64))] = {
        "blocks": [128, 256, 512], "seconds": 1e-3,
        "flops_per_s": 1e12, "speedup_vs_twopass": 1.3,
    }
    assert prof.best_blocks(4, 250, 500, 500, 60) == (128, 256, 512)
    assert prof.best_blocks(4, 256, 4096, 512, 64) is None
    assert prof.lora_speedup() == pytest.approx(1.3)


# ---------------------------------------------------------------------------
# Cost-model / planner feedback
# ---------------------------------------------------------------------------


def _profile(speedup=1.4):
    prof = KernelProfile(backend="cpu")
    prof.entries[_bucket_key("cpu", shape_bucket(4, 256, 2048, 2048, 64))] = {
        "blocks": None, "seconds": 1e-3, "flops_per_s": 1e12,
        "speedup_vs_twopass": speedup,
    }
    return prof


def test_calibrate_sets_ragged_and_rate():
    prior = CostModel(get_config("qwen25-7b"), A100_40G)
    cal = _profile(1.4).calibrate(prior)
    assert cal.ragged and cal.lora_rate_scale == pytest.approx(1.4)
    assert not prior.ragged  # original untouched
    # mixed-rank pack gets cheaper under ragged accounting + measured rate
    configs = _cfgs((8, 64))
    assert cal.iter_time(configs, 1, 512) < prior.iter_time(configs, 1, 512)
    # memory stays bucketed (the pack still allocates padded weights)
    assert cal.job_mem_bytes(configs, 1, 512) == prior.job_mem_bytes(configs, 1, 512)


def test_uncalibrated_model_bit_identical():
    """lora_rate_scale=1.0 / ragged=False must not perturb the prior."""
    cfg = get_config("qwen25-7b")
    a = CostModel(cfg, A100_40G)
    b = CostModel(cfg, A100_40G, lora_rate_scale=1.0)
    configs = _cfgs((8, 64, 128))
    for d in (1, 2, 4, 8):
        assert a.iter_time(configs, d, 512) == b.iter_time(configs, d, 512)


def test_autotuned_profile_shifts_planner():
    """THE acceptance assertion: the pad-aware prior keeps a rank-8 and a
    rank-64 config in separate jobs (padding makes the mixed pack
    expensive); the autotune-calibrated estimator knows the kernels run
    ragged segments and packs them into one wider job."""
    prior = CostModel(get_config("qwen25-7b"), A100_40G)
    cal = _profile(1.4).calibrate(prior)
    configs = _cfgs((8, 64))
    g, seq, steps = 2, 512, 1000

    def decision(cm):
        return tuple(sorted(
            (tuple(sorted(j.config_ids)), j.degree)
            for j in dtm(cm, configs, g, seq, steps).jobs
        ))

    d_prior, d_cal = decision(prior), decision(cal)
    assert d_prior != d_cal
    assert d_prior == (((0,), 1), ((1,), 1))  # split, degree-1 each
    assert d_cal == (((0, 1), 2),)  # packed together at degree 2
    # and the full planner sees it too
    s_prior = plan(prior, configs, g, seq, steps)
    s_cal = plan(cal, configs, g, seq, steps)
    assert len(s_prior.jobs) == 2 and len(s_cal.jobs) == 1


def test_seed_observations_feed_profiled_model():
    prior = CostModel(get_config("qwen25-7b"), A100_40G)
    prof = _profile(1.4)
    store = ObservationStore()
    packs = [(_cfgs((8, 64)), 2, 512), (_cfgs((16, 16)), 1, 512)]
    prof.seed_observations(store, prior, packs)
    assert len(store) == 2
    pm = ProfiledCostModel(prior, store)
    cal = prof.calibrate(prior)
    for configs, d, seq in packs:
        # the profiled planner now answers with the fused-rate prediction
        assert pm.iter_time(configs, d, seq) == pytest.approx(
            cal.iter_time(configs, d, seq)
        )
        assert pm.iter_time(configs, d, seq) < prior.iter_time(configs, d, seq)
    # simulation contract intact: the virtual model is still the pure prior
    assert pm.virtual_model() is prior


# ---------------------------------------------------------------------------
# ContextVar impl default (satellite: no cross-thread races)
# ---------------------------------------------------------------------------


def test_default_impl_contextvar_scoped():
    assert ops.default_impl() == "auto"
    with ops.use_impl("fused"):
        assert ops.default_impl() == "fused"
        with ops.use_impl("xla"):
            assert ops.default_impl() == "xla"
        assert ops.default_impl() == "fused"
    assert ops.default_impl() == "auto"


def test_default_impl_does_not_leak_across_threads():
    """set_default_impl in one thread must never race another thread's
    resolution — each thread sees its own context (worker threads get the
    default, which is why the executor plumbs impl explicitly)."""
    seen = {}

    def worker():
        seen["worker"] = ops.default_impl()
        ops.set_default_impl("pallas")
        seen["worker_after_set"] = ops.default_impl()

    with ops.use_impl("fused"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        seen["main"] = ops.default_impl()
    assert seen["worker"] == "auto"  # fresh context, not the caller's
    assert seen["worker_after_set"] == "pallas"
    assert seen["main"] == "fused"  # worker's set never leaked back


def test_adaptive_engine_captures_callers_impl():
    """_run_adaptive dispatches segments on executor threads that never see
    the caller's contextvars — the engine must capture the caller's default
    impl and pass it to every run_segment explicitly (regression: the
    ContextVar migration would otherwise silently downgrade adaptive runs
    to the default tier on multi-device hosts)."""
    from harness import FakeRunner, NoPool, ScriptedExecutor

    from repro.configs.base import get_config, reduced
    from repro.sched.cost_model import CostModel
    from repro.sched.engine import Arrival, ExecutionEngine
    from repro.sched.profile import ProfiledCostModel

    prior = CostModel(get_config("qwen25-7b"), A100_40G)
    prior.setup_time = 0.0
    est = ProfiledCostModel(prior, drift_threshold=0.5)
    eng = ExecutionEngine(est, 1)
    fake = ScriptedExecutor(prior, slow=1.0)
    with ops.use_impl("fused"):
        eng.run_online_local(
            [Arrival(0.0, _cfgs((8,), seq=128)[0], 8)],
            reduced(get_config("qwen25-7b")),
            None,
            n_steps=8,
            seq=128,
            pool=NoPool(),
            runner=FakeRunner(fake, 1),
            probe_steps=2,
        )
    assert fake.impls and all(i == "fused" for i in fake.impls)


def test_runner_captures_callers_impl(monkeypatch):
    """ClusterRunner.run captures the *calling* context's impl and threads
    it to run_segment explicitly (workers can't see the contextvar)."""
    from repro.cluster.runner import ClusterRunner

    captured = {}

    class FakeExecutor:
        def pack_template(self, *a, **k):
            return None

        def run_segment(self, seg, *a, **k):
            captured["impl"] = k.get("impl")

            class R:
                wall_seconds = 0.0
                real_start = 0.0
                real_end = 0.0

            return R()

    runner = ClusterRunner(executor=FakeExecutor(), concurrent=False)

    class Seg:
        start = 0.0
        job_id = 0
        config_ids = (0,)
        start_steps = (0,)
        done_ids = (0,)
        preempted = False
        run_steps = 1
        degree = 1
        units = ()

    cfgs = {0: _cfgs((8,))[0]}
    with ops.use_impl("fused"):
        runner.run([Seg()], cfgs, {0: 1}, None, None, seq=8)
    assert captured["impl"] == "fused"

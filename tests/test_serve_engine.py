"""Continuous-batching serve engine (ISSUE 6 tentpole).

Covers the slot cache (LRU order, pinning), row lifecycle (admission,
retirement, reuse), the tune-then-serve handoff (training job -> serve slot
with no disk round trip; packed-state extraction bit-exact against
``load_packed_state``), and the headline claim: a width-R continuous batch
emits exactly the tokens the width-1 sequential path emits, per request.

Non-MoE config throughout — MoE capacity couples decode rows, so row-level
bit-exactness only holds for dense models (documented on the engine).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LoraConfig, get_config, reduced
from repro.core.adapter import pack_meta
from repro.core.packed_lora import extract_adapter
from repro.models.model import init_model
from repro.serve.decode import generate, prefill_chunked
from repro.serve.engine import (
    AdapterSlotCache,
    ServeEngine,
    ServeExecutor,
    ServeRequest,
    poisson_requests,
)
from repro.train.checkpoint import CheckpointPool

CFG = reduced(get_config("gemma3-1b"))
RANK, ALPHA = 8, 16.0


@pytest.fixture(scope="module")
def world():
    """Base params + three distinct 'trained' adapters (host trees)."""
    meta = pack_meta([LoraConfig(rank=RANK, alpha=ALPHA)] * 3)
    base, lora = init_model(jax.random.PRNGKey(0), CFG, meta)
    lora = jax.tree.map(lambda x: x + 0.02, lora)  # nonzero deltas
    adapters = {f"ad{i}": extract_adapter(lora, i) for i in range(3)}
    return base, lora, adapters


def _engine(base, adapters, **kw):
    kw.setdefault("rows", 2)
    kw.setdefault("smax", 48)
    kw.setdefault("r_bucket", RANK)
    eng = ServeEngine(CFG, base, serve_executor=ServeExecutor(), **kw)
    for aid, tree in adapters.items():
        eng.publish(aid, tree, {"rank": RANK, "alpha": ALPHA})
    return eng


def _prompts(n, lo=4, hi=9, seed=1):
    rng = np.random.RandomState(seed)
    return [
        rng.randint(0, CFG.vocab_size, size=rng.randint(lo, hi)).astype(np.int32)
        for _ in range(n)
    ]


# ---------------------------------------------------------------------------
# Adapter slot cache (pure host logic, no jax)
# ---------------------------------------------------------------------------


def test_slot_cache_lru_eviction_order():
    cache = AdapterSlotCache(2)
    cache.publish("a", {"w": 1}, {})
    cache.publish("b", {"w": 2}, {})
    cache.get("a")  # a is now most-recent
    cache.publish("c", {"w": 3}, {})  # evicts b (LRU), not a
    assert "b" not in cache and "a" in cache and "c" in cache
    assert cache.evictions == 1
    cache.get("c")
    cache.publish("d", {"w": 4}, {})  # now a is LRU
    assert cache.ids() == ["c", "d"]


def test_slot_cache_pinning_and_exhaustion():
    cache = AdapterSlotCache(2)
    cache.publish("a", {"w": 1}, {})
    cache.publish("b", {"w": 2}, {})
    cache.pin("a")
    cache.pin("b")
    with pytest.raises(RuntimeError, match="pinned"):
        cache.publish("c", {"w": 3}, {})
    cache.unpin("b")
    cache.publish("c", {"w": 3}, {})  # b evictable now
    assert cache.ids() == ["a", "c"]
    # re-publish of a resident id refreshes in place (no eviction)
    cache.publish("a", {"w": 9}, {})
    assert cache.get("a")[0] == {"w": 9} and cache.evictions == 1


def test_slot_cache_miss_loads_from_pool(tmp_path):
    pool = CheckpointPool(str(tmp_path))
    tree = {"q": {"a": np.ones((2, 3), np.float32)}}
    pool.save_adapter("x", tree, {"rank": 4, "alpha": 8.0})
    cache = AdapterSlotCache(2, pool=pool)
    got, meta = cache.get("x")
    np.testing.assert_array_equal(got["q"]["a"], tree["q"]["a"])
    assert meta["rank"] == 4 and cache.misses == 1
    cache.get("x")
    assert cache.hits == 1
    with pytest.raises(KeyError, match="neither staged nor"):
        cache.get("nope")


# ---------------------------------------------------------------------------
# Row lifecycle
# ---------------------------------------------------------------------------


def test_row_reuse_after_retirement(world):
    base, _, adapters = world
    eng = _engine(base, adapters, rows=1)
    prompts = _prompts(3)
    reqs = [
        ServeRequest(i, f"ad{i}", prompts[i], max_new_tokens=3)
        for i in range(3)
    ]
    stats = eng.serve(reqs)
    # one row served all three requests back to back
    assert [r.request_id for r in stats.results] == [0, 1, 2]
    assert stats.tokens_emitted == 9
    assert all(r is None for r in eng._rows)
    assert (eng._scales == 0.0).all()
    # retirement released every pin: all slots evictable again
    assert eng.slot_cache._pins == {}
    # each emits its adapter's tokens, not its predecessor's
    per_adapter = {r.adapter_id: r.tokens for r in stats.results}
    assert len(per_adapter) == 3


def test_continuous_matches_sequential_bitwise(world):
    """The acceptance bit: width-R continuous batching emits exactly the
    width-1 sequential tokens, request by request, on a Poisson trace with
    staggered arrivals and mixed prompt lengths."""
    base, _, adapters = world
    eng = _engine(base, adapters, rows=2)
    prompts = _prompts(5)
    reqs = poisson_requests(
        [f"ad{i % 3}" for i in range(5)], prompts, 2.0,
        max_new_tokens=5, seed=3,
    )
    cont = eng.serve(reqs)
    seq = eng.serve_sequential(reqs)
    assert len(cont.results) == len(seq.results) == 5
    for a, b in zip(cont.results, seq.results):
        assert a.request_id == b.request_id
        np.testing.assert_array_equal(a.tokens, b.tokens)
    # and continuous batching does the same work in fewer decode steps
    assert cont.steps < seq.steps


def test_engine_matches_generate(world):
    """The engine's per-request output equals the pre-engine ``generate()``
    path for the same adapter/prompt (same executor compile cache)."""
    base, _, adapters = world
    eng = _engine(base, adapters, rows=2)
    prompt = _prompts(1, seed=7)[0]
    req = ServeRequest(0, "ad1", prompt, max_new_tokens=4)
    stats = eng.serve([req])
    from repro.core.packed_lora import inject_adapter

    meta1 = pack_meta([LoraConfig(rank=RANK, alpha=ALPHA)])
    _, l1 = init_model(jax.random.PRNGKey(0), CFG, meta1)
    lora1 = inject_adapter(
        jax.tree.map(lambda x: np.zeros_like(np.asarray(x)), l1),
        adapters["ad1"], 0,
    )
    toks = generate(
        base, jax.tree.map(jnp.asarray, lora1), CFG, meta1,
        jnp.asarray(prompt[None, :]), 4,
    )
    np.testing.assert_array_equal(stats.results[0].tokens, np.asarray(toks[0]))


def test_prompt_overflow_rejected(world):
    """An oversized request is rejected with an errored ServeResult — the
    drain keeps serving everything else (no mid-flight ValueError), no pin
    leaks, and the rejection never records a queue-wait/TTFT sample."""
    base, _, adapters = world
    eng = _engine(base, adapters, rows=1, smax=16)
    bad = ServeRequest(0, "ad0", _prompts(1, lo=14, hi=15)[0],
                       max_new_tokens=8)
    good = ServeRequest(1, "ad1", _prompts(1, lo=4, hi=6)[0],
                        max_new_tokens=3)
    stats = eng.serve([bad, good])
    assert len(stats.results) == 2
    rej, ok = stats.results[0], stats.results[1]
    assert rej.request_id == 0 and "exceeds smax" in rej.error
    assert rej.tokens.shape == (0,)
    assert ok.request_id == 1 and ok.error is None
    assert len(ok.tokens) == 3
    # rejection left nothing behind: no pins, no latency-histogram samples
    assert eng.slot_cache._pins == {}
    assert stats.queue_wait.count == 1 and stats.ttft.count == 1


def test_unknown_adapter_rejected_engine_keeps_serving(world):
    """Adapter-resolution failures are rejections too, not drain aborts."""
    base, _, adapters = world
    eng = _engine(base, adapters, rows=1)
    reqs = [
        ServeRequest(0, "nope", _prompts(1)[0], max_new_tokens=3),
        ServeRequest(1, "ad0", _prompts(1, seed=2)[0], max_new_tokens=3),
    ]
    stats = eng.serve(reqs)
    assert "neither staged nor" in stats.results[0].error
    assert stats.results[1].error is None
    assert len(stats.results[1].tokens) == 3
    assert eng.slot_cache._pins == {}


def test_executor_compile_cache_is_reused(world):
    """The generate() re-jit fix: same (cfg, width) => same jitted callable,
    across engine admissions and across generate() calls."""
    base, _, adapters = world
    ex = ServeExecutor()
    s1 = ex.step_fn(CFG, 2)
    s2 = ex.step_fn(CFG, 2)
    assert s1 is s2
    assert ex.step_fn(CFG, 1) is not s1  # width is part of the key
    n0 = ex.cache_size
    ex.prefill_fn(CFG, 1)
    ex.prefill_fn(CFG, 1)
    assert ex.cache_size == n0 + 1
    # the chunked-prefill fn shares the cache: one entry per (cfg, width),
    # so a burst of admissions never recompiles it
    c1 = ex.prefill_chunk_fn(CFG, 1)
    assert ex.prefill_chunk_fn(CFG, 1) is c1
    assert ex.cache_size == n0 + 2


# ---------------------------------------------------------------------------
# Tune-then-serve handoff
# ---------------------------------------------------------------------------


def test_handoff_packed_state_bitexact_vs_load(tmp_path, world):
    """publish_from_packed_state stages exactly the adapter that
    ``load_packed_state`` + ``extract_adapter`` yields."""
    _, lora, _ = world
    pool = CheckpointPool(str(tmp_path))
    opt = jax.tree.map(np.zeros_like, jax.tree.map(np.asarray, lora))
    pool.save_packed_state(
        "t0", jax.tree.map(np.asarray, lora), {"m": opt, "v": opt},
        {"steps_done": 1},
    )
    eng = ServeEngine(CFG, None, rows=1, smax=16, r_bucket=RANK)
    eng.publish_from_packed_state(
        pool, "t0", 1, "hot", rank=RANK, alpha=ALPHA
    )
    want_lora, _, _ = pool.load_packed_state("t0")
    want = extract_adapter(want_lora, 1)
    got, meta = eng.slot_cache.get("hot")
    flat_got = jax.tree.leaves(got)
    flat_want = jax.tree.leaves(want)
    assert len(flat_got) == len(flat_want) > 0
    for g, w in zip(flat_got, flat_want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    assert meta == {"rank": RANK, "alpha": ALPHA}


def test_tune_then_serve_without_disk(world, monkeypatch):
    """A freshly trained adapter is served straight from memory: the engine
    has NO checkpoint pool, so any disk path would fail loudly — and the
    served tokens match serving the same weights via an explicit pool
    round trip (the handoff loses nothing)."""
    from repro.train.data import packed_batch_iterator
    from repro.train.optimizer import init_opt_state
    from repro.train.trainer import train_loop

    base, _, _ = world
    cfgs = [LoraConfig(rank=RANK, alpha=ALPHA, learning_rate=1e-3,
                       batch_size=1, seq_len=16)]
    meta = pack_meta(cfgs)
    _, lora0 = init_model(jax.random.PRNGKey(3), CFG, meta)
    data = packed_batch_iterator(CFG, cfgs, seq=16)
    out = train_loop(base, lora0, CFG, meta, data, 2)
    trained = extract_adapter(jax.tree.map(np.asarray, out["lora"]), 0)

    prompt = _prompts(1, seed=11)[0]
    req = ServeRequest(0, "fresh", prompt, max_new_tokens=4)

    eng = ServeEngine(CFG, base, rows=1, smax=32, r_bucket=RANK,
                      checkpoint_pool=None)
    eng.publish("fresh", trained, {"rank": RANK, "alpha": ALPHA})
    direct = eng.serve([req])
    assert len(direct.results) == 1 and direct.cache_misses == 0

    import tempfile

    with tempfile.TemporaryDirectory() as d:
        pool = CheckpointPool(d)
        pool.save_adapter("fresh", trained, {"rank": RANK, "alpha": ALPHA})
        eng2 = ServeEngine(CFG, base, rows=1, smax=32, r_bucket=RANK,
                           checkpoint_pool=pool)
        via_disk = eng2.serve([req])
    np.testing.assert_array_equal(
        direct.results[0].tokens, via_disk.results[0].tokens
    )
    assert via_disk.cache_misses == 1  # the disk path actually loaded


# ---------------------------------------------------------------------------
# Chunked, decode-interleaved admission (ISSUE 9)
# ---------------------------------------------------------------------------


def _width1_lora():
    meta1 = pack_meta([LoraConfig(rank=RANK, alpha=ALPHA)])
    _, lora1 = init_model(jax.random.PRNGKey(5), CFG, meta1)
    return jax.tree.map(lambda x: x + 0.02, lora1)


def test_prefill_chunked_bitwise_vs_oneshot(world):
    """Chunked prefill is *bitwise* one-shot prefill — logits and every
    cache leaf — for chunk sizes below, at, and above the prompt length.
    The invariant the interleaved admission path rests on."""
    base, _, _ = world
    lora1 = _width1_lora()
    scales = jnp.full((1,), ALPHA / RANK, jnp.float32)
    toks = jnp.asarray(_prompts(1, lo=23, hi=24, seed=9)[0][None, :])
    ex = ServeExecutor()
    lg_ref, c_ref = ex.prefill_fn(CFG, 1)(
        base, lora1, scales, {"tokens": toks}
    )
    ref_leaves = jax.tree.leaves(c_ref)
    for chunk in (3, 8, 23, 64):  # uneven / even / exact / chunk > prompt
        lg, c = prefill_chunked(
            base, lora1, scales, toks, CFG, chunk, executor=ex
        )
        np.testing.assert_array_equal(np.asarray(lg), np.asarray(lg_ref))
        leaves = jax.tree.leaves(c)
        assert len(leaves) == len(ref_leaves)
        for got, want in zip(leaves, ref_leaves):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_chunked_engine_tokens_bitwise_vs_sequential(world):
    """Acceptance: the chunked-admission engine emits exactly the tokens of
    the synchronous engine AND the width-1 sequential baseline, on a trace
    with long prompts (many chunks) and staggered arrivals."""
    base, _, adapters = world
    prompts = _prompts(5, lo=12, hi=24, seed=13)
    reqs = poisson_requests(
        [f"ad{i % 3}" for i in range(5)], prompts, 2.0,
        max_new_tokens=5, seed=4,
    )
    sync = _engine(base, adapters).serve(reqs)
    seq = _engine(base, adapters).serve_sequential(reqs)
    for chunk in (4, 64):  # multi-chunk and chunk-covers-whole-prompt
        eng = _engine(base, adapters, prefill_chunk=chunk)
        got = eng.serve(reqs)
        assert len(got.results) == 5
        for a, b, c in zip(got.results, sync.results, seq.results):
            assert a.request_id == b.request_id == c.request_id
            np.testing.assert_array_equal(a.tokens, b.tokens)
            np.testing.assert_array_equal(a.tokens, c.tokens)
        assert all(r is None for r in eng._rows)
        assert eng.slot_cache._pins == {}


def test_chunked_prefill_emits_row_spans(world):
    """Admission cost shows up on the row's own track as bounded
    ``serve.prefill_chunk`` spans, one per chunk."""
    from repro.obs import Tracer

    base, _, adapters = world
    tracer = Tracer()
    eng = _engine(base, adapters, rows=1, prefill_chunk=4, tracer=tracer)
    prompt = _prompts(1, lo=10, hi=11, seed=17)[0]  # 10 tokens -> 3 chunks
    eng.serve([ServeRequest(0, "ad0", prompt, max_new_tokens=3)])
    chunks = [s for s in tracer.spans() if s.name == "serve.prefill_chunk"]
    assert len(chunks) == 3
    assert all(s.cat == "serve" and s.track == "row0" for s in chunks)
    assert [s.args["pos"] for s in chunks] == [0, 4, 8]
    assert [s.args["chunk"] for s in chunks] == [4, 4, 2]
    # the old one-shot stall span is gone from the chunked path
    assert not any(s.name == "serve.prefill" for s in tracer.spans())


def test_submit_records_enqueue_wall(world):
    """The queue-wait fix: a request submitted before serve() measures its
    wait from submit time, not from a silent 0.0 default."""
    base, _, adapters = world
    eng = _engine(base, adapters, rows=1)
    eng.submit(ServeRequest(0, "ad0", _prompts(1)[0], max_new_tokens=3))
    time.sleep(0.05)
    trace = ServeRequest(1, "ad1", _prompts(1, seed=2)[0], max_new_tokens=3,
                         arrival=0.0)
    stats = eng.serve([trace])
    assert len(stats.results) == 2
    assert stats.queue_wait.count == 2 and stats.ttft.count == 2
    # the submitted request waited at least the sleep (the old code
    # reported ~0 here); the trace request's wait is measured from its
    # arrival during the drain, not from engine construction
    assert stats.queue_wait.values()[0] >= 0.05
    assert stats.ttft.values()[0] >= 0.05


def test_max_steps_retires_inflight_rows(world):
    """A bounded drain surfaces in-flight rows as partial results and
    releases their pins instead of leaking them."""
    base, _, adapters = world
    eng = _engine(base, adapters)
    reqs = [
        ServeRequest(i, f"ad{i % 3}", p, max_new_tokens=10)
        for i, p in enumerate(_prompts(3, seed=21))
    ]
    stats = eng.serve(reqs, max_steps=3)
    # two rows were in flight; each got prefill token + 3 decode steps
    assert stats.steps == 3
    assert len(stats.results) == 2
    for r in stats.results:
        assert r.error is None
        assert 1 <= len(r.tokens) < 10  # partial, not dropped
    assert stats.tokens_emitted == sum(len(r.tokens) for r in stats.results)
    # rows freed, pins released, adapters cleared
    assert all(r is None for r in eng._rows)
    assert eng.slot_cache._pins == {}
    assert (eng._scales == 0.0).all()
    # the never-admitted request is still queued for a later drain
    assert [q.request_id for q in eng.queue] == [2]
    stats2 = eng.serve()
    assert [r.request_id for r in stats2.results] == [2]
    assert len(stats2.results[0].tokens) == 10


def test_deadline_rejects_overdue_queued_request(world):
    """A queued request already past its deadline_ms is rejected before any
    prefill is spent on it; everything else keeps serving."""
    base, _, adapters = world
    eng = _engine(base, adapters, rows=1)
    eng.submit(ServeRequest(0, "ad0", _prompts(1)[0], max_new_tokens=3,
                            deadline_ms=30.0))
    time.sleep(0.06)  # the queued request expires before the drain starts
    live = ServeRequest(1, "ad1", _prompts(1, seed=2)[0], max_new_tokens=3)
    stats = eng.serve([live])
    by_id = {r.request_id: r for r in stats.results}
    assert by_id[0].error == "deadline"
    assert len(by_id[0].tokens) == 0
    assert by_id[1].error is None and len(by_id[1].tokens) == 3
    # the reject happened at admission: no queue-wait/TTFT sample, no pin
    assert stats.queue_wait.count == 1 and stats.ttft.count == 1
    assert eng.slot_cache._pins == {}
    assert 0 not in eng._enq_abs


def test_deadline_retires_inflight_row_as_partial(world):
    """A row that blows its deadline mid-flight retires as a partial result
    (tokens so far, error="deadline") with its pins released — the same
    contract as the max_steps bounded drain — and the row refills."""
    base, _, adapters = world
    eng = _engine(base, adapters, rows=2)
    prompts = _prompts(3, seed=31)
    reqs = [
        # 1ms: survives the admission check (enqueue -> admit is the same
        # drain pass) but is certainly overdue by the first in-flight check
        ServeRequest(0, "ad0", prompts[0], max_new_tokens=10,
                     deadline_ms=1.0),
        ServeRequest(1, "ad1", prompts[1], max_new_tokens=3),
        ServeRequest(2, "ad2", prompts[2], max_new_tokens=3),
    ]
    stats = eng.serve(reqs)
    by_id = {r.request_id: r for r in stats.results}
    assert by_id[0].error == "deadline"
    assert 1 <= len(by_id[0].tokens) < 10  # partial, not dropped
    for rid in (1, 2):
        assert by_id[rid].error is None and len(by_id[rid].tokens) == 3
    assert stats.tokens_emitted == sum(len(r.tokens) for r in stats.results)
    # rows freed (request 2 reused the expired row), pins all released
    assert all(r is None for r in eng._rows)
    assert eng.slot_cache._pins == {}
    assert eng._enq_abs == {}

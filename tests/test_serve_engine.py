"""Continuous-batching serve engine (ISSUE 6 tentpole).

Covers the slot cache (LRU order, pinning), row lifecycle (admission,
retirement, reuse), the tune-then-serve handoff (training job -> serve slot
with no disk round trip; packed-state extraction bit-exact against
``load_packed_state``), and the headline claim: a width-R continuous batch
emits exactly the tokens the width-1 sequential path emits, per request.

Non-MoE config throughout — MoE capacity couples decode rows, so row-level
bit-exactness only holds for dense models (documented on the engine).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LoraConfig, get_config, reduced
from repro.core.adapter import pack_meta
from repro.core.packed_lora import extract_adapter
from repro.models.model import init_model
from repro.serve.decode import generate
from repro.serve.engine import (
    AdapterSlotCache,
    ServeEngine,
    ServeExecutor,
    ServeRequest,
    poisson_requests,
)
from repro.train.checkpoint import CheckpointPool

CFG = reduced(get_config("gemma3-1b"))
RANK, ALPHA = 8, 16.0


@pytest.fixture(scope="module")
def world():
    """Base params + three distinct 'trained' adapters (host trees)."""
    meta = pack_meta([LoraConfig(rank=RANK, alpha=ALPHA)] * 3)
    base, lora = init_model(jax.random.PRNGKey(0), CFG, meta)
    lora = jax.tree.map(lambda x: x + 0.02, lora)  # nonzero deltas
    adapters = {f"ad{i}": extract_adapter(lora, i) for i in range(3)}
    return base, lora, adapters


def _engine(base, adapters, **kw):
    kw.setdefault("rows", 2)
    kw.setdefault("smax", 48)
    kw.setdefault("r_bucket", RANK)
    eng = ServeEngine(CFG, base, serve_executor=ServeExecutor(), **kw)
    for aid, tree in adapters.items():
        eng.publish(aid, tree, {"rank": RANK, "alpha": ALPHA})
    return eng


def _prompts(n, lo=4, hi=9, seed=1):
    rng = np.random.RandomState(seed)
    return [
        rng.randint(0, CFG.vocab_size, size=rng.randint(lo, hi)).astype(np.int32)
        for _ in range(n)
    ]


# ---------------------------------------------------------------------------
# Adapter slot cache (pure host logic, no jax)
# ---------------------------------------------------------------------------


def test_slot_cache_lru_eviction_order():
    cache = AdapterSlotCache(2)
    cache.publish("a", {"w": 1}, {})
    cache.publish("b", {"w": 2}, {})
    cache.get("a")  # a is now most-recent
    cache.publish("c", {"w": 3}, {})  # evicts b (LRU), not a
    assert "b" not in cache and "a" in cache and "c" in cache
    assert cache.evictions == 1
    cache.get("c")
    cache.publish("d", {"w": 4}, {})  # now a is LRU
    assert cache.ids() == ["c", "d"]


def test_slot_cache_pinning_and_exhaustion():
    cache = AdapterSlotCache(2)
    cache.publish("a", {"w": 1}, {})
    cache.publish("b", {"w": 2}, {})
    cache.pin("a")
    cache.pin("b")
    with pytest.raises(RuntimeError, match="pinned"):
        cache.publish("c", {"w": 3}, {})
    cache.unpin("b")
    cache.publish("c", {"w": 3}, {})  # b evictable now
    assert cache.ids() == ["a", "c"]
    # re-publish of a resident id refreshes in place (no eviction)
    cache.publish("a", {"w": 9}, {})
    assert cache.get("a")[0] == {"w": 9} and cache.evictions == 1


def test_slot_cache_miss_loads_from_pool(tmp_path):
    pool = CheckpointPool(str(tmp_path))
    tree = {"q": {"a": np.ones((2, 3), np.float32)}}
    pool.save_adapter("x", tree, {"rank": 4, "alpha": 8.0})
    cache = AdapterSlotCache(2, pool=pool)
    got, meta = cache.get("x")
    np.testing.assert_array_equal(got["q"]["a"], tree["q"]["a"])
    assert meta["rank"] == 4 and cache.misses == 1
    cache.get("x")
    assert cache.hits == 1
    with pytest.raises(KeyError, match="neither staged nor"):
        cache.get("nope")


# ---------------------------------------------------------------------------
# Row lifecycle
# ---------------------------------------------------------------------------


def test_row_reuse_after_retirement(world):
    base, _, adapters = world
    eng = _engine(base, adapters, rows=1)
    prompts = _prompts(3)
    reqs = [
        ServeRequest(i, f"ad{i}", prompts[i], max_new_tokens=3)
        for i in range(3)
    ]
    stats = eng.serve(reqs)
    # one row served all three requests back to back
    assert [r.request_id for r in stats.results] == [0, 1, 2]
    assert stats.tokens_emitted == 9
    assert all(r is None for r in eng._rows)
    assert (eng._scales == 0.0).all()
    # retirement released every pin: all slots evictable again
    assert eng.slot_cache._pins == {}
    # each emits its adapter's tokens, not its predecessor's
    per_adapter = {r.adapter_id: r.tokens for r in stats.results}
    assert len(per_adapter) == 3


def test_continuous_matches_sequential_bitwise(world):
    """The acceptance bit: width-R continuous batching emits exactly the
    width-1 sequential tokens, request by request, on a Poisson trace with
    staggered arrivals and mixed prompt lengths."""
    base, _, adapters = world
    eng = _engine(base, adapters, rows=2)
    prompts = _prompts(5)
    reqs = poisson_requests(
        [f"ad{i % 3}" for i in range(5)], prompts, 2.0,
        max_new_tokens=5, seed=3,
    )
    cont = eng.serve(reqs)
    seq = eng.serve_sequential(reqs)
    assert len(cont.results) == len(seq.results) == 5
    for a, b in zip(cont.results, seq.results):
        assert a.request_id == b.request_id
        np.testing.assert_array_equal(a.tokens, b.tokens)
    # and continuous batching does the same work in fewer decode steps
    assert cont.steps < seq.steps


def test_engine_matches_generate(world):
    """The engine's per-request output equals the pre-engine ``generate()``
    path for the same adapter/prompt (same executor compile cache)."""
    base, _, adapters = world
    eng = _engine(base, adapters, rows=2)
    prompt = _prompts(1, seed=7)[0]
    req = ServeRequest(0, "ad1", prompt, max_new_tokens=4)
    stats = eng.serve([req])
    from repro.core.packed_lora import inject_adapter

    meta1 = pack_meta([LoraConfig(rank=RANK, alpha=ALPHA)])
    _, l1 = init_model(jax.random.PRNGKey(0), CFG, meta1)
    lora1 = inject_adapter(
        jax.tree.map(lambda x: np.zeros_like(np.asarray(x)), l1),
        adapters["ad1"], 0,
    )
    toks = generate(
        base, jax.tree.map(jnp.asarray, lora1), CFG, meta1,
        jnp.asarray(prompt[None, :]), 4,
    )
    np.testing.assert_array_equal(stats.results[0].tokens, np.asarray(toks[0]))


def test_prompt_overflow_rejected(world):
    base, _, adapters = world
    eng = _engine(base, adapters, rows=1, smax=16)
    req = ServeRequest(0, "ad0", _prompts(1, lo=14, hi=15)[0],
                      max_new_tokens=8)
    with pytest.raises(ValueError, match="exceeds smax"):
        eng.serve([req])


def test_executor_compile_cache_is_reused(world):
    """The generate() re-jit fix: same (cfg, width) => same jitted callable,
    across engine admissions and across generate() calls."""
    base, _, adapters = world
    ex = ServeExecutor()
    s1 = ex.step_fn(CFG, 2)
    s2 = ex.step_fn(CFG, 2)
    assert s1 is s2
    assert ex.step_fn(CFG, 1) is not s1  # width is part of the key
    n0 = ex.cache_size
    ex.prefill_fn(CFG, 1)
    ex.prefill_fn(CFG, 1)
    assert ex.cache_size == n0 + 1


# ---------------------------------------------------------------------------
# Tune-then-serve handoff
# ---------------------------------------------------------------------------


def test_handoff_packed_state_bitexact_vs_load(tmp_path, world):
    """publish_from_packed_state stages exactly the adapter that
    ``load_packed_state`` + ``extract_adapter`` yields."""
    _, lora, _ = world
    pool = CheckpointPool(str(tmp_path))
    opt = jax.tree.map(np.zeros_like, jax.tree.map(np.asarray, lora))
    pool.save_packed_state(
        "t0", jax.tree.map(np.asarray, lora), {"m": opt, "v": opt},
        {"steps_done": 1},
    )
    eng = ServeEngine(CFG, None, rows=1, smax=16, r_bucket=RANK)
    eng.publish_from_packed_state(
        pool, "t0", 1, "hot", rank=RANK, alpha=ALPHA
    )
    want_lora, _, _ = pool.load_packed_state("t0")
    want = extract_adapter(want_lora, 1)
    got, meta = eng.slot_cache.get("hot")
    flat_got = jax.tree.leaves(got)
    flat_want = jax.tree.leaves(want)
    assert len(flat_got) == len(flat_want) > 0
    for g, w in zip(flat_got, flat_want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    assert meta == {"rank": RANK, "alpha": ALPHA}


def test_tune_then_serve_without_disk(world, monkeypatch):
    """A freshly trained adapter is served straight from memory: the engine
    has NO checkpoint pool, so any disk path would fail loudly — and the
    served tokens match serving the same weights via an explicit pool
    round trip (the handoff loses nothing)."""
    from repro.train.data import packed_batch_iterator
    from repro.train.optimizer import init_opt_state
    from repro.train.trainer import train_loop

    base, _, _ = world
    cfgs = [LoraConfig(rank=RANK, alpha=ALPHA, learning_rate=1e-3,
                       batch_size=1, seq_len=16)]
    meta = pack_meta(cfgs)
    _, lora0 = init_model(jax.random.PRNGKey(3), CFG, meta)
    data = packed_batch_iterator(CFG, cfgs, seq=16)
    out = train_loop(base, lora0, CFG, meta, data, 2)
    trained = extract_adapter(jax.tree.map(np.asarray, out["lora"]), 0)

    prompt = _prompts(1, seed=11)[0]
    req = ServeRequest(0, "fresh", prompt, max_new_tokens=4)

    eng = ServeEngine(CFG, base, rows=1, smax=32, r_bucket=RANK,
                      checkpoint_pool=None)
    eng.publish("fresh", trained, {"rank": RANK, "alpha": ALPHA})
    direct = eng.serve([req])
    assert len(direct.results) == 1 and direct.cache_misses == 0

    import tempfile

    with tempfile.TemporaryDirectory() as d:
        pool = CheckpointPool(d)
        pool.save_adapter("fresh", trained, {"rank": RANK, "alpha": ALPHA})
        eng2 = ServeEngine(CFG, base, rows=1, smax=32, r_bucket=RANK,
                           checkpoint_pool=pool)
        via_disk = eng2.serve([req])
    np.testing.assert_array_equal(
        direct.results[0].tokens, via_disk.results[0].tokens
    )
    assert via_disk.cache_misses == 1  # the disk path actually loaded

"""Cluster subsystem: device-pool accounting, planned unit assignment,
executor compile-cache behavior, and — on a multi-device (forced) host —
concurrent-vs-sequential bit-exactness of per-adapter losses.

The multi-device tests skip on a 1-device host; CI runs the fast set a
second time under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so
the concurrent path is exercised on every PR.
"""
import jax
import numpy as np
import pytest
from harness import InjectedCrash, ScriptedExecutor, fake_pool

from repro.cluster import (
    ClusterRunner,
    DevicePool,
    SliceExecutor,
    assign_units,
    peak_overlap,
    pick_host_units,
)
from repro.configs.base import LoraConfig, default_search_space, get_config, reduced
from repro.core.adapter import pack_meta
from repro.launch.mesh import make_host_mesh, slice_mesh
from repro.models.model import init_model
from repro.sched.cost_model import A100_40G, CostModel
from repro.sched.engine import ExecutionEngine, poisson_trace
from repro.sched.planner import Schedule, ScheduledJob

MULTIDEV = jax.device_count() >= 4


# ---------------------------------------------------------------------------
# Device pool accounting
# ---------------------------------------------------------------------------


def test_pool_acquire_release_accounting():
    pool = fake_pool(8)  # accounting needs no jax devs
    assert pool.total == 8 and pool.free == 8
    s1 = pool.acquire(3)
    assert s1.units == (0, 1, 2) and s1.width == 3
    s2 = pool.acquire(5)
    assert s2.units == (3, 4, 5, 6, 7)
    assert pool.free == 0
    assert pool.try_acquire(1) is None  # exhausted
    pool.release(s1)
    assert pool.free == 3
    s3 = pool.try_acquire(2)
    assert s3 is not None and set(s3.units) <= {0, 1, 2}
    pool.release(s2)
    pool.release(s3)
    assert pool.free == 8


def test_pool_exhaustion_and_errors():
    pool = fake_pool(4)
    with pytest.raises(ValueError, match="only 4"):
        pool.acquire(5)
    s = pool.acquire(4)
    with pytest.raises(TimeoutError):
        pool.acquire(1, timeout=0.01)
    pool.release(s)
    with pytest.raises(RuntimeError, match="double release"):
        pool.release(s)


def test_pool_acquire_specific_units():
    pool = fake_pool(4)
    s = pool.acquire_units((1, 3))
    assert s.units == (1, 3) and s.devices == ("fake1", "fake3")
    with pytest.raises(TimeoutError, match=r"\[1\]"):
        pool.acquire_units((0, 1), timeout=0.01)
    pool.release(s)
    assert pool.free == 4


def test_pool_lease_releases_on_crash():
    """Acquisition as a context manager (ISSUE 4 satellite): the unit comes
    back even when the body dies — no release-after-crash leak."""
    pool = fake_pool(4)
    with pytest.raises(InjectedCrash):
        with pool.lease(2):
            assert pool.free == 2
            raise InjectedCrash("boom")
    assert pool.free == 4
    with pytest.raises(InjectedCrash):
        with pool.lease_units((0, 3)):
            raise InjectedCrash("boom")
    assert pool.free == 4
    s = pool.acquire(1)  # adopt-an-acquired-slice variant
    with pytest.raises(InjectedCrash):
        with pool.held(s):
            raise InjectedCrash("boom")
    assert pool.free == 4


def test_pool_map_units_wraps_degenerate():
    pool = fake_pool(1)
    assert pool.map_units((0, 3, 5)) == (0,)  # everything folds onto dev 0


def test_runner_crash_releases_units_and_raises():
    """Regression (ISSUE 4): a segment whose executor dies mid-run must not
    leak its device units — the run raises the crash AND the pool drains
    back to fully free (ClusterRunner asserts this itself on the success
    path; here we check the crash path)."""
    from repro.sched.engine import JobSegment

    prior = CostModel(get_config("qwen25-7b"), A100_40G)
    cfgs = {
        0: LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1, seq_len=16),
        1: LoraConfig(rank=8, alpha=16.0, learning_rate=1e-3, batch_size=1, seq_len=16),
    }
    segs = [
        JobSegment(
            job_id=i, config_ids=(i,), degree=1, start=float(i), end=i + 1.0,
            start_steps=(0,), run_steps=2, done_ids=(i,), units=(i,),
        )
        for i in range(2)
    ]
    for concurrent in (False, True):
        pool = fake_pool(4)
        ex = ScriptedExecutor(prior, crash_on=lambda idx, seg: idx == 0)
        runner = ClusterRunner(ex, pool, concurrent=concurrent)
        with pytest.raises(InjectedCrash):
            runner.run(segs, cfgs, {0: 2, 1: 2}, None, None, seq=16)
        assert pool.free == pool.total, (concurrent, pool.free)


def test_pick_host_units_host_disjoint_and_best_fit():
    free = [0, 1, 4, 5, 6, 7]
    # host_size None: plain lowest-first (single-host behavior)
    assert pick_host_units(free, 3, None) == (0, 1, 4)
    # degree 2 fits host 0 (2 free) better than host 1 (4 free): best-fit
    assert pick_host_units(free, 2, 4) == (0, 1)
    assert pick_host_units(free, 4, 4) == (4, 5, 6, 7)
    # no single host has 3 free units on host_size=2 pools
    assert pick_host_units([0, 3, 4, 7], 2, 2) is None
    assert pick_host_units([0, 1], 4, 4) is None


# ---------------------------------------------------------------------------
# Unit assignment (static + online planner)
# ---------------------------------------------------------------------------


def test_assign_units_disjoint_and_reusing():
    units = assign_units(
        [(0.0, 2.0, 2), (0.0, 1.0, 2), (1.0, 2.0, 2), (2.0, 3.0, 4)], 4
    )
    assert units[0] == (0, 1)
    assert units[1] == (2, 3)
    assert units[2] == (2, 3)  # reuses the units freed at t=1
    assert units[3] == (0, 1, 2, 3)
    with pytest.raises(RuntimeError, match="oversubscribe"):
        assign_units([(0.0, 1.0, 3), (0.0, 1.0, 2)], 4)


def test_assign_units_host_aware():
    units = assign_units(
        [(0.0, 1.0, 2), (0.0, 1.0, 1), (0.0, 1.0, 1)], 4, host_size=2
    )
    # the degree-2 job gets a whole host; the singles share the other
    assert units[0] in ((0, 1), (2, 3))
    for u in units:
        assert len({x // 2 for x in u}) == 1  # host-disjoint
    assert sorted(x for u in units for x in u) == [0, 1, 2, 3]
    # a degree-2 interval that only fits by spanning hosts must raise
    with pytest.raises(RuntimeError, match="host"):
        assign_units(
            [(0.0, 2.0, 1), (0.0, 1.0, 1), (1.0, 2.0, 2), (0.0, 2.0, 1)],
            4,
            host_size=2,
        )


def test_plan_online_assigns_disjoint_units():
    cm = CostModel(get_config("command-r-35b"), A100_40G)
    eng = ExecutionEngine(cm, 8)
    configs = default_search_space(16, 1024)
    steps = np.random.RandomState(0).choice([200, 500, 1000, 2000], size=16)
    trace = poisson_trace(configs, 800.0, seed=1, steps=steps)
    sched = eng.plan_online(trace, 1024, 1000, migration_budget=2)
    assert all(len(s.units) == s.degree for s in sched.segments)
    sched.validate()  # checks unit range + overlap disjointness
    # corrupting a unit assignment must be caught
    import dataclasses

    bad = dataclasses.replace(
        sched.segments[0], units=(99,) * sched.segments[0].degree
    )
    sched.segments[0] = bad
    with pytest.raises(RuntimeError, match="units"):
        sched.validate()


def test_resume_deps_latest_writer_no_self_dep():
    """Regression: a zero-step re-preemption re-writes the same (cid, step)
    checkpoint key; the resumer must depend on the latest *earlier* writer,
    never on itself (which would deadlock the dispatcher)."""
    from repro.cluster import resume_deps
    from repro.sched.engine import JobSegment

    def seg(job_id, start, start_step, run_steps, preempted):
        return JobSegment(
            job_id=job_id, config_ids=(0,), degree=1,
            start=start, end=start + 1.0,
            start_steps=(start_step,), run_steps=run_steps,
            done_ids=() if preempted else (0,), preempted=preempted,
        )

    order = [
        seg(0, 0.0, 0, 3, True),   # writes (0, 3)
        seg(1, 1.0, 3, 0, True),   # resumes @3, preempted after 0 steps:
                                   # re-writes (0, 3)
        seg(2, 2.0, 3, 5, False),  # resumes @3: depends on seg 1, not 0
    ]
    assert resume_deps(order) == [[], [0], [1]]


# ---------------------------------------------------------------------------
# Executor compile cache
# ---------------------------------------------------------------------------


def test_executor_cache_hits_same_shape_packs():
    """Two packs with identical (n, shape) but different hyperparameters
    share one step build — hyperparameters are runtime args."""
    cfg = reduced(get_config("qwen25-7b"))
    ex = SliceExecutor()
    s1, _ = ex.step_fn(cfg, 2)
    s2, _ = ex.step_fn(cfg, 2)
    assert s1 is s2
    assert ex.n_builds == 1 and ex.n_hits == 1
    s3, _ = ex.step_fn(cfg, 3)  # different pack width: new build
    assert s3 is not s1
    assert ex.n_builds == 2


def test_executor_cache_integration_run_segments():
    """Running two same-shape packs through the engine builds one step and
    one pack template; a third, different-shape pack adds one more."""
    cfg = reduced(get_config("qwen25-7b"))
    cm = CostModel(cfg, A100_40G)
    configs = [
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1, seq_len=16),
        LoraConfig(rank=8, alpha=16.0, learning_rate=5e-4, batch_size=1, seq_len=16),
        LoraConfig(rank=16, alpha=16.0, learning_rate=1e-3, batch_size=1, seq_len=16),
    ]
    jobs = [ScheduledJob((i,), 1, float(i), float(i + 1)) for i in range(3)]
    sched = Schedule(jobs, 3.0, 1)
    eng = ExecutionEngine(cm, 1)
    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta(configs))
    ex = SliceExecutor()
    runner = ClusterRunner(ex, DevicePool(jax.devices()[:1]), concurrent=False)
    records, _ = eng.run_local(
        sched, configs, cfg, base, n_steps=2, seq=16, runner=runner
    )
    assert len(records) == 3
    # 3 single-config packs, all n=1: ONE step build; but two r_buckets
    # (8 and 16) -> two pack templates
    assert ex.n_builds == 1
    assert ex.n_hits == 2
    assert len(ex._templates) == 2


# ---------------------------------------------------------------------------
# Mesh helpers
# ---------------------------------------------------------------------------


def test_make_host_mesh_clear_error():
    need = 4 * jax.device_count()
    with pytest.raises(RuntimeError) as ei:
        make_host_mesh(4, jax.device_count())
    msg = str(ei.value)
    assert str(need) in msg and str(jax.device_count()) in msg
    assert "xla_force_host_platform_device_count" in msg


def test_slice_mesh_subset():
    devs = jax.devices()
    m = slice_mesh(devs, 1)
    assert m.devices.shape == (1, 1)
    with pytest.raises(RuntimeError, match="only"):
        slice_mesh(devs[:1], 2)


# ---------------------------------------------------------------------------
# Concurrent vs sequential on a multi-device host
# ---------------------------------------------------------------------------


@pytest.mark.skipif(not MULTIDEV, reason="needs >=4 (forced) host devices")
def test_concurrent_matches_sequential_bitexact():
    """The acceptance property: a 4-group schedule executed concurrently on
    disjoint mesh slices produces bit-identical per-adapter losses to the
    sequential baseline, and the segments really overlap."""
    cfg = reduced(get_config("qwen25-7b"))
    cm = CostModel(cfg, A100_40G)
    seq = 16
    grid = [
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1, seq_len=seq),
        LoraConfig(rank=8, alpha=16.0, learning_rate=5e-4, batch_size=1, seq_len=seq),
        LoraConfig(rank=16, alpha=16.0, learning_rate=1e-3, batch_size=1, seq_len=seq),
        LoraConfig(rank=16, alpha=32.0, learning_rate=2e-4, batch_size=1, seq_len=seq),
    ]
    jobs = [ScheduledJob((i,), 1, 0.0, 1.0) for i in range(4)]
    sched = Schedule(jobs, 1.0, 4)
    eng = ExecutionEngine(cm, 4)
    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta(grid))
    ex = SliceExecutor()  # shared: both modes use the same compiled steps
    devs = jax.devices()[:4]
    out = {}
    for mode in (False, True):
        runner = ClusterRunner(ex, DevicePool(devs), concurrent=mode)
        records, _ = eng.run_local(
            sched, grid, cfg, base, n_steps=3, seq=seq, runner=runner
        )
        losses = np.concatenate([r.final_losses for r in records])
        assert np.isfinite(losses).all()
        out[mode] = (records, losses)
    np.testing.assert_array_equal(out[False][1], out[True][1])
    # concurrent mode really overlapped (>= 2 segments at one instant)
    peak = peak_overlap(
        [(r.real_start, r.real_end) for r in out[True][0]]
    )
    assert peak >= 2, peak


@pytest.mark.skipif(not MULTIDEV, reason="needs >=4 (forced) host devices")
def test_width2_slice_runs_and_matches():
    """A degree-2 segment executes tensor-parallel on its 2-device slice and
    still matches the sequential run bit-for-bit."""
    cfg = reduced(get_config("qwen25-7b"))
    cm = CostModel(cfg, A100_40G)
    seq = 16
    grid = [
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1, seq_len=seq),
        LoraConfig(rank=8, alpha=16.0, learning_rate=5e-4, batch_size=1, seq_len=seq),
    ]
    jobs = [ScheduledJob((0,), 2, 0.0, 1.0), ScheduledJob((1,), 2, 0.0, 1.0)]
    sched = Schedule(jobs, 1.0, 4)
    eng = ExecutionEngine(cm, 4)
    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta(grid))
    ex = SliceExecutor()
    out = {}
    for mode in (False, True):
        runner = ClusterRunner(
            ex, DevicePool(jax.devices()[:4]), concurrent=mode
        )
        records, _ = eng.run_local(
            sched, grid, cfg, base, n_steps=3, seq=seq, runner=runner
        )
        out[mode] = np.concatenate([r.final_losses for r in records])
        assert np.isfinite(out[mode]).all()
    np.testing.assert_array_equal(out[False], out[True])


@pytest.mark.skipif(not MULTIDEV, reason="needs >=4 (forced) host devices")
def test_online_preempt_resume_concurrent(tmp_path):
    """run_online_local with a migration executes concurrently: the resumed
    segment waits for its predecessor's checkpoint (cross-slice dependency)
    and every adapter still finishes its exact budget."""
    from repro.train.checkpoint import CheckpointPool

    cfg = reduced(get_config("qwen25-7b"))
    cm = CostModel(cfg, A100_40G)
    cm.setup_time = 0.0
    eng = ExecutionEngine(cm, 1)
    a = LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1, seq_len=16)
    b = LoraConfig(rank=16, alpha=16.0, learning_rate=5e-4, batch_size=1, seq_len=16)
    it = cm.iter_time([a], 1, 16)
    from repro.sched.engine import Arrival

    trace = [Arrival(0.0, a, 6), Arrival(2.5 * it, b, 5)]
    pool = CheckpointPool(str(tmp_path / "pool"))
    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta([a]))
    runner = ClusterRunner(SliceExecutor(), DevicePool(), concurrent=True)
    records, sched = eng.run_online_local(
        trace, cfg, base, n_steps=6, seq=16, pool=pool,
        migration_budget=1, preempt_min_remaining=0.0, runner=runner,
    )
    assert sched.n_migrations == 1
    executed = {0: 0, 1: 0}
    for seg in sched.segments:
        for cid, st0 in zip(seg.config_ids, seg.start_steps):
            executed[cid] += min(sched.total_steps[cid] - st0, seg.run_steps)
    assert executed == {0: 6, 1: 5}
    for cid, total in ((0, 6), (1, 5)):
        meta = pool.load_meta(f"adapter_{cid:04d}")
        assert meta["total_steps"] == total
        assert np.isfinite(meta["final_loss"])

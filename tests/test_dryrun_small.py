"""Sharding + dry-run machinery on a small FORCED-device host mesh.

These tests run in a subprocess with XLA_FLAGS=--xla_force_host_platform_
device_count=8 (the main test process must keep seeing 1 CPU device), and
exercise the same param/batch/cache sharding rules and lower/compile path the
512-device production dry-run uses. The full production sweep is
``python -m repro.launch.dryrun --all`` (results in EXPERIMENTS.md §Dry-run).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # subprocess compiles; full set runs on main

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, timeout=540):
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        JAX_PLATFORMS="cpu",
        PYTHONPATH=os.path.join(ROOT, "src"),
    )
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


def test_shard_and_train_step_on_4x2_mesh():
    """Reduced arch, real 8-device host mesh (4 data x 2 model): shard params
    per the production rules, run one REAL train step, check finiteness and
    that adapter grads stay sharded."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import LoraConfig, get_config, reduced
        from repro.core.adapter import pack_meta
        from repro.launch.mesh import make_host_mesh
        from repro.launch.sharding import batch_specs, make_dist, param_specs, to_named
        from repro.models.model import init_model
        from repro.train.data import packed_batch_iterator
        from repro.train.optimizer import init_opt_state
        from repro.train.trainer import make_train_step

        assert jax.device_count() == 8, jax.device_count()
        cfg = reduced(get_config("qwen25-7b"), d_model=256)
        configs = [LoraConfig(rank=8, alpha=8., learning_rate=1e-3, batch_size=2)
                   for _ in range(4)]
        meta = pack_meta(configs)
        mesh = make_host_mesh(4, 2)
        base, lora = init_model(jax.random.PRNGKey(0), cfg, meta)
        with mesh:
            base_sp = to_named(param_specs(jax.eval_shape(lambda: base), cfg, mesh), mesh)
            lora_sp = to_named(param_specs(jax.eval_shape(lambda: lora), cfg, mesh), mesh)
            base = jax.device_put(base, base_sp)
            lora = jax.device_put(lora, lora_sp)
            opt = init_opt_state(lora)
            it = packed_batch_iterator(cfg, configs, seq=16)
            b = next(it)
            bs = to_named(batch_specs(jax.eval_shape(lambda: b), mesh), mesh)
            b = jax.device_put(b, bs)
            dist = make_dist(mesh, meta.n * meta.max_batch)
            step = make_train_step(cfg, meta, dist=dist, jit=True)
            lora2, opt2, m = step(base, lora, opt, b)
            loss = float(m["loss"])
        assert np.isfinite(loss), loss
        print("OK", loss)
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_production_mesh_shapes():
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert m1.devices.shape == (16, 16) and m1.axis_names == ("data", "model")
        assert m2.devices.shape == (2, 16, 16) and m2.axis_names == ("pod", "data", "model")
        print("OK")
    """)
    assert r.returncode == 0, r.stderr[-3000:]


def test_dryrun_lower_one_combo():
    """Full-size arch lowers (no compile — compile is the slow production
    sweep) on the 512-device production mesh, from the dryrun module."""
    r = _run("""
        from repro.launch.dryrun import lower_combo
        rep, info = lower_combo("gemma3-1b", "train_4k", compile_=False)
        assert rep is None and info["lower_s"] > 0
        print("OK", round(info["lower_s"], 1))
    """)
    assert r.returncode == 0, r.stderr[-3000:]


def test_dryrun_compile_decode_combo():
    """One full decode combo compiles end-to-end and yields roofline terms."""
    r = _run("""
        from repro.launch.dryrun import lower_combo
        rep, info = lower_combo("internvl2-1b", "decode_32k")
        row = rep.row(info["n_devices"])
        assert row["flops_per_device"] > 0
        assert row["t_compute_s"] > 0 and row["t_memory_s"] > 0
        assert row["bottleneck"] in ("compute", "memory", "collective")
        print("OK", row["bottleneck"])
    """)
    assert r.returncode == 0, r.stderr[-3000:]


def test_seq_parallel_residuals_same_values():
    """seq_sharded_residuals is a sharding CONSTRAINT, not a math change:
    loss and grads must match the baseline bitwise-ish on a real mesh."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import LoraConfig, get_config, reduced
        from repro.core.adapter import pack_meta
        from repro.launch.mesh import make_host_mesh
        from repro.launch.sharding import batch_specs, make_dist, param_specs, to_named
        from repro.models.model import init_model
        from repro.train.data import packed_batch_iterator
        from repro.train.trainer import loss_fn

        cfg = reduced(get_config("starcoder2-7b"), d_model=256)
        configs = [LoraConfig(rank=8, alpha=8., learning_rate=1e-3, batch_size=2)
                   for _ in range(2)]
        meta = pack_meta(configs)
        mesh = make_host_mesh(2, 4)
        base, lora = init_model(jax.random.PRNGKey(0), cfg, meta)
        it = packed_batch_iterator(cfg, configs, seq=16)
        b = next(it)
        nb = meta.n * meta.max_batch
        with mesh:
            losses = []
            for sp in (False, True):
                dist = make_dist(mesh, nb, seq_sharded_residuals=sp)
                l, per = jax.jit(lambda lo: loss_fn(
                    lo, base, b, cfg, meta, dist=dist))(lora)
                losses.append(float(l))
        np.testing.assert_allclose(losses[0], losses[1], rtol=1e-5)
        print("OK", losses)
    """)
    assert r.returncode == 0, r.stderr[-3000:]


def test_fsdp_mode_same_values():
    """FSDP execution mode (batch over data x model, weights gathered per
    use) is a LAYOUT change only: loss must equal the megatron baseline."""
    r = _run("""
        import jax, numpy as np
        from repro.configs.base import LoraConfig, get_config, reduced
        from repro.core.adapter import pack_meta
        from repro.launch.mesh import make_host_mesh
        from repro.launch.sharding import batch_specs, make_dist, param_specs, to_named
        from repro.models.model import init_model
        from repro.train.data import packed_batch_iterator
        from repro.train.trainer import loss_fn

        cfg = reduced(get_config("starcoder2-7b"), d_model=256)
        configs = [LoraConfig(rank=8, alpha=8., learning_rate=1e-3, batch_size=4)
                   for _ in range(2)]
        meta = pack_meta(configs)
        mesh = make_host_mesh(2, 4)
        base, lora = init_model(jax.random.PRNGKey(0), cfg, meta)
        b = next(packed_batch_iterator(cfg, configs, seq=16))
        nb = meta.n * meta.max_batch
        losses = []
        with mesh:
            for fsdp in (False, True):
                dist = make_dist(mesh, nb, fsdp=fsdp)
                bs = to_named(batch_specs(
                    jax.eval_shape(lambda: b), mesh, include_model=fsdp), mesh)
                bb = jax.device_put(b, bs)
                l, _ = jax.jit(lambda lo: loss_fn(lo, base, bb, cfg, meta,
                                                  dist=dist))(lora)
                losses.append(float(l))
        np.testing.assert_allclose(losses[0], losses[1], rtol=1e-5)
        print("OK", losses)
    """)
    assert r.returncode == 0, r.stderr[-3000:]


def test_flash_decode_cache_layout_same_values():
    """seq-over-model cache sharding changes collectives, not logits."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import LoraConfig, get_config, reduced
        from repro.core.adapter import pack_meta
        from repro.launch.mesh import make_host_mesh
        from repro.launch.sharding import cache_specs, make_dist, param_specs, to_named
        from repro.models.model import init_caches, init_model
        from repro.serve.decode import make_serve_step

        cfg = reduced(get_config("starcoder2-7b"), d_model=256)
        meta = pack_meta([LoraConfig(rank=8, alpha=8.)] * 2)
        mesh = make_host_mesh(2, 4)
        base, lora = init_model(jax.random.PRNGKey(0), cfg, meta)
        lora = jax.tree.map(lambda x: x + 0.01, lora)
        nb = 4
        caches = init_caches(cfg, nb, 32, jnp.float32)
        tok = jnp.ones((nb, 1), jnp.int32)
        outs = []
        with mesh:
            for som in (False, True):
                dist = make_dist(mesh, nb)
                cs = to_named(cache_specs(
                    jax.eval_shape(lambda: caches), mesh, nb,
                    seq_over_model=som), mesh)
                cc = jax.device_put(caches, cs)
                step = make_serve_step(cfg, meta, dist=dist, jit=False)
                _, lg, _ = jax.jit(step)(base, lora, cc, tok, jnp.int32(3))
                outs.append(np.asarray(lg))
        np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-4)
        print("OK")
    """)
    assert r.returncode == 0, r.stderr[-3000:]


def test_moe_ep_shard_map_on_mesh():
    """Expert-parallel MoE under shard_map on a real (1 data x 4 model) mesh
    == the dense oracle (capacity at no-drop)."""
    r = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs.base import MoEConfig
        from repro.models.layers.moe import apply_moe, init_moe
        from repro.models.transformer import shard_map_compat
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(2, 4)
        mcfg = MoEConfig(n_experts=4, top_k=2, d_expert=8, impl="ep",
                         capacity_factor=2.0)
        params = init_moe(jax.random.PRNGKey(0), 16, mcfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
        y_ref, aux_ref = apply_moe(params, x, MoEConfig(
            n_experts=4, top_k=2, d_expert=8, impl="dense", capacity_factor=2.0))

        def body(p, xx):
            return apply_moe(p, xx, mcfg, model_axis="model", model_axis_size=4)

        specs = {"router": {"w": P()}, "w_gate": P("model", None, None),
                 "w_up": P("model", None, None), "w_down": P("model", None, None)}
        with mesh:
            y, aux = jax.jit(shard_map_compat(
                body, mesh=mesh,
                in_specs=(specs, P("data", None, None)),
                out_specs=(P("data", None, None), P()),
                check_vma=False,
            ))(params, x)
        # capacity C=T*k/E*cf = 8*... per-shard T = 16 tokens, cap >= demand
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=5e-3, atol=5e-3)
        print("OK")
    """)
    assert r.returncode == 0, r.stderr[-3000:]

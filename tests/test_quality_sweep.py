"""Hyperparameter sensitivity on the synthetic permutation-LM task — the
shape of the paper's empirical study (Tables 2/3): different LoRA configs
reach different quality; a tuned config beats a bad default."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LoraConfig, get_config, reduced

pytestmark = pytest.mark.slow  # real-training sweep; full set runs on main
from repro.core.adapter import pack_meta
from repro.models import model as M
from repro.train.data import eval_batch, packed_batch_iterator
from repro.train.losses import top1_accuracy
from repro.train.optimizer import init_opt_state
from repro.train.trainer import make_train_step

CFG = reduced(get_config("qwen25-7b"))
SEQ = 32
STEPS = 30


def _tune(configs, steps=STEPS, seed=0):
    """Train a pack and return per-adapter held-out accuracy."""
    meta = pack_meta(configs)
    base, lora = M.init_model(jax.random.PRNGKey(seed), CFG, meta)
    it = packed_batch_iterator(CFG, configs, seq=SEQ, noise=0.05)
    step = make_train_step(CFG, meta)
    opt = init_opt_state(lora)
    for _ in range(steps):
        lora, opt, _ = step(base, lora, opt, next(it))
    ev = eval_batch(CFG, meta.n, seq=SEQ, batch=4, noise=0.0)
    h, _, _ = M.forward(base, lora, meta.scales(), {"tokens": ev["tokens"]}, CFG, n_pack=meta.n)
    lg = M.logits(base, h, CFG)
    acc = top1_accuracy(lg, ev["labels"], meta.n)
    return np.asarray(acc)


@pytest.fixture(scope="module")
def sweep_acc():
    # one pack, heterogeneous configs: a good one, a weak one, an lr-0 control
    configs = [
        LoraConfig(rank=16, alpha=32.0, learning_rate=5e-3, batch_size=4),   # tuned
        LoraConfig(rank=8, alpha=2.0, learning_rate=2e-5, batch_size=1),     # weak
        LoraConfig(rank=8, alpha=8.0, learning_rate=0.0, batch_size=1),      # base
    ]
    return _tune(configs)


def test_configs_differ_in_quality(sweep_acc):
    """Observation #1/#2: hyperparameters materially change quality."""
    assert sweep_acc[0] > sweep_acc[1] + 0.02, sweep_acc


def test_tuned_beats_base(sweep_acc):
    """Table 6: the best searched config beats the untrained base model."""
    assert sweep_acc[0] > sweep_acc[2] + 0.05, sweep_acc


def test_zero_lr_is_base_quality(sweep_acc):
    """lr=0 adapter == frozen base (B stays 0): chance-level on the task."""
    assert sweep_acc[2] < 0.2, sweep_acc


def test_data_stream_is_per_adapter_deterministic():
    """An adapter's sample stream depends only on its own config (packing-
    identity prerequisite)."""
    c1 = LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=2)
    c2 = LoraConfig(rank=16, alpha=4.0, learning_rate=2e-3, batch_size=1)
    it_a = packed_batch_iterator(CFG, [c1, c2], seq=SEQ)
    it_b = packed_batch_iterator(CFG, [c2, c1], seq=SEQ)  # order swapped
    ba, bb = next(it_a), next(it_b)
    bmax = 2
    # adapter c1 rows: slot 0 in a, slot 1 in b
    a_rows = np.asarray(ba["tokens"][0 * bmax : 0 * bmax + 2])
    b_rows = np.asarray(bb["tokens"][1 * bmax : 1 * bmax + 2])
    np.testing.assert_array_equal(a_rows, b_rows)

"""Pallas packed-matmul kernel vs the pure-jnp oracle.

Sweeps shapes (odd/aligned/tiny/large), dtypes (f32/bf16), block shapes, and
pack sizes; checks both forward values and (through the custom-vjp wrapper)
all four backward dataflows of the paper (§5.2 cases 1-4). The kernel runs in
interpret mode on CPU — the same kernel body that compiles for TPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import (skips sans hypothesis)

from repro.kernels.ops import (
    delta_flops,
    grouped_matmul,
    packed_lora_delta,
    rank_segments,
)
from repro.kernels.packed_matmul import packed_matmul
from repro.kernels import ref


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "n,m,k,l",
    [
        (1, 8, 16, 8),        # tiny, nothing aligned
        (2, 128, 128, 128),   # exactly one tile
        (3, 100, 36, 52),     # odd everything
        (4, 256, 8, 512),     # rank-like K=8 (never tiled)
        (8, 64, 128, 300),    # L not multiple of 128
        (2, 516, 260, 132),   # multiple tiles with remainders
    ],
)
def test_packed_matmul_matches_ref(dtype, n, m, k, l):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n * 1000 + m))
    x = _rand(k1, (n, m, k), dtype)
    w = _rand(k2, (n, k, l), dtype)
    scale = jnp.linspace(0.5, 2.0, n, dtype=jnp.float32)
    got = packed_matmul(x, w, scale, interpret=True)
    want = ref.packed_matmul_ref(x, w, scale)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("bm,bl,bk", [(8, 128, 128), (16, 256, 128), (256, 256, 512)])
def test_packed_matmul_block_shapes(bm, bl, bk):
    """Same values regardless of the BlockSpec tiling chosen."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    x = _rand(k1, (3, 40, 200), jnp.float32)
    w = _rand(k2, (3, 200, 72), jnp.float32)
    got = packed_matmul(x, w, None, block_m=bm, block_l=bl, block_k=bk, interpret=True)
    want = ref.packed_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_no_scale_is_identity_scale():
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = _rand(k1, (2, 16, 32), jnp.float32)
    w = _rand(k2, (2, 32, 16), jnp.float32)
    a = packed_matmul(x, w, None, interpret=True)
    b = packed_matmul(x, w, jnp.ones((2,)), interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_lora_delta_forward(impl):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    n, t, d, r, k = 4, 24, 48, 8, 40
    x = _rand(keys[0], (n, t, d), jnp.float32)
    a = _rand(keys[1], (n, d, r), jnp.float32)
    b = _rand(keys[2], (n, r, k), jnp.float32)
    alpha = jnp.asarray([0.5, 1.0, 2.0, 0.25])
    got = packed_lora_delta(x, a, b, alpha, impl=impl)
    want = ref.packed_lora_delta_ref(x, a, b, alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_lora_delta_grads_all_four_cases(impl):
    """The custom VJP (paper backward cases 1-4) against jax autodiff on the
    reference einsum implementation."""
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    n, t, d, r, k = 3, 16, 32, 8, 24
    x = _rand(keys[0], (n, t, d), jnp.float32)
    a = _rand(keys[1], (n, d, r), jnp.float32)
    b = _rand(keys[2], (n, r, k), jnp.float32)
    alpha = jnp.asarray([0.5, 1.0, 2.0])

    def f_kernel(x, a, b):
        return (packed_lora_delta(x, a, b, alpha, impl=impl) ** 2).sum()

    def f_ref(x, a, b):
        return (ref.packed_lora_delta_ref(x, a, b, alpha) ** 2).sum()

    gx, ga, gb = jax.grad(f_kernel, argnums=(0, 1, 2))(x, a, b)
    rx, ra, rb = jax.grad(f_ref, argnums=(0, 1, 2))(x, a, b)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ra), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(rb), rtol=1e-4, atol=1e-4)


def test_alpha_gets_zero_cotangent():
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    n, t, d, r, k = 2, 8, 16, 4, 12
    x = _rand(keys[0], (n, t, d), jnp.float32)
    a = _rand(keys[1], (n, d, r), jnp.float32)
    b = _rand(keys[2], (n, r, k), jnp.float32)
    alpha = jnp.asarray([1.0, 2.0])
    g = jax.grad(lambda al: packed_lora_delta(x, a, b, al).sum())(alpha)
    np.testing.assert_allclose(np.asarray(g), 0.0)


def test_sequential_matches_packed():
    """The paper's equivalence claim (§3.2): per-adapter math identical to
    single-adapter computation."""
    keys = jax.random.split(jax.random.PRNGKey(4), 3)
    n, t, d, r, k = 5, 12, 20, 8, 28
    x = _rand(keys[0], (n, t, d), jnp.float32)
    a = _rand(keys[1], (n, d, r), jnp.float32)
    b = _rand(keys[2], (n, r, k), jnp.float32)
    alpha = jnp.linspace(0.25, 2.0, n)
    packed = packed_lora_delta(x, a, b, alpha, impl="pallas")
    seq = ref.sequential_lora_delta_ref(x, a, b, alpha)
    np.testing.assert_allclose(np.asarray(packed), np.asarray(seq), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Property-based sweeps
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 6),
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    l=st.integers(1, 160),
)
def test_packed_matmul_property(n, m, k, l):
    k1, k2 = jax.random.split(jax.random.PRNGKey(m * 7 + k * 3 + l))
    x = _rand(k1, (n, m, k), jnp.float32)
    w = _rand(k2, (n, k, l), jnp.float32)
    got = packed_matmul(x, w, None, interpret=True)
    want = ref.packed_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 4),
    r_real=st.integers(1, 16),
    r_pad=st.integers(0, 16),
)
def test_rank_padding_exact(n, r_real, r_pad):
    """Zero-padded rank columns contribute exactly 0 to output AND grads —
    the correctness basis of heterogeneous-rank packing."""
    t, d, k = 8, 24, 20
    keys = jax.random.split(jax.random.PRNGKey(r_real * 31 + r_pad), 3)
    x = _rand(keys[0], (n, t, d), jnp.float32)
    a_real = _rand(keys[1], (n, d, r_real), jnp.float32)
    b_real = _rand(keys[2], (n, r_real, k), jnp.float32)
    alpha = jnp.ones((n,))
    a_padded = jnp.pad(a_real, ((0, 0), (0, 0), (0, r_pad)))
    b_padded = jnp.pad(b_real, ((0, 0), (0, r_pad), (0, 0)))

    out_r = packed_lora_delta(x, a_real, b_real, alpha)
    out_p = packed_lora_delta(x, a_padded, b_padded, alpha)
    np.testing.assert_allclose(np.asarray(out_r), np.asarray(out_p), rtol=1e-5, atol=1e-5)

    ga_p, gb_p = jax.grad(
        lambda a, b: (packed_lora_delta(x, a, b, alpha) ** 2).sum(), argnums=(0, 1)
    )(a_padded, b_padded)
    # gradient w.r.t. padded region of B is exactly 0 (A-pad columns are 0)
    np.testing.assert_allclose(np.asarray(gb_p[:, r_real:, :]), 0.0, atol=1e-6)


def test_rank_segments_structure():
    order, inv, segs = rank_segments((8, 4, 8, 16, 4))
    assert order == (1, 4, 0, 2, 3)
    assert segs == [(0, 2, 4), (2, 4, 8), (4, 5, 16)]
    # inv undoes order
    assert tuple(order[i] for i in inv) != inv  # non-trivial permutation
    assert [order[inv[i]] for i in range(5)] == list(range(5))
    # homogeneous pack: one segment, identity order
    order, inv, segs = rank_segments((8, 8, 8))
    assert order == (0, 1, 2) and segs == [(0, 3, 8)]


def test_delta_flops_ragged_savings():
    ranks = (8, 8, 64, 64)
    padded = delta_flops(ranks, 2048, 2048, 16, ragged=False)
    ragged = delta_flops(ranks, 2048, 2048, 16, ragged=True)
    # bucket = 64: the two rank-8 adapters each save (64-8)/64 of their work
    assert padded == pytest.approx(4 * 2.0 * 16 * 64 * 4096)
    assert ragged == pytest.approx(2.0 * 16 * 4096 * (8 + 8 + 64 + 64))
    assert ragged < padded
    # homogeneous packs save nothing
    assert delta_flops((16, 16), 64, 64, 8, ragged=True) == delta_flops(
        (16, 16), 64, 64, 8, ragged=False
    )


@settings(max_examples=15, deadline=None)
@given(
    ranks=st.lists(st.integers(1, 24), min_size=2, max_size=5),
)
def test_ragged_segments_match_padded_property(ranks):
    """Property (ISSUE 5 satellite): for ANY rank mixture, ragged same-rank
    segments produce the padded pack's values, and the sliced-off padding
    receives bit-zero gradient — the region where zero-padding used to
    contribute (numerically) zero now structurally contributes nothing."""
    ranks = tuple(ranks)
    n, t, d, k = len(ranks), 6, 20, 16
    bucket = max(ranks)
    keys = jax.random.split(jax.random.PRNGKey(sum(ranks)), 3)
    x = _rand(keys[0], (n, t, d), jnp.float32)
    a = _rand(keys[1], (n, d, bucket), jnp.float32)
    b = _rand(keys[2], (n, bucket, k), jnp.float32)
    mask_a = jnp.arange(bucket)[None, None, :] < jnp.asarray(ranks)[:, None, None]
    mask_b = jnp.arange(bucket)[None, :, None] < jnp.asarray(ranks)[:, None, None]
    a, b = a * mask_a, b * mask_b
    alpha = jnp.ones((n,))

    out_p = packed_lora_delta(x, a, b, alpha)
    out_r = packed_lora_delta(x, a, b, alpha, ranks=ranks)
    np.testing.assert_allclose(
        np.asarray(out_r), np.asarray(out_p), rtol=2e-5, atol=2e-5
    )

    ga, gb = jax.grad(
        lambda a, b: (packed_lora_delta(x, a, b, alpha, ranks=ranks) ** 2).sum(),
        argnums=(0, 1),
    )(a, b)
    for i, r in enumerate(ranks):
        assert (np.asarray(ga)[i, :, r:] == 0.0).all()
        assert (np.asarray(gb)[i, r:, :] == 0.0).all()


def test_grouped_matmul_dispatch():
    """auto == xla off-TPU; explicit pallas gives the same numbers."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(9))
    x = _rand(k1, (2, 16, 32), jnp.float32)
    w = _rand(k2, (2, 32, 48), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(grouped_matmul(x, w, impl="auto")),
        np.asarray(grouped_matmul(x, w, impl="pallas")),
        rtol=1e-5, atol=1e-5,
    )

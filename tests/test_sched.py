"""Scheduler: cost model sanity, knapsack optimality vs brute force, DTM,
job planner (Alg. 2), the Thm 6.1 AR bound, and baseline orderings that
reproduce the paper's qualitative results (PLoRA < MinGPU < MaxGPU)."""
import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import (skips sans hypothesis)

from repro.configs.base import LoraConfig, default_search_space, get_config
from repro.sched.cost_model import (
    A10_24G,
    A100_40G,
    TPU_V5E,
    CostModel,
    active_param_count,
    lora_param_count,
    model_param_count,
)
from repro.sched.dtm import dtm
from repro.sched.knapsack import brute_force, solve_pack
from repro.sched.planner import (
    max_gpu_schedule,
    min_gpu_schedule,
    plan,
    sequential_plora_schedule,
)

CFG7B = get_config("qwen25-7b")
SEQ = 1024
STEPS = 100


@pytest.fixture(scope="module")
def cm():
    return CostModel(CFG7B, A100_40G)


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------


def test_param_counts_plausible(cm):
    n = model_param_count(CFG7B)
    assert 6e9 < n < 9e9, n  # "7B"
    a = active_param_count(CFG7B)
    assert a == n  # dense model
    moe = get_config("qwen3-moe-30b-a3b")
    assert active_param_count(moe) < 0.25 * model_param_count(moe)


def test_lora_param_fraction(cm):
    """Paper §2.1: rank-64 adapter on Qwen-2.5-7B updates ~3.4% of params."""
    frac = lora_param_count(CFG7B, 64) / model_param_count(CFG7B)
    assert 0.01 < frac < 0.06, frac


def test_memory_monotone_in_pack_size(cm):
    c = LoraConfig(rank=32, alpha=32, batch_size=1, seq_len=SEQ)
    m1 = cm.job_mem_bytes([c], 1, SEQ)
    m2 = cm.job_mem_bytes([c] * 4, 1, SEQ)
    assert m2 > m1
    # paper §3.2: one adapter ~18.2 GB, two ~20.4 GB on A100-40G => packing
    # the base dominates; marginal adapter cost small
    marginal = (m2 - m1) / 3
    assert marginal < 0.25 * m1


def test_paper_memory_scale(cm):
    """Single rank-64 adapter on 7B fits a 40 GB A100 with slack (paper
    reports 18.2 GB at bs=1 short-seq; our act model is coarser but must fit)."""
    c = LoraConfig(rank=64, alpha=64, batch_size=1, seq_len=SEQ)
    m = cm.job_mem_bytes([c], 1, SEQ)
    assert m < 0.9 * 40e9, m / 1e9


def test_iter_time_decreasing_in_devices(cm):
    c = LoraConfig(rank=32, alpha=32, batch_size=8, seq_len=SEQ)
    times = [cm.iter_time([c] * 8, d, SEQ) for d in (1, 2, 4, 8)]
    assert times[0] > times[-1]


def test_throughput_increases_with_packing(cm):
    """The paper's core observation: at bs=1 on short (GLUE-scale) sequences
    the device is so underutilized that packing N adapters raises LoRA
    throughput nearly Nx."""
    c = LoraConfig(rank=32, alpha=32, batch_size=1, seq_len=128)
    t1 = cm.throughput([c], 1, 128)
    t8 = cm.throughput([c] * 8, 1, 128)
    assert t8 > 4.0 * t1, (t1, t8)


def test_paper_anchor_bs1_to_bs8():
    """§5.1: iteration time grows only ~10% from bs 1 -> 8 (short seqs)."""
    cm = CostModel(CFG7B, A100_40G)
    c1 = LoraConfig(rank=32, alpha=32, batch_size=1, seq_len=64)
    c8 = LoraConfig(rank=32, alpha=32, batch_size=8, seq_len=64)
    ratio = cm.iter_time([c8], 1, 64) / cm.iter_time([c1], 1, 64)
    assert 1.0 < ratio < 1.35, ratio


def test_paper_anchor_naive_8pack():
    """§5.1: naive sequential 8-pack is ~3.6x slower than a single adapter."""
    cm = CostModel(CFG7B, A100_40G)
    c = LoraConfig(rank=32, alpha=32, batch_size=1, seq_len=64)
    ratio = cm.iter_time_sequential([c] * 8, 1, 64) / cm.iter_time([c], 1, 64)
    assert 2.5 < ratio < 4.5, ratio


def test_min_degree(cm):
    c = LoraConfig(rank=32, alpha=32, batch_size=1, seq_len=SEQ)
    assert cm.min_degree([c], SEQ) == 1
    cm32 = CostModel(get_config("command-r-35b"), A100_40G)
    assert cm32.min_degree([c], SEQ) >= 2  # 35B needs >1 40GB GPU


# ---------------------------------------------------------------------------
# Knapsack / F(D, K)
# ---------------------------------------------------------------------------


def test_solve_pack_beats_or_matches_brute_force_throughput(cm):
    configs = default_search_space(10, SEQ)
    got = solve_pack(cm, configs, 1, SEQ)
    want = brute_force(cm, configs, 1, SEQ)
    assert got is not None and want is not None
    # same throughput up to the additive-surrogate gap (must be >= 90% opt)
    assert got[1] >= 0.90 * want[1], (got[1], want[1])


def test_solve_pack_respects_memory(cm):
    configs = default_search_space(30, SEQ)
    res = solve_pack(cm, configs, 1, SEQ)
    assert res is not None
    sel = [configs[i] for i in res[0]]
    assert cm.fits(sel, 1, SEQ)


def test_solve_pack_none_when_base_doesnt_fit():
    cm35 = CostModel(get_config("command-r-35b"), A100_40G)
    res = solve_pack(cm35, default_search_space(5, SEQ), 1, SEQ)
    assert res is None  # 35B model cannot fit a single 40G device


@settings(max_examples=10, deadline=None)
@given(n=st.integers(3, 10), d=st.sampled_from([1, 2, 4]))
def test_solve_pack_feasibility_property(n, d):
    cm = CostModel(CFG7B, A100_40G)
    configs = default_search_space(n, SEQ)
    res = solve_pack(cm, configs, d, SEQ)
    if res is not None:
        sel = [configs[i] for i in res[0]]
        assert cm.fits(sel, d, SEQ)
        assert len(set(res[0])) == len(res[0])  # no duplicates


# ---------------------------------------------------------------------------
# DTM (Alg. 1) + planner (Alg. 2)
# ---------------------------------------------------------------------------


def test_dtm_covers_all_or_uses_all_devices(cm):
    configs = default_search_space(24, SEQ)
    res = dtm(cm, configs, 8, SEQ, STEPS)
    used = sum(j.degree for j in res.jobs)
    covered = {i for j in res.jobs for i in j.config_ids}
    assert used <= 8
    assert covered <= set(range(24))
    assert covered  # something scheduled


def test_dtm_degrees_are_powers_of_two(cm):
    configs = default_search_space(16, SEQ)
    res = dtm(cm, configs, 8, SEQ, STEPS)
    for j in res.jobs:
        assert j.degree & (j.degree - 1) == 0


def test_planner_schedules_every_config(cm):
    configs = default_search_space(40, SEQ)
    sched = plan(cm, configs, 8, SEQ, STEPS)
    covered = sorted(i for j in sched.jobs for i in j.config_ids)
    assert covered == list(range(40))
    # each config exactly once (paper Eq 3)
    assert len(covered) == len(set(covered))


def test_planner_never_oversubscribes(cm):
    from repro.sched.engine import ExecutionEngine

    configs = default_search_space(40, SEQ)
    sched = plan(cm, configs, 8, SEQ, STEPS)
    ExecutionEngine(cm, 8).simulate(sched)  # raises on oversubscription


def test_ar_bound_in_paper_range(cm):
    """Paper: AR between 1.05 and 1.14 in practice; bound must be >= 1 and
    small for the 120-config space."""
    configs = default_search_space(120, SEQ)
    sched = plan(cm, configs, 8, SEQ, STEPS)
    ar = sched.ar()
    assert 1.0 <= ar <= 1.25, ar


def test_makespan_ordering_plora_min_max(cm):
    """Fig. 4 qualitative: PLoRA < MinGPU < MaxGPU."""
    configs = default_search_space(60, SEQ)
    s_p = plan(cm, configs, 8, SEQ, STEPS)
    s_min = min_gpu_schedule(cm, configs, 8, SEQ, STEPS)
    s_max = max_gpu_schedule(cm, configs, 8, SEQ, STEPS)
    assert s_p.makespan < s_min.makespan < s_max.makespan


def test_sequential_plora_between(cm):
    """Fig. 6: Sequential PLoRA (planner only, no packed kernels) sits
    between MinGPU and full PLoRA on short-seq (paper-regime) workloads."""
    seq = 128
    configs = default_search_space(40, seq)
    s_p = plan(cm, configs, 8, seq, STEPS)
    s_seq = sequential_plora_schedule(cm, configs, 8, seq, STEPS)
    s_min = min_gpu_schedule(cm, configs, 8, seq, STEPS)
    assert s_p.makespan < s_seq.makespan < s_min.makespan


@pytest.mark.parametrize("hw", [A100_40G, A10_24G, TPU_V5E])
def test_planner_works_across_hardware(hw):
    cm = CostModel(get_config("qwen25-7b"), hw)
    configs = default_search_space(16, SEQ)
    if cm.min_degree([configs[0]], SEQ) is None:
        pytest.skip("base model does not fit this hardware pool")
    sched = plan(cm, configs, min(hw.n_devices, 8), SEQ, STEPS)
    assert sched.makespan > 0
    assert sorted(i for j in sched.jobs for i in j.config_ids) == list(range(16))


def test_calibration_scales_time(cm):
    c = LoraConfig(rank=32, alpha=32, batch_size=1, seq_len=SEQ)
    cm2 = CostModel(CFG7B, A100_40G)
    t_pred = cm2.iter_time([c], 1, SEQ)
    cm2.calibrate(measured_iter_time=2 * t_pred, configs=[c], d=1, seq=SEQ)
    t_new = cm2.iter_time([c], 1, SEQ)
    np.testing.assert_allclose(t_new, 2 * t_pred, rtol=1e-6)

"""Per-architecture smoke tests (assignment requirement).

For every assigned architecture: instantiate the REDUCED variant of the same
family (≤4 layers effective pattern, d_model ≤ 512, ≤4 experts), run one
forward and one train step on CPU, assert output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LoraConfig, get_config, list_archs, reduced
from repro.core.adapter import pack_meta
from repro.models import model as M
from repro.train.data import packed_batch_iterator
from repro.train.optimizer import init_opt_state
from repro.train.trainer import make_train_step

ARCHS = list_archs()
SEQ = 32


def _batch_for(cfg, key, nb, seq):
    batch = {"tokens": jax.random.randint(key, (nb, seq), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["frames"] = 0.1 * jax.random.normal(
            key, (nb, cfg.encoder_seq_len, cfg.d_model)
        )
    if cfg.n_patch_tokens:
        batch["patches"] = 0.1 * jax.random.normal(
            key, (nb, cfg.n_patch_tokens, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_is_reduced(arch):
    cfg = reduced(get_config(arch))
    assert cfg.d_model <= 512
    assert cfg.n_layers <= 6
    if cfg.moe.enabled:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, key, meta2):
    cfg = reduced(get_config(arch))
    base, lora = M.init_model(key, cfg, meta2)
    nb = meta2.n * meta2.max_batch
    batch = _batch_for(cfg, key, nb, SEQ)
    h, _, aux = M.forward(base, lora, meta2.scales(), batch, cfg, n_pack=meta2.n)
    s_total = SEQ + (cfg.n_patch_tokens or 0)
    assert h.shape == (nb, s_total, cfg.d_model)
    assert bool(jnp.isfinite(h).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, key):
    cfg = reduced(get_config(arch))
    configs = [
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1),
        LoraConfig(rank=16, alpha=16.0, learning_rate=5e-4, batch_size=1),
    ]
    meta = pack_meta(configs)
    base, lora = M.init_model(key, cfg, meta)
    it = packed_batch_iterator(cfg, configs, seq=SEQ)
    step = make_train_step(cfg, meta, jit=False)
    opt = init_opt_state(lora)
    lora2, opt2, metrics = step(base, lora, opt, next(it))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert metrics["per_adapter_loss"].shape == (meta.n,)
    assert bool(jnp.isfinite(metrics["per_adapter_loss"]).all())
    # adapter B must have moved away from zero after one step
    moved = jax.tree.reduce(
        lambda acc, x: acc + float(jnp.abs(x).sum()),
        jax.tree.map(lambda a, b: a - b, lora, lora2),
        0.0,
    )
    assert moved > 0.0
    assert int(opt2["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_dims_match_assignment(arch):
    """The FULL configs carry the exact published dimensions."""
    spec = {
        "mamba2-370m": dict(n_layers=48, d_model=1024, vocab_size=50_280),
        "qwen3-moe-30b-a3b": dict(n_layers=48, d_model=2048, vocab_size=151_936),
        "whisper-tiny": dict(n_layers=4, d_model=384, vocab_size=51_865),
        "minicpm3-4b": dict(n_layers=62, d_model=2560, vocab_size=73_448),
        "gemma3-1b": dict(n_layers=26, d_model=1152, vocab_size=262_144),
        "command-r-35b": dict(n_layers=40, d_model=8192, vocab_size=256_000),
        "jamba-v0.1-52b": dict(n_layers=32, d_model=4096, vocab_size=65_536),
        "starcoder2-7b": dict(n_layers=32, d_model=4608, vocab_size=49_152),
        "grok-1-314b": dict(n_layers=64, d_model=6144, vocab_size=131_072),
        "internvl2-1b": dict(n_layers=24, d_model=896, vocab_size=151_655),
        "qwen25-7b": dict(n_layers=28, d_model=3584, vocab_size=152_064),
    }[arch]
    cfg = get_config(arch)
    for k, v in spec.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_moe_config_details():
    q = get_config("qwen3-moe-30b-a3b")
    assert q.moe.n_experts == 128 and q.moe.top_k == 8
    g = get_config("grok-1-314b")
    assert g.moe.n_experts == 8 and g.moe.top_k == 2
    j = get_config("jamba-v0.1-52b")
    assert j.moe.n_experts == 16 and j.moe.top_k == 2


def test_jamba_layer_pattern():
    cfg = get_config("jamba-v0.1-52b")
    kinds = cfg.layer_kinds()
    assert kinds.count("attn") == 4  # 32 layers, 1 attn per 8
    assert kinds[3] == "attn"
    ffns = cfg.ffn_kinds()
    assert ffns.count("moe") == 16  # every other layer


def test_gemma_local_global_pattern():
    from repro.models.transformer import layer_specs

    cfg = get_config("gemma3-1b")
    specs = layer_specs(cfg)
    windows = [s.window for s in specs]
    # every 6th layer global (window 0), rest local 512
    assert windows[5] == 0 and windows[0] == 512
    assert sum(1 for w in windows if w == 0) == 26 // 6
    thetas = {s.theta for s in specs}
    assert thetas == {10_000.0, 1e6}


@pytest.mark.parametrize("arch", ["mamba2-370m", "gemma3-1b", "jamba-v0.1-52b"])
def test_long_context_archs_marked(arch):
    assert get_config(arch).supports_long_context


def test_lora_starts_at_zero_delta(key, meta2):
    """B=0 init => packed model output == base model output at step 0."""
    cfg = reduced(get_config("starcoder2-7b"))
    base, lora = M.init_model(key, cfg, meta2)
    nb = meta2.n * 2
    batch = _batch_for(cfg, key, nb, SEQ)
    h_with, _, _ = M.forward(base, lora, meta2.scales(), batch, cfg, n_pack=meta2.n)
    h_without, _, _ = M.forward(base, {}, meta2.scales(), batch, cfg, n_pack=meta2.n)
    np.testing.assert_allclose(
        np.asarray(h_with), np.asarray(h_without), rtol=1e-6, atol=1e-6
    )

"""HLO cost parser: trip-count-aware FLOPs/bytes/collectives vs known-size
programs and XLA's own cost_analysis on unrolled graphs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import analyze_hlo, _parse_instr, _shape_bytes
from repro.roofline.analysis import RooflineReport


def _compiled_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_parse_instr_tuple_with_index_comments():
    line = (
        "  %while.13 = (s32[], f32[8,64]{1,0}, f32[4,8,64]{2,1,0}, "
        "/*index=5*/f32[4,64,64]{2,1,0}) while(%tuple.20), condition=%c, body=%b"
    )
    name, shape, op = _parse_instr(line)
    assert name == "while.13" and op == "while"
    assert "f32[4,64,64]" in shape


def test_parse_instr_simple():
    line = "  ROOT %dot.1 = f32[128,512]{1,0} dot(%a, %b), lhs_contracting_dims={1}"
    name, shape, op = _parse_instr(line)
    assert (name, op) == ("dot.1", "dot")


def test_shape_bytes():
    assert _shape_bytes("f32[128,512]{1,0}") == 128 * 512 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[4], s32[2,2])") == 16 + 16


def test_single_dot_flops():
    m, k, n = 128, 256, 512
    text = _compiled_text(
        lambda a, b: a @ b,
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
    )
    c = analyze_hlo(text)
    assert c.flops == 2.0 * m * k * n


def test_scan_trip_count_multiplies():
    """A 4-iteration scan of one matmul must count 4x the flops; the same
    program unrolled gives XLA more fusion freedom, so unrolled <= scan and
    both within 35% of the analytic count."""
    L, b, d = 4, 8, 64
    ws = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((b, d), jnp.float32)

    def scanned(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    def unrolled(ws, x):
        for i in range(L):
            x = jnp.tanh(x @ ws[i])
        return x.sum()

    analytic = 2.0 * b * d * d * L * 3  # fwd + dx + dw per layer
    f_s = analyze_hlo(_compiled_text(jax.grad(scanned), ws, x)).flops
    f_u = analyze_hlo(_compiled_text(jax.grad(unrolled), ws, x)).flops
    assert abs(f_s - analytic) / analytic < 0.35, (f_s, analytic)
    assert abs(f_u - analytic) / analytic < 0.35, (f_u, analytic)


def test_nested_scan():
    def inner(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=3)
        return y

    def outer(x, w):
        def body(c, _):
            return inner(c, w), None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y.sum()

    b, d = 4, 32
    text = _compiled_text(
        outer, jax.ShapeDtypeStruct((b, d), jnp.float32),
        jax.ShapeDtypeStruct((d, d), jnp.float32),
    )
    c = analyze_hlo(text)
    analytic = 2.0 * b * d * d * 15
    assert abs(c.flops - analytic) / analytic < 0.1, (c.flops, analytic)


def test_bytes_positive_and_plausible():
    d = 256
    text = _compiled_text(
        lambda a, b: a @ b,
        jax.ShapeDtypeStruct((d, d), jnp.float32),
        jax.ShapeDtypeStruct((d, d), jnp.float32),
    )
    c = analyze_hlo(text)
    min_traffic = 3 * d * d * 4  # two reads + one write
    assert c.bytes >= min_traffic
    assert c.bytes <= 4 * min_traffic


def test_collectives_counted_with_wire_factors():
    hlo = """
HloModule m

ENTRY %main (p: f32[16,128]) -> f32[16,128] {
  %p = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add
  %ag = f32[32,128]{1,0} all-gather(%ar), dimensions={0}
  ROOT %cp = f32[16,128]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    c = analyze_hlo(hlo)
    ar = 16 * 128 * 4 * 2.0  # all-reduce wire factor 2
    ag = 32 * 128 * 4 * 1.0
    cp = 16 * 128 * 4 * 1.0
    assert c.coll["all-reduce"] == ar
    assert c.coll["all-gather"] == ag
    assert c.coll["collective-permute"] == cp
    assert c.coll_total == ar + ag + cp


def test_roofline_report_terms():
    r = RooflineReport(
        arch="x", shape="train_4k", mesh="16x16",
        flops_per_device=197e12,  # exactly 1 second of compute
        bytes_per_device=819e9,   # exactly 1 second of HBM
        collective_per_device=25e9,  # 0.5 s of link
    )
    np.testing.assert_allclose(r.t_compute, 1.0)
    np.testing.assert_allclose(r.t_memory, 1.0)
    np.testing.assert_allclose(r.t_collective, 0.5)
    assert r.bottleneck in ("compute", "memory")
    row = r.row(256)
    assert set(row) >= {"arch", "t_compute_s", "bottleneck", "useful_flop_ratio"}


def test_model_flops_ratio():
    r = RooflineReport(
        arch="x", shape="s", mesh="m",
        flops_per_device=1e12, bytes_per_device=1.0,
        collective_per_device=0.0, model_flops=128e12,
    )
    np.testing.assert_allclose(r.useful_flop_ratio(256), 0.5)

"""Execution engine (simulate + run_local) and the checkpoint pool."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LoraConfig, get_config, reduced
from repro.core.adapter import pack_meta
from repro.models.model import init_model
from repro.sched.cost_model import A100_40G, CostModel
from repro.sched.engine import ExecutionEngine, ResourceMonitor, replay_measured
from repro.sched.planner import Schedule, ScheduledJob, plan
from repro.train.checkpoint import CheckpointPool, load_tree, save_tree


def test_resource_monitor():
    m = ResourceMonitor(8)
    assert m.acquire(5) and m.free == 3
    assert not m.acquire(4)
    m.release(5)
    assert m.free == 8


def test_simulate_detects_oversubscription():
    cm = CostModel(get_config("qwen25-7b"), A100_40G)
    bad = Schedule(
        jobs=[
            ScheduledJob((0,), 8, 0.0, 10.0),
            ScheduledJob((1,), 8, 5.0, 15.0),  # overlaps on all devices
        ],
        makespan=15.0,
        g=8,
    )
    with pytest.raises(RuntimeError):
        ExecutionEngine(cm, 8).simulate(bad)


def test_replay_measured_ordering():
    sched = Schedule(
        jobs=[ScheduledJob((0,), 4, 0, 10), ScheduledJob((1,), 4, 0, 10)],
        makespan=10, g=8,
    )
    from repro.sched.engine import JobRecord

    records = [JobRecord(sched.jobs[0], 3.0), JobRecord(sched.jobs[1], 5.0)]
    assert replay_measured(sched, records, 8) == 5.0  # concurrent
    assert replay_measured(sched, records, 4) == 8.0  # forced serial


def test_run_local_end_to_end(tmp_path):
    """Plan a tiny space, run the packed jobs for real on CPU, and check the
    checkpoint pool holds every adapter with sane metadata."""
    cfg = reduced(get_config("qwen25-7b"))
    cm = CostModel(cfg, A100_40G)
    configs = [
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1, seq_len=16),
        LoraConfig(rank=16, alpha=16.0, learning_rate=5e-4, batch_size=1, seq_len=16),
        LoraConfig(rank=8, alpha=32.0, learning_rate=1e-4, batch_size=2, seq_len=16),
    ]
    sched = plan(cm, configs, 2, 16, n_steps=2)
    engine = ExecutionEngine(cm, 2)
    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta(configs))
    pool = CheckpointPool(str(tmp_path / "pool"))
    records, makespan = engine.run_local(
        sched, configs, cfg, base, n_steps=2, seq=16, pool=pool
    )
    assert makespan > 0
    assert len(pool.list()) == len(configs)
    for i in range(len(configs)):
        meta = pool.load_meta(f"adapter_{i:04d}")
        assert meta["rank"] == configs[i].rank
        assert np.isfinite(meta["final_loss"])
        tree = pool.load_adapter(f"adapter_{i:04d}")
        leaves = jax.tree.leaves(tree)
        assert leaves and all(np.isfinite(np.asarray(l)).all() for l in leaves)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "layer": {"a": jnp.arange(6.0).reshape(2, 3), "b": jnp.zeros((4,))},
        "scalar": jnp.asarray(3.0),
    }
    p = str(tmp_path / "ck" / "t.npz")
    save_tree(p, tree, {"note": "hi"})
    back = load_tree(p)
    np.testing.assert_allclose(np.asarray(back["layer"]["a"]), np.asarray(tree["layer"]["a"]))
    np.testing.assert_allclose(np.asarray(back["scalar"]), 3.0)


def test_extracted_adapter_ranks(tmp_path):
    """extract_adapter crops padding back to each adapter's true rank."""
    from repro.core.packed_lora import extract_adapter

    cfg = reduced(get_config("qwen25-7b"))
    configs = [
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1),
        LoraConfig(rank=24, alpha=16.0, learning_rate=5e-4, batch_size=1),
    ]
    meta = pack_meta(configs)
    assert meta.r_bucket == 24
    _, lora = init_model(jax.random.PRNGKey(0), cfg, meta)
    a0 = extract_adapter(lora, 0, meta.ranks)
    a1 = extract_adapter(lora, 1, meta.ranks)

    def ranks_in(t, out):
        if isinstance(t, dict):
            if set(t) == {"a", "b"}:
                out.append(t["a"].shape[-1])
            else:
                for v in t.values():
                    ranks_in(v, out)
        return out

    assert set(ranks_in(a0, [])) == {8}
    assert set(ranks_in(a1, [])) == {24}

"""The paper's core equivalence: packed fine-tuning == single-adapter
fine-tuning, adapter by adapter (§3.2 "the computation of each adapter in
packed LoRA fine-tuning is identical to LoRA fine-tuning with this single
LoRA adapter").

We train (a) each adapter alone and (b) both packed, on identical per-adapter
data streams, and require the final losses/weights to agree to float
tolerance. Also: per-adapter LRs are honored, loss decreases over training,
and gradients do not leak across adapters in a pack.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LoraConfig, get_config, reduced
from repro.core.adapter import pack_meta
from repro.core.packed_lora import extract_adapter
from repro.models import model as M
from repro.train.data import packed_batch_iterator
from repro.train.optimizer import init_opt_state
from repro.train.trainer import make_train_step, train_loop

CFG = reduced(get_config("qwen25-7b"))
SEQ = 24


def _train(configs, n_steps=4, seed=0):
    meta = pack_meta(configs)
    key = jax.random.PRNGKey(seed)
    base, lora = M.init_model(key, CFG, meta)
    it = packed_batch_iterator(CFG, configs, seq=SEQ)
    step = make_train_step(CFG, meta, jit=False)
    opt = init_opt_state(lora)
    hist = []
    for _ in range(n_steps):
        lora, opt, m = step(base, lora, opt, next(it))
        hist.append(np.asarray(m["per_adapter_loss"]))
    return lora, np.stack(hist), meta


def test_packed_equals_single_adapter_losses():
    c1 = LoraConfig(rank=8, alpha=8.0, learning_rate=2e-3, batch_size=2)
    c2 = LoraConfig(rank=16, alpha=8.0, learning_rate=1e-3, batch_size=2)
    _, h_packed, _ = _train([c1, c2])
    _, h1, _ = _train([c1])
    _, h2, _ = _train([c2])
    # identical math up to float reduction order (NB=4 vs NB=2 GEMMs reduce
    # in different orders; AdamW's rsqrt amplifies ~1e-7 per-step noise by
    # step 4 — to ~3e-4 or ~2e-3 depending on the host's XLA CPU codegen,
    # hence the 5e-3 relative tolerance)
    np.testing.assert_allclose(h_packed[:, 0], h1[:, 0], rtol=5e-3, atol=1e-3)
    np.testing.assert_allclose(h_packed[:, 1], h2[:, 0], rtol=5e-3, atol=1e-3)


def test_packed_equals_single_adapter_weights():
    """Init uses per-pack RNG so we compare packed-slot-0 vs a 1-pack whose
    rank layout matches: same config in slot 0, same init key, same data."""
    c1 = LoraConfig(rank=8, alpha=8.0, learning_rate=2e-3, batch_size=2)
    # pack with identical second adapter so r_bucket matches a single run
    c2 = LoraConfig(rank=8, alpha=4.0, learning_rate=5e-4, batch_size=2)
    lora_p, _, meta_p = _train([c1, c2])
    lora_s, _, meta_s = _train([c1])
    a_p = extract_adapter(lora_p, 0, meta_p.ranks)
    a_s = extract_adapter(lora_s, 0, meta_s.ranks)

    # compare every {a,b} pair found in both trees
    def collect(t, out, pfx=""):
        if isinstance(t, dict):
            for k, v in t.items():
                collect(v, out, f"{pfx}/{k}")
        else:
            out[pfx] = np.asarray(t)
        return out

    fp, fs = collect(a_p, {}), collect(a_s, {})
    assert fp.keys() == fs.keys()
    # A inits differ only through the pack RNG split; B starts at 0 for both
    # and every gradient depends on the SAME data stream, so after training
    # the B matrices must be near-identical IF the A inits are. Our init
    # splits one key across the pack, so A matrices differ — instead verify
    # the invariant that holds regardless: per-adapter losses match (above)
    # and adapter-0 weights are independent of what else is in the pack:
    c3 = LoraConfig(rank=8, alpha=16.0, learning_rate=1e-4, batch_size=1)
    lora_q, _, meta_q = _train([c1, c3])
    a_q = extract_adapter(lora_q, 0, meta_q.ranks)
    fq = collect(a_q, {})
    for k in fp:
        np.testing.assert_allclose(fp[k], fq[k], rtol=2e-4, atol=2e-4)


def test_no_gradient_leak_across_adapters():
    """Adapter 1's grad is identically zero w.r.t. adapter 0's data."""
    c1 = LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1)
    c2 = LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1)
    meta = pack_meta([c1, c2])
    key = jax.random.PRNGKey(0)
    base, lora = M.init_model(key, CFG, meta)
    it = packed_batch_iterator(CFG, [c1, c2], seq=SEQ)
    batch = next(it)
    # mask adapter 1's labels entirely -> its gradient must be exactly 0
    from repro.train.losses import IGNORE

    labels = np.asarray(batch["labels"]).copy()
    labels[1:] = IGNORE  # adapter 1 owns rows [B, 2B)
    batch = dict(batch, labels=jnp.asarray(labels))

    from repro.train.trainer import loss_fn

    grads = jax.grad(lambda l: loss_fn(l, base, batch, CFG, meta)[0])(lora)

    def check(t):
        if isinstance(t, dict):
            for k, v in t.items():
                check(v)
        else:
            # pack dim is axis 0 (no layer blocks in reduced cfg? blocks exist)
            pass

    # flatten with path to find pack axis
    for path, leaf in jax.tree_util.tree_flatten_with_path(grads)[0]:
        in_blocks = any(getattr(p, "key", None) == "blocks" for p in path)
        ax = 1 if in_blocks else 0
        g1 = np.asarray(jnp.take(leaf, 1, axis=ax))
        np.testing.assert_allclose(g1, 0.0, atol=1e-7, err_msg=str(path))


def test_loss_decreases_over_training():
    c = LoraConfig(rank=16, alpha=32.0, learning_rate=5e-3, batch_size=4)
    _, hist, _ = _train([c], n_steps=30)
    assert hist[-1, 0] < hist[0, 0], hist[:, 0]


def test_per_adapter_lr_honored():
    """lr=0 adapter must not move; lr>0 adapter must."""
    c_frozen = LoraConfig(rank=8, alpha=8.0, learning_rate=0.0, batch_size=1)
    c_live = LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1)
    meta = pack_meta([c_frozen, c_live])
    key = jax.random.PRNGKey(0)
    base, lora0 = M.init_model(key, CFG, meta)
    it = packed_batch_iterator(CFG, [c_frozen, c_live], seq=SEQ)
    step = make_train_step(CFG, meta, jit=False)
    opt = init_opt_state(lora0)
    lora1, _, _ = step(base, lora0, opt, next(it))
    for path, (l0, l1) in zip(
        jax.tree_util.tree_flatten_with_path(lora0)[0],
        zip(jax.tree.leaves(lora0), jax.tree.leaves(lora1)),
    ):
        in_blocks = any(getattr(p, "key", None) == "blocks" for p in path[0])
        ax = 1 if in_blocks else 0
        d0 = np.abs(np.asarray(jnp.take(l0 - l1, 0, axis=ax)))
        np.testing.assert_allclose(d0, 0.0, atol=0.0, err_msg="frozen adapter moved")
    moved = sum(
        float(jnp.abs(a - b).sum()) for a, b in zip(jax.tree.leaves(lora0), jax.tree.leaves(lora1))
    )
    assert moved > 0.0


def test_train_loop_api():
    c = LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=2)
    meta = pack_meta([c])
    key = jax.random.PRNGKey(0)
    base, lora = M.init_model(key, CFG, meta)
    out = train_loop(
        base, lora, CFG, meta, packed_batch_iterator(CFG, [c], seq=SEQ), 3
    )
    assert len(out["history"]) == 3
    assert all(np.isfinite(h).all() for h in out["history"])

"""Serving path: prefill + decode == full forward, across attention families
(GQA, MLA, sliding-window, SSM, hybrid, enc-dec, VLM). Also the multi-LoRA
decode equivalence (adapters applied at decode == merged weights)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LoraConfig, get_config, reduced
from repro.core.adapter import pack_meta
from repro.core.packed_lora import merge_model
from repro.models import model as M
from repro.serve.decode import generate, make_prefill, make_serve_step, pad_caches

# whisper's decode path needs enc_out (cross-KV comes from the cache)
DECODE_ARCHS = [
    "starcoder2-7b",      # plain GQA
    "minicpm3-4b",        # MLA absorbed decode
    "gemma3-1b",          # sliding window + dual theta
    "mamba2-370m",        # SSM state decode
    "jamba-v0.1-52b",     # hybrid + MoE
    "qwen3-moe-30b-a3b",  # MoE
    "internvl2-1b",       # VLM patch prefix
    "whisper-tiny",       # enc-dec cross attention
]


def _setup(arch, meta, seed=0, dtype=jnp.float32):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(seed)
    base, lora = M.init_model(key, cfg, meta)
    # give B nonzero values so adapters actually matter at decode
    lora = jax.tree.map(lambda x: x + 0.01, lora)
    return cfg, base, lora


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_then_decode_matches_full_forward(arch, meta2):
    cfg, base, lora = _setup(arch, meta2)
    nb = meta2.n * 2
    s_prompt, n_dec = 8, 4
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (nb, s_prompt + n_dec), 0, cfg.vocab_size)
    scales = meta2.scales()
    extra = {}
    if cfg.is_encdec:
        extra["frames"] = 0.1 * jax.random.normal(key, (nb, cfg.encoder_seq_len, cfg.d_model))
    if cfg.n_patch_tokens:
        extra["patches"] = 0.1 * jax.random.normal(key, (nb, cfg.n_patch_tokens, cfg.d_model))

    # full forward over the whole sequence
    batch_full = {"tokens": toks, **extra}
    h_full, _, _ = M.forward(base, lora, scales, batch_full, cfg, n_pack=meta2.n)
    lg_full = M.logits(base, h_full, cfg)

    # prefill s_prompt then decode the rest token by token
    lg_pre, caches = M.prefill(
        base, lora, scales, {"tokens": toks[:, :s_prompt], **extra}, cfg, n_pack=meta2.n
    )
    n_patch = cfg.n_patch_tokens or 0
    caches = pad_caches(caches, n_patch + s_prompt + n_dec)
    lgs = [lg_pre[:, -1]]
    for t in range(n_dec - 1):
        pos = n_patch + s_prompt + t
        lg_t, caches = M.decode_step(
            base, lora, scales, toks[:, s_prompt + t : s_prompt + t + 1],
            caches, jnp.int32(pos), cfg, n_pack=meta2.n,
        )
        lgs.append(lg_t[:, 0])
    lg_dec = jnp.stack(lgs, axis=1)  # (NB, n_dec, V)
    want = lg_full[:, n_patch + s_prompt - 1 : n_patch + s_prompt - 1 + n_dec]
    np.testing.assert_allclose(
        np.asarray(lg_dec), np.asarray(want), rtol=2e-2, atol=2e-2
    )


def test_generate_shapes(meta2):
    cfg, base, lora = _setup("starcoder2-7b", meta2)
    nb = meta2.n * 2
    prompt = jax.random.randint(jax.random.PRNGKey(2), (nb, 6), 0, cfg.vocab_size)
    out = generate(base, lora, cfg, meta2, prompt, n_new=5)
    assert out.shape == (nb, 5)
    assert int(out.max()) < cfg.padded_vocab


def test_decode_respects_adapters(meta2):
    """Different adapters in the pack must produce different logits for the
    same token stream (multi-LoRA serving does route per-adapter)."""
    cfg, base, lora = _setup("starcoder2-7b", meta2, seed=3)
    nb = meta2.n * 1
    caches = M.init_caches(cfg, nb, 16)
    tok = jnp.ones((nb, 1), jnp.int32)
    lg, _ = M.decode_step(
        base, lora, meta2.scales(), tok, caches, jnp.int32(0), cfg, n_pack=meta2.n
    )
    # adapter 0 vs adapter 1 rows see the same token but different adapters
    assert float(jnp.abs(lg[0] - lg[1]).max()) > 1e-6


def test_merged_weights_match_adapter_path():
    """W + alpha/r * A B as a merged checkpoint == adapter applied on the fly
    (paper Fig. 1 inference merge)."""
    c = LoraConfig(rank=8, alpha=16.0, learning_rate=0.0, batch_size=1)
    meta = pack_meta([c])
    cfg = reduced(get_config("starcoder2-7b"))
    key = jax.random.PRNGKey(4)
    base, lora = M.init_model(key, cfg, meta)
    lora = jax.tree.map(lambda x: x + 0.02, lora)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab_size)
    h_adapter, _, _ = M.forward(base, lora, meta.scales(), {"tokens": toks}, cfg, n_pack=1)
    merged = merge_model(base, lora, np.asarray(meta.scales()), 0)
    h_merged, _, _ = M.forward(merged, {}, meta.scales(), {"tokens": toks}, cfg, n_pack=1)
    np.testing.assert_allclose(
        np.asarray(h_adapter), np.asarray(h_merged), rtol=5e-3, atol=5e-3
    )


def test_serve_step_fn(meta2):
    cfg, base, lora = _setup("gemma3-1b", meta2)
    nb = meta2.n * 2
    step = make_serve_step(cfg, meta2, jit=False)
    caches = M.init_caches(cfg, nb, 32)
    tok = jnp.ones((nb, 1), jnp.int32)
    nxt, lg, caches2 = step(base, lora, caches, tok, jnp.int32(0))
    assert nxt.shape == (nb,)
    assert lg.shape[0] == nb
    # cache got written at pos 0
    leaf0 = jax.tree.leaves(caches)[0]
    leaf1 = jax.tree.leaves(caches2)[0]
    assert float(jnp.abs(leaf1 - leaf0).sum()) >= 0.0  # structure intact


def test_long_window_decode_masks_future(meta2):
    """Sliding-window decode: positions beyond the window contribute nothing."""
    cfg = reduced(get_config("gemma3-1b"))
    key = jax.random.PRNGKey(5)
    base, lora = M.init_model(key, cfg, meta2)
    nb = meta2.n * 1
    smax = 64
    caches = M.init_caches(cfg, nb, smax)
    # poison cache far beyond any reachable position; decode at pos=0 must
    # not be affected by entries at positions > 0 (mask kpos <= pos)
    poisoned = jax.tree.map(
        lambda x: x + 100.0 if x.ndim >= 3 else x, caches
    )
    tok = jnp.ones((nb, 1), jnp.int32)
    lg_clean, _ = M.decode_step(
        base, lora, meta2.scales(), tok, caches, jnp.int32(0), cfg, n_pack=meta2.n
    )
    lg_poison, _ = M.decode_step(
        base, lora, meta2.scales(), tok, poisoned, jnp.int32(0), cfg, n_pack=meta2.n
    )
    np.testing.assert_allclose(
        np.asarray(lg_clean), np.asarray(lg_poison), rtol=1e-4, atol=1e-4
    )

"""Quantized frozen-base tier (kernels/quant.py + fused dequant epilogue).

The acceptance-critical invariant: the fused kernel consuming a quantized
base ({"codes","scales"} dicts) is BIT-EXACT against explicitly
dequantizing the base and running the same kernel — forward and backward,
both impls, f32 and bf16 activations. Dequantization is elementwise, so
per-tile in-kernel dequant commutes with tiling; any mismatch is a kernel
bug, not rounding. On top of that: quantizer error bounds (hypothesis),
the eligibility walk, executor cache keys, the KernelPolicy wire field,
the cost-model planner shift, and the serve sampling satellites.
"""
import pickle

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import

from repro.configs.base import LoraConfig, get_config, reduced
from repro.core.adapter import pack_meta
from repro.kernels.fused import fused_lora
from repro.kernels.quant import (
    NF4_CODEBOOK,
    dequantize,
    dequantize_base_params,
    is_quantized,
    logical_shape,
    nf4_block,
    quantize_base_params,
    quantize_weight,
    quantized_nbytes,
)
from repro.sched.cost_model import A100_40G, CostModel


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _setup(n, t, d, r, l, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(d * 10 + l), 4)
    x = _rand(ks[0], (n, t, d), dtype)
    w = np.asarray(jax.random.normal(ks[1], (d, l), jnp.float32)) * 0.1
    a = _rand(ks[2], (n, d, r), dtype) * 0.1
    b = _rand(ks[3], (n, r, l), dtype) * 0.1
    alpha = jnp.linspace(0.25, 2.0, n)
    return x, w, a, b, alpha


# ---------------------------------------------------------------------------
# Quantizer: round-trip error bounds + layout
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    half_d=st.integers(2, 24),
    l=st.integers(1, 32),
    seed=st.integers(0, 2**16),
)
def test_int8_roundtrip_error_bound(half_d, l, seed):
    """Symmetric per-output-channel int8: |w - dq(q(w))| <= scale/2 + ulp."""
    d = 2 * half_d
    rng = np.random.RandomState(seed)
    w = rng.randn(d, l).astype(np.float32) * rng.uniform(0.01, 10.0)
    q = quantize_weight(w, "int8")
    assert q["codes"].dtype == np.int8 and q["scales"].shape == (1, l)
    dq = np.asarray(dequantize(q))
    bound = q["scales"][0] * 0.5 * (1 + 1e-5) + 1e-7
    assert (np.abs(w - dq) <= bound[None, :]).all()


@settings(max_examples=25, deadline=None)
@given(
    half_d=st.integers(1, 12),
    l=st.integers(1, 24),
    seed=st.integers(0, 2**16),
)
def test_nf4_roundtrip_error_bound(half_d, l, seed):
    """nf4 block-scaled: error <= blockwise scale * half the widest gap
    between adjacent codebook levels (nearest-level assignment)."""
    d = 2 * half_d
    rng = np.random.RandomState(seed)
    w = rng.randn(d, l).astype(np.float32)
    q = quantize_weight(w, "nf4")
    blk = nf4_block(d)
    assert q["codes"].dtype == np.uint8 and q["codes"].shape == (d // 2, l)
    assert q["scales"].shape == (d // blk, l)
    dq = np.asarray(dequantize(q))
    half_gap = float(np.max(np.diff(np.sort(NF4_CODEBOOK)))) / 2.0
    scales = np.repeat(q["scales"], blk, axis=0)  # (d, l) blockwise
    assert (np.abs(w - dq) <= scales * half_gap * (1 + 1e-5) + 1e-7).all()


def test_quantized_nbytes_and_logical_shape():
    w = np.random.RandomState(0).randn(384, 256).astype(np.float32)
    q8, q4 = quantize_weight(w, "int8"), quantize_weight(w, "nf4")
    assert logical_shape(q8) == (384, 256) == logical_shape(q4)
    assert quantized_nbytes(q8) < w.nbytes / 3.8  # ~4x smaller than f32
    assert quantized_nbytes(q4) < quantized_nbytes(q8)  # nf4 denser still
    assert is_quantized(q8) and is_quantized(q4) and not is_quantized(w)


def test_eligibility_walk_quantizes_projections_only():
    """quantize_base_params hits projection 'w' leaves under eligible
    parents and leaves embeddings / lm_head / norms / 1-D leaves dense."""
    params = {
        "embed": {"w": np.ones((8, 4), np.float32)},
        "blocks": {
            "q": {"w": np.ones((4, 4), np.float32)},
            "gate": {"w": np.ones((4, 6), np.float32)},
            "ln": {"scale": np.ones((4,), np.float32)},
        },
        "lm_head": {"w": np.ones((4, 8), np.float32)},
    }
    out = quantize_base_params(params, "int8")
    assert is_quantized(out["blocks"]["q"]["w"])
    assert is_quantized(out["blocks"]["gate"]["w"])
    assert not is_quantized(out["embed"]["w"])
    assert not is_quantized(out["lm_head"]["w"])
    assert out["blocks"]["ln"]["scale"].shape == (4,)
    # mode=None is the identity
    same = quantize_base_params(params, None)
    assert not any(
        is_quantized(leaf) for leaf in jax.tree.leaves(
            same, is_leaf=is_quantized) if isinstance(leaf, dict)
    )
    # round trip back to dense restores shapes
    dense = dequantize_base_params(out)
    assert dense["blocks"]["q"]["w"].shape == (4, 4)


# ---------------------------------------------------------------------------
# THE acceptance matrix: in-kernel dequant bit-exact vs dequantize-then-run
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("impl", ["fused_xla", "fused_pallas"])
@pytest.mark.parametrize("mode", ["int8", "nf4"])
def test_quantized_fused_bit_exact_fwd_bwd(mode, impl, dtype):
    x, w, a, b, alpha = _setup(3, 16, 40, 8, 36, dtype)
    q = quantize_weight(w, mode)
    wd = dequantize(q)  # the reference base: dense, same values

    def loss(fn_w, x, a, b):
        y = fused_lora(x, fn_w, a, b, alpha, impl=impl)
        return jnp.sum(y.astype(jnp.float32) ** 2), y

    (lq, yq), gq = jax.value_and_grad(
        lambda *o: loss(q, *o), argnums=(0, 1, 2), has_aux=True)(x, a, b)
    (ld, yd), gd = jax.value_and_grad(
        lambda *o: loss(wd, *o), argnums=(0, 1, 2), has_aux=True)(x, a, b)
    np.testing.assert_array_equal(np.asarray(yq), np.asarray(yd))
    assert float(lq) == float(ld)
    for got, want, name in zip(gq, gd, ("dx", "dA", "dB")):
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want), err_msg=name)


@pytest.mark.parametrize("mode", ["int8", "nf4"])
def test_quantized_base_grads_match_dense_base(mode):
    """A/B adapter grads do not depend on HOW the base is stored — the
    quantized run reproduces the dense-on-dequantized-values run exactly."""
    x, w, a, b, alpha = _setup(2, 8, 32, 4, 24)
    q = quantize_weight(w, mode)
    wd = dequantize(q)

    def loss(wv, a, b):
        return jnp.sum(fused_lora(x, wv, a, b, alpha, impl="fused_xla") ** 2)

    ga_q, gb_q = jax.grad(loss, argnums=(1, 2))(q, a, b)
    ga_d, gb_d = jax.grad(loss, argnums=(1, 2))(wd, a, b)
    np.testing.assert_array_equal(np.asarray(ga_q), np.asarray(ga_d))
    np.testing.assert_array_equal(np.asarray(gb_q), np.asarray(gb_d))


def test_end_to_end_train_step_parity():
    """One jitted packed train step on a quantized base produces the SAME
    loss and adapter update as the explicitly dequantized base."""
    from repro.models.model import init_model
    from repro.train.data import packed_batch_iterator
    from repro.train.optimizer import init_opt_state
    from repro.train.trainer import make_train_step

    cfg = reduced(get_config("qwen25-7b"))
    configs = [LoraConfig(rank=4, alpha=8.0, learning_rate=1e-3,
                          batch_size=1, seq_len=8)] * 2
    meta = pack_meta(configs)
    base, lora = init_model(jax.random.PRNGKey(0), cfg, meta)
    qbase = quantize_base_params(base, "int8")
    dbase = dequantize_base_params(qbase)
    batch = next(packed_batch_iterator(cfg, configs, seq=8))
    outs = []
    for bp, bd in ((qbase, "int8"), (dbase, None)):
        step = make_train_step(cfg, meta, impl="fused_xla", base_dtype=bd)
        lora2, _, m = step(bp, jax.tree.map(jnp.copy, lora),
                           init_opt_state(lora), batch)
        outs.append((float(m["loss"]), jax.tree.leaves(lora2)))
    assert outs[0][0] == outs[1][0]
    for lq, ld in zip(outs[0][1], outs[1][1]):
        np.testing.assert_array_equal(np.asarray(lq), np.asarray(ld))


# ---------------------------------------------------------------------------
# Policy plumbing: executor cache key + multihost wire message
# ---------------------------------------------------------------------------


def test_executor_cache_keyed_on_base_dtype():
    from repro.cluster import SliceExecutor

    cfg = reduced(get_config("qwen25-7b"))
    ex = SliceExecutor()
    s1, _ = ex.step_fn(cfg, 2)
    s2, _ = ex.step_fn(cfg, 2, base_dtype="int8")
    s3, _ = ex.step_fn(cfg, 2, base_dtype="int8")
    assert s1 is not s2 and s2 is s3
    assert ex.n_builds == 2 and ex.n_hits == 1


def test_kernel_policy_wire_roundtrip():
    """KernelPolicy crosses the host-dispatch wire (pickle) with base_dtype
    intact, and old payloads without the field still decode (getattr
    default on the worker side)."""
    from repro.cluster.multihost import KernelPolicy

    pol = KernelPolicy(impl="fused_xla", remat="save", base_dtype="nf4")
    back = pickle.loads(pickle.dumps(pol))
    assert back.base_dtype == "nf4" and back.impl == "fused_xla"
    legacy = KernelPolicy(impl="xla")  # default None = dense
    assert getattr(legacy, "base_dtype", None) is None


# ---------------------------------------------------------------------------
# Cost model: quantized base shifts the packing decision
# ---------------------------------------------------------------------------


def test_base_bytes_per_param_table():
    cfg = get_config("qwen25-7b")
    dense = CostModel(cfg, A100_40G)
    i8 = CostModel(cfg, A100_40G, base_dtype="int8")
    n4 = CostModel(cfg, A100_40G, base_dtype="nf4")
    assert dense.base_bytes_per_param() == dense.prec_bytes  # bit-identical
    assert i8.base_bytes_per_param() == pytest.approx(1.0 + 4.0 / 256.0)
    assert n4.base_bytes_per_param() == pytest.approx(0.5 + 4.0 / 64.0)
    # the ISSUE's >= 1.8x memory-reduction claim, at the model level
    assert dense.base_weight_bytes() / i8.base_weight_bytes() >= 1.8
    assert i8.base_weight_bytes() / n4.base_weight_bytes() >= 1.7
    with pytest.raises(ValueError, match="unknown base_dtype"):
        CostModel(cfg, A100_40G, base_dtype="fp8").base_bytes_per_param()


def test_quantized_base_shifts_planner():
    """THE planner-shift assertion (test_autotune idiom): under a memory
    ceiling where two dense-base configs cannot co-reside on one device,
    the int8 cost model fits them together — the knapsack packs denser."""
    from repro.sched.dtm import dtm
    from repro.sched.planner import plan

    cfg = get_config("qwen25-7b")
    configs = [
        LoraConfig(rank=8, alpha=16.0, learning_rate=1e-3,
                   batch_size=1, seq_len=512),
        LoraConfig(rank=8, alpha=16.0, learning_rate=5e-4,
                   batch_size=1, seq_len=512),
    ]
    dense = CostModel(cfg, A100_40G)
    i8 = CostModel(cfg, A100_40G, base_dtype="int8")
    # ceiling between a SINGLE dense config and the dense two-pack: each
    # dense config fits alone but the pair must split, while the quantized
    # base (4x smaller) co-packs both with room to spare. fits() compares
    # against load_factor * mem_bytes, so undo the factor.
    need_d1 = dense.job_mem_bytes(configs[:1], 1, 512)
    need_d2 = dense.job_mem_bytes(configs, 1, 512)
    need_q2 = i8.job_mem_bytes(configs, 1, 512)
    assert need_q2 < need_d1 < need_d2
    hw = A100_40G.scaled(
        mem_bytes=(need_d1 + need_d2) / 2 / dense.load_factor)
    dense_c = CostModel(cfg, hw)
    i8_c = CostModel(cfg, hw, base_dtype="int8")
    assert dense_c.fits(configs[:1], 1, 512)
    assert not dense_c.fits(configs, 1, 512)
    assert i8_c.fits(configs, 1, 512)

    # one device: no degree escalation can rescue the dense pack, so the
    # planner must SPLIT it — while the quantized base co-packs both configs
    def decision(cm):
        return tuple(sorted(
            (tuple(sorted(j.config_ids)), j.degree)
            for j in dtm(cm, configs, 1, 512, 100).jobs
        ))

    d_dense, d_i8 = decision(dense_c), decision(i8_c)
    assert d_dense != d_i8
    assert d_i8 == (((0, 1), 1),)  # quantized: one pack, one device
    s_dense = plan(dense_c, configs, 1, 512, 100)
    s_i8 = plan(i8_c, configs, 1, 512, 100)
    assert len(s_i8.jobs) < len(s_dense.jobs)  # packed denser


# ---------------------------------------------------------------------------
# Serve sampling satellites
# ---------------------------------------------------------------------------


def test_sample_tokens_topk1_is_greedy():
    from repro.serve.engine import sample_tokens

    lg = jnp.asarray(np.random.RandomState(0).randn(4, 33), jnp.float32)
    temp = jnp.full((4,), 0.9, jnp.float32)
    topk = jnp.full((4,), 1, jnp.int32)
    got = sample_tokens(lg, temp, topk, jax.random.PRNGKey(7))
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(jnp.argmax(lg, axis=-1), np.int32))
    # zero temperature is greedy regardless of top_k
    zero = sample_tokens(lg, jnp.zeros((4,)), jnp.zeros((4,), jnp.int32),
                         jax.random.PRNGKey(7))
    np.testing.assert_array_equal(
        np.asarray(zero), np.asarray(jnp.argmax(lg, axis=-1), np.int32))


def test_sample_tokens_deterministic_and_topk_masked():
    from repro.serve.engine import sample_tokens

    lg = jnp.asarray(np.random.RandomState(1).randn(8, 64), jnp.float32)
    temp = jnp.full((8,), 1.3, jnp.float32)
    topk = jnp.full((8,), 5, jnp.int32)
    a = sample_tokens(lg, temp, topk, jax.random.PRNGKey(3))
    b = sample_tokens(lg, temp, topk, jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # every sampled token is inside each row's top-5 set
    top5 = np.argsort(np.asarray(lg), axis=-1)[:, -5:]
    for i, t in enumerate(np.asarray(a)):
        assert t in top5[i]


def test_serve_mixed_greedy_and_sampled_rows():
    """A sampled request rides next to greedy rows without recompiling the
    greedy baseline away: greedy requests in the same drain emit exactly
    the tokens an all-greedy engine emits."""
    from repro.core.packed_lora import extract_adapter
    from repro.models.model import init_model
    from repro.serve.engine import ServeEngine, ServeExecutor, ServeRequest

    cfg = reduced(get_config("gemma3-1b"))
    meta = pack_meta([LoraConfig(rank=4, alpha=8.0)] * 2)
    base, lora = init_model(jax.random.PRNGKey(0), cfg, meta)
    lora = jax.tree.map(lambda x: x + 0.02, lora)
    adapters = {f"ad{i}": extract_adapter(lora, i) for i in range(2)}
    prompt = np.arange(5, dtype=np.int32) % cfg.vocab_size

    def run(reqs):
        eng = ServeEngine(cfg, base, rows=2, smax=32, r_bucket=4,
                          serve_executor=ServeExecutor())
        for aid, tree in adapters.items():
            eng.publish(aid, tree, {"rank": 4, "alpha": 8.0})
        stats = eng.serve(reqs)
        return {r.request_id: r.tokens for r in stats.results}

    greedy_only = run([
        ServeRequest(0, "ad0", prompt, max_new_tokens=4),
    ])
    mixed = run([
        ServeRequest(0, "ad0", prompt, max_new_tokens=4),
        ServeRequest(1, "ad1", prompt, max_new_tokens=4,
                     temperature=0.8, top_k=4),
    ])
    np.testing.assert_array_equal(mixed[0], greedy_only[0])
    # same-seed engines reproduce the sampled row too
    mixed2 = run([
        ServeRequest(0, "ad0", prompt, max_new_tokens=4),
        ServeRequest(1, "ad1", prompt, max_new_tokens=4,
                     temperature=0.8, top_k=4),
    ])
    np.testing.assert_array_equal(mixed[1], mixed2[1])


def test_sample_step_cached_per_shape_not_per_temperature():
    """Temperature/top_k are runtime args: one sample step per (cfg, rows)
    key, shared across every request's sampling knobs."""
    from repro.serve.engine import ServeExecutor

    cfg = reduced(get_config("gemma3-1b"))
    ex = ServeExecutor()
    f1 = ex.sample_step_fn(cfg, 2)
    n0 = ex.cache_size
    f2 = ex.sample_step_fn(cfg, 2)
    assert f1 is f2 and ex.cache_size == n0
    ex.sample_step_fn(cfg, 4)  # new row width: new entry
    assert ex.cache_size == n0 + 1

"""Chunked CE vs naive full-logits CE; AdamW per-adapter semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import (skips sans hypothesis)

from repro.train.losses import IGNORE, chunked_cross_entropy, top1_accuracy
from repro.train.optimizer import adamw_update, init_opt_state


def _naive_ce(hidden, unembed, labels, n_pack, vocab):
    lg = (hidden @ unembed).astype(jnp.float32)
    lg = jnp.where(jnp.arange(lg.shape[-1]) < vocab, lg, -1e30)
    mask = (labels != IGNORE)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(lg, axis=-1)
    tgt = jnp.take_along_axis(lg, safe[..., None], -1)[..., 0]
    nll = jnp.where(mask, lse - tgt, 0.0)
    nll_n = nll.reshape(n_pack, -1).sum(-1)
    cnt_n = mask.astype(jnp.float32).reshape(n_pack, -1).sum(-1)
    per = nll_n / jnp.maximum(cnt_n, 1.0)
    return per, per.sum()


@pytest.mark.parametrize("s,chunk", [(16, 64), (64, 16), (65, 16), (17, 8)])
def test_chunked_ce_matches_naive(s, chunk):
    key = jax.random.PRNGKey(0)
    nb, d, vpad, vocab, n_pack = 4, 16, 64, 50, 2
    hidden = jax.random.normal(key, (nb, s, d))
    unembed = jax.random.normal(jax.random.PRNGKey(1), (d, vpad)) * 0.1
    labels = jax.random.randint(jax.random.PRNGKey(2), (nb, s), 0, vocab)
    labels = labels.at[:, -2:].set(IGNORE)
    per, total = chunked_cross_entropy(
        hidden, unembed, labels, n_pack, chunk=chunk, vocab=vocab
    )
    per_n, total_n = _naive_ce(hidden, unembed, labels, n_pack, vocab)
    np.testing.assert_allclose(np.asarray(per), np.asarray(per_n), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(total), float(total_n), rtol=1e-5)


def test_chunked_ce_grad_matches_naive():
    key = jax.random.PRNGKey(3)
    nb, s, d, vpad, vocab = 2, 32, 8, 32, 30
    hidden = jax.random.normal(key, (nb, s, d))
    unembed = jax.random.normal(jax.random.PRNGKey(4), (d, vpad)) * 0.1
    labels = jax.random.randint(jax.random.PRNGKey(5), (nb, s), 0, vocab)
    g1 = jax.grad(lambda h: chunked_cross_entropy(h, unembed, labels, 2, chunk=8, vocab=vocab)[1])(hidden)
    g2 = jax.grad(lambda h: _naive_ce(h, unembed, labels, 2, vocab)[1])(hidden)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-5)


def test_all_ignored_rows_are_safe():
    hidden = jnp.ones((2, 8, 4))
    unembed = jnp.ones((4, 16))
    labels = jnp.full((2, 8), IGNORE)
    per, total = chunked_cross_entropy(hidden, unembed, labels, 2, vocab=16)
    assert bool(jnp.isfinite(per).all()) and float(total) == 0.0


def test_padded_vocab_never_predicted():
    key = jax.random.PRNGKey(6)
    hidden = jax.random.normal(key, (1, 4, 8))
    unembed = jax.random.normal(jax.random.PRNGKey(7), (8, 32))
    # huge logit mass on padded column 31 — must be masked out
    unembed = unembed.at[:, 31].set(100.0)
    labels = jnp.zeros((1, 4), jnp.int32)
    per, _ = chunked_cross_entropy(hidden, unembed, labels, 1, vocab=31)
    assert bool(jnp.isfinite(per).all())


@settings(max_examples=10, deadline=None)
@given(s=st.integers(1, 48), chunk=st.sampled_from([4, 16, 64]))
def test_chunked_ce_property(s, chunk):
    key = jax.random.PRNGKey(s)
    hidden = jax.random.normal(key, (2, s, 8))
    unembed = jax.random.normal(jax.random.PRNGKey(s + 1), (8, 24)) * 0.2
    labels = jax.random.randint(jax.random.PRNGKey(s + 2), (2, s), 0, 20)
    per, _ = chunked_cross_entropy(hidden, unembed, labels, 2, chunk=chunk, vocab=20)
    per_n, _ = _naive_ce(hidden, unembed, labels, 2, 20)
    np.testing.assert_allclose(np.asarray(per), np.asarray(per_n), rtol=1e-4, atol=1e-4)


def test_top1_accuracy():
    lg = jnp.zeros((2, 3, 5)).at[:, :, 2].set(1.0)
    labels = jnp.asarray([[2, 2, IGNORE], [2, 0, IGNORE]])
    acc = top1_accuracy(lg, labels, 2)
    np.testing.assert_allclose(np.asarray(acc), [1.0, 0.5])


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def _tree():
    return {
        "q": {"a": jnp.ones((2, 4, 3)), "b": jnp.zeros((2, 3, 4))},
        "blocks": {"mlp": {"a": jnp.ones((5, 2, 4, 3))}},  # (L, N, ...)
    }


def test_adamw_per_adapter_lr():
    params = _tree()
    grads = jax.tree.map(jnp.ones_like, params)
    opt = init_opt_state(params)
    lr = jnp.asarray([0.0, 1e-2])
    new, opt2 = adamw_update(grads, opt, params, lr)
    # adapter 0 (lr=0) unchanged on both plain and blocks leaves
    np.testing.assert_allclose(np.asarray(new["q"]["a"][0]), 1.0)
    np.testing.assert_allclose(np.asarray(new["blocks"]["mlp"]["a"][:, 0]), 1.0)
    # adapter 1 moved by ~lr (first step: mhat/sqrt(vhat) = 1)
    np.testing.assert_allclose(np.asarray(new["q"]["a"][1]), 1.0 - 1e-2, rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(new["blocks"]["mlp"]["a"][:, 1]), 1.0 - 1e-2, rtol=1e-4
    )
    assert int(opt2["step"]) == 1


def test_adamw_moments_update():
    params = {"a": jnp.zeros((1, 2, 2))}
    grads = {"a": jnp.full((1, 2, 2), 2.0)}
    opt = init_opt_state(params)
    _, opt2 = adamw_update(grads, opt, params, jnp.asarray([1e-3]))
    np.testing.assert_allclose(np.asarray(opt2["m"]["a"]), 0.2, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(opt2["v"]["a"]), 0.004, rtol=1e-6)


def test_adamw_weight_decay():
    params = {"a": jnp.full((1, 2, 2), 10.0)}
    grads = {"a": jnp.zeros((1, 2, 2))}
    opt = init_opt_state(params)
    new, _ = adamw_update(grads, opt, params, jnp.asarray([1e-2]), weight_decay=0.1)
    assert float(new["a"].mean()) < 10.0

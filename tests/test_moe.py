"""MoE: expert-parallel local dispatch vs the dense oracle, capacity
behaviour, load-balance aux loss, and the shard_map path on a forced
multi-device host mesh (separate-process test lives in test_dryrun_small)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import (skips sans hypothesis)

from repro.configs.base import MoEConfig
from repro.models.layers.moe import (
    _moe_dense,
    _moe_ep_local,
    apply_moe,
    init_moe,
    moe_capacity,
)


def _setup(key, d=16, e=4, f=8, topk=2, cf=None):
    mcfg = MoEConfig(
        n_experts=e, top_k=topk, d_expert=f,
        capacity_factor=cf if cf is not None else e / topk,  # no dropping
    )
    params = init_moe(key, d, mcfg)
    return params, mcfg


def test_ep_local_matches_dense_when_no_dropping():
    """With capacity >= T the EP dispatch computes exactly the dense answer."""
    key = jax.random.PRNGKey(0)
    params, mcfg = _setup(key)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 16))
    y_dense, aux_d = _moe_dense(params, x, mcfg)
    cap = moe_capacity(24, mcfg)
    assert cap >= 24 * mcfg.top_k / mcfg.n_experts
    y_ep, aux_e = _moe_ep_local(params, x, mcfg, 0, mcfg.n_experts, capacity=24)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ep), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(aux_d), float(aux_e), rtol=1e-5)


def test_expert_slices_sum_to_full():
    """Sum of per-slice partial outputs == all-experts output (the psum
    identity the shard_map path relies on)."""
    key = jax.random.PRNGKey(2)
    params, mcfg = _setup(key, e=4)
    x = jax.random.normal(jax.random.PRNGKey(3), (16, 16))
    full, _ = _moe_ep_local(params, x, mcfg, 0, 4, capacity=16)
    parts = []
    for lo in range(0, 4, 2):
        # the shard_map path hands each shard only its expert slice
        # (router stays replicated)
        local = dict(
            params,
            w_gate=params["w_gate"][lo : lo + 2],
            w_up=params["w_up"][lo : lo + 2],
            w_down=params["w_down"][lo : lo + 2],
        )
        y, _ = _moe_ep_local(local, x, mcfg, lo, 2, capacity=16)
        parts.append(y)
    np.testing.assert_allclose(
        np.asarray(sum(parts)), np.asarray(full), rtol=1e-4, atol=1e-4
    )


def test_capacity_drops_tokens_gracefully():
    """Tiny capacity must not crash or produce NaN; dropped tokens pass
    through with zero expert contribution."""
    key = jax.random.PRNGKey(4)
    params, mcfg = _setup(key, cf=0.1)
    x = jax.random.normal(jax.random.PRNGKey(5), (32, 16))
    y, aux = _moe_ep_local(params, x, mcfg, 0, mcfg.n_experts, capacity=2)
    assert bool(jnp.isfinite(y).all())
    assert bool(jnp.isfinite(aux))


def test_aux_loss_is_one_for_uniform_router():
    """Perfectly balanced routing => Switch aux loss ~= 1 (its minimum)."""
    mcfg = MoEConfig(n_experts=4, top_k=1, d_expert=8, capacity_factor=4.0)
    key = jax.random.PRNGKey(0)
    params = init_moe(key, 16, mcfg)
    # zero router weights -> uniform probs -> f_e ~ 1/E, p_e = 1/E
    params["router"]["w"] = jnp.zeros_like(params["router"]["w"])
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    _, aux = _moe_dense(params, x, mcfg)
    assert 0.9 <= float(aux) <= 1.1, float(aux)


def test_apply_moe_shapes():
    key = jax.random.PRNGKey(6)
    params, mcfg = _setup(key)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 12, 16))
    y, aux = apply_moe(params, x, mcfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


@settings(max_examples=10, deadline=None)
@given(t=st.integers(4, 64), e=st.sampled_from([2, 4]), topk=st.integers(1, 2))
def test_moe_dense_chunking_invariance(t, e, topk):
    """_moe_dense chunk boundary must not change values."""
    key = jax.random.PRNGKey(t)
    mcfg = MoEConfig(n_experts=e, top_k=topk, d_expert=8, capacity_factor=e / topk)
    params = init_moe(key, 16, mcfg)
    x = jax.random.normal(jax.random.PRNGKey(t + 1), (t, 16))
    y1, _ = _moe_dense(params, x, mcfg, chunk=8)
    y2, _ = _moe_dense(params, x, mcfg, chunk=1024)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)


def test_gate_weights_normalized():
    from repro.models.layers.moe import _router

    mcfg = MoEConfig(n_experts=8, top_k=2, d_expert=8)
    params = init_moe(jax.random.PRNGKey(0), 16, mcfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    gates, idx, _ = _router(x, params, mcfg)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < 8 and int(idx.min()) >= 0

"""Mamba-2 SSD layer: chunked scan vs naive step recurrence, decode-step
equivalence with the full-sequence pass, and chunk-size invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import (skips sans hypothesis)

from repro.configs.base import SSMConfig, get_config, reduced
from repro.models.layers.ssm import (
    _ssd_scan,
    apply_ssm,
    apply_ssm_decode,
    init_ssm,
    init_ssm_cache,
    ssd_reference,
)


def _inputs(key, nb, s, h, p, n):
    ks = jax.random.split(key, 4)
    xs = jax.random.normal(ks[0], (nb, s, h, p))
    b = jax.random.normal(ks[1], (nb, s, n)) * 0.5
    c = jax.random.normal(ks[2], (nb, s, n)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (nb, s, h)))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, h))
    return xs, b, c, dt, a_log


@pytest.mark.parametrize("s,chunk", [(16, 4), (32, 8), (17, 8), (64, 64), (8, 16)])
def test_ssd_scan_matches_reference(s, chunk):
    xs, b, c, dt, a_log = _inputs(jax.random.PRNGKey(0), 2, s, 3, 4, 5)
    y, _ = _ssd_scan(xs, b, c, dt, a_log, chunk)
    want = ssd_reference(xs, b, c, dt, a_log)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    s=st.integers(2, 40),
    chunk=st.sampled_from([2, 4, 8, 16]),
    h=st.integers(1, 4),
)
def test_ssd_scan_property(s, chunk, h):
    xs, b, c, dt, a_log = _inputs(jax.random.PRNGKey(s * 100 + chunk), 1, s, h, 4, 4)
    y, _ = _ssd_scan(xs, b, c, dt, a_log, chunk)
    want = ssd_reference(xs, b, c, dt, a_log)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_chunk_size_invariance():
    xs, b, c, dt, a_log = _inputs(jax.random.PRNGKey(1), 2, 48, 2, 4, 6)
    y1, s1 = _ssd_scan(xs, b, c, dt, a_log, 4)
    y2, s2 = _ssd_scan(xs, b, c, dt, a_log, 16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


def test_final_state_continues_sequence():
    """State after seq[:s1] + scan of seq[s1:] == full-sequence scan."""
    xs, b, c, dt, a_log = _inputs(jax.random.PRNGKey(2), 1, 32, 2, 4, 4)
    y_full, state_full = _ssd_scan(xs, b, c, dt, a_log, 8)
    _, state_a = _ssd_scan(xs[:, :16], b[:, :16], c[:, :16], dt[:, :16], a_log, 8)
    # continue by stepping the reference recurrence from state_a
    a = -jnp.exp(a_log)
    state = state_a
    for t in range(16, 32):
        decay = jnp.exp(dt[:, t] * a)
        state = state * decay[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], b[:, t], xs[:, t].astype(jnp.float32)
        )
    np.testing.assert_allclose(
        np.asarray(state), np.asarray(state_full), rtol=1e-4, atol=1e-4
    )


def test_decode_step_matches_full_sequence(meta2):
    """Running apply_ssm over S tokens == prefill state + decode steps."""
    cfg = reduced(get_config("mamba2-370m"))
    scfg = cfg.ssm
    d = cfg.d_model
    key = jax.random.PRNGKey(3)
    params, lora = init_ssm(key, d, scfg, meta2, cfg.lora_targets)
    nb = meta2.n * 2
    x = 0.1 * jax.random.normal(key, (nb, 12, d))
    scales = meta2.scales()

    y_full, cache_mid = apply_ssm(
        params, lora, scales, x[:, :8], scfg=scfg, n_pack=meta2.n, return_state=True
    )
    # decode the remaining 4 tokens one at a time
    cache = cache_mid
    outs = []
    for t in range(8, 12):
        y_t, cache = apply_ssm_decode(
            params, lora, scales, x[:, t : t + 1], cache, scfg=scfg, n_pack=meta2.n
        )
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    y_ref, _ = apply_ssm(
        params, lora, scales, x, scfg=scfg, n_pack=meta2.n, return_state=False
    )
    np.testing.assert_allclose(
        np.asarray(y_dec), np.asarray(y_ref[:, 8:]), rtol=5e-3, atol=5e-3
    )


def test_cache_shapes():
    scfg = SSMConfig(d_state=16, head_dim=32, expand=2)
    c = init_ssm_cache(4, 256, scfg)
    di = scfg.d_inner(256)
    assert c["conv"].shape == (4, scfg.d_conv - 1, di + 2 * scfg.d_state)
    assert c["state"].shape == (4, di // 32, 32, 16)

"""Profile-guided cost estimation: EWMA math, JSON round-trip, fallback
ladder, drift thresholds, step-offset data streams, and the engine-level
adaptive loop (probe / continue-in-place / drift re-assignment) against the
shared scripted executor (tests/harness.py) with controlled slowdowns."""
import numpy as np
import pytest
from harness import FakeRunner, NoPool, ScriptedExecutor

from repro.configs.base import LoraConfig, get_config, reduced
from repro.sched.cost_model import A100_40G, CostModel
from repro.sched.engine import Arrival, ExecutionEngine
from repro.sched.profile import (
    ObservationStore,
    ProfiledCostModel,
    obs_key,
)

SEQ = 64


@pytest.fixture()
def prior():
    cm = CostModel(get_config("qwen25-7b"), A100_40G)
    cm.setup_time = 0.0
    return cm


def _cfg(rank=8, alpha=8.0, bs=1):
    return LoraConfig(
        rank=rank, alpha=alpha, learning_rate=1e-3, batch_size=bs, seq_len=SEQ
    )


# ---------------------------------------------------------------------------
# ObservationStore
# ---------------------------------------------------------------------------


def test_ewma_update_math():
    store = ObservationStore(alpha=0.5)
    key = ("m", 1, 8, 1, 1, SEQ)
    store.update(key, 2.0, 1.0)
    obs = store.get(key)
    assert obs.ewma == 2.0 and obs.n == 1  # first observation is taken as-is
    store.update(key, 4.0, 1.0)
    obs = store.get(key)
    assert obs.ewma == pytest.approx(0.5 * 2.0 + 0.5 * 4.0)
    assert obs.n == 2
    store.update(key, 4.0, 1.0)
    assert store.get(key).ewma == pytest.approx(0.5 * 3.0 + 0.5 * 4.0)
    assert store.n_observations == 3 and len(store) == 1


def test_ratio_tracking_per_degree():
    store = ObservationStore(alpha=0.5)
    store.update(("m", 1, 8, 1, 2, SEQ), measured=3.0, predicted_prior=1.0)
    assert store.ratio(2) == pytest.approx(3.0)
    assert store.ratio(4) is None  # unseen degree: NO cross-degree bleed
    assert store.ratio() == pytest.approx(3.0)  # global (diagnostics)
    store.update(("m", 1, 8, 1, 4, SEQ), measured=1.0, predicted_prior=1.0)
    assert store.ratio(4) == pytest.approx(1.0)
    assert store.ratio(2) == pytest.approx(3.0)  # unchanged


def test_json_roundtrip(tmp_path, prior):
    est = ProfiledCostModel(prior)
    c = [_cfg()]
    est.observe(c, 1, SEQ, 0.123)
    est.observe(c, 1, SEQ, 0.456)
    est.observe(c, 2, SEQ, 0.9)
    path = str(tmp_path / "profile.json")
    est.store.save(path)
    loaded = ObservationStore.load(path)
    est2 = ProfiledCostModel(prior, loaded)
    assert est2.iter_time(c, 1, SEQ) == est.iter_time(c, 1, SEQ)
    assert est2.iter_time(c, 2, SEQ) == est.iter_time(c, 2, SEQ)
    k = est.key(c, 1, SEQ)
    assert loaded.get(k).n == est.store.get(k).n
    assert loaded.ratio(2) == est.store.ratio(2)
    assert loaded.alpha == est.store.alpha


def test_json_schema_guard(tmp_path):
    with pytest.raises(ValueError, match="schema"):
        ObservationStore.from_json({"schema": 999})


# ---------------------------------------------------------------------------
# ProfiledCostModel fallback ladder + interface
# ---------------------------------------------------------------------------


def test_fallback_ladder(prior):
    est = ProfiledCostModel(prior)
    a, b = [_cfg()], [_cfg(rank=16, alpha=16.0)]
    t_prior = prior.iter_time(a, 1, SEQ)
    # 1. nothing observed -> pure prior
    assert est.iter_time(a, 1, SEQ) == t_prior
    # 2. exact key observed -> EWMA wins
    est.observe(a, 1, SEQ, 3.0 * t_prior)
    assert est.iter_time(a, 1, SEQ) == pytest.approx(3.0 * t_prior)
    # 3. unseen key at an OBSERVED degree -> prior * ratio[degree]
    tb = prior.iter_time(b, 1, SEQ)
    assert est.iter_time(b, 1, SEQ) == pytest.approx(3.0 * tb)
    # 4. unseen degree -> optimistic pure prior (drives exploration)
    tb2 = prior.iter_time(b, 2, SEQ)
    assert est.iter_time(b, 2, SEQ) == tb2
    assert est.observed(a, 1, SEQ) and not est.observed(b, 1, SEQ)


def test_memory_and_attrs_delegate_to_prior(prior):
    est = ProfiledCostModel(prior)
    c = [_cfg()]
    est.observe(c, 1, SEQ, 99.0)  # time observations must not touch memory
    assert est.fits(c, 8, SEQ) == prior.fits(c, 8, SEQ)
    assert est.min_degree(c, SEQ) == prior.min_degree(c, SEQ)
    assert est.setup_time == prior.setup_time
    assert est.hw is prior.hw and est.cfg is prior.cfg
    # derived job queries price through the PROFILED iter_time
    assert est.job_time(c, 1, SEQ, 10) == pytest.approx(
        prior.setup_time + 10 * 99.0
    )
    # simulation contract
    assert est.adaptive and not prior.adaptive
    assert est.virtual_model() is prior
    assert prior.virtual_model() is prior


def test_drift_sign_and_threshold(prior):
    est = ProfiledCostModel(prior, drift_threshold=0.5)
    c = [_cfg()]
    t = prior.iter_time(c, 1, SEQ)
    assert est.drift(c, 1, SEQ, 3.0 * t) == pytest.approx(2.0)  # starved
    assert est.drift(c, 1, SEQ, 0.5 * t) == pytest.approx(-0.5)  # over-prov
    assert abs(est.drift(c, 1, SEQ, 1.2 * t)) < est.drift_threshold


def test_obs_key_is_shape_not_hyperparams():
    a = _cfg(alpha=8.0)
    b = _cfg(alpha=32.0)  # same shape, different hyperparameters
    assert obs_key("m", [a], 1, SEQ) == obs_key("m", [b], 1, SEQ)
    wider = _cfg(bs=4)
    assert obs_key("m", [a], 1, SEQ) != obs_key("m", [wider], 1, SEQ)
    assert obs_key("m", [a], 1, SEQ) != obs_key("m", [a], 2, SEQ)


# ---------------------------------------------------------------------------
# Step-offset data streams (what makes probe/split/resume bit-exact)
# ---------------------------------------------------------------------------


def test_packed_batch_iterator_start_steps_offsets():
    from repro.train.data import packed_batch_iterator

    cfg = reduced(get_config("qwen25-7b"))
    configs = [_cfg(), _cfg(rank=16, alpha=16.0, bs=2)]
    full = packed_batch_iterator(cfg, configs, seq=32)
    ref = [next(full) for _ in range(5)]
    resumed = packed_batch_iterator(cfg, configs, seq=32, start_steps=(2, 2))
    for step in (2, 3, 4):
        batch = next(resumed)
        for k in ref[step]:
            np.testing.assert_array_equal(
                np.asarray(ref[step][k]), np.asarray(batch[k])
            )


# ---------------------------------------------------------------------------
# Engine-level adaptive loop with the shared scripted executor
# (ScriptedExecutor / FakeRunner / NoPool live in tests/harness.py now)
# ---------------------------------------------------------------------------


def _adaptive_run(prior_factory, slow, steps=20, probe_steps=4, g=1):
    est = ProfiledCostModel(prior_factory(), drift_threshold=0.5)
    eng = ExecutionEngine(est, g)
    fake = ScriptedExecutor(prior_factory(), slow=slow)
    trace = [Arrival(0.0, _cfg(), steps)]
    records, sched = eng.run_online_local(
        trace,
        reduced(get_config("qwen25-7b")),
        None,
        n_steps=steps,
        seq=SEQ,
        pool=NoPool(),
        runner=FakeRunner(fake, g),
        probe_steps=probe_steps,
    )
    return records, sched


def _make_prior():
    cm = CostModel(get_config("qwen25-7b"), A100_40G)
    cm.setup_time = 0.0
    return cm


def test_adaptive_drift_triggers_exactly_one_reassignment():
    """A 3x-slowed executor: the probe segment measures the drift, the
    residual is re-assigned through the planner — exactly once — and the
    step accounting still comes out exact."""
    records, sched = _adaptive_run(_make_prior, slow=3.0)
    assert sched.n_probes == 1
    assert sched.n_reassignments == 1
    assert len(sched.segments) == 2  # probe + re-planned residual
    assert sched.segments[0].preempted and not sched.segments[1].preempted
    executed = sum(
        min(sched.total_steps[cid] - s.start_steps[i], s.run_steps)
        for s in sched.segments
        for i, cid in enumerate(s.config_ids)
    )
    assert executed == 20
    assert sorted(sched.completed) == [0]
    assert len(records) == 2
    # the re-planned residual was priced with the measured (3x) rate
    assert sched.timings[1].predicted_iter == pytest.approx(
        3.0 * sched.timings[0].predicted_iter, rel=1e-6
    )


def test_adaptive_within_threshold_continues_in_place():
    """Measured rate within the drift threshold: the probe's residual
    continues on the same units without a re-assignment."""
    records, sched = _adaptive_run(_make_prior, slow=1.05)
    assert sched.n_probes == 1
    assert sched.n_reassignments == 0
    assert len(sched.segments) == 2  # probe + in-place continuation
    assert sched.segments[0].units == sched.segments[1].units
    executed = sum(
        min(sched.total_steps[cid] - s.start_steps[i], s.run_steps)
        for s in sched.segments
        for i, cid in enumerate(s.config_ids)
    )
    assert executed == 20


def test_adaptive_observed_key_skips_probe():
    """Once a (shape, degree) key is measured, later jobs of the same shape
    dispatch their full residual in one segment."""
    est = ProfiledCostModel(_make_prior(), drift_threshold=0.5)
    eng = ExecutionEngine(est, 1)
    fake = ScriptedExecutor(_make_prior(), slow=1.0)
    # second job arrives (in real time) after the first finished, so the
    # planner sees them separately instead of packing them into one job
    trace = [Arrival(0.0, _cfg(), 20), Arrival(0.1, _cfg(alpha=9.0), 20)]
    _, sched = eng.run_online_local(
        trace,
        reduced(get_config("qwen25-7b")),
        None,
        n_steps=20,
        seq=SEQ,
        pool=NoPool(),
        runner=FakeRunner(fake, 1),
        probe_steps=4,
    )
    # same obs key (alpha is not part of the shape): one probe total
    assert sched.n_probes == 1
    assert sorted(sched.completed) == [0, 1]
    per_cid = {}
    for s in sched.segments:
        per_cid.setdefault(s.config_ids[0], []).append(s.run_steps)
    assert sorted(len(v) for v in per_cid.values()) == [1, 2]


def test_adaptive_unschedulable_raises():
    cm = CostModel(get_config("command-r-35b"), A100_40G)  # won't fit 1 unit
    est = ProfiledCostModel(cm)
    eng = ExecutionEngine(est, 1)
    fake = ScriptedExecutor(cm)
    trace = [Arrival(0.0, LoraConfig(rank=8, alpha=8.0, seq_len=1024), 5)]
    with pytest.raises(RuntimeError, match="never be scheduled"):
        eng.run_online_local(
            trace,
            get_config("command-r-35b"),
            None,
            n_steps=5,
            seq=1024,
            pool=NoPool(),
            runner=FakeRunner(fake, 1),
        )


def test_simulation_stays_on_pure_prior():
    """plan_online through a ProfiledCostModel engine is byte-identical to
    the pure prior's plan regardless of observation state — the simulation
    contract of the estimator interface."""
    from repro.configs.base import default_search_space
    from repro.sched.engine import poisson_trace

    prior = CostModel(get_config("command-r-35b"), A100_40G)
    est = ProfiledCostModel(prior)
    configs = default_search_space(12, 1024)
    steps = np.random.RandomState(0).choice([200, 500, 1000], size=12)
    trace = poisson_trace(configs, 600.0, seed=1, steps=steps)
    ref = ExecutionEngine(prior, 8).plan_online(trace, 1024, 1000)
    # pollute the profile with nonsense observations; the plan must not move
    for c in configs[:4]:
        est.observe([c], 1, 1024, 123.456)
        est.observe([c], 4, 1024, 0.001)
    out = ExecutionEngine(est, 8).plan_online(trace, 1024, 1000)
    assert out.segments == ref.segments
    assert out.makespan == ref.makespan
    assert out.completed == ref.completed

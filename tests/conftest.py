"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches run on
the single real CPU device; only launch/dryrun.py forces 512 host devices
(and it must be a separate process, which tests/test_dryrun_small.py does)."""
import os

# Deterministic, quiet, single-device CPU runs.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LoraConfig, get_config, list_archs, reduced
from repro.core.adapter import pack_meta


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def meta2():
    """A small heterogeneous 2-adapter pack used across tests."""
    return pack_meta(
        [
            LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=2),
            LoraConfig(rank=16, alpha=4.0, learning_rate=5e-4, batch_size=2),
        ]
    )


def all_arch_ids():
    return list_archs()


@pytest.fixture(scope="session")
def reduced_cfgs():
    return {name: reduced(get_config(name)) for name in list_archs()}

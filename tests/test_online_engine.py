"""Event-driven online execution engine: admission determinism, dynamic
repacking vs the frozen-queue baseline, budget-capped migration, per-adapter
step budgets, and bit-exact preempt/resume through the CheckpointPool."""
import jax
import numpy as np
import pytest

from repro.configs.base import LoraConfig, default_search_space, get_config, reduced
from repro.core.adapter import pack_meta
from repro.core.packed_lora import extract_adapter, inject_adapter
from repro.sched.cost_model import A100_40G, CostModel
from repro.sched.engine import (
    Arrival,
    ExecutionEngine,
    poisson_trace,
)
from repro.sched.planner import plan
from repro.train.checkpoint import CheckpointPool

SEQ = 1024
STEPS = 1000


@pytest.fixture(scope="module")
def cm35():
    return CostModel(get_config("command-r-35b"), A100_40G)


@pytest.fixture(scope="module")
def cm7():
    return CostModel(get_config("qwen25-7b"), A100_40G)


def _mixed_trace(n=16, mean_interarrival=800.0):
    """Heterogeneous-residual Poisson workload on a memory-bound model:
    packs must split across degrees, so waves have staggered finish times —
    the regime where repack-on-free matters."""
    configs = default_search_space(n, SEQ)
    steps = np.random.RandomState(0).choice(
        [200, 500, 1000, 2000, 4000], size=n
    )
    return poisson_trace(configs, mean_interarrival, seed=1, steps=steps)


# ---------------------------------------------------------------------------
# Virtual event loop
# ---------------------------------------------------------------------------


def test_online_admission_deterministic(cm35):
    trace = _mixed_trace()
    eng = ExecutionEngine(cm35, 8)
    a = eng.plan_online(trace, SEQ, STEPS, repack="event", migration_budget=4)
    b = eng.plan_online(trace, SEQ, STEPS, repack="event", migration_budget=4)
    assert a.segments == b.segments
    assert a.makespan == b.makespan
    assert a.completed == b.completed


def test_t0_eager_event_loop_matches_plan(cm7):
    """All-at-t=0 with eager admission is exactly Algorithm 2: the event
    loop replans the remainder at every device-free event, same as plan()."""
    configs = default_search_space(24, SEQ)
    trace = [Arrival(0.0, c) for c in configs]
    eng = ExecutionEngine(cm7, 8)
    online = eng.plan_online(trace, SEQ, 100, admission="eager")
    static = plan(cm7, configs, 8, SEQ, 100)
    assert online.makespan == pytest.approx(static.makespan, rel=1e-9)
    assert sorted(online.completed) == list(range(24))


def test_repack_on_free_beats_drain(cm35):
    """The tentpole claim: replanning on every device-free event admits
    arrivals onto freed devices while long jobs still run; the frozen-queue
    baseline waits for the full drain."""
    trace = _mixed_trace()
    eng = ExecutionEngine(cm35, 8)
    ev = eng.plan_online(trace, SEQ, STEPS, repack="event")
    dr = eng.plan_online(trace, SEQ, STEPS, repack="drain")
    assert ev.makespan < 0.85 * dr.makespan, (ev.makespan, dr.makespan)
    ev.validate()
    dr.validate()
    assert 0.0 < ev.utilization() <= 1.0


def test_migration_budget_capped_and_beneficial(cm35):
    trace = _mixed_trace()
    eng = ExecutionEngine(cm35, 8)
    no_mig = eng.plan_online(trace, SEQ, STEPS, repack="event", migration_budget=0)
    assert no_mig.n_migrations == 0
    assert not any(s.preempted for s in no_mig.segments)
    mig = eng.plan_online(trace, SEQ, STEPS, repack="event", migration_budget=4)
    assert 1 <= mig.n_migrations <= 4
    assert any(s.preempted for s in mig.segments)
    assert mig.makespan < no_mig.makespan
    mig.validate()


def test_step_accounting_exact(cm35):
    """Across preemptions and resumes, every config trains exactly its step
    budget: per-segment executed steps sum to the total, and completion
    times are recorded for every admitted config."""
    trace = _mixed_trace()
    eng = ExecutionEngine(cm35, 8)
    sched = eng.plan_online(trace, SEQ, STEPS, repack="event", migration_budget=4)
    executed = {cid: 0 for cid in range(len(trace))}
    for seg in sched.segments:
        for cid, st0 in zip(seg.config_ids, seg.start_steps):
            resid = sched.total_steps[cid] - st0
            executed[cid] += min(resid, seg.run_steps)
    assert executed == sched.total_steps
    assert sorted(sched.completed) == list(range(len(trace)))
    assert sched.makespan >= max(sched.completed.values())


def test_unschedulable_trace_raises(cm35):
    eng = ExecutionEngine(cm35, 1)  # 35B base cannot fit one 40G unit
    trace = [Arrival(0.0, LoraConfig(rank=8, alpha=8.0, seq_len=SEQ))]
    with pytest.raises(RuntimeError, match="never be scheduled"):
        eng.plan_online(trace, SEQ, 10)


# ---------------------------------------------------------------------------
# Preempt/resume state machinery (real arrays)
# ---------------------------------------------------------------------------


def test_inject_extract_roundtrip_bitexact(tmp_path):
    """Preempted adapter state must survive extract -> CheckpointPool ->
    inject into a *different* pack (new partners, new bucket rank) with the
    real rank columns bit-identical."""
    cfg = reduced(get_config("qwen25-7b"))
    from repro.models.model import init_model

    src_configs = [
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1),
        LoraConfig(rank=16, alpha=16.0, learning_rate=5e-4, batch_size=1),
    ]
    meta = pack_meta(src_configs)
    _, lora = init_model(jax.random.PRNGKey(3), cfg, meta)
    adapter = extract_adapter(lora, 1, meta.ranks)

    pool = CheckpointPool(str(tmp_path / "pool"))
    pool.save_adapter_state("0001", {"w": adapter}, {"steps_done": 0})
    state, _ = pool.load_adapter_state("0001")

    dst_configs = [
        LoraConfig(rank=16, alpha=16.0, learning_rate=5e-4, batch_size=1),
        LoraConfig(rank=32, alpha=32.0, learning_rate=1e-4, batch_size=2),
        LoraConfig(rank=8, alpha=4.0, learning_rate=1e-3, batch_size=1),
    ]
    dst_meta = pack_meta(dst_configs)
    assert dst_meta.r_bucket != meta.r_bucket  # genuinely different pack
    _, dst_lora = init_model(jax.random.PRNGKey(4), cfg, dst_meta)
    dst_lora = inject_adapter(dst_lora, state["w"], 0)
    back = extract_adapter(dst_lora, 0, dst_meta.ranks)

    flat_a = jax.tree_util.tree_leaves_with_path(adapter)
    flat_b = jax.tree_util.tree_leaves_with_path(back)
    assert len(flat_a) == len(flat_b) > 0
    for (pa, la), (pb, lb) in zip(flat_a, flat_b):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_step_budget_freezes_adapter():
    """Per-adapter step budgets: an adapter stops updating once it has
    trained its own budget, while packmates keep going — what lets real
    execution match the virtual scheduler's per-adapter accounting."""
    from repro.models.model import init_model
    from repro.train.data import packed_batch_iterator
    from repro.train.optimizer import init_opt_state
    from repro.train.trainer import make_train_step

    cfg = reduced(get_config("qwen25-7b"))
    configs = [
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1, seq_len=16),
        LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1, seq_len=16),
    ]
    meta = pack_meta(configs)
    base, lora = init_model(jax.random.PRNGKey(0), cfg, meta)
    it = packed_batch_iterator(cfg, configs, seq=16)
    step = make_train_step(cfg, meta, step_budgets=[2, 5])
    opt = init_opt_state(lora, n_pack=meta.n)
    snaps = []
    for _ in range(5):
        lora, opt, _ = step(base, lora, opt, next(it))
        snaps.append(extract_adapter(lora, 0, meta.ranks))
    assert np.asarray(opt["step"]).tolist() == [2, 5]
    # adapter 0 froze after its 2-step budget ...
    for a, b in zip(jax.tree.leaves(snaps[1]), jax.tree.leaves(snaps[4])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # ... while it did train up to the budget
    diffs = [
        float(np.abs(np.asarray(a) - np.asarray(b)).max())
        for a, b in zip(jax.tree.leaves(snaps[0]), jax.tree.leaves(snaps[1]))
    ]
    assert max(diffs) > 0


# ---------------------------------------------------------------------------
# Real execution through the event loop
# ---------------------------------------------------------------------------


def test_run_online_local_preempt_resume(tmp_path):
    """End-to-end on CPU XLA: a running job is preempted by an admission
    event, its adapter checkpoints through the pool, resumes inside a new
    pack with the arrival, and every adapter finishes with its exact step
    budget and finite losses."""
    from repro.models.model import init_model

    cfg = reduced(get_config("qwen25-7b"))
    cm = CostModel(cfg, A100_40G)
    cm.setup_time = 0.0  # virtual seconds; keeps the crafted timing simple
    eng = ExecutionEngine(cm, 1)
    a = LoraConfig(rank=8, alpha=8.0, learning_rate=1e-3, batch_size=1, seq_len=16)
    b = LoraConfig(rank=16, alpha=16.0, learning_rate=5e-4, batch_size=1, seq_len=16)
    it = cm.iter_time([a], 1, 16)
    trace = [Arrival(0.0, a, 6), Arrival(2.5 * it, b, 5)]
    pool = CheckpointPool(str(tmp_path / "pool"))
    base, _ = init_model(jax.random.PRNGKey(0), cfg, pack_meta([a]))
    records, sched = eng.run_online_local(
        trace,
        cfg,
        base,
        n_steps=6,
        seq=16,
        pool=pool,
        migration_budget=1,
        preempt_min_remaining=0.0,
    )
    assert sched.n_migrations == 1
    assert any(s.preempted for s in sched.segments)
    # the preempted adapter checkpointed resumable state through the pool
    assert pool.has_adapter_state("0000")
    _, smeta = pool.load_adapter_state("0000")
    assert 0 < int(smeta["steps_done"]) < 6
    # both adapters finished with finite losses and exact step budgets
    for cid, total in ((0, 6), (1, 5)):
        meta = pool.load_meta(f"adapter_{cid:04d}")
        assert meta["total_steps"] == total
        assert np.isfinite(meta["final_loss"])
        tree = pool.load_adapter(f"adapter_{cid:04d}")
        assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(tree))
    executed = {0: 0, 1: 0}
    for seg in sched.segments:
        for cid, st0 in zip(seg.config_ids, seg.start_steps):
            executed[cid] += min(sched.total_steps[cid] - st0, seg.run_steps)
    assert executed == {0: 6, 1: 5}
    assert len(records) == len(sched.segments)

"""Scheduler invariants as properties (ISSUE 4 satellite).

Hypothesis-driven where available (guarded via tests/_hyp.py — minimal
installs degrade these to skips, never collection errors), with deterministic
example-based twins underneath so the invariant checkers themselves are
always exercised:

  * planned units are host-disjoint (a mesh slice never spans hosts);
  * residual steps are conserved across replan / preempt / split — every
    adapter executes exactly its step budget, no more, no fewer;
  * ``OnlineSchedule.validate`` rejects overlapping unit assignments.
"""
import dataclasses

import numpy as np
import pytest
from _hyp import HAVE_HYPOTHESIS, given, settings, st

from repro.configs.base import LoraConfig, get_config
from repro.sched.cost_model import A100_40G, CostModel
from repro.sched.engine import ExecutionEngine, JobSegment, poisson_trace

G = 8


def _space(ranks, bss):
    return [
        LoraConfig(
            rank=r, alpha=2.0 * r, learning_rate=1e-4, batch_size=b,
            seq_len=1024,
        )
        for r, b in zip(ranks, bss)
    ]


def _plan(ranks, bss, seed, host_size, migration_budget=2):
    cm = CostModel(get_config("command-r-35b"), A100_40G)
    eng = ExecutionEngine(cm, G, host_size=host_size)
    configs = _space(ranks, bss)
    steps = np.random.RandomState(seed).choice([200, 500, 1000], len(configs))
    trace = poisson_trace(configs, 600.0, seed=seed, steps=steps)
    return eng.plan_online(
        trace, 1024, 1000, migration_budget=migration_budget
    )


def check_invariants(sched, host_size):
    """The three properties every plan must satisfy."""
    sched.validate(host_size=host_size)  # oversubscription + unit overlap
    for s in sched.segments:
        assert len(s.units) == s.degree
        if host_size is not None:
            assert len({u // host_size for u in s.units}) == 1, (
                f"segment {s.job_id} spans hosts: {s.units}"
            )
    # residual conservation: executed steps per config == its exact budget,
    # across however many replan/preempt/split segments it was cut into
    executed = {cid: 0 for cid in sched.total_steps}
    for s in sched.segments:
        for i, cid in enumerate(s.config_ids):
            executed[cid] += min(
                sched.total_steps[cid] - s.start_steps[i], s.run_steps
            )
    assert executed == sched.total_steps, executed
    # split segments chain exactly: a resume starts where a preempt stopped
    progress = {cid: 0 for cid in sched.total_steps}
    for s in sorted(sched.segments, key=lambda s: (s.start, s.job_id)):
        for i, cid in enumerate(s.config_ids):
            assert s.start_steps[i] == progress[cid], (cid, s)
            progress[cid] += min(
                sched.total_steps[cid] - s.start_steps[i], s.run_steps
            )


# ---------------------------------------------------------------------------
# Deterministic twins (always run, hypothesis or not)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("host_size", [None, 2, 4])
def test_invariants_hold_example(host_size):
    sched = _plan(
        ranks=[8, 16, 32, 8, 64, 16], bss=[1, 2, 1, 4, 1, 2], seed=3,
        host_size=host_size,
    )
    assert sched.segments
    check_invariants(sched, host_size)


def test_validate_rejects_overlapping_units_example():
    sched = _plan(ranks=[8, 16, 32, 8], bss=[1, 1, 1, 1], seed=1,
                  host_size=None, migration_budget=0)
    overlapping = [
        (i, j)
        for i, a in enumerate(sched.segments)
        for j, b in enumerate(sched.segments)
        if i < j and a.start < b.end - 1e-9 and b.start < a.end - 1e-9
    ]
    if not overlapping:
        pytest.skip("plan produced no time-overlapping segments")
    i, j = overlapping[0]
    sched.segments[j] = dataclasses.replace(
        sched.segments[j],
        degree=sched.segments[i].degree,
        units=sched.segments[i].units,
    )
    with pytest.raises(RuntimeError, match="share device units|oversubscribes"):
        sched.validate()


def test_validate_rejects_host_spanning_units_example():
    seg = JobSegment(
        job_id=0, config_ids=(0,), degree=2, start=0.0, end=1.0,
        start_steps=(0,), run_steps=5, done_ids=(0,), units=(1, 2),
    )
    from repro.sched.engine import OnlineSchedule

    sched = OnlineSchedule(
        segments=[seg], makespan=1.0, g=4, completed={0: 1.0},
        total_steps={0: 5},
    )
    sched.validate()  # fine without host structure
    with pytest.raises(RuntimeError, match="span hosts"):
        sched.validate(host_size=2)


# ---------------------------------------------------------------------------
# Hypothesis properties (skipped gracefully on minimal installs)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    ranks=st.lists(st.sampled_from([8, 16, 32, 64]), min_size=1, max_size=7),
    seed=st.integers(min_value=0, max_value=2**16),
    host_size=st.sampled_from([None, 2, 4, 8]),
    migration_budget=st.integers(min_value=0, max_value=3),
)
def test_planned_schedules_satisfy_invariants(
    ranks, seed, host_size, migration_budget
):
    bss = [1 + (i % 2) for i in range(len(ranks))]
    sched = _plan(ranks, bss, seed, host_size, migration_budget)
    assert sched.segments
    check_invariants(sched, host_size)


@settings(max_examples=15, deadline=None)
@given(
    ranks=st.lists(st.sampled_from([8, 16, 32]), min_size=2, max_size=6),
    seed=st.integers(min_value=0, max_value=2**16),
    victim=st.integers(min_value=0, max_value=10**6),
)
def test_validate_rejects_corrupted_unit_assignments(ranks, seed, victim):
    """Any corruption that makes two time-overlapping segments share a unit
    (or puts a unit out of range) must be caught by validate()."""
    bss = [1] * len(ranks)
    sched = _plan(ranks, bss, seed, host_size=None, migration_budget=1)
    if not sched.segments:
        return
    s = sched.segments[victim % len(sched.segments)]
    sched.segments[victim % len(sched.segments)] = dataclasses.replace(
        s, units=(G + 1,) * s.degree  # out-of-range units
    )
    with pytest.raises(RuntimeError):
        sched.validate()


if HAVE_HYPOTHESIS:
    # the property suite only counts when it can actually draw examples;
    # keep a breadcrumb in -v output either way
    def test_hypothesis_available():
        assert HAVE_HYPOTHESIS

"""Unified tracing + metrics layer (ISSUE 7 tentpole).

Four layers, cheapest first:

  * span mechanics — nesting/parenting on one thread, isolation of the
    thread-local stacks under concurrency, the disabled tracer being a
    *true* no-op (same shared context manager object, zero state);
  * metrics registry — counter/gauge/histogram semantics and the
    percentile summaries the serve stats surface;
  * Chrome trace-event export — the JSON must satisfy the event schema
    ``validate_chrome_trace`` checks (the same check CI runs on the
    bench_serve artifact) and carry one metadata track per span track;
  * cross-process stitching — a traced ``HostDispatcher`` over the
    in-memory ``FakeHostTransport`` ships a ``TraceCtx`` with every run
    request and ingests the worker-shaped span replies under the dispatch
    span, rebased onto the dispatcher clock, on ``host{h}/``-prefixed
    tracks.
"""
import json
import pickle
import threading
import time

import pytest
from harness import DictPool, ScriptedExecutor, fake_pool

from repro.obs import (
    NULL_TRACER,
    Histogram,
    MetricsRegistry,
    TraceCtx,
    Tracer,
    percentile,
    trace_tiers,
    validate_chrome_trace,
)

# ---------------------------------------------------------------------------
# Span mechanics
# ---------------------------------------------------------------------------


def test_span_nesting_parents_and_ordering():
    tr = Tracer()
    with tr.span("a", cat="engine") as a:
        with tr.span("b", cat="engine") as b:
            with tr.span("c", cat="engine") as c:
                pass
        with tr.span("d", cat="engine") as d:
            pass
    by_name = {s.name: s for s in tr.spans()}
    assert set(by_name) == {"a", "b", "c", "d"}
    assert by_name["a"].parent_id is None
    assert by_name["b"].parent_id == a.span_id
    assert by_name["c"].parent_id == b.span_id
    assert by_name["d"].parent_id == a.span_id
    # every span roots at the outermost one
    assert {s.root_id for s in tr.spans()} == {a.span_id}
    # children close before (and start after) their parent
    assert a.start <= b.start and b.end <= a.end
    assert b.end <= d.start  # sequential siblings don't overlap
    assert c.span_id != d.span_id != b.span_id


def test_explicit_parent_overrides_thread_stack():
    tr = Tracer()
    with tr.span("root", cat="runner") as root:
        pass
    with tr.span("w", cat="runner", parent=root.span_id) as w:
        pass
    got = {s.name: s for s in tr.spans()}
    assert got["w"].parent_id == root.span_id


def test_concurrent_threads_have_isolated_stacks():
    tr = Tracer()
    barrier = threading.Barrier(2)
    ids = {}

    def work(name):
        with tr.span(f"outer.{name}", cat="engine") as o:
            barrier.wait()  # both threads are now inside their outer span
            with tr.span(f"inner.{name}", cat="engine") as i:
                pass
            ids[name] = (o.span_id, i.span_id)

    ts = [threading.Thread(target=work, args=(n,)) for n in ("t0", "t1")]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    by_name = {s.name: s for s in tr.spans()}
    assert len(by_name) == 4
    for n in ("t0", "t1"):
        # each inner span parents to ITS thread's outer span, never the
        # other thread's (the stacks are thread-local)
        assert by_name[f"inner.{n}"].parent_id == ids[n][0]
        assert by_name[f"inner.{n}"].root_id == ids[n][0]
    assert ids["t0"][0] != ids["t1"][0]


def test_disabled_tracer_is_a_true_noop():
    cm1 = NULL_TRACER.span("anything", cat="engine", job_id=1)
    cm2 = NULL_TRACER.span("else", cat="serve")
    assert cm1 is cm2  # one shared context manager, no allocation
    with cm1 as sp:
        assert sp.span_id == 0
    NULL_TRACER.instant("marker", cat="engine")
    NULL_TRACER.add_span("ext", 0.0, 1.0, cat="serve")
    assert NULL_TRACER.spans() == []
    assert NULL_TRACER.current_span_id() is None
    # its metrics sink is stateless too
    c = NULL_TRACER.metrics.counter("x")
    c.inc()
    h = NULL_TRACER.metrics.histogram("y")
    h.record(1.0)
    assert NULL_TRACER.metrics.to_json() == {
        "counters": {}, "gauges": {}, "histograms": {}
    }


def test_add_span_and_instant():
    tr = Tracer()
    t = time.perf_counter()
    tr.add_span("serve.request", t, t + 0.5, cat="serve", track="row1",
                request_id=3)
    with tr.span("outer", cat="engine"):
        tr.instant("engine.launch", cat="engine", job_id=9)
    by_name = {s.name: s for s in tr.spans()}
    req = by_name["serve.request"]
    assert req.end - req.start == pytest.approx(0.5)
    assert req.args["request_id"] == 3
    mark = by_name["engine.launch"]
    assert mark.start == mark.end  # zero-duration
    assert mark.parent_id == by_name["outer"].span_id


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_histogram_percentiles():
    h = Histogram("t")
    for v in range(1, 101):  # 1..100
        h.record(float(v))
    s = h.summary()
    assert s["count"] == 100
    assert s["min"] == 1.0 and s["max"] == 100.0
    assert s["p50"] == pytest.approx(50.5)
    assert s["p95"] == pytest.approx(95.05)
    assert s["p99"] == pytest.approx(99.01)
    empty = Histogram("e").summary()
    assert empty["count"] == 0
    assert empty["p50"] != empty["p50"]  # NaN
    assert percentile([1.0, 2.0], 0.5) == pytest.approx(1.5)


def test_registry_get_or_create_and_json():
    m = MetricsRegistry()
    m.counter("hits").inc()
    m.counter("hits").inc(2)
    assert m.counter("hits").value == 3
    m.gauge("free").set(4)
    m.gauge("free").set(2)
    m.histogram("lat").record(0.25)
    blob = m.to_json()
    assert blob["counters"]["hits"] == 3
    assert blob["gauges"]["free"] == 2
    assert blob["histograms"]["lat"]["count"] == 1
    # sampled gauges keep a (t, v) history for counter tracks
    assert [v for _, v in m.gauge("free").samples()] == [4, 2]


# ---------------------------------------------------------------------------
# Chrome trace-event export
# ---------------------------------------------------------------------------


def test_chrome_export_schema_and_tracks(tmp_path):
    tr = Tracer()
    with tr.span("engine.plan", cat="engine", track="main"):
        with tr.span("runner.segment", cat="runner", track="unit0",
                     job_id=1):
            pass
    tr.metrics.gauge("cluster.free_units").set(3)
    path = tmp_path / "trace.json"
    tr.export(str(path))
    obj = json.loads(path.read_text())
    assert validate_chrome_trace(obj) == []
    assert trace_tiers(obj) == ["engine", "runner"]
    xs = [e for e in obj["traceEvents"] if e["ph"] == "X"]
    assert len(xs) == 2
    child = next(e for e in xs if e["name"] == "runner.segment")
    parent = next(e for e in xs if e["name"] == "engine.plan")
    assert child["args"]["parent_span"] == parent["args"]["span_id"]
    assert child["dur"] >= 0 and child["ts"] >= parent["ts"]
    # one thread_name metadata row per track, plus the gauge counter track
    names = {e["args"]["name"] for e in obj["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"main", "unit0", "counter:cluster.free_units"} <= names
    assert any(e["ph"] == "C" for e in obj["traceEvents"])
    assert obj["otherData"]["trace_id"] == tr.trace_id


def test_validate_rejects_malformed_events():
    bad = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": -5.0, "dur": 1},
        {"ph": "Z", "name": "b", "pid": 1},
        {"ph": "X", "pid": 1, "tid": 1, "ts": 0, "dur": 1},
    ]}
    problems = validate_chrome_trace(bad)
    assert len(problems) == 3


# ---------------------------------------------------------------------------
# Ingest + cross-process stitching
# ---------------------------------------------------------------------------


def test_ingest_remaps_ids_offsets_clocks_and_prefixes_tracks():
    tr = Tracer()
    with tr.span("dispatch.segment", cat="dispatch", track="host0") as d:
        pass
    worker_spans = [
        {"name": "host0.segment", "cat": "host", "track": "",
         "span_id": 1, "parent_id": None, "root_id": 1,
         "start": 0.0, "end": 2.0, "args": {}},
        {"name": "executor.train", "cat": "executor", "track": "unit1",
         "span_id": 2, "parent_id": 1, "root_id": 1,
         "start": 0.5, "end": 1.5, "args": {}},
    ]
    tr.ingest(worker_spans, offset=100.0, parent_id=d.span_id,
              track_prefix="host0/")
    by_name = {s.name: s for s in tr.spans()}
    root = by_name["host0.segment"]
    child = by_name["executor.train"]
    assert root.parent_id == d.span_id  # stitched under the dispatch span
    assert root.start == 100.0 and root.end == 102.0  # rebased clock
    assert child.parent_id == root.span_id  # remapped, not the worker's 1
    assert child.span_id != 2 and root.span_id != 1
    assert root.track == "host0/worker" and child.track == "host0/unit1"
    assert child.root_id == root.span_id


def test_trace_ctx_rides_the_wire_and_worker_spans_stitch():
    from repro.cluster.multihost import HostDispatcher

    from test_multihost import _cfg, _fake_factory, _seg

    tracer = Tracer()
    made = []
    cfgs = {i: _cfg(alpha=8.0 * (i + 1)) for i in range(4)}
    segs = [_seg(job_id=i, cids=(i,), units=(i,)) for i in range(4)]
    pool = DictPool()
    with HostDispatcher(
        [2, 2], transport_factory=_fake_factory(made), tracer=tracer
    ) as disp:
        result = disp.run(
            segs, cfgs, {i: 3 for i in range(4)}, None, None,
            seq=16, pool=pool,
        )
    assert len(result.records) == 4
    # every run request shipped a pickled TraceCtx of THIS trace
    ctxs = [c for trp in made for c in trp.trace_ctxs]
    assert len(ctxs) == 4
    for ctx in ctxs:
        assert isinstance(ctx, TraceCtx)
        assert ctx.trace_id == tracer.trace_id
        assert isinstance(ctx.parent, int)  # the dispatch span's id
    assert pickle.loads(pickle.dumps(ctxs[0])) == ctxs[0]

    spans = tracer.spans()
    dispatch = {s.span_id: s for s in spans if s.name == "dispatch.segment"}
    assert len(dispatch) == 4
    hosts = [s for s in spans if s.cat == "host"]
    assert len(hosts) == 4
    for h in hosts:
        # stitched: the worker root's parent IS a dispatch span, and the
        # worker clock was rebased inside the dispatch window
        assert h.parent_id in dispatch
        d = dispatch[h.parent_id]
        assert h.track.startswith(f"host{d.args['host']}/")
        assert h.start >= d.start - 1e-6
    assert {h.name for h in hosts} == {"host0.segment", "host1.segment"}
    # the fabricated executor child rides along, reparented under its root
    execs = [s for s in spans if s.name == "executor.segment"]
    assert len(execs) == 4
    host_ids = {h.span_id for h in hosts}
    assert all(e.parent_id in host_ids for e in execs)
    # the whole thing exports as a valid multi-tier chrome trace
    obj = tracer.to_chrome()
    assert validate_chrome_trace(obj) == []
    assert {"dispatch", "host", "executor", "runner"} <= set(trace_tiers(obj))


def test_untraced_dispatch_ships_no_ctx_or_spans():
    from repro.cluster.multihost import HostDispatcher

    from test_multihost import _cfg, _fake_factory, _seg

    made = []
    segs = [_seg(job_id=0, cids=(0,), units=(0,))]
    with HostDispatcher([1], transport_factory=_fake_factory(made)) as disp:
        disp.run(segs, {0: _cfg()}, {0: 3}, None, None, seq=16,
                 pool=DictPool())
    assert made[0].trace_ctxs == [None]


# ---------------------------------------------------------------------------
# Runner integration (scripted executor, no jax)
# ---------------------------------------------------------------------------


def test_runner_emits_spans_and_free_units_gauge():
    from repro.cluster.runner import ClusterRunner
    from repro.configs.base import LoraConfig, get_config, reduced
    from repro.sched.cost_model import A100_40G, CostModel
    from test_multihost import _seg

    cfg = reduced(get_config("qwen25-7b"))
    prior = CostModel(cfg, A100_40G)
    tracer = Tracer()
    runner = ClusterRunner(
        ScriptedExecutor(prior), fake_pool(2), concurrent=True,
        tracer=tracer,
    )
    segs = [_seg(job_id=i, cids=(i,), units=(i,)) for i in range(2)]
    cfgs = {i: LoraConfig(rank=8, alpha=8.0, seq_len=16) for i in range(2)}
    runner.run(segs, cfgs, {0: 3, 1: 3}, None, None, seq=16)
    by_name = {}
    for s in tracer.spans():
        by_name.setdefault(s.name, []).append(s)
    assert len(by_name["runner.run"]) == 1
    assert len(by_name["runner.segment"]) == 2
    assert len(by_name["runner.wait_units"]) == 2
    run_id = by_name["runner.run"][0].span_id
    # pool-thread segment spans stitch under the dispatcher-thread run span
    assert all(s.parent_id == run_id for s in by_name["runner.segment"])
    samples = tracer.metrics.gauge("cluster.free_units").samples()
    assert samples and samples[-1][1] == 2  # all units returned at the end

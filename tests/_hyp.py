"""Guarded ``hypothesis`` import (ISSUE 1 satellite).

Property-based tests use ``from _hyp import given, settings, st``. When
``hypothesis`` is installed (the CI/[test] extra) this re-exports the real
API unchanged. On a minimal install (``requirements.txt`` only) the suite
must degrade to *skips*, not collection errors, so this module falls back to
stub decorators that mark every ``@given`` test as skipped via
``pytest.importorskip`` semantics while leaving all non-property tests in
the same file runnable.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # minimal install: degrade property tests to skips
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategies:
        """Stub for ``hypothesis.strategies``: every strategy builder returns
        an inert placeholder (the ``given`` stub never draws from it)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
